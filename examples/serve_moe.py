"""End-to-end serving driver (the paper's workload): batched requests
against an MoE model through the continuous-batching engine with a
pluggable scheduling policy (the paper's FinDEP online planner by default,
or any baseline schedule via --policy) and a pluggable admission policy
(--admission fcfs|spf|token_budget, --token-budget N for Sarathi-style
chunked prefill admission).

Run:  PYTHONPATH=src python examples/serve_moe.py [--requests 16]
      PYTHONPATH=src python examples/serve_moe.py --policy sequential
      PYTHONPATH=src python examples/serve_moe.py --admission token_budget \
          --token-budget 64
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import FinDEPPlanner, PAPER_A6000
from repro.core.planner import PlannerConfig
from repro.runtime import ADMISSIONS, Request, ServingEngine
from repro.sched import POLICIES, make_policy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--policy", choices=POLICIES, default="findep",
                    help="scheduling policy for the MoE layers")
    ap.add_argument("--admission", choices=ADMISSIONS, default="fcfs",
                    help="request admission policy")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-step prefill token budget (chunked prefill)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    policy = None
    if cfg.is_moe:
        planner = FinDEPPlanner(cfg, DepClusterConfig(8, 3, 5),
                                PAPER_A6000,
                                PlannerConfig(mem_cap_samples=8))
        policy = make_policy(args.policy, planner, static_seq_len=256)
    eng = ServingEngine(cfg, num_slots=args.slots, max_context=256,
                        plan_policy=policy, admission=args.admission,
                        token_budget=args.token_budget, dtype=jnp.float32)

    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        prompt = list(rng.randint(0, cfg.vocab_size,
                                  size=rng.randint(4, 48)))
        reqs.append(Request(prompt=prompt, max_new_tokens=args.max_new,
                            temperature=0.0 if i % 2 == 0 else 0.8))
        eng.submit(reqs[-1])

    t0 = time.perf_counter()
    finished = eng.run()
    dt = time.perf_counter() - t0

    done = sum(len(r.output) for r in reqs)
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    print(f"\nserved {len(finished)}/{args.requests} requests / "
          f"{done} tokens in {dt:.1f}s -> {done/dt:.1f} tokens/s decode")
    print(f"TTFT: mean {np.mean(ttfts)*1e3:.0f} ms, "
          f"p90 {np.percentile(ttfts, 90)*1e3:.0f} ms")
    print(f"first outputs: {[r.output[:6] for r in reqs[:3]]}")

    if eng.plan_cache is not None:
        s = eng.plan_cache.stats
        print(f"\npolicy={args.policy} admission={args.admission}: "
              f"{len(eng.plan_cache)} shapes resolved, "
              f"{s.hits} cache hits ({s.hit_rate:.0%}), "
              f"{s.solve_time_total*1e3:.1f} ms total solve time")
        entries = eng.resolved_plans().items()
        prefills = sorted(k for k, _ in entries if k[0] == "prefill")
        decodes = sorted(k for k, _ in entries if k[0] == "decode")
        plans = dict(entries)
        for phase, bucket, batch in prefills:
            p = plans[(phase, bucket, batch)]
            print(f"  {phase:>7} bucket={bucket:<5} batch={batch}: "
                  f"m_a={p.m_a} r1={p.r1} r2={p.r2} {p.order}")
        for phase, occ in decodes:
            p = plans[(phase, occ)]
            print(f"  {phase:>7} {occ!r}: "
                  f"m_a={p.m_a} r1={p.r1} r2={p.r2} {p.order}")


if __name__ == "__main__":
    main()
