"""End-to-end serving driver (the paper's workload): batched requests
against an MoE model through the continuous-batching engine with a
pluggable scheduling policy (the paper's FinDEP online planner by default,
or any baseline schedule via --policy) and a pluggable admission policy
(--admission fcfs|spf|token_budget, --token-budget N for Sarathi-style
chunked prefill admission).

The planner's cost models come from repro.profiling's measured-cost loop:

  --calibrate          run the on-device microbenchmarks now, fit the
                       alpha-beta models, persist them to --profile-store
                       (named --profile NAME, default: the host key slug)
  --profile NAME       plan from a previously stored fit (or a registry
                       profile: paper_a6000 / tpu_v5e) — no re-measurement
  --drift-threshold X  enable drift detection: a cached plan whose EWMA
                       predicted-vs-measured residual exceeds X is
                       re-solved in the background while the stale plan
                       keeps serving

and the expert placement loop (repro.placement) closes observe -> place
-> plan over the gate's routing skew:

  --replicate-hot-k K      replicate the K hottest experts onto every EP
                           rank when a re-balance lands (REP task: their
                           tokens never cross the A2E/E2A wire)
  --rebalance-threshold X  re-solve the expert->rank map in the
                           background when the worst rank's observed load
                           exceeds X times the uniform share (e.g. 1.25)

Observability (repro.obs):

  --trace-out OUT.json       record phase + request-lifecycle spans and
                             write a Perfetto-loadable Chrome trace
  --metrics-out OUT.jsonl    append metrics-registry snapshots (final,
                             or every --metrics-interval seconds); the
                             final TTFT/TPOT p50/p99 summary prints from
                             the same registry's histograms

Run:  PYTHONPATH=src python examples/serve_moe.py [--requests 16]
      PYTHONPATH=src python examples/serve_moe.py --policy sequential
      PYTHONPATH=src python examples/serve_moe.py --calibrate
      PYTHONPATH=src python examples/serve_moe.py \
          --profile $(ls .repro-profiles | head -1 | sed s/.json//)
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import FinDEPPlanner, PAPER_A6000
from repro.core.planner import PlannerConfig
from repro.profiling import ProfileStore
from repro.runtime import ADMISSIONS, Request, ServingEngine
from repro.sched import POLICIES, make_policy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--policy", choices=POLICIES, default="findep",
                    help="scheduling policy for the MoE layers")
    ap.add_argument("--admission", choices=ADMISSIONS, default="fcfs",
                    help="request admission policy")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-step prefill token budget (chunked prefill)")
    ap.add_argument("--calibrate", action="store_true",
                    help="microbenchmark this host, fit + persist a "
                         "HardwareProfile, and plan from it")
    ap.add_argument("--profile", default=None,
                    help="plan from a stored/registry profile by name "
                         "(with --calibrate: the name to store under)")
    ap.add_argument("--profile-store", default=".repro-profiles",
                    help="ProfileStore root directory")
    ap.add_argument("--drift-threshold", type=float, default=None,
                    help="enable drift-triggered background plan refresh "
                         "at this |residual| (e.g. 0.5)")
    ap.add_argument("--replicate-hot-k", type=int, default=0,
                    help="replicate the K hottest experts onto every EP "
                         "rank at each re-balance (0 = no replication)")
    ap.add_argument("--rebalance-threshold", type=float, default=None,
                    help="background expert re-placement when the worst "
                         "rank's load exceeds this multiple of the "
                         "uniform share (e.g. 1.25; None = never)")
    ap.add_argument("--attn-impl", choices=("decode_kernel", "xla"),
                    default="decode_kernel",
                    help="decode attention: ragged Pallas kernel (streams "
                         "ceil(len/bc) KV blocks per slot) or dense SDPA")
    ap.add_argument("--kv-layout", choices=("dense", "paged"),
                    default="dense",
                    help="KV memory: dense [B, max_context] rows or "
                         "block-granular pages with shared-prefix reuse")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared system-prompt tokens "
                         "to every request (shows the paged prefix cache)")
    ap.add_argument("--interleave", choices=("streams", "off"),
                    default="streams",
                    help="DEP executor emission: 'streams' interleaves "
                         "the r1 micro-batch streams in scheduled start "
                         "order; 'off' runs them back-to-back "
                         "(bit-identical outputs, different overlap)")
    ap.add_argument("--trace-out", default=None, metavar="OUT.json",
                    help="record engine spans (phases, request "
                         "lifecycles) and write a Chrome-trace/Perfetto "
                         "JSON file")
    ap.add_argument("--metrics-out", default=None, metavar="OUT.jsonl",
                    help="append metrics-registry snapshots to this "
                         "JSONL file")
    ap.add_argument("--metrics-interval", type=float, default=None,
                    metavar="SECONDS",
                    help="with --metrics-out: snapshot every N seconds "
                         "while serving (default: one final snapshot)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    store = ProfileStore(args.profile_store)
    policy = None
    if cfg.is_moe:
        planner = FinDEPPlanner(cfg, DepClusterConfig(8, 3, 5), PAPER_A6000,
                                PlannerConfig(mem_cap_samples=8))
        policy = make_policy(args.policy, planner, static_seq_len=256)
    # the engine owns the measured-cost-model flow: calibrate= measures +
    # persists, profile= loads a stored/registry fit (no re-measurement)
    eng = ServingEngine(cfg, num_slots=args.slots, max_context=256,
                        plan_policy=policy, admission=args.admission,
                        token_budget=args.token_budget,
                        calibrate=args.calibrate, profile=args.profile,
                        profile_store=store,
                        drift_threshold=args.drift_threshold,
                        attn_impl=args.attn_impl,
                        kv_layout=args.kv_layout,
                        replicate_hot_k=args.replicate_hot_k,
                        rebalance_threshold=args.rebalance_threshold,
                        tracer=bool(args.trace_out),
                        interleave=args.interleave,
                        dtype=jnp.float32)
    if eng.calibration is not None:
        res = eng.calibration
        r2s = {k: round(v, 4) for k, v in res.fit_r2.items()}
        print(f"calibrated {res.profile.name!r} in {res.wall_s:.1f}s "
              f"(R^2 {r2s}"
              + (", comm=proxy" if res.comm_is_proxy else "")
              + f") -> {store.root}")
    elif args.profile:
        print(f"planning from profile {args.profile!r} "
              f"(store {store.root} or registry) — no re-measurement")

    rng = np.random.RandomState(0)
    system = list(rng.randint(0, cfg.vocab_size, size=args.shared_prefix))
    reqs = []
    for i in range(args.requests):
        prompt = system + list(rng.randint(0, cfg.vocab_size,
                                           size=rng.randint(4, 48)))
        reqs.append(Request(prompt=prompt, max_new_tokens=args.max_new,
                            temperature=0.0 if i % 2 == 0 else 0.8))
        eng.submit(reqs[-1])

    t0 = time.perf_counter()
    if args.metrics_out and args.metrics_interval:
        # periodic snapshots while serving (one JSONL line each)
        start = len(eng.finished)
        last_snap = t0
        while True:
            progressed = eng.step()
            now = time.perf_counter()
            if now - last_snap >= args.metrics_interval:
                eng.metrics.export_jsonl(args.metrics_out)
                last_snap = now
            if not progressed and not eng.waiting:
                break
        finished = eng.finished[start:]
    else:
        finished = eng.run()
    dt = time.perf_counter() - t0

    done = sum(len(r.output) for r in reqs)
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    print(f"\nserved {len(finished)}/{args.requests} requests / "
          f"{done} tokens in {dt:.1f}s -> {done/dt:.1f} tokens/s decode")
    print(f"TTFT: mean {np.mean(ttfts)*1e3:.0f} ms, "
          f"p90 {np.percentile(ttfts, 90)*1e3:.0f} ms")
    if eng.metrics is not None:
        # the registry's histograms over every finished request
        def _pcts(name):
            h = eng.metrics.histogram(name)
            return h.p50, h.p99, h.count
        t50, t99, tn = _pcts("repro_engine_ttft_seconds")
        p50, p99, pn = _pcts("repro_engine_tpot_seconds")
        if tn:
            print(f"TTFT p50 {t50*1e3:.0f} ms, p99 {t99*1e3:.0f} ms "
                  f"(n={tn}, log-bucket estimate)")
        if pn:
            print(f"TPOT p50 {p50*1e3:.0f} ms, p99 {p99*1e3:.0f} ms "
                  f"(n={pn})")
    print(f"first outputs: {[r.output[:6] for r in reqs[:3]]}")

    if eng.plan_cache is not None:
        s = eng.plan_cache.stats
        print(f"\npolicy={args.policy} admission={args.admission}: "
              f"{len(eng.plan_cache)} shapes resolved, "
              f"{s.hits} cache hits ({s.hit_rate:.0%}), "
              f"{s.solve_time_total*1e3:.1f} ms total solve time")
        entries = eng.resolved_plans().items()
        prefills = sorted(k for k, _ in entries if k[0] == "prefill")
        decodes = sorted(k for k, _ in entries if k[0] == "decode")
        plans = dict(entries)
        for key in prefills:
            phase, bucket, batch = key[:3]
            skew = f" skew={key[3]!r}" if len(key) > 3 else ""
            p = plans[key]
            print(f"  {phase:>7} bucket={bucket:<5} batch={batch}: "
                  f"m_a={p.m_a} r1={p.r1} r2={p.r2} {p.order}{skew}")
        for key in decodes:
            phase, occ = key[:2]
            skew = f" skew={key[2]!r}" if len(key) > 2 else ""
            p = plans[key]
            print(f"  {phase:>7} {occ!r}: "
                  f"m_a={p.m_a} r1={p.r1} r2={p.r2} {p.order}{skew}")

    load = eng.expert_load()
    if load is not None:
        pl = eng.placement
        print(f"\nexpert load: imbalance {load['imbalance']:.2f}x uniform "
              f"(worst rank {load['rank_imbalance']:.2f}x), "
              f"{eng.stats.dropped_tokens} assignments dropped, "
              f"placement epoch {int(load['epoch'])}"
              + (f" (hot experts {pl.replicated})"
                 if pl is not None and pl.replicated else ""))

    paging = eng.paging_stats()
    if paging is not None:
        print(f"\npaged KV (block={paging['block_size']}): "
              f"{paging['blocks_used']}/{paging['blocks_usable']} pages "
              f"({paging['utilization']:.0%} pinned), prefix hit-rate "
              f"{paging['prefix_hit_rate']:.0%} "
              f"({paging['prefix_hit_tokens']} tokens), "
              f"{paging['preemptions']} preemptions")

    if eng.telemetry is not None and eng.telemetry.phases:
        print("\ntelemetry (predicted vs measured):")
        for phase, st in sorted(eng.telemetry.summary().items()):
            res = st["residual"]
            print(f"  {phase:>7}: n={st['count']:<4} "
                  f"measured={st['measured_s']:.3f}s "
                  f"predicted={st['predicted_s']:.3f}s "
                  + (f"residual={res:+.1%}" if res is not None else
                     "residual=n/a"))
    if eng.drift is not None:
        eng.drift.refresher.drain()
        ds, cs = eng.drift.stats, eng.plan_cache.stats
        print(f"drift: {ds.drift_events} events over {ds.observations} "
              f"observations -> {cs.refreshes} background re-solves "
              f"(threshold {args.drift_threshold:+.0%})")
        eng.close()

    if args.trace_out and eng.tracer is not None:
        from repro.obs import export_chrome_trace, validate_chrome_trace
        obj = export_chrome_trace(args.trace_out, tracer=eng.tracer,
                                  meta={"arch": args.arch,
                                        "policy": args.policy})
        stats = validate_chrome_trace(obj)
        print(f"\nwrote trace {args.trace_out}: {stats['complete']} spans "
              f"on {stats['tracks']} tracks (open in ui.perfetto.dev)")
    if args.metrics_out and eng.metrics is not None:
        eng.metrics.export_jsonl(args.metrics_out)
        print(f"wrote metrics snapshot -> {args.metrics_out}")


if __name__ == "__main__":
    main()
