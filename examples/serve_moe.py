"""End-to-end serving driver (the paper's workload): batched requests
against an MoE model through the continuous-batching engine with FinDEP
online planning.

Run:  PYTHONPATH=src python examples/serve_moe.py [--requests 16]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import FinDEPPlanner, PAPER_A6000
from repro.core.planner import PlannerConfig
from repro.runtime import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    planner = None
    if cfg.is_moe:
        planner = FinDEPPlanner(cfg, DepClusterConfig(8, 3, 5),
                                PAPER_A6000,
                                PlannerConfig(mem_cap_samples=8))
    eng = ServingEngine(cfg, num_slots=args.slots, max_context=256,
                        planner=planner, dtype=jnp.float32)
    if planner is not None:
        p = planner.plan(256)
        print(f"online FinDEP plan for the decode bucket: r1={p.r1} "
              f"r2={p.r2} order={p.order} "
              f"(solved in {planner.last_solve_time*1e3:.1f} ms)")

    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        prompt = list(rng.randint(0, cfg.vocab_size,
                                  size=rng.randint(4, 48)))
        reqs.append(Request(prompt=prompt, max_new_tokens=args.max_new,
                            temperature=0.0 if i % 2 == 0 else 0.8))
        eng.submit(reqs[-1])

    t0 = time.perf_counter()
    while eng.step() or eng.waiting:
        pass
    dt = time.perf_counter() - t0

    done = sum(len(r.output) for r in reqs)
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    print(f"\nserved {args.requests} requests / {done} tokens "
          f"in {dt:.1f}s -> {done/dt:.1f} tokens/s decode")
    print(f"TTFT: mean {np.mean(ttfts)*1e3:.0f} ms, "
          f"p90 {np.percentile(ttfts, 90)*1e3:.0f} ms")
    print(f"first outputs: {[r.output[:6] for r in reqs[:3]]}")


if __name__ == "__main__":
    main()
