"""Train a small MoE LM for a few hundred steps on synthetic Markov data
(loss drops toward the data's entropy floor), then checkpoint.

Presets:  --preset tiny   (~4M params,  fast CI run; default)
          --preset 100m   (~100M params, a few hundred steps — the full
                           deliverable run; several hours on 1 CPU core)

Run:  PYTHONPATH=src python examples/train_small.py --steps 200
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ModelConfig, MoEConfig
from repro.training import train

PRESETS = {
    "tiny": ModelConfig(
        name="tiny-moe", family="moe", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, ffn_dim=0, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, expert_ffn_dim=256,
                      num_shared_experts=1, shared_ffn_dim=256)),
    "100m": ModelConfig(
        name="moe-100m", family="moe", num_layers=12, d_model=512,
        num_heads=8, num_kv_heads=4, ffn_dim=0, vocab_size=32000,
        moe=MoEConfig(num_experts=8, top_k=2, expert_ffn_dim=1024,
                      num_shared_experts=1, shared_ffn_dim=1024)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"training {cfg.name}: ~{cfg.num_params()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x {args.seq}")
    res = train(cfg, steps=args.steps, batch_size=args.batch,
                seq_len=args.seq, lr=args.lr, ckpt_path=args.ckpt,
                log_every=max(args.steps // 20, 1))
    print(f"loss {res.losses[0]:.3f} -> {res.final_loss:.3f} "
          f"({res.tokens_per_s:.0f} tokens/s); checkpoint at {args.ckpt}")


if __name__ == "__main__":
    main()
