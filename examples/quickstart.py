"""Quickstart: FinDEP end to end in two minutes.

1. Pick an MoE backbone (DeepSeek-V2 style, with shared experts).
2. Calibrate/choose a hardware profile and build the planner.
3. Solve the fine-grained schedule (m_a, r1, m_e, r2, order) — Alg. 1.
4. Compare against naive DEP and best-configured PPPipe.
5. Run the actual MoE layer with the solved r2-chunked schedule on the
   host devices (real shard_map all_to_alls when >1 device is available).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import (FinDEPPlanner, PAPER_A6000, best_pppipe, naive_plan)
from repro.core.planner import PlannerConfig


def main():
    # ---- 1-2: model + cluster + hardware profile -------------------------
    cfg = get_config("deepseek-v2-lite")
    cluster = DepClusterConfig(num_devices=8, ag=3, eg=5)
    planner = FinDEPPlanner(cfg, cluster, PAPER_A6000,
                            PlannerConfig(mem_cap_samples=8))

    # ---- 3: solve ----------------------------------------------------------
    plan = planner.plan(seq_len=4096)
    print(f"FinDEP plan: m_a={plan.m_a} r1={plan.r1} m_e={plan.m_e:.0f} "
          f"r2={plan.r2} order={plan.order}")
    print(f"  solve time: {planner.last_solve_time*1e3:.1f} ms "
          f"(paper claim: < 1 s)")
    print(f"  predicted throughput: {plan.throughput:.0f} tokens/s")

    # ---- 4: baselines --------------------------------------------------------
    models = planner.stage_models(4096)
    T = len(cfg.moe_layer_indices())
    pp = best_pppipe(models, T, 8, r1_cap=8)
    nv = naive_plan(models, T, 8)
    print(f"\nbest PPPipe:  {pp.throughput:.0f} tokens/s "
          f"(FinDEP speedup {plan.throughput/pp.throughput:.3f}x)")
    print(f"naive DEP:    {nv.throughput:.0f} tokens/s "
          f"(FinDEP speedup {plan.throughput/nv.throughput:.3f}x)")

    # ---- 5: execute the schedule for real ------------------------------------
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_model
    n_dev = len(jax.devices())
    mesh = make_host_mesh(model=min(2, n_dev)) if n_dev > 1 else None
    smoke = get_smoke_config("deepseek-v2-lite")
    model = make_model(smoke, mesh, plan=plan, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                smoke.vocab_size)
    logits, _, aux = model.forward(params, tokens)
    print(f"\nexecuted reduced model with the solved schedule: "
          f"logits {logits.shape}, aux loss {float(aux):.4f}, "
          f"devices={n_dev}, moe_impl={model.ctx.moe_impl}")


if __name__ == "__main__":
    main()
