"""Paper §5.5 live: requests with varying sequence lengths arrive; the
FinDEP policy re-solves (r1, r2, order) per shape in milliseconds — with
repeated shapes served from the PlanCache — vs a static PPPipe
configuration tuned for the expected shape.

Run:  PYTHONPATH=src python examples/online_adaptation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config
from repro.configs.base import DepClusterConfig
from repro.core import PAPER_A6000, FinDEPPlanner, best_pppipe
from repro.core.analytic import StageTimes
from repro.core.planner import PlannerConfig
from repro.core.simulator import simulate_pppipe
from repro.sched import FinDEPPolicy, PlanCache


def main():
    cfg = get_config("deepseek-v2-lite")
    cluster = DepClusterConfig(num_devices=8, ag=3, eg=5)
    planner = FinDEPPlanner(cfg, cluster, PAPER_A6000,
                            PlannerConfig(mem_cap_samples=4, r1_cap=4))
    cache = PlanCache(FinDEPPolicy(planner))
    T = planner.num_moe_layers()

    # static PPPipe tuned for the "expected" S = 2048
    models_ref = planner.stage_models(2048)
    pp_cfg = best_pppipe(models_ref, T, 4, r1_cap=4)
    print(f"static PPPipe config (tuned for S=2048): "
          f"m_a={pp_cfg.m_a} r1={pp_cfg.r1}")

    rng = np.random.RandomState(0)
    total_fd = total_pp = 0.0
    print(f"\n{'arrival S':>10} {'FinDEP plan':>24} {'solve ms':>9} "
          f"{'FinDEP tok/s':>13} {'static PP':>10} {'speedup':>8}")
    for _ in range(8):
        S = int(rng.choice([512, 1024, 2048, 4096, 8192]))
        plan = cache.get("prefill", S, 4)
        models = planner.stage_models(S)
        st = StageTimes.from_models(models, pp_cfg.m_a,
                                    models.me_from_ma(pp_cfg.m_a, 1))
        res = simulate_pppipe(st, T, pp_cfg.r1)
        pp_tps = pp_cfg.r1 * pp_cfg.m_a * cluster.ag * S / res.makespan
        total_fd += plan.throughput
        total_pp += pp_tps
        print(f"{S:>10} m_a={plan.m_a} r1={plan.r1} r2={plan.r2:>2} "
              f"{plan.order:>5} {cache.stats.solve_time_last*1e3:>8.1f} "
              f"{plan.throughput:>13.0f} {pp_tps:>10.0f} "
              f"{plan.throughput/pp_tps:>7.3f}x")
    print(f"\naggregate speedup over the trace: "
          f"{total_fd/total_pp:.3f}x (paper Table 6: 1.00-1.24x)")
    s = cache.stats
    print(f"plan cache: {s.misses} solves + {s.hits} hits over "
          f"{s.lookups} arrivals ({s.solve_time_total*1e3:.1f} ms "
          f"total solver time)")


if __name__ == "__main__":
    main()
