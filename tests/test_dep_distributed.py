"""DEP shard_map execution vs the dense oracle — runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4 (the main pytest process
stays single-device)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

# some subprocess snippets build explicit-axis-type meshes; the streams
# parity test uses a plain mesh and runs everywhere
needs_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType unavailable in this jax version")

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@needs_axis_type
@pytest.mark.slow
def test_dep_seq_mode_matches_dense_oracle():
    out = run_sub(textwrap.dedent("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import moe as moe_lib
        from repro.models.transformer import ExecutionContext
        from repro.core import dep
        from repro.core.solver import Plan
        mesh = jax.make_mesh((2,2), ("data","model"),
            axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen2-moe-a2.7b")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
        key = jax.random.PRNGKey(1)
        params = moe_lib.moe_init(key, cfg.d_model, cfg.moe, 4)
        x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
        y_ref, _ = moe_lib.moe_apply_dense(params, x, cfg.moe, 4)
        ctx = ExecutionContext(mesh=mesh, moe_impl="dep")
        # the (2, "AASS", 3) case exercises the m_e-aligned capacity:
        # chunk sizes are multiples of the solver's modeled granularity
        for r2, order, m_e in [(1,"AASS",1),(2,"ASAS",1),(4,"AASS",1),
                               (2,"AASS",3)]:
            plan = Plan(m_a=1,r1=1,m_e=m_e,r2=r2,order=order,
                        throughput=0,makespan=0)
            with mesh:
                y, _ = jax.jit(lambda p, x: dep.moe_apply_dep(
                    p, x, cfg.moe, ctx, 4, plan=plan.exec_graph()))(
                    params, x)
            err = float(jnp.max(jnp.abs(y - y_ref)))
            assert err < 1e-5, (r2, order, err)
            print("ok", r2, order, err)
    """))
    assert out.count("ok") == 4


@needs_axis_type
@pytest.mark.slow
def test_dep_decode_mode_and_grads():
    out = run_sub(textwrap.dedent("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import moe as moe_lib
        from repro.models.transformer import ExecutionContext
        from repro.core import dep
        from repro.core.solver import Plan
        mesh = jax.make_mesh((2,2), ("data","model"),
            axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen2-moe-a2.7b")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
        key = jax.random.PRNGKey(1)
        params = moe_lib.moe_init(key, cfg.d_model, cfg.moe, 4)
        # decode mode (S=1 < mesh model size -> replicated-token psum path)
        xd = jax.random.normal(key, (4, 1, cfg.d_model), jnp.float32)
        y_ref, _ = moe_lib.moe_apply_dense(params, xd, cfg.moe, 4)
        ctx = ExecutionContext(mesh=mesh, moe_impl="dep")
        with mesh:
            y, _ = jax.jit(lambda p, x: dep.moe_apply_dep(
                p, x, cfg.moe, ctx, 4))(params, xd)
        assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-5
        print("ok decode")
        # the replicated-token path honors the solved order: ASAS (shared
        # expert split across chunk boundaries) must match the oracle too
        for order in ("ASAS", "AASS"):
            plan = Plan(m_a=1, r1=1, m_e=1, r2=2, order=order,
                        throughput=0, makespan=0)
            with mesh:
                y, _ = jax.jit(lambda p, x: dep.moe_apply_dep(
                    p, x, cfg.moe, ctx, 4,
                    plan=plan.exec_graph()))(params, xd)
            assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-5, order
            print("ok decode", order)
        # gradients flow through the all_to_all path
        x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
        def loss(p):
            with mesh:
                y, aux = dep.moe_apply_dep(p, x, cfg.moe, ctx, 4)
            return jnp.sum(y**2) + aux
        g = jax.jit(jax.grad(loss))(params)
        finite = all(bool(jnp.all(jnp.isfinite(l)))
                     for l in jax.tree.leaves(g))
        nonzero = any(float(jnp.max(jnp.abs(l))) > 0
                      for l in jax.tree.leaves(g))
        assert finite and nonzero
        print("ok grads")
    """))
    assert "ok decode" in out and "ok grads" in out


@needs_axis_type
@pytest.mark.slow
def test_seqsharded_decode_attention_matches_local():
    out = run_sub(textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.models.attention import _decode_core_seqsharded
        mesh = jax.make_mesh((2,2), ("data","model"),
            axis_types=(jax.sharding.AxisType.Auto,)*2)
        key = jax.random.PRNGKey(0)
        B, C, Kv, H, D = 4, 64, 2, 8, 32
        ks = jax.random.split(key, 5)
        q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
        kn = jax.random.normal(ks[1], (B, 1, Kv, D), jnp.float32)
        vn = jax.random.normal(ks[2], (B, 1, Kv, D), jnp.float32)
        ck = jax.random.normal(ks[3], (B, C, Kv, D), jnp.float32)
        cv = jax.random.normal(ks[4], (B, C, Kv, D), jnp.float32)
        index = jnp.asarray(37, jnp.int32)
        with mesh:
            out, nk, nv = jax.jit(lambda *a: _decode_core_seqsharded(
                *a, mesh, "model", ("data",), False))(
                q, kn, vn, ck, cv, index)
        # local reference
        import math
        ck2 = ck.at[:, 37].set(kn[:, 0]); cv2 = cv.at[:, 37].set(vn[:, 0])
        valid = jnp.arange(C) <= 37
        g = H // Kv
        qh = q[:, 0].reshape(B, Kv, g, D)
        lg = jnp.einsum("bkgd,bskd->bkgs", qh, ck2) / math.sqrt(D)
        lg = jnp.where(valid[None,None,None], lg, -1e30)
        p = jax.nn.softmax(lg, -1)
        ref = jnp.einsum("bkgs,bskd->bkgd", p, cv2).reshape(B,1,H,D)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        assert float(jnp.max(jnp.abs(nk - ck2))) < 1e-6
        print("ok", err)
    """))
    assert "ok" in out


@pytest.mark.slow
def test_interleaved_streams_bit_identical_to_off():
    """The tentpole bit-parity lock: for ONE lowered graph, the
    ``interleave="streams"`` emission (scheduled start order, default
    priority hints) produces bit-identical outputs to the
    ``interleave="off"`` walk — sequence AND replicated-decode dispatch,
    ASAS and AASS, r1 in {1, 2, 4} — and both match the dense oracle.
    Streams slice capacity, not routing, so the reorder commutes."""
    out = run_sub(textwrap.dedent("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import moe as moe_lib
        from repro.models.transformer import ExecutionContext
        from repro.core import dep
        from repro.core.taskgraph import ExecProgram, lower_exec
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = get_smoke_config("qwen2-moe-a2.7b")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
        key = jax.random.PRNGKey(1)
        params = moe_lib.moe_init(key, cfg.d_model, cfg.moe, 4)
        ctx = ExecutionContext(mesh=mesh, moe_impl="dep")
        xs = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
        xd = jax.random.normal(key, (4, 1, cfg.d_model), jnp.float32)
        cases = [(xs, "seq"), (xd, "dec")]
        for x, tag in cases:
            y_ref, _ = moe_lib.moe_apply_dense(params, x, cfg.moe, 4)
            for order in ("ASAS", "AASS"):
                for r1 in (1, 2, 4):
                    g = lower_exec(2, order, 1, r1=r1)
                    def run(prog):
                        with mesh:
                            y, _ = jax.jit(
                                lambda p, xx: dep.moe_apply_dep(
                                    p, xx, cfg.moe, ctx, 4,
                                    plan=prog))(params, x)
                        return y
                    y_off = run(ExecProgram(g, interleave="off"))
                    y_str = run(ExecProgram(g, interleave="streams"))
                    assert jnp.array_equal(y_off, y_str), \\
                        (tag, order, r1)
                    err = float(jnp.max(jnp.abs(y_str - y_ref)))
                    assert err < 1e-5, (tag, order, r1, err)
                    print("ok", tag, order, r1)
    """))
    assert out.count("ok") == 12
