"""The static verification layer (repro.analysis).

Positive direction: every lowering the repo actually produces passes all
three passes clean (the CI gate property), and the planner/engine
``validate=`` knobs accept real plans.

Negative direction (detector sensitivity): each pass must FLAG a
deliberately broken artifact with an actionable message — a corrupted
service order deadlocks, a tampered schedule races, a tampered block
table dereferences garbage, a mutable static arg / host sync / tracer
leak lints, and a tampered hint vector is rejected by
``ServingEngine(validate=True)`` at plan time.

Plus the jit-static hashability regression: every type the registry
declares jit-static must hash/compare by value across construction
paths (fresh solves, cached-property materialization, epoch bumps).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import AnalysisError, PASSES, codes, run_all
from repro.analysis.graphcheck import (check_capacity, check_exec_program,
                                       check_graph, check_hints,
                                       check_schedule_result,
                                       check_structure, find_deadlock,
                                       sweep)
from repro.analysis.jitlint import (STATIC_ARG_TYPES, check_static_types,
                                    lint_source)
from repro.analysis.kernelcheck import (check_dense_index_map,
                                        check_flash_index_map,
                                        check_paged_index_map)
from repro.analysis.report import Violation
from repro.configs import get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import PAPER_A6000, FinDEPPlanner
from repro.core.planner import PlannerConfig
from repro.core.solver import Plan
from repro.core.taskgraph import (ATTN, EXP, GATE, _HINT_COSTS, ExecProgram,
                                  lower_exec, schedule, stream_major_order,
                                  stream_serial_deps)
from repro.placement import Placement, SkewSummary
from repro.runtime import Request, ServingEngine
from repro.sched import StaticPolicy

CFG = get_smoke_config("qwen2-moe-a2.7b")
CLUSTER = DepClusterConfig(num_devices=8, ag=3, eg=5)


def mk_planner(**kw):
    return FinDEPPlanner(CFG, CLUSTER, PAPER_A6000,
                         PlannerConfig(mem_cap_samples=8), **kw)


class _TamperedGraph:
    """Duck-typed stand-in: a real graph's parameters with a corrupted
    task tuple (the real TaskGraph derives its tasks from the lowering
    parameters, so a broken tuple can only come from a future bug —
    which is exactly what the structural checks must catch)."""

    def __init__(self, graph, tasks):
        for f in ("T", "r1", "r2", "order", "m_e", "has_shared",
                  "shared_blocks_a2e", "hot_experts", "placement_epoch",
                  "shared_segments"):
            setattr(self, f, getattr(graph, f))
        self.tasks = tuple(tasks)


# ---------------------------------------------------------------------------
# graphcheck: positives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", ["ASAS", "AASS"])
@pytest.mark.parametrize("r1", [1, 2, 4])
def test_exec_lowerings_clean(order, r1):
    g = lower_exec(4, order, m_e=3, r1=r1)
    assert check_graph(g) == []
    for mode in ("off", "streams"):
        assert check_exec_program(ExecProgram(g, mode, None)) == []


def test_planner_lowering_clean():
    planner = mk_planner()
    plan = planner.plan(256, 4)
    assert check_graph(planner.lower(plan)) == []
    assert check_exec_program(plan.exec_program()) == []


def test_fast_sweep_clean():
    """The CI gate property on the representative slice: every policy's
    lowering over the reduced shape space, zero violations."""
    violations, combos = sweep(fast=True)
    assert violations == []
    assert combos > 50


# ---------------------------------------------------------------------------
# graphcheck: negatives (detector sensitivity)
# ---------------------------------------------------------------------------

def test_structure_flags_forward_dep_and_bad_ranges():
    g = lower_exec(2, "ASAS")
    tasks = list(g.tasks)
    tasks[1] = dataclasses.replace(tasks[1], deps=(len(tasks) + 3,))
    tasks[2] = dataclasses.replace(tasks[2], layer=99)
    vs = check_structure(_TamperedGraph(g, tasks))
    assert set(codes(vs)) == {"dep-not-earlier", "layer-range"}
    msg = next(str(v) for v in vs if v.code == "dep-not-earlier")
    assert "not an earlier emission" in msg and "graphcheck" in msg


def test_capacity_flags_missing_chunk():
    g = lower_exec(3, "ASAS", r1=2)
    dropped = next(t for t in g.tasks if t.kind == EXP and t.chunk == 1)
    vs = check_capacity(_TamperedGraph(
        g, [t for t in g.tasks if t is not dropped]))
    assert "capacity-conservation" in codes(vs)
    msg = next(str(v) for v in vs if v.code == "capacity-conservation")
    assert "EXP" in msg and "missing" in msg


def test_race_detector_flags_tampered_schedule():
    g = lower_exec(4, "ASAS", r1=2)
    res = schedule(g, _HINT_COSTS)
    assert check_schedule_result(res) == []
    res.starts[len(g.tasks) - 1] = 0.0       # yank the last task to t=0
    vs = check_schedule_result(res)
    got = set(codes(vs))
    assert "lane-race" in got and "dep-order" in got
    msg = next(v.message for v in vs if v.code == "lane-race")
    assert "occupies the lane" in msg


def test_deadlock_flags_corrupted_service_order():
    """GATE served before its ATTN dep on the shared AG lane is an
    immediate two-cycle: GATE dep-waits ATTN, ATTN lane-waits GATE."""
    g = lower_exec(2, "ASAS", r1=2)
    order = list(range(len(g.tasks)))
    ai = next(i for i, t in enumerate(g.tasks) if t.kind == ATTN)
    gi = next(i for i, t in enumerate(g.tasks)
              if t.kind == GATE and t.mb == g.tasks[ai].mb)
    pa, pg = order.index(ai), order.index(gi)
    order[pa], order[pg] = gi, ai
    cycle = find_deadlock(g, service_order=order)
    assert cycle is not None
    kinds = {g.tasks[i].kind for i in cycle}
    assert kinds == {ATTN, GATE}


def test_deadlock_flags_truncated_service_order():
    g = lower_exec(2, "ASAS")
    stuck = find_deadlock(g, service_order=range(len(g.tasks) - 1))
    assert stuck == [len(g.tasks) - 1]


def test_executed_realizations_are_deadlock_free():
    """The realizations the system actually takes must complete — the
    emission order, and the sequential executor's stream-major order
    under the cross-stream serialization edges."""
    for r1 in (1, 2, 4):
        g = lower_exec(4, "ASAS", r1=r1)
        assert find_deadlock(g) is None
        assert find_deadlock(g, service_order=stream_major_order(g),
                             extra_deps=stream_serial_deps(g)) is None


def test_hint_checks_flag_tampered_vectors():
    g = lower_exec(4, "ASAS", r1=2)
    n = len(g.tasks)
    good = schedule(g, _HINT_COSTS).priority_hints()
    assert check_hints(ExecProgram(g, "streams", good)) == []

    reversed_ = ExecProgram(g, "streams", tuple(reversed(good)))
    assert codes(check_hints(reversed_)) == ["hint-dep-order"]
    short = ExecProgram(g, "streams", good[:-1])
    assert codes(check_hints(short)) == ["hint-length"]
    dup = ExecProgram(g, "streams", (0,) * n)
    assert "hint-not-permutation" in codes(check_hints(dup))
    assert codes(check_exec_program(reversed_)) == ["hint-dep-order"]


def test_exec_interleaved_error_names_both_tasks():
    """Satellite: the dep-consistency failure must name the offending
    pair (kind/layer/mb/chunk), their hint ranks and their interleaved
    positions — not just two bare indices."""
    g = lower_exec(4, "ASAS", r1=2)
    bad = tuple(reversed(schedule(g, _HINT_COSTS).priority_hints()))
    with pytest.raises(ValueError) as ei:
        g.exec_interleaved(bad)
    msg = str(ei.value)
    assert "would run before its dependency" in msg
    assert "(layer=" in msg and "mb=" in msg and "chunk=" in msg
    assert "hint" in msg and "interleaved position" in msg


# ---------------------------------------------------------------------------
# kernelcheck
# ---------------------------------------------------------------------------

def test_production_index_maps_clean():
    assert check_dense_index_map(60, 16, [0, 1, 15, 16, 17, 59, 60]) == []
    assert check_flash_index_map(2, 8, 2, 4, 4) == []


def test_paged_checker_flags_tampered_table():
    bs = 16
    # row 0: block 1 is in-length but unallocated; row 1: page out of
    # range; row 2: an in-length block mapped to the scratch page
    tables = np.array([[3, -1, -1], [99, 2, -1], [0, 4, -1]], np.int32)
    vs = check_paged_index_map(tables, [2 * bs, bs, bs], num_pages=8,
                               bs=bs)
    got = set(codes(vs))
    assert {"paged-live-step-unallocated", "paged-page-range",
            "paged-live-step-scratch"} <= got
    msg = next(v.message for v in vs
               if v.code == "paged-live-step-unallocated")
    assert "promised coverage" in msg


def test_paged_checker_accepts_real_ledger():
    from repro.runtime.paging import PagedKVCacheManager
    bs = 16
    kv = PagedKVCacheManager(3, max_context=4 * bs, block_size=bs,
                             num_blocks=16)
    kv.take(0)
    kv.assign_blocks(0, list(range(bs + 3)))
    kv.set_length(0, bs + 4)
    assert check_paged_index_map(kv._tables, kv.lengths(),
                                 kv.pool.num_blocks, bs) == []


# ---------------------------------------------------------------------------
# jitlint
# ---------------------------------------------------------------------------

def test_jitlint_repo_clean():
    violations, _ = __import__("repro.analysis.jitlint",
                               fromlist=["run"]).run()
    assert violations == []


def test_jitlint_flags_mutable_static_and_host_sync():
    src = (
        "import functools\n"
        "import jax\n"
        "import numpy as np\n"
        "\n"
        "@functools.partial(jax.jit, static_argnames=('opts',))\n"
        "def step(x, opts=[]):\n"
        "    y = np.asarray(x)\n"
        "    return y.item()\n"
    )
    vs = lint_source(src, "fake.py", hot=True)
    got = codes(vs)
    assert "static-arg-mutable" in got
    assert got.count("host-sync") == 2
    msg = next(v.message for v in vs if v.code == "static-arg-mutable")
    assert "opts" in msg


def test_jitlint_flags_tracer_context_leak():
    src = (
        "def walk():\n"
        "    from repro.obs.trace import active_tracer\n"
        "    return active_tracer()\n"
    )
    vs = lint_source(src, "dep.py", tracer_module=True)
    assert "tracer-context-leak" in codes(vs)


def test_static_type_registry_clean():
    assert check_static_types() == []
    assert len(STATIC_ARG_TYPES) >= 5


def test_static_type_checker_flags_unhashable_fields():
    @dataclasses.dataclass(frozen=True)
    class BadStatic:
        xs: list

    @dataclasses.dataclass
    class NotFrozen:
        x: int = 0

    vs = check_static_types(extra=(BadStatic, NotFrozen))
    msgs = " | ".join(v.message for v in vs)
    assert "BadStatic.xs" in msgs and "unhashable" in msgs
    assert "NotFrozen" in msgs and "frozen" in msgs


# ---------------------------------------------------------------------------
# jit-static hashability / identity regression (every registry type)
# ---------------------------------------------------------------------------

def test_plan_identity_across_fresh_solves():
    p1 = mk_planner().plan(256, 4)
    p2 = mk_planner().plan(256, 4)
    assert p1 == p2 and hash(p1) == hash(p2)
    assert len({p1, p2}) == 1


def test_taskgraph_identity_and_cached_materialization():
    g1 = lower_exec(4, "ASAS", m_e=3, r1=2)
    g2 = lower_exec(4, "ASAS", m_e=3, r1=2)
    assert g1 == g2 and hash(g1) == hash(g2)
    _ = g1.tasks                       # materialize the lazy tuple
    assert g1 == g2 and hash(g1) == hash(g2)
    assert g1 != dataclasses.replace(g1, placement_epoch=1)
    assert g1 != dataclasses.replace(g1, hot_experts=1)


def test_exec_program_identity_hints_and_modes():
    g = lower_exec(4, "ASAS", r1=2)
    hints = schedule(g, _HINT_COSTS).priority_hints()
    a = ExecProgram(g, "streams", hints)
    b = ExecProgram(g, "streams", hints)
    assert a == b and hash(a) == hash(b)
    assert a != ExecProgram(g, "streams", None)
    assert a != ExecProgram(g, "off", hints)
    assert len({a, b, ExecProgram(g, "off", hints)}) == 2


def test_placement_identity_excludes_loads():
    kw = dict(num_experts=8, num_ranks=4,
              assignment=(0, 0, 1, 1, 2, 2, 3, 3), replicated=(2,))
    a = Placement(**kw, loads=(1.0,) * 8)
    b = Placement(**kw, loads=(9.0,) * 8)      # telemetry only
    assert a == b and hash(a) == hash(b)
    assert a != Placement(**kw, epoch=1)


def test_skew_summary_identity():
    a = SkewSummary(kappa=1.25, rho=0.125, max_expert=1.5, hot_k=1)
    b = SkewSummary(kappa=1.25, rho=0.125, max_expert=1.5, hot_k=1)
    assert a == b and hash(a) == hash(b)
    assert not a.is_uniform and SkewSummary().is_uniform
    assert {a: "x"}[b] == "x"


# ---------------------------------------------------------------------------
# planner / engine validate= knobs
# ---------------------------------------------------------------------------

def test_planner_validate_accepts_real_solves():
    planner = mk_planner(validate=True)
    for S in (128, 256):
        planner.plan(S, 4)                      # must not raise


def test_engine_validate_rejects_tampered_hints(monkeypatch):
    """Acceptance: a tampered hint vector is rejected at plan time —
    before any trace sees the program."""
    pol = StaticPolicy.from_planner(mk_planner(), 64)
    orig = Plan.exec_program

    def tampered(self, *a, **kw):
        prog = orig(self, *a, **kw)
        if prog.hints is None:
            return prog
        return ExecProgram(prog.graph, prog.interleave,
                           tuple(reversed(prog.hints)))

    monkeypatch.setattr(Plan, "exec_program", tampered)
    eng = ServingEngine(CFG, num_slots=2, max_context=64,
                        plan_policy=pol, validate=True,
                        dtype=jnp.float32)
    eng.submit(Request(prompt=[1, 2, 3, 4], max_new_tokens=2))
    with pytest.raises(AnalysisError) as ei:
        eng.run()
    assert any(v.code == "hint-dep-order" for v in ei.value.violations)
    # opt-in: without validate the single-device engine never builds the
    # program, and serving is unaffected
    eng2 = ServingEngine(CFG, num_slots=2, max_context=64,
                        plan_policy=pol, dtype=jnp.float32)
    eng2.submit(Request(prompt=[1, 2, 3, 4], max_new_tokens=2))
    assert len(eng2.run()) == 1


def test_engine_validate_clean_serving_and_memo():
    pol = StaticPolicy.from_planner(mk_planner(), 64)
    eng = ServingEngine(CFG, num_slots=2, max_context=64,
                        plan_policy=pol, validate=True,
                        dtype=jnp.float32)
    eng.submit(Request(prompt=[5, 6, 7], max_new_tokens=2))
    assert len(eng.run()) == 1
    assert len(eng._validated_programs) >= 1
    before = len(eng._validated_programs)
    eng.submit(Request(prompt=[8, 9, 10], max_new_tokens=2))
    eng.run()
    assert len(eng._validated_programs) == before   # memo, not re-check


# ---------------------------------------------------------------------------
# CLI / runner surface
# ---------------------------------------------------------------------------

def test_run_all_rejects_unknown_pass():
    with pytest.raises(ValueError, match="unknown pass"):
        run_all(("nope",))


def test_cli_check_exits_zero_on_clean_pass(capsys):
    from repro.analysis.__main__ import main
    assert main(["kernelcheck", "--fast", "--check", "-q"]) == 0
    out = capsys.readouterr().out
    assert "kernelcheck: 0 violation(s)" in out


def test_analysis_error_message_lists_violations():
    err = AnalysisError([Violation("graphcheck", "deadlock", "g", "boom")])
    assert "deadlock" in str(err) and "boom" in str(err)
    assert err.violations[0].code == "deadlock"
