"""Per-architecture smoke tests (required deliverable): every assigned
architecture instantiates a REDUCED variant (<=2 layers, d_model<=512,
<=4 experts) and runs one forward + one train step on CPU, asserting
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.steps import make_train_step
from repro.models import build_model, frontend_shape
from repro.training.optimizer import AdamWConfig, init_opt_state

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _inputs(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    fs = frontend_shape(cfg, ShapeConfig("t", S, B, "t"))
    extra = jax.random.normal(KEY, fs, jnp.float32) if fs else None
    return tokens, extra


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_config_limits(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2 or cfg.family == "ssm" and cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(KEY)
    tokens, extra = _inputs(cfg)
    logits, _, aux = model.forward(params, tokens, extra_embeds=extra)
    exp_len = S + (extra.shape[1] if extra is not None
                   and cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_len, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(KEY)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt_state = init_opt_state(params, opt_cfg)
    tokens, extra = _inputs(cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    if extra is not None:
        new_p, new_s, metrics = step(params, opt_state, tokens, extra)
    else:
        new_p, new_s, metrics = step(params, opt_state, tokens)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_s.step) == 1
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(new_p), jax.tree.leaves(params)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(KEY)
    tokens, extra = _inputs(cfg)
    memory = model.encode(params, extra) if cfg.is_encoder_decoder else None
    ee = None if cfg.is_encoder_decoder else extra
    _, caches = model.prefill(params, tokens, extra_embeds=ee,
                              memory=memory, seq_budget=S + 4)
    lg, caches = model.decode_step(params, tokens[:, :1], caches,
                                   memory=memory)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
