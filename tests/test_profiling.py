"""repro.profiling: microbench units, profile store round-trips, telemetry
residuals, drift-triggered background refresh, and the satellite hooks
(cost-aware cache eviction, launch policy knobs, executor m_e alignment)."""
import math
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import FinDEPPlanner, PAPER_A6000, PlannerConfig
from repro.core.perf_model import (AlphaBeta, HardwareProfile, PROFILES,
                                   build_stage_models, fit_profile,
                                   get_profile, register_profile)
from repro.core.solver import Plan
from repro.profiling import (CalibrationResult, DriftMonitor, PlanRefresher,
                             ProfileKey, ProfileStore, StepTimer,
                             measure_attention, measure_gemm,
                             measure_all_to_all, rescale_policy_hardware)
from repro.sched import FinDEPPolicy, PlanCache

CFG = get_smoke_config("qwen2-moe-a2.7b")
CLUSTER = DepClusterConfig(num_devices=8, ag=3, eg=5)


def mk_planner(hw=PAPER_A6000, **kw):
    return FinDEPPlanner(CFG, CLUSTER, hw,
                         PlannerConfig(mem_cap_samples=8, **kw))


def synthetic_profile(name="synth"):
    """An exactly-linear 'measurement' set and its fitted profile."""
    measured = {
        "gemm": (np.linspace(1e6, 1e9, 8), 1.7e-4 + 8.6e-14
                 * np.linspace(1e6, 1e9, 8)),
        "attn": (np.linspace(1e5, 1e8, 8), 1.5e-4 + 1.5e-14
                 * np.linspace(1e5, 1e8, 8)),
        "comm": (np.linspace(2**16, 2**24, 8), 3.7e-4 + 2.5e-9
                 * np.linspace(2**16, 2**24, 8)),
    }
    profile, r2s = fit_profile(measured, name=name)
    return profile, r2s, measured


# ---------------------------------------------------------------------------
# microbench sample units
# ---------------------------------------------------------------------------

def test_gemm_samples_in_perf_model_units():
    s = measure_gemm(shapes=[(8, 16, 32), (16, 16, 32)], warmup=0, iters=1)
    assert s.kind == "gemm"
    assert s.xs == [8 * 16 * 32, 16 * 16 * 32]          # x = m*k*n
    assert all(t > 0 for t in s.ts) and len(s.ts) == 2


def test_attention_samples_in_perf_model_units():
    s = measure_attention(shapes=[(2, 16, 4, 8)], warmup=0, iters=1)
    # y = N_h * B * S^2 * (d_k + d_v)
    assert s.xs == [4 * 2 * 16 * 16 * (8 + 8)]
    assert s.ts[0] > 0


def test_comm_proxy_samples_are_bytes():
    import jax.numpy as jnp
    s = measure_all_to_all(mesh=None, sizes_bytes=[1 << 12, 1 << 14],
                           dtype=jnp.float32, warmup=0, iters=1)
    assert s.proxy                                       # no multi-dev axis
    assert s.xs == [float(1 << 12), float(1 << 14)]      # z = bytes/device
    assert all(t > 0 for t in s.ts)


def test_decode_samples_in_bytes_streamed_units():
    from repro.profiling import measure_decode_attention
    from repro.profiling.microbench import DECODE_HEAD_DIM, DECODE_KV_HEADS
    s = measure_decode_attention(shapes=[(2, 64, 0.5)], warmup=0, iters=1)
    assert s.kind == "decode"
    # z = B * c_eff * Kv * (d_k + d_v) * itemsize, c_eff = int(C * fill)
    c_eff = 32
    assert s.xs == [2.0 * c_eff * DECODE_KV_HEADS * 2 * DECODE_HEAD_DIM * 4]
    assert s.ts[0] > 0
    assert s.proxy or s.xs        # jnp stand-in off-TPU is flagged


def test_decode_fit_round_trips_and_drives_stage_models():
    """The optional decode primitive fits its own alpha-beta, survives
    the dict round-trip bit-for-bit, and replaces the prefill attention
    fit in t_a exactly when decode_context > 0."""
    from dataclasses import replace

    _, _, measured = synthetic_profile()
    zs = np.linspace(2**16, 2**24, 8)
    measured["decode"] = (zs, 2.0e-4 + 5.0e-9 * zs)
    profile, r2s = fit_profile(measured, name="decode_fit")
    assert r2s["decode"] > 0.999999
    assert profile.decode.alpha == pytest.approx(2.0e-4)
    assert profile.decode.beta == pytest.approx(5.0e-9)
    assert HardwareProfile.from_dict(profile.as_dict()) == profile

    from repro.core.perf_model import DepModelSpec
    spec = DepModelSpec.from_model_config(CFG, 256)
    no_decode_fit = replace(profile, decode=None)
    # prefill (decode_context == 0): the decode fit must not perturb t_a
    assert (build_stage_models(profile, spec, CLUSTER).t_a
            == build_stage_models(no_decode_fit, spec, CLUSTER).t_a)
    # decode phase: dedicated fit changes the attention term
    dspec = replace(spec, decode_context=512.0)
    with_fit = build_stage_models(profile, dspec, CLUSTER).t_a
    fallback = build_stage_models(no_decode_fit, dspec, CLUSTER).t_a
    assert with_fit != fallback
    # the bytes-streamed unit uses kv heads: expected beta contribution
    kv = CFG.num_kv_heads or CFG.num_heads
    expected = (256 * 512.0 * kv * 2 * CFG.head_dim
                * CLUSTER.dtype_bytes * profile.decode.beta)
    gemm_part = fallback.beta - profile.attn.beta * (
        256 * 512.0 * CFG.num_heads * 2 * CFG.head_dim)
    assert with_fit.beta == pytest.approx(gemm_part + expected)


def test_fit_consumes_microbench_samples():
    """The sample dict plugs straight into the perf-model fitting path and
    an exactly-linear sweep is recovered with R^2 ~ 1."""
    profile, r2s, measured = synthetic_profile()
    assert min(r2s.values()) > 0.999999
    assert profile.gemm.alpha == pytest.approx(1.7e-4)
    assert profile.gemm.beta == pytest.approx(8.6e-14)
    models = build_stage_models(
        profile,
        __import__("repro.core.perf_model", fromlist=["DepModelSpec"])
        .DepModelSpec.from_model_config(CFG, 256), CLUSTER)
    assert models.t_e(4.0) > 0


# ---------------------------------------------------------------------------
# profile serialization + store round-trip
# ---------------------------------------------------------------------------

def test_profile_dict_roundtrip_bit_for_bit():
    profile, _, _ = synthetic_profile()
    again = HardwareProfile.from_dict(profile.as_dict())
    assert again == profile          # float dataclass eq == bitwise here


def test_profile_registry():
    p = HardwareProfile("unit_test_prof", AlphaBeta(1e-4, 1e-12),
                        AlphaBeta(1e-4, 1e-12), AlphaBeta(1e-4, 1e-9))
    register_profile(p)
    try:
        assert get_profile("unit_test_prof") is p
        with pytest.raises(KeyError, match="unknown hardware profile"):
            get_profile("no_such_profile")
    finally:
        PROFILES.pop("unit_test_prof", None)


def test_scaled_profile_preserves_argmax():
    planner_a = mk_planner(PAPER_A6000)
    planner_b = mk_planner(PAPER_A6000.scaled(3.0))
    pa = planner_a.plan(256, 4)
    pb = planner_b.plan(256, 4)
    assert (pa.m_a, pa.r1, pa.r2, pa.order) == (pb.m_a, pb.r1, pb.r2,
                                                pb.order)
    assert pb.makespan == pytest.approx(3.0 * pa.makespan)


def test_store_roundtrip_preserves_plans_bit_for_bit(tmp_path):
    profile, r2s, measured = synthetic_profile("roundtrip")
    store = ProfileStore(tmp_path / "profiles")
    key = ProfileKey(device_kind="cpu", mesh_shape=(1,), dtype="float32")
    samples = {k: (list(map(float, xs)), list(map(float, ts)))
               for k, (xs, ts) in measured.items()}
    store.put(profile, key, name="rt", fit_r2=r2s, samples=samples)
    entry = store.get("rt")
    assert entry.profile == profile                     # bit-for-bit
    assert entry.key == key
    assert entry.samples == samples
    assert entry.fit_r2 == r2s
    # plans solved from the loaded fit ARE the plans from the fresh fit
    assert mk_planner(entry.profile).plan(256, 4) == \
        mk_planner(profile).plan(256, 4)
    # keyed lookup + staleness metadata
    assert store.get_for_key(key).name == "rt"
    assert entry.age_s < 60 and not entry.is_stale(3600)
    assert entry.is_stale(0)
    assert store.names() == ["rt"] and store.has("rt")
    with pytest.raises(KeyError):
        store.get("missing")


def test_store_ignores_unknown_schema(tmp_path):
    store = ProfileStore(tmp_path)
    profile, _, _ = synthetic_profile()
    store.put(profile, ProfileKey("cpu", (1,), "float32"), name="ok")
    (tmp_path / "bad.json").write_text('{"schema": 999, "name": "bad"}')
    (tmp_path / "junk.json").write_text("not json")
    assert store.names() == ["ok"]
    with pytest.raises(KeyError, match="schema"):
        store.get("bad")


def test_calibration_result_stores(tmp_path):
    """A (synthetic) CalibrationResult persists through put_calibration."""
    from repro.profiling.microbench import MicrobenchSamples
    profile, r2s, measured = synthetic_profile("calib")
    samples = {k: MicrobenchSamples(k, list(map(float, xs)),
                                    list(map(float, ts)),
                                    proxy=(k == "comm"))
               for k, (xs, ts) in measured.items()}
    res = CalibrationResult(profile=profile, fit_r2=r2s, samples=samples,
                            wall_s=0.1)
    assert res.comm_is_proxy and res.min_r2() > 0.99
    store = ProfileStore(tmp_path)
    entry = store.put_calibration(res, ProfileKey("cpu", (1,), "float32"))
    assert entry.comm_proxy
    assert store.load_profile(entry.name) == profile


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_residual_zero_when_fed_own_predictions():
    """Feeding the timer the model's own predictions yields exactly zero
    residual per phase and per key."""
    timer = StepTimer()
    planner = mk_planner()
    for S in (64, 256):
        plan = planner.plan(S, 4)
        for _ in range(3):
            timer.observe("decode", plan.makespan,
                          predicted_s=plan.makespan, key=("decode", S))
    assert timer.residuals() == {"decode": 0.0}
    assert timer.key_residual(("decode", 64)) == 0.0
    assert timer.phases["decode"].count == 6


def test_residual_signs_and_ewma():
    timer = StepTimer(smoothing=1.0)        # no smoothing: ewma == last
    r = timer.observe("decode", 2.0, predicted_s=1.0, key="k")
    assert r == pytest.approx(1.0)          # 100% slower than modeled
    timer.observe("decode", 0.5, predicted_s=1.0, key="k")
    assert timer.key_residual("k") == pytest.approx(-0.5)
    timer.reset_key("k")
    assert timer.key_residual("k") is None
    # phase aggregate: (2.5 - 2.0) / 2.0
    assert timer.residuals()["decode"] == pytest.approx(0.25)


def test_key_warmup_excludes_first_call_compile():
    """A key's first observation (jit compile) must not poison the EWMA:
    a one-off 100x outlier followed by on-model steps never reads as
    drift."""
    timer = StepTimer(key_warmup=1)
    timer.observe("decode", 100.0, predicted_s=1.0, key="k")  # compile
    assert timer.key_residual("k") is None
    for _ in range(3):
        timer.observe("decode", 1.0, predicted_s=1.0, key="k")
    assert timer.key_residual("k") == 0.0
    assert timer.keys["k"].count == 3


def test_measure_context_manager():
    timer = StepTimer()
    with timer.measure("prefill", predicted_s=1e-9):
        time.sleep(0.01)
    st = timer.phases["prefill"]
    assert st.count == 1 and st.measured_s >= 0.01
    assert st.last_residual > 0          # measured >> 1ns prediction


# ---------------------------------------------------------------------------
# drift-triggered refresh
# ---------------------------------------------------------------------------

class SlowRefreshPolicy:
    """First resolve is instant; every later one sleeps (a 'solver
    hiccup') and bumps r2 so refreshed plans are distinguishable."""

    name = "slowrefresh"

    def __init__(self, delay=0.5):
        self.delay = delay
        self.calls = 0

    def resolve(self, phase, seq_bucket=None, batch_per_device=None, *,
                occupancy=None):
        self.calls += 1
        if self.calls > 1:
            time.sleep(self.delay)
        return Plan(m_a=1, r1=1, m_e=1.0, r2=self.calls, order="AASS",
                    throughput=1.0, makespan=1.0)


def test_synthetic_drift_one_resolve_never_blocks():
    """Acceptance: injected drift triggers EXACTLY one background re-solve
    for the key; lookups keep being served (by the stale plan) while the
    slow re-solve runs; nothing on the observe path ever waits on it."""
    pol = SlowRefreshPolicy(delay=0.5)
    cache = PlanCache(pol)
    monitor = DriftMonitor(cache, threshold=0.3, min_samples=2,
                           recalibrate=False)
    try:
        stale = cache.get("decode", 256, 4)
        assert stale.r2 == 1
        key = ("decode", 256, 4)
        t0 = time.perf_counter()
        warm = monitor.observe(key, measured_s=9.0, predicted_s=1.0)
        first = monitor.observe(key, measured_s=2.0, predicted_s=1.0)
        triggered = monitor.observe(key, measured_s=2.0, predicted_s=1.0)
        observe_walltime = time.perf_counter() - t0
        assert not warm                       # first call: jit-compile
        # warmup, excluded from the EWMA (9.0 would otherwise dominate)
        assert not first                      # below min_samples
        assert triggered                      # breach -> scheduled
        assert observe_walltime < 0.25        # never waits on the solve
        # stale plan keeps serving mid-refresh
        assert cache.get("decode", 256, 4) is stale
        # further drift on the same key while in flight: deduplicated
        assert not monitor.observe(key, measured_s=2.0, predicted_s=1.0)
        monitor.refresher.drain()
        assert pol.calls == 2                 # exactly one re-solve
        assert cache.get("decode", 256, 4).r2 == 2
        assert cache.stats.refreshes == 1
        assert monitor.stats.drift_events == 1
        # episode closed: residual history restarted
        assert monitor.timer.key_residual(key) is None
    finally:
        monitor.close()


def test_no_drift_no_refresh():
    pol = SlowRefreshPolicy()
    cache = PlanCache(pol)
    monitor = DriftMonitor(cache, threshold=0.5, min_samples=1,
                           recalibrate=False)
    try:
        cache.get("decode", 256, 4)
        for _ in range(5):
            assert not monitor.observe(("decode", 256, 4), 1.04, 1.0)
        monitor.refresher.drain()
        assert pol.calls == 1 and cache.stats.refreshes == 0
    finally:
        monitor.close()


def test_drift_recalibration_rescales_planner():
    planner = mk_planner()
    policy = FinDEPPolicy(planner)
    beta0 = planner.hardware.gemm.beta
    assert rescale_policy_hardware(policy, 2.0)
    assert planner.hardware.gemm.beta == pytest.approx(2.0 * beta0)
    assert planner._cache == {}              # memo dropped with the profile
    cache = PlanCache(policy)
    monitor = DriftMonitor(cache, threshold=0.5, min_samples=1,
                           recalibrate=True)
    try:
        plan = cache.get("decode", 256, 4)
        key = ("decode", 256, 4)
        monitor.observe(key, measured_s=3.0 * plan.makespan,
                        predicted_s=plan.makespan)       # key warmup
        assert monitor.observe(key, measured_s=3.0 * plan.makespan,
                               predicted_s=plan.makespan)
        monitor.refresher.drain()
        refreshed = cache.get("decode", 256, 4)
        # same schedule (uniform rescale preserves argmax), honest makespan
        assert (refreshed.m_a, refreshed.r2) == (plan.m_a, plan.r2)
        assert refreshed.makespan == pytest.approx(3.0 * plan.makespan)
    finally:
        monitor.close()


def test_recalibration_refreshes_every_entry():
    """One hardware-wide drift episode corrects everything once: the
    rescale refreshes ALL cached entries and restarts every key's
    residual history, instead of letting each stale key re-breach and
    compound the correction."""
    planner = mk_planner()
    cache = PlanCache(FinDEPPolicy(planner))
    monitor = DriftMonitor(cache, threshold=0.5, min_samples=1,
                           recalibrate=True)
    try:
        pa = cache.get("decode", 256, 4)
        cache.get("decode", 512, 4)
        key = ("decode", 256, 4)
        monitor.observe(key, 3.0 * pa.makespan, pa.makespan)  # warmup
        assert monitor.observe(key, 3.0 * pa.makespan, pa.makespan)
        monitor.refresher.drain()
        assert cache.stats.refreshes == 2          # both entries re-solved
        assert monitor.stats.drift_events == 1     # ... in ONE episode
        for k in (key, ("decode", 512, 4)):
            assert monitor.timer.key_residual(k) is None
        # both cached makespans now predict the 3x-slower hardware
        assert cache.get("decode", 512, 4).makespan > 0
        assert cache.get("decode", 256, 4).makespan == \
            pytest.approx(3.0 * pa.makespan)
    finally:
        monitor.close()


def test_cluster_from_mesh_degenerate_shapes():
    from repro.launch import steps
    full_model = SimpleNamespace(shape={"model": 8}, size=8)
    c = steps.cluster_from_mesh(full_model)      # eg capped below n
    assert c.ag + c.eg <= c.num_devices and c.eg == 7
    with pytest.raises(ValueError, match=">= 2 devices"):
        steps.cluster_from_mesh(SimpleNamespace(shape={"model": 1},
                                                size=1))


def test_refresher_in_flight_dedup_and_errors():
    class Boom:
        name = "boom"

        def resolve(self, *a, **k):
            raise RuntimeError("solver exploded")

    cache = PlanCache(Boom())
    r = PlanRefresher(cache)
    assert r.request(("decode", 1, 1))
    r.drain()
    assert r.failed == 1 and r.completed == 0      # error contained
    r.close()


def test_cache_refresh_forces_planner_resolve():
    """PlanCache.refresh must re-run Algorithm 1, not hit the planner
    memo (the policy's invalidate() hook)."""
    planner = mk_planner()
    cache = PlanCache(FinDEPPolicy(planner))
    cache.get("prefill", 256, 4)
    n = planner.solve_count
    cache.refresh(("prefill", 256, 4))
    assert planner.solve_count == n + 1
    assert cache.stats.refreshes == 1


def test_engine_drift_refresh_end_to_end():
    """Acceptance: a served workload whose measured step times dwarf the
    modeled makespans (the profile under-predicts by orders of magnitude)
    trips drift; re-solves happen in the background and every request
    still finishes."""
    import jax.numpy as jnp
    from repro.runtime import Request, ServingEngine
    hw = PAPER_A6000.scaled(1e-5, name="way_too_fast")
    eng = ServingEngine(CFG, num_slots=2, max_context=128,
                        plan_policy=FinDEPPolicy(mk_planner(hw)),
                        drift_threshold=0.5, drift_min_samples=2,
                        dtype=jnp.float32)
    try:
        rng = np.random.RandomState(0)
        reqs = [Request(prompt=list(rng.randint(0, CFG.vocab_size,
                                                size=rng.randint(4, 30))),
                        max_new_tokens=5) for _ in range(4)]
        for r in reqs:
            eng.submit(r)
        finished = eng.run()
        assert len(finished) == 4
        eng.drift.refresher.drain()
        assert eng.drift.stats.drift_events >= 1
        assert eng.plan_cache.stats.refreshes >= 1
        assert eng.drift.refresher.failed == 0
        res = eng.telemetry.residuals()
        assert res.get("decode") is not None
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# satellite: cost-aware bounded PlanCache
# ---------------------------------------------------------------------------

class TunableLatencyPolicy:
    name = "tunable"

    def __init__(self):
        self.delay = 0.0
        self.calls = 0

    def resolve(self, phase, seq_bucket=None, batch_per_device=None, *,
                occupancy=None):
        self.calls += 1
        if self.delay:
            time.sleep(self.delay)
        return Plan(m_a=1, r1=1, m_e=1.0, r2=self.calls, order="AASS",
                    throughput=1.0, makespan=1.0)


def test_cache_cost_aware_eviction():
    pol = TunableLatencyPolicy()
    cache = PlanCache(pol, capacity=2)
    pol.delay = 0.05
    cache.get("prefill", 64, 1)              # expensive solve ...
    cache.get("prefill", 64, 1)              # ... and reused -> high score
    pol.delay = 0.0
    cache.get("prefill", 128, 1)             # cheap, never reused
    cache.get("prefill", 256, 1)             # third entry: over capacity
    assert len(cache) == 2
    assert cache.stats.evictions == 1
    keys = set(cache.entries())
    assert ("prefill", 64, 1) in keys        # protected by hits x latency
    assert ("prefill", 128, 1) not in keys   # the zero-score victim
    assert ("prefill", 256, 1) in keys       # fresh entry never self-evicts
    # evicted shape re-solves on next sight
    n = pol.calls
    cache.get("prefill", 128, 1)
    assert pol.calls == n + 1


def test_cache_invalidate():
    pol = TunableLatencyPolicy()
    cache = PlanCache(pol)
    cache.get("decode", 64, 1)
    assert cache.invalidate(("decode", 64, 1))
    assert not cache.invalidate(("decode", 64, 1))
    assert cache.stats.invalidations == 1
    cache.get("decode", 64, 1)
    assert pol.calls == 2


def test_cache_unbounded_by_default():
    pol = TunableLatencyPolicy()
    cache = PlanCache(pol)
    for S in range(1, 30):
        cache.get("prefill", S, 1)
    assert len(cache) == 29 and cache.stats.evictions == 0


# ---------------------------------------------------------------------------
# satellite: engine profile knobs + launch policy knobs
# ---------------------------------------------------------------------------

def test_engine_profile_kwarg_retunes_planner():
    import jax.numpy as jnp
    from repro.runtime import ServingEngine
    planner = mk_planner()
    hw = PAPER_A6000.scaled(2.0, name="a6000_x2")
    eng = ServingEngine(CFG, num_slots=1, max_context=64,
                        plan_policy=FinDEPPolicy(planner),
                        profile=hw, dtype=jnp.float32)
    assert planner.hardware is hw
    eng.close()


def test_engine_profile_by_name_from_store(tmp_path):
    import jax.numpy as jnp
    from repro.runtime import ServingEngine
    profile, _, _ = synthetic_profile("stored_for_engine")
    store = ProfileStore(tmp_path)
    store.put(profile, ProfileKey("cpu", (1,), "float32"),
              name="stored_for_engine")
    planner = mk_planner()
    eng = ServingEngine(CFG, num_slots=1, max_context=64,
                        plan_policy=FinDEPPolicy(planner),
                        profile="stored_for_engine", profile_store=store,
                        dtype=jnp.float32)
    assert planner.hardware == profile
    eng.close()


def test_launch_policy_knobs():
    from repro.launch import steps
    mesh = SimpleNamespace(shape={"data": 2, "model": 4}, size=8)
    cluster = steps.cluster_from_mesh(mesh)
    assert (cluster.num_devices, cluster.ag, cluster.eg) == (8, 2, 4)
    plan = steps.resolve_launch_plan(CFG, mesh, "findep", 256,
                                     batch_per_device=4)
    assert plan is not None and plan.r1 * plan.m_a == 4
    # decode mode resolves through the decode phase; named baselines work
    seq = steps.resolve_launch_plan(CFG, mesh, "sequential", 256,
                                    mode="decode", batch_per_device=2)
    assert seq.r2 == 1
    # policy objects pass through untouched
    pol = FinDEPPolicy(mk_planner())
    assert steps.resolve_launch_plan(CFG, mesh, pol, 256,
                                     batch_per_device=4) == \
        pol.resolve("prefill", 256, 4)
    # non-MoE config / no mesh -> no schedule
    dense = get_smoke_config("qwen2-1.5b")
    assert steps.resolve_launch_plan(dense, mesh, "findep", 256) is None
    assert steps.resolve_launch_plan(CFG, None, "findep", 256) is None


def test_launch_policy_with_calibrated_store_profile(tmp_path):
    from repro.launch import steps
    profile, _, _ = synthetic_profile("launch_fit")
    store = ProfileStore(tmp_path)
    store.put(profile, ProfileKey("cpu", (1,), "float32"),
              name="launch_fit")
    mesh = SimpleNamespace(shape={"data": 2, "model": 4}, size=8)
    pol = steps.launch_policy(CFG, mesh, "findep", profile="launch_fit",
                              profile_store=store)
    assert pol.planner.hardware == profile


# ---------------------------------------------------------------------------
# satellite: executor honors the solved m_e granularity
# ---------------------------------------------------------------------------

def test_exec_program_carries_floored_me():
    plan = Plan(m_a=4, r1=2, m_e=3.7, r2=2, order="ASAS",
                throughput=1.0, makespan=1.0)
    prog = plan.exec_program()
    assert (prog.graph.r2, prog.graph.order, prog.graph.m_e) == \
        (2, "ASAS", 3)
    assert prog.graph.r1 == 2          # defaults to the plan's stream split
    assert prog.interleave == "streams"
    tiny = Plan(m_a=1, r1=1, m_e=0.4, r2=1, order="AASS",
                throughput=1.0, makespan=1.0)
    assert tiny.exec_program().graph.m_e == 1
    assert plan.exec_program(streams=4).graph.r1 == 4


def test_expert_capacity_honors_plan_granularity():
    """The executor's capacity request (multiple_of = r2 * m_e) yields
    chunk sizes that are multiples of the solver's modeled m_e and never
    shrinks capacity (no new drops)."""
    from repro.models import moe as moe_lib
    mcfg = CFG.moe
    r2, m_e = 4, 3
    base = moe_lib.expert_capacity(100, mcfg, multiple_of=r2)
    aligned = moe_lib.expert_capacity(100, mcfg, multiple_of=r2 * m_e)
    assert aligned >= base
    assert aligned % (r2 * m_e) == 0
    assert (aligned // r2) % m_e == 0        # per-chunk tokens align to m_e


# ---------------------------------------------------------------------------
# satellite: per-primitive drift attribution (task-tagged residuals)
# ---------------------------------------------------------------------------

def test_exec_schedule_shim_is_gone():
    """PR 5's one-release ``ExecSchedule``/``Plan.exec_schedule()`` shims
    are removed: the executor consumes ``ExecProgram``/``TaskGraph``."""
    import repro.core.solver as solver_mod
    assert not hasattr(solver_mod, "ExecSchedule")
    plan = Plan(m_a=1, r1=1, m_e=1.0, r2=2, order="ASAS",
                throughput=1.0, makespan=1.0)
    assert not hasattr(plan, "exec_schedule")


def test_fit_primitive_scales_recovers_known_scales():
    from repro.profiling import fit_primitive_scales
    true = {"gemm": 1.0, "attn": 1.3, "comm": 2.0}
    rows = []
    comps = [(1.0, 0.5, 0.2), (0.3, 0.2, 1.5), (0.5, 1.0, 0.1),
             (0.9, 0.1, 0.9)]
    for g, a, c in comps:
        rows.append(({"gemm": g, "attn": a, "comm": c},
                     true["gemm"] * g + true["attn"] * a + true["comm"] * c))
    scales = fit_primitive_scales(rows)
    assert scales is not None
    for k, v in true.items():
        assert scales[k] == pytest.approx(v, rel=1e-9), k


def test_fit_primitive_scales_unidentifiable_falls_back():
    from repro.profiling import fit_primitive_scales
    # one composition repeated: rank 1 < 3 active primitives -> None
    row = ({"gemm": 1.0, "attn": 0.5, "comm": 0.2}, 2.0)
    assert fit_primitive_scales([row, row, row]) is None
    # too few rows / no rows
    assert fit_primitive_scales([row]) is None
    assert fit_primitive_scales([]) is None
    # zero-signal primitive keeps scale 1.0 while the rest are fitted
    rows = [({"gemm": 1.0, "attn": 0.0, "comm": 0.5}, 1.0 + 2.0 * 0.5),
            ({"gemm": 0.2, "attn": 0.0, "comm": 1.5}, 0.2 + 2.0 * 1.5)]
    scales = fit_primitive_scales(rows)
    assert scales["attn"] == 1.0
    assert scales["comm"] == pytest.approx(2.0, rel=1e-9)


def test_steptimer_accumulates_breakdown_past_warmup():
    timer = StepTimer(key_warmup=1)
    bd = {"gemm": 0.6, "attn": 0.3, "comm": 0.1}
    timer.observe("decode", 2.0, predicted_s=1.0, key="k", breakdown=bd)
    st = timer.keys["k"]
    assert st.breakdown == {} and st.measured_s == 0.0   # warmup excluded
    timer.observe("decode", 2.0, predicted_s=1.0, key="k", breakdown=bd)
    timer.observe("decode", 2.0, predicted_s=1.0, key="k", breakdown=bd)
    assert st.measured_s == pytest.approx(4.0)
    assert st.predicted_s == pytest.approx(2.0)
    assert st.breakdown["gemm"] == pytest.approx(1.2)
    timer.reset_key("k")
    assert timer.keys["k"].breakdown == {}


def test_drift_per_primitive_rescales_comm_separately():
    """Task-tagged residuals: keys with different gemm/attn/comm
    compositions identify a comm-only slowdown, so the recalibrating
    episode retunes alpha_c/beta_c by ~2x while the compute terms stay
    put (the uniform rescale would have inflated everything)."""
    planner = mk_planner()
    policy = FinDEPPolicy(planner)
    cache = PlanCache(policy)
    monitor = DriftMonitor(cache, threshold=0.2, min_samples=2,
                           recalibrate=True, per_primitive=True)
    hw0 = planner.hardware
    comps = {("decode", 256, 1): (0.8, 0.15, 0.05),
             ("decode", 256, 2): (0.1, 0.1, 0.8),
             ("decode", 256, 4): (0.3, 0.6, 0.1)}
    try:
        for (_, s, b), _comp in comps.items():
            cache.get("decode", s, b)
        # comm runs 2x slower than modeled; compute on time. Observations
        # interleave across keys (as a serving loop's steps do), so by
        # the time one key breaches, all three compositions carry tags.
        for _ in range(4):       # warmup + min_samples + breach
            for key, (g, a, c) in comps.items():
                bd = {"gemm": g, "attn": a, "comm": c}
                monitor.observe(key, g + a + 2.0 * c, 1.0, breakdown=bd)
        monitor.refresher.drain()
        assert monitor.stats.drift_events >= 1
        scales = monitor.stats.last_scales
        assert scales is not None
        assert scales["comm"] == pytest.approx(2.0, rel=1e-6)
        assert scales["gemm"] == pytest.approx(1.0, rel=1e-6)
        assert planner.hardware.comm.beta == \
            pytest.approx(2.0 * hw0.comm.beta, rel=1e-6)
        assert planner.hardware.gemm.beta == \
            pytest.approx(hw0.gemm.beta, rel=1e-6)
    finally:
        monitor.close()


def test_drift_without_tags_falls_back_to_uniform():
    planner = mk_planner()
    cache = PlanCache(FinDEPPolicy(planner))
    monitor = DriftMonitor(cache, threshold=0.5, min_samples=1,
                           recalibrate=True, per_primitive=True)
    hw0 = planner.hardware
    try:
        plan = cache.get("decode", 256, 4)
        key = ("decode", 256, 4)
        monitor.observe(key, 3.0 * plan.makespan, plan.makespan)  # warmup
        assert monitor.observe(key, 3.0 * plan.makespan, plan.makespan)
        monitor.refresher.drain()
        assert monitor.stats.last_scales is None       # uniform fallback
        assert planner.hardware.gemm.beta == \
            pytest.approx(3.0 * hw0.gemm.beta)
        assert planner.hardware.comm.beta == \
            pytest.approx(3.0 * hw0.comm.beta)
    finally:
        monitor.close()


def test_plan_breakdown_flows_through_engine_observe():
    """Solved plans carry the lowered graph's gemm/attn/comm split and
    the engine's observe path forwards it into the timer's key sums."""
    planner = mk_planner()
    plan = planner.plan(256, 4)
    assert plan.breakdown is not None
    assert plan.breakdown.total == pytest.approx(plan.makespan, rel=1e-9)
    timer = StepTimer(key_warmup=0)
    timer.observe("decode", plan.makespan, predicted_s=plan.makespan,
                  key="k", breakdown=plan.breakdown.as_dict())
    st = timer.keys["k"]
    assert sum(st.breakdown.values()) == pytest.approx(plan.makespan)


# ---------------------------------------------------------------------------
# satellite: periodic background re-calibration (stale stored profile)
# ---------------------------------------------------------------------------

def _stub_calibration(name="recal"):
    profile, r2s, _ = synthetic_profile(name)
    samples = {k: SimpleNamespace(as_xt=lambda: ([1.0, 2.0], [1e-3, 2e-3]),
                                  proxy=(k == "comm"))
               for k in ("gemm", "attn", "comm")}
    return CalibrationResult(profile=profile, fit_r2=r2s, samples=samples,
                             wall_s=0.01)


def test_periodic_recalibrator_runs_when_stale(tmp_path):
    from repro.profiling import PeriodicRecalibrator
    planner = mk_planner()
    cache = PlanCache(FinDEPPolicy(planner))
    cache.get("prefill", 256, 4)
    store = ProfileStore(tmp_path)
    result = _stub_calibration()
    recal = PeriodicRecalibrator(
        cache, store, key=ProfileKey("cpu", (1,), "float32"),
        max_age_s=3600.0, calibrate_fn=lambda: result,
        poll_interval_s=0.0)
    try:
        assert recal.due()                      # empty store = stale
        assert recal.maybe_recalibrate()
        recal.drain()
        assert recal.recalibrations == 1
        assert store.get_for_key(recal.key).profile == result.profile
        assert planner.hardware == result.profile     # policy reprofiled
        assert cache.stats.refreshes == 1             # entry re-solved
        # fresh profile: not due, no second run
        assert not recal.due()
        assert not recal.maybe_recalibrate()
        # force path still dedups through the worker and reruns
        assert recal.maybe_recalibrate(force=True)
        recal.drain()
        assert recal.recalibrations == 2
    finally:
        recal.close()


def test_engine_wires_periodic_recalibration(tmp_path):
    """ServingEngine(recalibrate_max_age_s=...) polls the store each step
    without ever blocking on a microbenchmark."""
    from repro.runtime.engine import ServingEngine
    store = ProfileStore(tmp_path)
    profile, _, _ = synthetic_profile("fresh")
    store.put(profile, ProfileKey.for_host(None), name="fresh")
    eng = ServingEngine(CFG, num_slots=1, max_context=64,
                        plan_policy=FinDEPPolicy(mk_planner()),
                        profile_store=store, recalibrate_max_age_s=3600.0)
    try:
        assert eng.recalibrator is not None
        assert not eng.step()                  # idle; poll must be a no-op
        assert eng.recalibrator.recalibrations == 0
        # stale store -> a forced pass recalibrates in the background
        eng.recalibrator.calibrate_fn = lambda: _stub_calibration("eng")
        assert eng.recalibrator.maybe_recalibrate(force=True)
        eng.recalibrator.drain()
        assert eng.recalibrator.recalibrations == 1
        assert eng.plan_policy.planner.hardware == \
            _stub_calibration("eng").profile
    finally:
        eng.close()


def test_recalibrating_episode_never_compounds_while_refresh_in_flight():
    """A second breach arriving before the first episode's re-solves land
    must NOT rescale again: the stale entries still serve old predictions,
    so re-rescaling would compound the correction (2x -> 4x -> ...)."""
    pol = SlowRefreshPolicy(delay=0.5)
    cache = PlanCache(pol)
    planner_like = SimpleNamespace(
        hardware=PAPER_A6000, set_hardware=lambda hw: scales_applied.append(hw))
    pol.planner = planner_like
    scales_applied = []
    monitor = DriftMonitor(cache, threshold=0.3, min_samples=1,
                           recalibrate=True, per_primitive=False)
    try:
        cache.get("decode", 256, 4)
        key = ("decode", 256, 4)
        monitor.observe(key, 2.0, 1.0)                      # warmup
        assert monitor.observe(key, 2.0, 1.0)               # episode 1
        # refresh in flight (slow solver): further breaches on OTHER
        # observations must not start a second rescaling episode
        for _ in range(3):
            assert not monitor.observe(key, 2.0, 1.0)
        assert len(scales_applied) == 1                     # ONE rescale
        monitor.refresher.drain()
        assert monitor.stats.drift_events == 1
    finally:
        monitor.close()
