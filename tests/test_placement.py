"""Expert placement subsystem: load telemetry, hot-expert replication,
skew-aware planning (ROADMAP item 2).

Pure-python pieces (tracker EWMA, greedy rebalancer, skew summaries,
plan-cache keys, REP lowering) run in-process; the replicated DEP
executor's bit-parity and drop accounting run under a 4-device subprocess
mesh like tests/test_dep_distributed.py.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.perf_model import (PAPER_A6000, DepClusterConfig,
                                   DepModelSpec, build_stage_models)
from repro.core.planner import FinDEPPlanner, PlannerConfig
from repro.core.taskgraph import (EXP, GATE, REP, LoweringSpec, lower,
                                  lower_exec)
from repro.placement import (UNIFORM_SKEW, ExpertLoadTracker, Placement,
                             SkewSummary, capacity_scale, max_rank_load,
                             modeled_exp_time, rank_loads, rebalance,
                             zipf_loads)
from repro.sched import PlanCache
from repro.sched.policy import FinDEPPolicy

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def _planner(**kw):
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    return FinDEPPlanner(cfg, DepClusterConfig(8, 3, 5), PAPER_A6000,
                         PlannerConfig(mem_cap_samples=8, **kw))


# ---------------------------------------------------------------------------
# tracker: EWMA math + zipf loads
# ---------------------------------------------------------------------------

def test_zipf_loads_shape_and_skew():
    f = zipf_loads(16, s=1.2)
    assert f.shape == (16,)
    assert abs(f.sum() - 1.0) < 1e-12
    assert f[0] == f.max() and f[-1] == f.min()
    perm = list(reversed(range(16)))
    g = zipf_loads(16, s=1.2, permutation=perm)
    assert g[perm[0]] == f[0]


def test_tracker_ewma_matches_hand_rolled():
    tr = ExpertLoadTracker(4, smoothing=0.25)
    h1 = np.array([8.0, 4.0, 2.0, 2.0])
    h2 = np.array([1.0, 1.0, 1.0, 1.0])
    tr.observe(h1)
    np.testing.assert_allclose(tr.layer_loads(0), h1 / h1.sum())
    tr.observe(h2)
    want = 0.25 * (h2 / h2.sum()) + 0.75 * (h1 / h1.sum())
    np.testing.assert_allclose(tr.layer_loads(0), want)
    # [L, E] observations track per layer; aggregate() is the layer mean
    tr2 = ExpertLoadTracker(4)
    tr2.observe(np.stack([h1, h2]))
    assert tr2.layers == 2
    np.testing.assert_allclose(
        tr2.aggregate(), (h1 / h1.sum() + h2 / h2.sum()) / 2)
    # normalization: prefill (many tokens) and decode (few) weigh equally
    tr3 = ExpertLoadTracker(4, smoothing=0.5)
    tr3.observe(h1 * 100)
    tr3.observe(h1)
    np.testing.assert_allclose(tr3.layer_loads(0), h1 / h1.sum())


def test_tracker_imbalance_and_reset():
    tr = ExpertLoadTracker(4)
    assert tr.imbalance() == pytest.approx(1.0)   # uniform before data
    tr.observe([10.0, 0.0, 0.0, 0.0])
    assert tr.imbalance() == pytest.approx(4.0)   # one expert owns all
    tr.reset()
    assert tr.observations == 0 and tr.layers == 0


def test_tracker_rejects_bad_shapes():
    tr = ExpertLoadTracker(4)
    with pytest.raises(ValueError):
        tr.observe(np.zeros(5))
    with pytest.raises(ValueError):
        ExpertLoadTracker(4, smoothing=0.0)


# ---------------------------------------------------------------------------
# rebalancer: greedy LPT + hot replication
# ---------------------------------------------------------------------------

def test_rebalance_reduces_modeled_exp_time():
    loads = zipf_loads(16, s=1.2)
    uniform = Placement.uniform(16, 4)
    t_uniform = modeled_exp_time(uniform, loads, 1.0)
    lpt = rebalance(loads, 4)
    t_lpt = modeled_exp_time(lpt, loads, 1.0)
    hot = rebalance(loads, 4, replicate_hot_k=2, epoch=1)
    t_hot = modeled_exp_time(hot, loads, 1.0)
    # zipf's hot head lands in rank 0's contiguous block: LPT flattens
    # it, replication removes it from the EG lane entirely
    assert t_lpt < t_uniform
    assert t_hot < t_lpt
    assert hot.replicated == (0, 1)               # the two hottest ids
    assert hot.epoch == 1 and hot.hot_experts == 2


def test_rebalance_keeps_uniform_slot_counts():
    loads = zipf_loads(12, s=1.5)
    pl = rebalance(loads, 3, replicate_hot_k=2)
    counts = [0] * 3
    for r in pl.assignment:
        counts[r] += 1
    assert counts == [4, 4, 4]
    # perm is a true permutation realizing the assignment
    perm = pl.perm
    assert sorted(perm) == list(range(12))
    per = pl.experts_per_rank
    for e, r in enumerate(pl.assignment):
        assert perm[e] // per == r
    # deterministic: same inputs, same placement
    assert rebalance(loads, 3, replicate_hot_k=2) == pl


def test_rebalance_flat_loads_is_noop_quality():
    loads = np.ones(8) / 8
    pl = rebalance(loads, 4)
    assert max_rank_load(pl, loads) == pytest.approx(0.25)
    assert pl.hot_experts == 0
    np.testing.assert_allclose(rank_loads(pl, loads), 0.25)


def test_placement_uniform_identity():
    pl = Placement.uniform(8, 4)
    assert pl.is_uniform
    assert pl.perm == tuple(range(8))
    lpt = rebalance(zipf_loads(8, 1.2), 4)
    assert not lpt.is_uniform


def test_placement_validation():
    with pytest.raises(ValueError):
        Placement(num_experts=4, num_ranks=2, assignment=(0, 0, 0, 1))
    with pytest.raises(ValueError):
        Placement(num_experts=4, num_ranks=3, assignment=(0, 1, 2, 0))
    with pytest.raises(ValueError):
        Placement(num_experts=4, num_ranks=2, assignment=(0, 0, 1, 1),
                  replicated=(1, 1))


# ---------------------------------------------------------------------------
# skew summary + capacity scale
# ---------------------------------------------------------------------------

def test_skew_summary_quantized_and_hashable():
    tr = ExpertLoadTracker(8)
    tr.observe(zipf_loads(8, 1.2))
    s1 = tr.summary(num_ranks=2)
    tr.observe(zipf_loads(8, 1.2))   # same regime -> same fingerprint
    s2 = tr.summary(num_ranks=2)
    assert s1 == s2 and hash(s1) == hash(s2)
    assert s1.kappa % 0.125 == 0 and s1.max_expert % 0.125 == 0
    assert not s1.is_uniform
    assert UNIFORM_SKEW.is_uniform
    # no observations: uniform fingerprint carrying the placement epoch
    empty = ExpertLoadTracker(8).summary(
        placement=rebalance(zipf_loads(8), 2, replicate_hot_k=1, epoch=3))
    assert empty.epoch == 3 and empty.hot_k == 1


def test_skew_summary_replication_semantics():
    tr = ExpertLoadTracker(8)
    tr.observe(zipf_loads(8, 1.2))
    hot = rebalance(tr.aggregate(), 2, replicate_hot_k=2, epoch=1)
    s = tr.summary(placement=hot)
    # replicated experts carry their tokens off the EG lane
    assert s.rho > 0.0
    assert s.kappa < tr.summary(num_ranks=2).kappa
    assert s.hot_k == 2 and s.epoch == 1


def test_capacity_scale():
    assert capacity_scale(None, 1.25) == 1.0
    assert capacity_scale(UNIFORM_SKEW, 1.25) == 1.0
    hot = SkewSummary(max_expert=2.5)
    assert capacity_scale(hot, 1.25) == pytest.approx(2.0)
    assert capacity_scale(hot, 4.0) == 1.0        # headroom already covers


# ---------------------------------------------------------------------------
# replica-aware lowering (REP tasks) + placement epoch identity
# ---------------------------------------------------------------------------

def test_lowering_zero_replicas_is_structurally_legacy():
    spec = LoweringSpec(T=2)
    base = lower_exec(2, "ASAS")
    assert base.hot_experts == 0 and base.placement_epoch == 0
    assert not base.tasks_of(REP)
    from repro.core.solver import Plan
    plan = Plan(m_a=1, r1=1, m_e=1, r2=2, order="ASAS",
                throughput=0, makespan=0)
    assert plan.exec_graph() is lower_exec(2, "ASAS")   # cached identity
    g0 = lower(plan, spec)
    g1 = lower(plan, spec, hot_experts=0, placement_epoch=0)
    assert g0 is g1


def test_lowering_rep_tasks_depend_on_gate():
    g = lower_exec(2, "ASAS", hot_experts=1, placement_epoch=5)
    all_tasks = g.tasks
    reps = g.tasks_of(REP)
    assert reps, "hot_experts > 0 must emit REP tasks"
    for _, t in reps:
        deps = [all_tasks[d].kind for d in t.deps]
        assert GATE in deps
    assert g.hot_experts == 1 and g.placement_epoch == 5
    # epoch changes identity (fresh jit key) but not structure
    g2 = lower_exec(2, "ASAS", hot_experts=1, placement_epoch=6)
    assert g2 is not g and g2 != g
    assert len(g2.tasks) == len(g.tasks)
    assert [t.kind for t in g2.tasks] == [t.kind for t in g.tasks]
    # executor walk: REP runs after its gate
    kinds = [t.kind for t in g.exec_walk()]
    assert REP in kinds
    assert kinds.index(REP) > kinds.index(GATE)


def test_exec_graph_placement_epoch_keys():
    from repro.core.solver import Plan
    plan = Plan(m_a=1, r1=1, m_e=1, r2=2, order="AASS",
                throughput=0, makespan=0)
    a = plan.exec_graph(hot_experts=1, placement_epoch=1)
    b = plan.exec_graph(hot_experts=1, placement_epoch=2)
    c = plan.exec_graph()
    assert a != b and a != c
    assert hash(a) != hash(c)


# ---------------------------------------------------------------------------
# skew-aware planning: cost model + plan-cache keys + invalidation
# ---------------------------------------------------------------------------

def test_stage_models_uniform_skew_is_legacy():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    spec = DepModelSpec.from_model_config(cfg, 512)
    cluster = DepClusterConfig(8, 3, 5)
    legacy = build_stage_models(PAPER_A6000, spec, cluster)
    uni = build_stage_models(PAPER_A6000, spec, cluster, skew=UNIFORM_SKEW)
    assert uni.t_e == legacy.t_e and uni.t_c == legacy.t_c
    assert uni.t_rep is None
    skewed = build_stage_models(
        PAPER_A6000, spec, cluster,
        skew=SkewSummary(kappa=1.5, rho=0.25, max_expert=2.0, hot_k=1,
                         epoch=1))
    # worst-rank EXP inflates; comm deflates by the hot fraction
    assert skewed.t_e.beta == pytest.approx(legacy.t_e.beta * 1.5)
    assert skewed.t_c.beta == pytest.approx(legacy.t_c.beta * 0.75)
    assert skewed.t_rep is not None and skewed.t_rep.beta > 0


def test_planner_memoizes_per_skew():
    plr = _planner()
    p_uni = plr.plan(512, 8)
    assert plr.plan(512, 8, skew=UNIFORM_SKEW) is p_uni
    skew = SkewSummary(kappa=1.5, rho=0.25, max_expert=2.0, hot_k=1,
                       epoch=1)
    p_skew = plr.plan(512, 8, skew=skew)
    n = plr.solve_count
    assert plr.plan(512, 8, skew=skew) is p_skew
    assert plr.solve_count == n


def test_plan_cache_skew_keys_and_epoch_invalidation():
    cache = PlanCache(FinDEPPolicy(_planner()))
    p0 = cache.get("prefill", 512, 8)
    s1 = SkewSummary(kappa=1.5, rho=0.25, max_expert=2.0, hot_k=1, epoch=1)
    p1 = cache.get("prefill", 512, 8, skew=s1)
    assert ("prefill", 512, 8) in cache.entries()
    assert ("prefill", 512, 8, s1) in cache.entries()
    # uniform skew normalizes to the legacy key (no duplicate entry)
    assert cache.get("prefill", 512, 8, skew=UNIFORM_SKEW) is p0
    assert len(cache) == 2
    # refresh parses the skew-suffixed key back apart
    cache.refresh(("prefill", 512, 8, s1))
    assert cache.stats.refreshes == 1
    # an epoch bump keys NEW entries; the engine invalidates stale ones
    s2 = SkewSummary(kappa=1.0, rho=0.25, max_expert=2.0, hot_k=1, epoch=2)
    cache.get("prefill", 512, 8, skew=s2)
    for key in list(cache.entries()):
        tail = key[-1]
        if isinstance(tail, SkewSummary) and tail.epoch != 2:
            cache.invalidate(key)
    assert ("prefill", 512, 8, s1) not in cache.entries()
    assert ("prefill", 512, 8, s2) in cache.entries()
    assert p1 is not None


# ---------------------------------------------------------------------------
# dropped-token accounting (single device)
# ---------------------------------------------------------------------------

def test_moe_dispatch_counts_dropped_tokens():
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import moe as moe_lib
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    key = jax.random.PRNGKey(0)
    params = moe_lib.moe_init(key, cfg.d_model, cfg.moe, 4)
    x = jax.random.normal(key, (6, 8, cfg.d_model), jnp.float32)
    y, aux, stats = moe_lib.moe_apply_capacity(params, x, cfg.moe, 4,
                                               return_stats=True)
    assert stats.load.shape == (4,)
    # every assignment is either kept or dropped
    total = 6 * 8 * cfg.moe.top_k
    assert float(stats.load.sum()) == pytest.approx(total)
    assert 0 <= int(stats.dropped) <= total
    # ample capacity drops nothing
    import dataclasses
    roomy = dataclasses.replace(cfg.moe, capacity_factor=16.0)
    _, _, st2 = moe_lib.moe_apply_capacity(params, x, roomy, 4,
                                           return_stats=True)
    assert int(st2.dropped) == 0
    # the default return stays the legacy 2-tuple, bit-identical
    y2, aux2 = moe_lib.moe_apply_capacity(params, x, cfg.moe, 4)
    assert bool(jnp.array_equal(y, y2)) and bool(jnp.array_equal(aux, aux2))


def test_expert_capacity_scale():
    from repro.configs import get_smoke_config
    from repro.models import moe as moe_lib
    mcfg = get_smoke_config("qwen2-moe-a2.7b").moe
    base = moe_lib.expert_capacity(64, mcfg, 4)
    assert moe_lib.expert_capacity(64, mcfg, 4, scale=1.0) == base
    assert moe_lib.expert_capacity(64, mcfg, 4, scale=2.0) == 2 * base
    # scale < 1 never shrinks below the configured sizing
    assert moe_lib.expert_capacity(64, mcfg, 4, scale=0.5) == base


# ---------------------------------------------------------------------------
# replicated DEP executor: bit-parity + drop regression (subprocess mesh)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_replicated_executor_bit_parity_and_drops():
    out = run_sub(textwrap.dedent("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import moe as moe_lib
        from repro.models.transformer import ExecutionContext
        from repro.core import dep
        from repro.core.solver import Plan
        from repro.placement import Placement, rebalance
        mesh = jax.make_mesh((2,2), ("data","model"))
        cfg = get_smoke_config("qwen2-moe-a2.7b")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
        key = jax.random.PRNGKey(1)
        params = moe_lib.moe_init(key, cfg.d_model, cfg.moe, 4)
        x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
        ctx = ExecutionContext(mesh=mesh, moe_impl="dep")
        plan = Plan(m_a=1, r1=1, m_e=1, r2=2, order="ASAS",
                    throughput=0, makespan=0)
        with mesh:
            y_ref, _ = jax.jit(lambda p, x: dep.moe_apply_dep(
                p, x, cfg.moe, ctx, 4, plan=plan.exec_graph()))(params, x)

        # uniform placement takes the legacy path bit-identically
        uni = Placement.uniform(4, 2)
        with mesh:
            y_uni, _ = jax.jit(lambda p, x: dep.moe_apply_dep(
                p, x, cfg.moe, ctx, 4, plan=plan.exec_graph(),
                placement=uni))(params, x)
        assert bool(jnp.array_equal(y_ref, y_uni)), "uniform placement"
        print("ok uniform")

        # replicated placement on engine-permuted weights: bit-identical
        # to the unreplicated walk (each hot row's FFN is the same einsum
        # rows either way)
        pl = rebalance([8.0, 1.0, 1.0, 1.0], 2, replicate_hot_k=1, epoch=1)
        assert pl.hot_experts == 1
        gather = jnp.asarray(np.argsort(np.asarray(pl.perm)))
        pp = dict(params)
        pp["experts"] = jax.tree.map(lambda a: a[gather], params["experts"])
        g = plan.exec_graph(hot_experts=1, placement_epoch=pl.epoch)
        with mesh:
            y_rep, _, st_rep = jax.jit(lambda p, x: dep.moe_apply_dep(
                p, x, cfg.moe, ctx, 4, plan=g, placement=pl,
                return_stats=True))(pp, x)
        assert bool(jnp.array_equal(y_ref, y_rep)), float(
            jnp.max(jnp.abs(y_ref - y_rep)))
        print("ok replicated")

        # drop regression at TIGHT equal capacity: the replicated walk
        # never drops more than the unreplicated one (hot tokens bypass
        # the capacity-bound dispatch buffers)
        tight = dataclasses.replace(cfg.moe, capacity_factor=1.0)
        with mesh:
            _, _, st_base = jax.jit(lambda p, x: dep.moe_apply_dep(
                p, x, tight, ctx, 4, plan=plan.exec_graph(),
                return_stats=True))(params, x)
            _, _, st_hot = jax.jit(lambda p, x: dep.moe_apply_dep(
                p, x, tight, ctx, 4, plan=g, placement=pl,
                return_stats=True))(pp, x)
        base_d, hot_d = int(st_base.dropped), int(st_hot.dropped)
        assert hot_d <= base_d, (hot_d, base_d)
        # stats stay logical: load histograms agree independent of layout
        assert bool(jnp.array_equal(st_base.load, st_hot.load))
        print("ok drops", base_d, hot_d)
    """))
    assert "ok uniform" in out and "ok replicated" in out \
        and "ok drops" in out


@pytest.mark.slow
def test_engine_rebalance_end_to_end():
    out = run_sub(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.core.perf_model import PAPER_A6000, DepClusterConfig
        from repro.core.planner import FinDEPPlanner, PlannerConfig
        from repro.placement import SkewSummary
        from repro.runtime.engine import ServingEngine
        from repro.runtime.request import Request
        from repro.sched import FinDEPPolicy
        mesh = jax.make_mesh((2,2), ("data","model"))
        cfg = get_smoke_config("qwen2-moe-a2.7b")
        def make(**kw):
            plr = FinDEPPlanner(cfg, DepClusterConfig(4, 2, 2),
                                PAPER_A6000,
                                PlannerConfig(mem_cap_samples=8))
            return ServingEngine(cfg, num_slots=4, max_context=64,
                                 seed=0, mesh=mesh,
                                 plan_policy=FinDEPPolicy(plr), **kw)
        def serve(eng, n=3, new=4):
            for i in range(n):
                eng.submit(Request(prompt=list(range(2, 10 + i)),
                                   max_new_tokens=new))
            done = eng.run()
            return sorted([tuple(r.output) for r in done])

        # telemetry on (no placement yet) == telemetry off, bit-identical
        base = serve(make())
        tracked_eng = make(track_expert_load=True)
        tracked = serve(tracked_eng)
        assert base == tracked, (base, tracked)
        assert tracked_eng.load_tracker.observations > 0
        assert tracked_eng.stats.dropped_tokens >= 0
        tracked_eng.close()
        print("ok engine parity")

        # forced rebalance mid-serve: epoch bumps, replica executes,
        # stale-epoch cache entries are invalidated, serving continues
        eng = make(replicate_hot_k=1, rebalance_threshold=10.0)
        serve(eng, n=2, new=3)
        pl = eng.rebalance_now()
        assert pl is not None and pl.hot_experts == 1 and pl.epoch >= 1
        serve(eng, n=2, new=3)
        for key in eng.resolved_plans():
            tail = key[-1]
            if isinstance(tail, SkewSummary):
                assert tail.epoch == pl.epoch, key
        assert eng.expert_load()["hot_experts"] == 1.0
        eng.close()
        print("ok engine rebalance")
    """))
    assert "ok engine parity" in out and "ok engine rebalance" in out


# ---------------------------------------------------------------------------
# engine weight permutation (no mesh needed)
# ---------------------------------------------------------------------------

def test_apply_placement_permutes_weights_and_composes():
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.runtime.engine import ServingEngine
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    eng = ServingEngine(cfg, num_slots=2, max_context=32)
    moe_layers = [i for i, layer in enumerate(eng.params["layers"])
                  if "moe" in layer]
    orig = {i: jax.tree.map(jnp.copy,
                            eng.params["layers"][i]["moe"]["experts"])
            for i in moe_layers}

    def check(pl):
        for i in moe_layers:
            cur = eng.params["layers"][i]["moe"]["experts"]
            for name in ("gate", "up", "down"):
                for e in range(pl.num_experts):
                    want = orig[i][name][e]
                    got = cur[name][pl.perm[e]]
                    assert bool(jnp.array_equal(want, got)), (i, name, e)

    p1 = rebalance([8.0, 1.0, 2.0, 1.0], 2, replicate_hot_k=1, epoch=1)
    eng._apply_placement(p1)
    assert eng.placement is p1
    check(p1)
    # second epoch composes on top of the first permutation
    p2 = rebalance([1.0, 1.0, 1.0, 9.0], 2, replicate_hot_k=1, epoch=2)
    eng._apply_placement(p2)
    check(p2)
    eng.close()
