"""Model substrate: decode==forward consistency, scan==loop, chunked CE,
flash==sdpa, across families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.models import build_model, frontend_shape
from repro.models.transformer import ExecutionContext, chunked_softmax_xent

KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _setup(arch, **model_kw):
    cfg = get_smoke_config(arch)
    ctx = ExecutionContext(moe_impl="dense")
    model = build_model(cfg, ctx=ctx, dtype=jnp.float32, **model_kw)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    fs = frontend_shape(cfg, ShapeConfig("t", S, B, "t"))
    extra = jax.random.normal(KEY, fs, jnp.float32) if fs else None
    return cfg, model, params, tokens, extra


@pytest.mark.parametrize("arch", [
    "qwen2-1.5b", "starcoder2-3b", "xlstm-1.3b", "recurrentgemma-9b",
    "deepseek-v2-lite", "qwen2-moe-a2.7b", "internvl2-1b",
    "seamless-m4t-large-v2", "granite-moe-1b-a400m",
])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == full-forward logits (exact caches)."""
    cfg, model, params, tokens, extra = _setup(arch)
    memory = model.encode(params, extra) if cfg.is_encoder_decoder else None
    ee = None if cfg.is_encoder_decoder else extra
    logits_full, _, _ = model.forward(params, tokens, extra_embeds=ee,
                                      memory=memory)
    half = S // 2
    lg, caches = model.prefill(params, tokens[:, :half], extra_embeds=ee,
                               memory=memory, seq_budget=S)
    off = (extra.shape[1] if (ee is not None and cfg.family == "vlm") else 0)
    errs = [float(jnp.max(jnp.abs(lg[:, -1] - logits_full[:, half - 1 + off])))]
    for t in range(half, S):
        lg, caches = model.decode_step(params, tokens[:, t:t + 1], caches,
                                       memory=memory)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, t + off]))))
    assert max(errs) < 1e-4, (arch, max(errs))


def test_sliding_window_ring_cache_decode():
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"),
                              attention="sliding", sliding_window=8)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_full, _, _ = model.forward(params, tokens)
    half = S // 2
    lg, caches = model.prefill(params, tokens[:, :half], seq_budget=S)
    errs = []
    for t in range(half, S):
        lg, caches = model.decode_step(params, tokens[:, t:t + 1], caches)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, t]))))
    assert max(errs) < 1e-4, max(errs)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "qwen2-moe-a2.7b",
                                  "xlstm-1.3b", "recurrentgemma-9b"])
def test_scan_layers_equals_loop(arch):
    cfg = get_smoke_config(arch)
    m_loop = build_model(cfg, dtype=jnp.float32)
    m_scan = build_model(cfg, scan_layers=True, dtype=jnp.float32)
    p_loop = m_loop.init(KEY)
    gsize = len(m_scan.group)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[p_loop["layers"][g * gsize:(g + 1) * gsize]
          for g in range(m_scan.num_groups)])
    p_scan = {k: v for k, v in p_loop.items() if k != "layers"}
    p_scan["layer_groups"] = stacked
    tokens = jax.random.randint(KEY, (B, 16), 0, cfg.vocab_size)
    l1, _, a1 = m_loop.forward(p_loop, tokens)
    l2, _, a2 = m_scan.forward(p_scan, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-5)
    assert float(abs(a1 - a2)) < 1e-6


def test_chunked_ce_equals_naive():
    cfg, model, params, tokens, _ = _setup("qwen2-1.5b")
    for chunk in (4, 8, 23, 64):
        loss_c = model.loss(params, tokens, ce_chunk=chunk)
        logits, _, _ = model.forward(params, tokens)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        naive = -jnp.take_along_axis(lp, tokens[:, 1:][..., None],
                                     -1).mean()
        assert float(abs(loss_c - naive)) < 1e-5, chunk


def test_chunked_ce_grads_match():
    cfg, model, params, tokens, _ = _setup("qwen2-1.5b")
    g1 = jax.grad(lambda p: model.loss(p, tokens, ce_chunk=8))(params)
    g2 = jax.grad(lambda p: model.loss(p, tokens, ce_chunk=1024))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_flash_impl_matches_xla():
    cfg = get_smoke_config("qwen2-1.5b")
    m1 = build_model(cfg, ctx=ExecutionContext(attn_impl="xla"),
                     dtype=jnp.float32)
    m2 = build_model(cfg, ctx=ExecutionContext(attn_impl="flash"),
                     dtype=jnp.float32)
    p = m1.init(KEY)
    tok = jax.random.randint(KEY, (2, 128), 0, cfg.vocab_size)
    l1, _, _ = m1.forward(p, tok)
    l2, _, _ = m2.forward(p, tok)
    assert float(jnp.max(jnp.abs(l1 - l2))) < 5e-5


def test_chunked_attention_matches_sdpa():
    from repro.models.attention import (_causal_mask, _flash_sdpa_xla,
                                        _sdpa)
    ks = jax.random.split(KEY, 3)
    Bs, Ss, H, Kv, D = 2, 200, 8, 2, 32
    q = jax.random.normal(ks[0], (Bs, Ss, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (Bs, Ss, Kv, D), jnp.float32)
    v = jax.random.normal(ks[2], (Bs, Ss, Kv, D), jnp.float32)
    pos = jnp.arange(Ss)
    for win in (None, 37):
        ref = _sdpa(q, k, v, _causal_mask(pos, pos, win))
        out = _flash_sdpa_xla(q, k, v, pos, pos, win, q_chunk=64,
                              k_chunk=48)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_rglru_kernel_path_matches_scan():
    from repro.models import rglru as rl
    cfg = get_smoke_config("recurrentgemma-9b")
    p = rl.rglru_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 128, cfg.d_model), jnp.float32)
    y1, s1 = rl.rglru_apply(p, cfg, x)
    y2, s2 = rl.rglru_apply(p, cfg, x, use_kernel=True)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-5
    assert float(jnp.max(jnp.abs(s1["h"] - s2["h"]))) < 1e-5
