import os
import sys

# Tests run single-device CPU (the dry-run sets its own 512-device flag in
# a separate process; do NOT set xla_force_host_platform_device_count here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
