"""FinDEP Algorithm 1: optimality vs brute force, theorem validation,
solver latency (< 1 s claim)."""
import time

import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import DepClusterConfig
from repro.core.analytic import ORDER_ASAS, ORDERS
from repro.core.baselines import best_pppipe, naive_plan
from repro.core.perf_model import (PAPER_A6000, TPU_V5E, AlphaBeta,
                                   DepModelSpec, HardwareProfile,
                                   build_stage_models)
from repro.core.solver import (get_max_r1, max_r2, solve, solve_brute_force,
                               solve_r2)


def models_for(S=2048, n_shared=2, hw=PAPER_A6000, ag=3, eg=5, E=64,
               top_k=6):
    spec = DepModelSpec(S=S, M=2048, H=1408, E=E, top_k=top_k,
                        n_shared=n_shared, shared_H=1408, T=8, n_heads=16,
                        d_k=128, d_v=128)
    cluster = DepClusterConfig(num_devices=ag + eg, ag=ag, eg=eg)
    return build_stage_models(hw, spec, cluster), spec.T


@pytest.mark.parametrize("n_shared,hw", [(2, PAPER_A6000), (0, PAPER_A6000),
                                         (2, TPU_V5E)])
def test_solver_matches_brute_force(n_shared, hw):
    models, T = models_for(n_shared=n_shared, hw=hw)
    plan, _ = solve(models, T, mem_cap_samples=12, objective="simulate",
                    r2_cap=12, r1_cap=12)
    bf = solve_brute_force(models, T, 12, objective="simulate", r2_cap=12,
                           r1_cap=12)
    assert plan.throughput == pytest.approx(bf.throughput, rel=1e-9)


def test_hybrid_at_least_as_good_as_analytic_choice():
    models, T = models_for()
    p_h, _ = solve(models, T, 16, objective="hybrid")
    p_a, _ = solve(models, T, 16, objective="analytic")
    # evaluate both final plans under the exact simulator
    from repro.core.solver import _throughput
    tps_h, _ = _throughput(models, T, p_h.m_a, p_h.r1, p_h.r2, p_h.order,
                           "simulate")
    tps_a, _ = _throughput(models, T, p_a.m_a, p_a.r1, p_a.r2, p_a.order,
                           "simulate")
    assert tps_h >= tps_a - 1e-9


def test_theorem1_2_monotone_in_ma():
    """Throughput (with per-m_a optimized r2) increases with m_a (Thm 1-2,
    Table 3)."""
    models, T = models_for()
    prev = 0.0
    for m_a in (1, 2, 4, 8, 16):
        r2, tps, _ = solve_r2(models, T, m_a, r1=1, order=ORDER_ASAS,
                              objective="analytic")
        assert tps >= prev - 1e-9, (m_a, tps, prev)
        prev = tps


def test_theorem3_monotone_in_r1():
    """Throughput non-decreasing in r1 (Thm 3, Table 4)."""
    models, T = models_for()
    prev = 0.0
    for r1 in (1, 2, 4, 8):
        r2, tps, _ = solve_r2(models, T, m_a=2, r1=r1, order=ORDER_ASAS,
                              objective="analytic")
        assert tps >= prev - 1e-9, (r1, tps, prev)
        prev = tps


def test_theorem4_unimodal_in_r2():
    """Eq. 17 convex in 1/r2 => throughput unimodal in integer r2."""
    models, T = models_for()
    from repro.core.solver import _throughput
    tps = [_throughput(models, T, 8, 2, r2, ORDER_ASAS, "analytic")[0]
           for r2 in range(1, max_r2(models, 8, 32) + 1)]
    peak = tps.index(max(tps))
    assert all(tps[i] <= tps[i + 1] + 1e-12 for i in range(peak)), tps
    assert all(tps[i] >= tps[i + 1] - 1e-12 for i in range(peak, len(tps) - 1))


def test_findep_beats_or_ties_pppipe_and_naive():
    """The paper's headline ordering: FinDEP >= best PPPipe >= naive.
    Holds structurally: FinDEP's search space contains PPPipe's schedules
    relaxed (shared no longer blocks a2e) and naive is PPPipe(r1=1)."""
    for hw in (PAPER_A6000, TPU_V5E):
        for n_shared in (0, 2):
            models, T = models_for(n_shared=n_shared, hw=hw)
            fd, _ = solve(models, T, 16, objective="simulate", r2_cap=8,
                          r1_cap=16)
            pp = best_pppipe(models, T, 16, r1_cap=16)
            nv = naive_plan(models, T, 16)
            assert fd.throughput >= pp.throughput * (1 - 1e-9)
            assert pp.throughput >= nv.throughput * (1 - 1e-9)


def test_solver_under_one_second():
    """Paper §5.4: 'the solver completes in under 1 second'."""
    models, T = models_for()
    t0 = time.perf_counter()
    plan, stats = solve(models, T, mem_cap_samples=64, objective="hybrid")
    dt = time.perf_counter() - t0
    assert dt < 1.0, dt
    assert plan.throughput > 0


def test_get_max_r1_memory_constraint():
    assert get_max_r1(4, 16) == 4
    assert get_max_r1(5, 16) == 3
    assert get_max_r1(17, 16) == 0
    assert get_max_r1(1, 16, r1_cap=8) == 8


def test_fixed_batch_mode():
    """Online mode: r1 * m_a must cover the arrived batch exactly."""
    models, T = models_for()
    plan, _ = solve(models, T, 16, objective="analytic", fixed_batch=12)
    assert plan.m_a * plan.r1 == 12


@given(seq=st.sampled_from([512, 1024, 2048, 4096, 8192]),
       n_shared=st.integers(0, 4), eg=st.integers(2, 7))
@settings(max_examples=20, deadline=None)
def test_solver_feasible_across_workloads(seq, n_shared, eg):
    models, T = models_for(S=seq, n_shared=n_shared, ag=8 - eg, eg=eg)
    plan, _ = solve(models, T, 8, objective="analytic")
    assert plan.r1 * plan.m_a <= 8
    assert plan.r2 >= 1 and plan.m_e >= 1
    assert plan.order in ORDERS
