"""Task-graph IR: golden topology, legacy-simulator parity, executor
walk order, per-primitive breakdowns, and executor bit-parity."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs.base import DepClusterConfig
from repro.core.analytic import ORDER_AASS, ORDER_ASAS, StageTimes
from repro.core.perf_model import (PAPER_A6000, TPU_V5E, DepModelSpec,
                                   build_stage_models)
from repro.core.simulator import simulate_dep
from repro.core.solver import Plan, plan_breakdown, solve
from repro.core.taskgraph import (A2E, ATTN, E2A, EXP, GATE, SHARED,
                                  LoweringSpec, TaskCosts, ascii_gantt,
                                  lower, lower_exec, schedule)

ST = StageTimes(t_a=0.013, t_s=0.012, t_e=0.011, t_c=0.004)


def _plan(r1, r2, order, m_e=1):
    return Plan(m_a=1, r1=r1, r2=r2, m_e=m_e, order=order,
                throughput=0.0, makespan=0.0)


def _models(S=2048, n_shared=2, hw=PAPER_A6000, ag=3, eg=5):
    """Table 5/7-style stage models (DeepSeek-V2-Lite dimensions on the
    paper's testbed-A cluster split)."""
    spec = DepModelSpec(S=S, M=2048, H=1408, E=64, top_k=6,
                        n_shared=n_shared, shared_H=1408, T=8, n_heads=16,
                        d_k=128, d_v=128)
    cluster = DepClusterConfig(num_devices=ag + eg, ag=ag, eg=eg)
    return build_stage_models(hw, spec, cluster), spec.T


# ---------------------------------------------------------------------------
# Golden topology
# ---------------------------------------------------------------------------


def test_golden_topology_asas():
    """ASAS: shared expert split into r2 segments per (layer, mb), one at
    each chunk boundary; a2e independent of shared (FinDEP rule 7)."""
    T, r1, r2 = 2, 2, 3
    g = lower(_plan(r1, r2, ORDER_ASAS), LoweringSpec(T=T))
    counts = {k: len(g.tasks_of(k)) for k in (ATTN, GATE, SHARED, A2E,
                                              EXP, E2A)}
    assert counts == {ATTN: T * r1, GATE: T * r1,
                      SHARED: T * r1 * r2,               # r2 segments
                      A2E: T * r1 * r2, EXP: T * r1 * r2,
                      E2A: T * r1 * r2}
    assert g.shared_segments == r2
    # every shared segment boundary 0..r2-1 appears once per (t, i)
    for t in range(T):
        for i in range(r1):
            bounds = sorted(task.chunk for _, task in
                            g.tasks_of(SHARED, layer=t, mb=i))
            assert bounds == list(range(r2))
    # FinDEP: no a2e task depends on any SHARED task
    shared_ids = {idx for idx, _ in g.tasks_of(SHARED)}
    for idx, task in g.tasks_of(A2E):
        assert not (set(task.deps) & shared_ids), (idx, task)
    g.validate()


def test_golden_topology_aass():
    """AASS: one whole-batch shared task per (layer, mb) at boundary 0."""
    T, r1, r2 = 2, 3, 4
    g = lower(_plan(r1, r2, ORDER_AASS), LoweringSpec(T=T))
    assert len(g.tasks_of(SHARED)) == T * r1
    assert all(task.chunk == 0 for _, task in g.tasks_of(SHARED))
    assert g.shared_segments == 1
    # AG lane order within a layer: all ATTN before all SHARED
    ag0 = [t for t in g.tasks if t.layer == 0 and t.resource == "AG"]
    first_shared = next(i for i, t in enumerate(ag0) if t.kind == SHARED)
    assert all(t.kind != ATTN for t in ag0[first_shared:])
    g.validate()


def test_golden_topology_blocking_and_no_shared():
    """naive/PPPipe lowering: a2e waits on the last shared segment;
    has_shared=False drops SHARED (and the dep)."""
    g = lower(_plan(2, 1, ORDER_ASAS),
              LoweringSpec(T=1, shared_blocks_a2e=True))
    shared_ids = {idx for idx, _ in g.tasks_of(SHARED)}
    for _, task in g.tasks_of(A2E):
        assert set(task.deps) & shared_ids, task
    g2 = lower(_plan(2, 2, ORDER_ASAS), LoweringSpec(T=2, has_shared=False))
    assert not g2.tasks_of(SHARED) and not g2.has_shared
    g2.validate()


def test_cross_layer_deps():
    """A(t+1, i) depends on (t, i)'s last e2a AND last shared segment."""
    T, r1, r2 = 3, 2, 2
    g = lower(_plan(r1, r2, ORDER_ASAS), LoweringSpec(T=T))
    for t in range(1, T):
        for i in range(r1):
            (a_idx, a_task), = g.tasks_of(ATTN, layer=t, mb=i)
            dep_kinds = {g.tasks[d].kind for d in a_task.deps}
            assert dep_kinds == {E2A, SHARED}
            for d in a_task.deps:
                assert g.tasks[d].layer == t - 1
                assert g.tasks[d].mb == i


def test_lowering_is_cached():
    """Equal (plan, spec) lower to the SAME object (lru-cached) — jit
    static-arg reuse never retraces for an identical schedule."""
    a = lower(_plan(2, 3, ORDER_ASAS), LoweringSpec(T=4))
    b = lower(_plan(2, 3, ORDER_ASAS), LoweringSpec(T=4))
    assert a is b
    assert lower_exec(3, ORDER_ASAS, 2) is lower_exec(3, ORDER_ASAS, 2)
    assert hash(a) == hash(b)
    assert a != lower(_plan(2, 3, ORDER_AASS), LoweringSpec(T=4))


# ---------------------------------------------------------------------------
# Executor walk order
# ---------------------------------------------------------------------------


def test_exec_walk_order_asas():
    walk = lower_exec(2, ORDER_ASAS).exec_walk()
    assert [(t.kind, t.chunk) for t in walk] == [
        (GATE, 0), (A2E, 0), (SHARED, 0), (EXP, 0), (E2A, 0),
        (A2E, 1), (SHARED, 1), (EXP, 1), (E2A, 1)]


def test_exec_walk_order_aass():
    walk = lower_exec(2, ORDER_AASS).exec_walk()
    assert [(t.kind, t.chunk) for t in walk] == [
        (GATE, 0), (A2E, 0), (SHARED, 0), (EXP, 0), (E2A, 0),
        (A2E, 1), (EXP, 1), (E2A, 1)]


def test_exec_graph_collapses_plan_identity():
    """Plans that differ only in modeled throughput/batching share one
    exec graph (bounded retraces)."""
    p1 = Plan(m_a=4, r1=2, m_e=3.7, r2=2, order=ORDER_ASAS,
              throughput=10.0, makespan=1.0)
    p2 = Plan(m_a=8, r1=1, m_e=3.2, r2=2, order=ORDER_ASAS,
              throughput=99.0, makespan=2.0)
    assert p1.exec_graph() is p2.exec_graph()
    assert p1.exec_graph().m_e == 3


# ---------------------------------------------------------------------------
# Parity: generic graph scheduler vs the legacy simulator recurrence
# ---------------------------------------------------------------------------


def _legacy_simulate_dep(st, T, r1, r2, order="ASAS",
                         shared_blocks_a2e=False):
    """The pre-refactor hand-written forward recurrence (verbatim)."""
    has_shared = st.t_s > 0.0
    if not has_shared:
        seq = [("A", i) for i in range(r1)]
    elif order == "ASAS":
        seq = [p for i in range(r1) for p in (("A", i), ("S", i))]
    else:
        seq = ([("A", i) for i in range(r1)]
               + [("S", i) for i in range(r1)])
    ag_free = a2e_free = eg_free = e2a_free = 0.0
    prev_ready = [0.0] * r1
    busy = {k: 0.0 for k in ("AG", "A2E", "EG", "E2A")}
    a_end = [0.0] * r1
    s_end = [0.0] * r1
    for _t in range(T):
        for kind, i in seq:
            if kind == "A":
                end = max(ag_free, prev_ready[i]) + st.t_a
                busy["AG"] += st.t_a
                a_end[i] = end
            else:
                end = max(ag_free, a_end[i]) + st.t_s
                busy["AG"] += st.t_s
                s_end[i] = end
            ag_free = end
        if not has_shared:
            for i in range(r1):
                s_end[i] = a_end[i]
        e2a_last = [0.0] * r1
        for i in range(r1):
            gate = s_end[i] if (shared_blocks_a2e and has_shared) \
                else a_end[i]
            for _j in range(r2):
                a2e_free = max(a2e_free, gate) + st.t_c
                busy["A2E"] += st.t_c
                eg_free = max(eg_free, a2e_free) + st.t_e
                busy["EG"] += st.t_e
                e2a_free = max(e2a_free, eg_free) + st.t_c
                busy["E2A"] += st.t_c
            e2a_last[i] = e2a_free
        for i in range(r1):
            prev_ready[i] = max(e2a_last[i], s_end[i])
    return max(max(e2a_last), max(s_end)), busy


@pytest.mark.parametrize("hw", [PAPER_A6000, TPU_V5E])
@pytest.mark.parametrize("S", [1024, 2048, 4096])
def test_parity_table_shapes(S, hw):
    """Graph-scheduler makespan == legacy simulator on the Table 5/7
    shapes (DeepSeek dims, both testbeds, solved plans per shape)."""
    models, T = _models(S=S, hw=hw)
    plan, _ = solve(models, T, mem_cap_samples=4, r1_cap=4, r2_cap=32)
    for r1, r2, order in [(plan.r1, plan.r2, plan.order), (1, 1, "ASAS"),
                          (4, 1, "ASAS"), (2, 8, "AASS"), (4, 4, "ASAS")]:
        st = StageTimes.from_models(models, plan.m_a,
                                    models.me_from_ma(plan.m_a, r2))
        legacy_ms, legacy_busy = _legacy_simulate_dep(st, T, r1, r2, order)
        res = simulate_dep(st, T, r1, r2, order=order)
        assert res.makespan == pytest.approx(legacy_ms, rel=1e-12), \
            (S, r1, r2, order)
        for k, v in legacy_busy.items():
            assert res.busy[k] == pytest.approx(v, rel=1e-12), k


def test_parity_randomized(rng):
    """Randomized stage times / shapes / lowering flags."""
    for _ in range(300):
        st = StageTimes(t_a=rng.uniform(1e-4, 5e-2),
                        t_s=float(rng.choice([0.0,
                                              rng.uniform(1e-4, 5e-2)])),
                        t_e=rng.uniform(1e-4, 5e-2),
                        t_c=rng.uniform(1e-5, 5e-2))
        T = int(rng.randint(1, 6))
        r1 = int(rng.randint(1, 6))
        r2 = int(rng.randint(1, 6))
        order = str(rng.choice(["ASAS", "AASS"]))
        blk = bool(rng.randint(0, 2))
        legacy_ms, _ = _legacy_simulate_dep(st, T, r1, r2, order, blk)
        res = simulate_dep(st, T, r1, r2, order=order,
                           shared_blocks_a2e=blk)
        assert res.makespan == pytest.approx(legacy_ms, rel=1e-12)


def test_makespan_fastpath_parity(rng):
    """``simulate_makespan`` (vectorized lane recurrence, the solver's
    simulate objective) agrees with the generic list scheduler across
    randomized stage times, shapes, orders, and lowering flags."""
    from repro.core.simulator import simulate_makespan
    for _ in range(300):
        st = StageTimes(t_a=rng.uniform(1e-4, 5e-2),
                        t_s=float(rng.choice([0.0,
                                              rng.uniform(1e-4, 5e-2)])),
                        t_e=rng.uniform(1e-4, 5e-2),
                        t_c=rng.uniform(1e-5, 5e-2))
        T = int(rng.randint(1, 6))
        r1 = int(rng.randint(1, 6))
        r2 = int(rng.randint(1, 6))
        order = str(rng.choice(["ASAS", "AASS"]))
        blk = bool(rng.randint(0, 2))
        exact = simulate_dep(st, T, r1, r2, order=order,
                             shared_blocks_a2e=blk).makespan
        fast = simulate_makespan(st, T, r1, r2, order=order,
                                 shared_blocks_a2e=blk)
        assert fast == pytest.approx(exact, rel=1e-9), \
            (T, r1, r2, order, blk)


def test_scheduler_invariants():
    """Per-resource mutual exclusion; makespan = max interval end; the
    scheduled SimResult exposes the underlying graph schedule."""
    res = simulate_dep(ST, 4, 3, 2, order=ORDER_ASAS,
                       record_intervals=True)
    for name, iv in res.intervals.items():
        for (s1, e1), (s2, e2) in zip(iv, iv[1:]):
            assert s2 >= e1 - 1e-12, (name, (s1, e1), (s2, e2))
    ends = [e for iv in res.intervals.values() for _, e in iv]
    assert res.makespan == pytest.approx(max(ends))
    assert res.scheduled is not None
    assert res.scheduled.makespan == res.makespan


# ---------------------------------------------------------------------------
# Per-primitive breakdowns (telemetry tags)
# ---------------------------------------------------------------------------


def test_breakdown_classes_sum_to_busy():
    g = lower(_plan(2, 3, ORDER_ASAS), LoweringSpec(T=4))
    res = schedule(g, TaskCosts.from_stage_times(ST))
    bd = res.breakdown()
    total_busy = sum(res.busy.values())
    assert bd.total == pytest.approx(total_busy, rel=1e-12)
    # comm class == both link lanes; attn == t_a tasks
    assert bd.comm == pytest.approx(res.busy["A2E"] + res.busy["E2A"])
    assert bd.attn == pytest.approx(4 * 2 * ST.t_a)
    assert bd.gemm == pytest.approx(res.busy["EG"] + 4 * 2 * ST.t_s)


def test_solver_attaches_normalized_breakdown():
    models, T = _models()
    plan, _ = solve(models, T, mem_cap_samples=4, r1_cap=4, r2_cap=16)
    assert plan.breakdown is not None
    assert plan.breakdown.total == pytest.approx(plan.makespan, rel=1e-9)
    # reproducible from the public helper
    again = plan_breakdown(models, T, plan)
    assert again.as_dict() == pytest.approx(plan.breakdown.as_dict())


def test_baseline_plans_carry_breakdown():
    from repro.core.baselines import (best_pppipe, eps_pipeline_plan,
                                      naive_plan)
    models, T = _models()
    for p in (naive_plan(models, T, 4), best_pppipe(models, T, 4, r1_cap=4),
              eps_pipeline_plan(models, T, 4)):
        assert p.breakdown is not None
        assert p.breakdown.total == pytest.approx(p.makespan, rel=1e-9)


def test_ascii_gantt_renders():
    g = lower(_plan(2, 2, ORDER_ASAS), LoweringSpec(T=2))
    out = ascii_gantt(schedule(g, TaskCosts.from_stage_times(ST)), width=60)
    lines = out.splitlines()
    assert len(lines) == 5 and lines[0].lstrip().startswith("AG")
    assert "E" in lines[2] and ">" in lines[1] and "<" in lines[3]


# ---------------------------------------------------------------------------
# Executor bit-parity: graph walker vs the pre-refactor loop (subprocess,
# 4 virtual devices; plain Mesh — no AxisType dependence)
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_executor_bit_parity_graph_vs_legacy_loop():
    """The graph walker emits the SAME op sequence as the pre-refactor
    hand-rolled chunk loop: sequence-mode outputs are bit-identical."""
    out = run_sub(textwrap.dedent("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import moe as moe_lib
        from repro.models.layers import mlp_apply
        from repro.models.transformer import ExecutionContext
        from repro.core import dep
        from repro.core.solver import Plan
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        # ---- the pre-refactor executor loop, verbatim ----------------
        def legacy_shared_schedule(order, shared_fn, shared_x, r2):
            if shared_fn is None:
                return lambda j: None
            if order == "ASAS":
                seg = shared_x.shape[0] // r2
                def emit(j):
                    lo = j * seg
                    hi = (shared_x.shape[0] if j == r2 - 1
                          else (j + 1) * seg)
                    return shared_fn(shared_x[lo:hi])
            else:
                def emit(j):
                    return shared_fn(shared_x) if j == 0 else None
            return emit

        def legacy_chunked(buffers, expert_params, axis, r2,
                           shared_fn=None, shared_x=None, order="AASS"):
            E_pad, C_loc, M = buffers.shape
            chunk = C_loc // r2
            def a2e(buf):
                return jax.lax.all_to_all(buf, axis, split_axis=0,
                                          concat_axis=1, tiled=True)
            def e2a(out):
                return jax.lax.all_to_all(out, axis, split_axis=1,
                                          concat_axis=0, tiled=True)
            emit = legacy_shared_schedule(order, shared_fn, shared_x, r2)
            outs, shared_parts = [], []
            for j in range(r2):
                buf = jax.lax.dynamic_slice_in_dim(buffers, j * chunk,
                                                   chunk, 1)
                dispatched = a2e(buf)
                part = emit(j)
                if part is not None:
                    shared_parts.append(part)
                outs.append(e2a(moe_lib.expert_ffn(expert_params,
                                                   dispatched)))
            shared_out = (jnp.concatenate(shared_parts, axis=0)
                          if shared_parts else None)
            return jnp.concatenate(outs, axis=1), shared_out
        # --------------------------------------------------------------

        mesh = jax.sharding.Mesh(
            np.array(jax.devices()).reshape(2, 2), ("data", "model"))
        cfg = get_smoke_config("qwen2-moe-a2.7b")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
        key = jax.random.PRNGKey(1)
        params = moe_lib.moe_init(key, cfg.d_model, cfg.moe, 4)
        x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
        ctx = ExecutionContext(mesh=mesh, moe_impl="dep")
        mcfg = cfg.moe
        E_pad = 4
        for r2, order, m_e in [(1, "AASS", 1), (2, "ASAS", 1),
                               (4, "AASS", 1), (2, "AASS", 3),
                               (4, "ASAS", 1)]:
            plan = Plan(m_a=1, r1=1, m_e=m_e, r2=r2, order=order,
                        throughput=0, makespan=0)
            with mesh:
                y_new, _ = jax.jit(lambda p, xx: dep.moe_apply_dep(
                    p, xx, mcfg, ctx, E_pad,
                    plan=plan.exec_graph()))(params, x)

            # legacy reference through an identical shard_map harness
            def local(x_loc, router_loc, experts_loc, shared_loc):
                Bl, Sl, M = x_loc.shape
                xf = x_loc.reshape(-1, M)
                cap = moe_lib.expert_capacity(xf.shape[0], mcfg, E_pad,
                                              multiple_of=r2 * m_e)
                info = moe_lib.moe_dispatch({"router": router_loc}, xf,
                                            mcfg, cap, E_pad)
                shared_fn = lambda xs: mlp_apply(shared_loc, xs)
                out, shared_out = legacy_chunked(
                    info.buffers, experts_loc, "model", r2,
                    shared_fn=shared_fn, shared_x=xf, order=order)
                y = moe_lib.moe_combine(info, out, xf.shape[0],
                                        x_loc.dtype)
                if shared_out is not None:
                    y = y + shared_out
                aux = jax.lax.psum(info.aux, ("data", "model")) / 4
                return y.reshape(Bl, Sl, M), aux

            in_spec = P("data", "model", None)
            with mesh:
                y_old, _ = jax.jit(shard_map(
                    local, mesh=mesh,
                    in_specs=(in_spec,
                              jax.tree.map(lambda _: P(),
                                           params["router"]),
                              jax.tree.map(lambda _: P("model", None,
                                                       None),
                                           params["experts"]),
                              jax.tree.map(lambda _: P(),
                                           params["shared"])),
                    out_specs=(in_spec, P()),
                    check_rep=False))(x, params["router"],
                                      params["experts"], params["shared"])
            diff = float(jnp.max(jnp.abs(y_new - y_old)))
            assert diff == 0.0, (r2, order, m_e, diff)
            print("bitpar ok", r2, order, m_e)
    """))
    assert out.count("bitpar ok") == 5


# ---------------------------------------------------------------------------
# Stream-aware lowering: exec_streams / exec_interleaved / priority hints
# ---------------------------------------------------------------------------


def test_exec_streams_groups_walk_by_mb():
    from repro.core.taskgraph import ExecProgram
    g = lower_exec(2, ORDER_ASAS, r1=3)
    streams = g.exec_streams()
    assert len(streams) == 3
    shape0 = [(t.kind, t.chunk) for t in streams[0]]
    for i, s in enumerate(streams):
        assert all(t.mb == i for t in s)
        assert [(t.kind, t.chunk) for t in s] == shape0
    # the "off" program is exactly the streams run back-to-back
    off = ExecProgram(g, interleave="off").walk()
    assert off == tuple(t for s in streams for t in s)


def test_exec_interleaved_is_dep_safe_and_interleaves():
    from repro.core.taskgraph import ExecProgram
    g = lower_exec(2, ORDER_ASAS, r1=3)
    off = ExecProgram(g, interleave="off").walk()
    inter = ExecProgram(g, interleave="streams").walk()
    # same task multiset, genuinely reordered across streams: some
    # later-stream task is emitted before an earlier stream retires
    key = lambda t: (t.mb, t.kind, t.chunk)
    assert sorted(map(key, inter)) == sorted(map(key, off))
    mbs = [t.mb for t in inter]
    assert mbs != sorted(mbs), "streams were not interleaved"
    # emission respects every dependency edge (positions via identity
    # on the graph's task list)
    pos = {}
    for p, t in enumerate(inter):
        pos[next(i for i, u in enumerate(g.tasks) if u is t)] = p
    for i, t in enumerate(g.tasks):
        if i in pos:
            for d in t.deps:
                if d in pos:
                    assert pos[d] < pos[i], (d, i)


def test_exec_interleaved_rejects_bad_hints():
    g = lower_exec(2, ORDER_ASAS, r1=2)
    with pytest.raises(ValueError, match="hints length"):
        g.exec_interleaved(hints=(0, 1, 2))
    n = len(g.tasks)
    reverse = tuple(range(n - 1, -1, -1))   # dep-inverting priority
    with pytest.raises(ValueError, match="dep-consistent"):
        g.exec_interleaved(hints=reverse)


def test_priority_hints_rank_scheduled_starts():
    g = lower_exec(2, ORDER_ASAS, r1=2)
    sched = schedule(g, TaskCosts.from_stage_times(ST))
    hints = sched.priority_hints()
    assert sorted(hints) == list(range(len(g.tasks)))
    order = sorted(range(len(hints)), key=lambda i: hints[i])
    starts = [sched.starts[i] for i in order]
    assert starts == sorted(starts)


def test_exec_program_static_arg_semantics():
    from repro.core.taskgraph import ExecProgram
    g = lower_exec(2, ORDER_ASAS, 3, r1=2)
    p = ExecProgram(g, interleave="streams")
    assert hash(p) == hash(ExecProgram(g, interleave="streams"))
    assert p != ExecProgram(g, interleave="off")
    assert p.streams == 2
    # capacity alignment is the full (stream, chunk, m_e) grid in BOTH
    # modes — that equality is what makes them bit-identical
    assert p.capacity_multiple == 2 * 2 * 3
    assert ExecProgram(g, interleave="off").capacity_multiple == 2 * 2 * 3
    with pytest.raises(ValueError, match="interleave"):
        ExecProgram(g, interleave="sideways")


def test_stream_serial_deps_and_major_order():
    from repro.core.taskgraph import (stream_major_order,
                                      stream_serial_deps)
    g = lower_exec(2, ORDER_ASAS, r1=3)
    extra = stream_serial_deps(g)
    firsts = {}
    for i, t in enumerate(g.tasks):
        firsts.setdefault(t.mb, i)
    # one serialization point per stream after the first
    assert set(extra) == {firsts[1], firsts[2]}
    for mb, first in firsts.items():
        if mb == 0:
            continue
        dep_tasks = [g.tasks[d] for d in extra[first]]
        assert all(t.mb == mb - 1 for t in dep_tasks)
        # one "last task" per lane the previous stream used
        lanes = {t.resource for t in dep_tasks}
        assert len(dep_tasks) == len(lanes)
    order = stream_major_order(g)
    assert sorted(order) == list(range(len(g.tasks)))
    mbs = [g.tasks[i].mb for i in order]
    assert mbs == sorted(mbs)
