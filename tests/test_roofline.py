"""Roofline machinery: HLO collective parsing, wire-byte formulas,
scan corrections."""
import pytest

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.roofline.analysis import (parse_collectives, scan_corrections,
                                     _shape_bytes)


def test_shape_bytes():
    assert _shape_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _shape_bytes("f32[2,3,4]") == 96
    assert _shape_bytes("pred[8]") == 8
    assert _shape_bytes("f32[]") == 4


HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,128]{1,0} parameter(0)
  %ag = bf16[64,128]{1,0} all-gather(bf16[16,128]{1,0} %p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[32,32]{1,0} all-reduce(f32[32,32]{1,0} %p0x), replica_groups={{0,1}}, to_apply=%add
  %rs = bf16[4,128]{1,0} reduce-scatter(bf16[16,128]{1,0} %p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = bf16[16,128]{1,0} all-to-all(bf16[16,128]{1,0} %p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = bf16[16,128]{1,0} collective-permute(bf16[16,128]{1,0} %p0), source_target_pairs={{0,1},{1,0}}
  %ars = f32[32,32]{1,0} all-reduce-start(f32[32,32]{1,0} %p0x), replica_groups={{0,1}}
  %ard = f32[32,32]{1,0} all-reduce-done(f32[32,32]{1,0} %ars)
}
"""


def test_parse_collectives_counts_and_bytes():
    stats = parse_collectives(HLO)
    assert stats.counts["all-gather"] == 1
    assert stats.counts["all-reduce"] == 2          # sync + -start (not -done)
    assert stats.counts["reduce-scatter"] == 1
    assert stats.counts["all-to-all"] == 1
    assert stats.counts["collective-permute"] == 1
    # all-gather: result 64*128*2 * (3/4)
    assert stats.wire_bytes["all-gather"] == pytest.approx(
        64 * 128 * 2 * 0.75)
    # all-reduce: 2 * operand * (1/2), twice
    assert stats.wire_bytes["all-reduce"] == pytest.approx(
        2 * (2 * 32 * 32 * 4 * 0.5))
    # reduce-scatter: operand * 3/4
    assert stats.wire_bytes["reduce-scatter"] == pytest.approx(
        16 * 128 * 2 * 0.75)
    # collective-permute: full operand
    assert stats.wire_bytes["collective-permute"] == pytest.approx(
        16 * 128 * 2)


def test_iota_replica_groups():
    hlo = ('%ag = bf16[64,128]{1,0} all-gather(bf16[16,128]{1,0} %x), '
           'replica_groups=[16,16]<=[256], dimensions={0}')
    stats = parse_collectives(hlo)
    assert stats.wire_bytes["all-gather"] == pytest.approx(
        64 * 128 * 2 * (15 / 16))


def test_scan_corrections_attention_only_when_chunked():
    cfg = get_config("qwen2-1.5b")
    short = scan_corrections(cfg, SHAPES["train_4k"], 16, "train")
    assert short["flops"] > 0          # 4096 > 2048 -> chunked attention
    dec = scan_corrections(cfg, SHAPES["decode_32k"], 16, "decode")
    assert dec["flops"] == 0.0         # decode: S == 1, no scans


def test_scan_corrections_ssm_dominant():
    cfg = get_config("xlstm-1.3b")
    c = scan_corrections(cfg, SHAPES["prefill_32k"], 16, "prefill")
    assert c["flops"] > 0 and c["bytes"] > 0
    # the mLSTM matrix-state traffic dominates its flops (memory-bound)
    assert c["bytes"] > c["flops"] * 0.2


def test_hybrid_no_time_scan_correction():
    """RG-LRU uses associative_scan (unrolled) — only the attention layers
    of the hybrid need correcting."""
    cfg = get_config("recurrentgemma-9b")
    c = scan_corrections(cfg, SHAPES["train_4k"], 16, "train")
    dense = get_config("qwen2-1.5b")
    # correction present (local attention layers) but no mlstm/slstm term
    assert c["flops"] > 0


MODERN_HLO = """
  %ar = f32[32,32]{1,0} all-reduce-start(%p0x), replica_groups={{0,1}}
  %a2a = bf16[16,128]{1,0} all-to-all(%p0), replica_groups={{0,1,2,3}}
  %cp = bf16[16,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %rs = bf16[4,128]{1,0} reduce-scatter(%p1), replica_groups={{0,1,2,3}}
"""


def test_parse_modern_hlo_untyped_operands():
    """Post-optimization HLO prints operands without inline types; bytes
    must be inferred from the result type."""
    stats = parse_collectives(MODERN_HLO)
    assert stats.wire_bytes["all-reduce"] == pytest.approx(
        2 * 32 * 32 * 4 * 0.5)
    assert stats.wire_bytes["all-to-all"] == pytest.approx(
        16 * 128 * 2 * 0.75)
    assert stats.wire_bytes["collective-permute"] == pytest.approx(
        16 * 128 * 2)
    # reduce-scatter operand = result * N
    assert stats.wire_bytes["reduce-scatter"] == pytest.approx(
        4 * 128 * 2 * 4 * 0.75)
