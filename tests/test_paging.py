"""Paged KV cache with shared-prefix reuse (repro.runtime.paging).

Pool/prefix level: BlockPool free-list + refcount lifecycle, sha256 chain
keys, reclaimable parking and LRU reclaim, refcounting under interleaved
frees.

Kernel level: the block-table Pallas decode mode is BIT-identical to the
dense kernel at matching block size on shuffled physical page layouts
(same blocks streamed in the same order => same flash accumulation), and
executed-block counts still scale with ceil(length/bs).

Manager level: paged ``merge_prefill`` scatters prefill rows into pages
bit-exactly; gathering a slot's page chain reproduces the dense cache
row; prefix-cache hits skip the copy but read back identical KV.

Engine level: a paged engine decodes token-identically to a dense engine
(both attn impls; the dense run pins ``decode_bc`` to the page size for
kernel-blocking parity), eviction/re-admission round-trips leak no pages,
preemption under a deliberately tiny pool re-queues and completes every
request, and watermark hysteresis gates admission.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, paged_decode_attention_pallas)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                gather_pages,
                                                paged_decode_attention_ref)
from repro.runtime import (BlockPool, PagedKVCacheManager, PrefixCache,
                           Request, RequestState, ServingEngine, chunk_keys)
from repro.runtime.kv import KVCacheManager

KEY = jax.random.PRNGKey(11)
BS = 16   # page size (min TPU lane tile)


def smoke_cfg(**kw):
    base = dict(name="paging-smoke", family="dense", num_layers=2,
                d_model=64, num_heads=4, num_kv_heads=2, ffn_dim=128,
                vocab_size=128, head_dim=16)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(autouse=True)
def _check_ledger_invariants(monkeypatch):
    """Every pool/manager any test in this module constructs (including
    the ones buried inside a ServingEngine) is invariant-checked at
    teardown: refcount leaks and double frees fail the scenario that
    caused them, not a later test as pool exhaustion."""
    pools, managers = [], []
    orig_pool, orig_mgr = BlockPool.__init__, PagedKVCacheManager.__init__

    def pool_init(self, *a, **kw):
        orig_pool(self, *a, **kw)
        pools.append(self)

    def mgr_init(self, *a, **kw):
        orig_mgr(self, *a, **kw)
        managers.append(self)

    monkeypatch.setattr(BlockPool, "__init__", pool_init)
    monkeypatch.setattr(PagedKVCacheManager, "__init__", mgr_init)
    yield
    owned = {id(kv.pool) for kv in managers}
    for kv in managers:
        kv.check_invariants()                 # includes kv.pool
    for pool in pools:
        if id(pool) not in owned:
            pool.check_invariants()


# ---------------------------------------------------------------------------
# BlockPool / PrefixCache units
# ---------------------------------------------------------------------------

class TestBlockPool:
    def test_alloc_free_roundtrip(self):
        pool = BlockPool(6, BS)
        assert pool.usable == 5
        pages = [pool.alloc() for _ in range(5)]
        assert pages == [1, 2, 3, 4, 5]       # deterministic low-first
        assert pool.alloc() is None
        assert pool.used_count() == 5
        for p in pages:
            assert pool.release(p) == 0
            pool.free(p)
        assert pool.free_count() == 5
        assert pool.frees == 5 and pool.allocs == 5

    def test_refcounts(self):
        pool = BlockPool(4, BS)
        p = pool.alloc()
        pool.retain(p)
        assert pool.ref(p) == 2
        assert pool.release(p) == 1           # still referenced: no free
        assert pool.release(p) == 0
        pool.free(p)
        assert pool.alloc() == p              # back on the free list

    def test_scratch_page_reserved(self):
        pool = BlockPool(3, BS)
        assert 0 not in [pool.alloc(), pool.alloc()]
        with pytest.raises(AssertionError):
            pool.free(0)

    def test_adopt_revives_reclaimed(self):
        pool = BlockPool(3, BS)
        p = pool.alloc()
        pool.release(p)       # refcount 0, NOT freed (caller parks it)
        pool.adopt(p)
        assert pool.ref(p) == 1


class TestPrefixCache:
    def test_chain_keys_commit_to_prefix(self):
        a = chunk_keys([1, 2, 3, 4, 5, 6], 2)
        b = chunk_keys([1, 2, 3, 4, 9, 9], 2)
        assert len(a) == 3
        assert a[:2] == b[:2] and a[2] != b[2]
        # partial tail chunks get no key
        assert len(chunk_keys([1, 2, 3], 2)) == 1
        assert chunk_keys([], 2) == []

    def test_park_and_reclaim_lru(self):
        pc = PrefixCache()
        ka, kb = chunk_keys([1, 2], 2)[0], chunk_keys([3, 4], 2)[0]
        pc.insert(ka, 5)
        pc.insert(kb, 6)
        pc.on_released(5)
        pc.on_released(6)
        pc.on_retained(6)                     # 6 re-shared: un-parked
        assert pc.reclaim() == 5              # oldest parked goes first
        assert pc.lookup(ka) is None          # key dropped: future misses
        assert pc.lookup(kb) == 6
        assert pc.reclaim() is None           # 6 is referenced again

    def test_refcounting_under_interleaved_free(self):
        """Three holders of one shared page freeing in arbitrary order:
        the page is parked exactly once, at the LAST release."""
        kv = PagedKVCacheManager(4, 64, block_size=BS)
        prompt = list(range(BS))              # exactly one full block
        slots = [kv.alloc() for _ in range(3)]
        for s in slots:
            kv.assign_blocks(s, prompt)
        page = int(kv._tables[slots[0], 0])
        assert all(int(kv._tables[s, 0]) == page for s in slots)
        assert kv.pool.ref(page) == 3
        for n_left, s in zip((2, 1, 0), (slots[1], slots[0], slots[2])):
            kv.free(s)
            assert kv.pool.ref(page) == n_left
        assert kv.prefix.reclaimable_count() == 1
        assert kv.pool.used_count() == 1      # parked, not leaked to 'used'


# ---------------------------------------------------------------------------
# kernel: block-table mode parity
# ---------------------------------------------------------------------------

def _paged_case(lengths, bs=BS, Kv=2, g=2, D=32, n_extra=3, seed=3):
    """Build a dense ragged cache + an equivalent SHUFFLED page layout."""
    B = len(lengths)
    H = Kv * g
    nmax = max((l + bs - 1) // bs for l in lengths) if any(lengths) else 1
    nmax = max(nmax, 1)
    C = nmax * bs
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, C, Kv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, C, Kv, D), jnp.float32)

    n_blocks = sum((l + bs - 1) // bs for l in lengths)
    P = 1 + n_blocks + n_extra                # page 0 reserved
    rng = np.random.RandomState(seed)
    order = rng.permutation(np.arange(1, P)).tolist()
    kp = np.zeros((P, bs, Kv, D), np.float32)
    vp = np.zeros((P, bs, Kv, D), np.float32)
    tbl = np.full((B, nmax), -1, np.int32)
    for b, l in enumerate(lengths):
        for c in range((l + bs - 1) // bs):
            page = order.pop()
            tbl[b, c] = page
            kp[page] = np.asarray(k[b, c * bs:(c + 1) * bs])
            vp[page] = np.asarray(v[b, c * bs:(c + 1) * bs])
    lens = jnp.asarray(lengths, jnp.int32)
    return q, k, v, jnp.asarray(kp), jnp.asarray(vp), lens, jnp.asarray(tbl)


class TestPagedKernel:
    LENGTHS = [0, 1, BS + 1, 3 * BS, 4 * BS - 7]

    def test_paged_ref_matches_dense_ref(self):
        q, k, v, kp, vp, lens, tbl = _paged_case(self.LENGTHS)
        dense = decode_attention_ref(q, k, v, lens)
        paged = paged_decode_attention_ref(q, kp, vp, lens, tbl)
        assert jnp.array_equal(dense, paged)

    def test_paged_kernel_bitwise_vs_dense_kernel(self):
        """Same logical blocks, same order, same flash math => bit-equal
        to the dense kernel run at bc == page size."""
        q, k, v, kp, vp, lens, tbl = _paged_case(self.LENGTHS)
        dense = decode_attention_pallas(q, k, v, lens, bc=BS)
        paged = paged_decode_attention_pallas(q, kp, vp, lens, tbl)
        assert jnp.array_equal(dense, paged)

    def test_block_skip_counts(self):
        q, k, v, kp, vp, lens, tbl = _paged_case(self.LENGTHS)
        _, counts = paged_decode_attention_pallas(
            q, kp, vp, lens, tbl, return_block_counts=True)
        want = [(l + BS - 1) // BS for l in self.LENGTHS]
        assert np.asarray(counts)[:, 0].tolist() == want

    def test_kernel_close_to_oracle(self):
        q, k, v, kp, vp, lens, tbl = _paged_case(self.LENGTHS)
        out = paged_decode_attention_pallas(q, kp, vp, lens, tbl)
        ref = paged_decode_attention_ref(q, kp, vp, lens, tbl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-6, rtol=2e-6)

    def test_gather_pages_clamps_unallocated(self):
        _, _, _, kp, vp, lens, tbl = _paged_case([BS, 2 * BS])
        dense = gather_pages(kp, tbl)
        assert dense.shape == (2, tbl.shape[1] * BS, kp.shape[2],
                               kp.shape[3])
        # row 0's unallocated tail entry gathered page 0 (zeros)
        assert not np.asarray(dense[0, BS:]).any()


# ---------------------------------------------------------------------------
# manager: prefill scatter parity, eviction round-trip
# ---------------------------------------------------------------------------

def _models(impl="xla", decode_bc=None):
    from repro.models.transformer import ExecutionContext, Model
    cfg = smoke_cfg()
    m_d = Model(cfg, ExecutionContext(attn_impl=impl, decode_bc=decode_bc),
                dtype=jnp.float32)
    m_p = Model(cfg, ExecutionContext(attn_impl=impl), dtype=jnp.float32)
    params = m_d.init(KEY)
    return cfg, m_d, m_p, params


class TestPagedManager:
    def test_prefill_scatter_bit_parity(self):
        """Gathering a paged slot's page chain reproduces the dense
        cache row exactly, including when the first block is a prefix
        hit (copy skipped, shared page already holds the bytes)."""
        cfg, m_d, m_p, params = _models()
        max_ctx = 64
        kv_d = KVCacheManager(3, max_ctx, m_d, dtype=jnp.float32)
        kv_p = PagedKVCacheManager(3, max_ctx, m_p, dtype=jnp.float32,
                                   block_size=BS)
        kv_d.ensure_caches(); kv_p.ensure_caches()
        rng = np.random.RandomState(5)
        toks = rng.randint(1, 128, size=(2, 40))
        toks[1, :BS] = toks[0, :BS]           # shared first block
        lens = [20, 33]
        _, pre = m_d.prefill(params, jnp.asarray(toks), seq_budget=max_ctx,
                             last_positions=jnp.asarray([19, 32]))
        for kv in (kv_d, kv_p):
            kv.take(0); kv.take(1)
        kv_d.merge_prefill([0, 1], pre, lens)
        kv_p.merge_prefill([0, 1], pre, lens,
                           tokens=[toks[0, :20].tolist(),
                                   toks[1, :33].tolist()])
        assert kv_p.paging.prefix_hit_tokens == BS    # row 1 block 0
        tbl = kv_p.table_array()
        for layer_d, layer_p in zip(kv_d.caches, kv_p.caches):
            for name in ("k", "v"):
                dense_rows = layer_d[name]
                paged_rows = gather_pages(layer_p[name], tbl)
                for slot, n in zip((0, 1), lens):
                    assert jnp.array_equal(dense_rows[slot, :n],
                                           paged_rows[slot, :n]), name
            assert jnp.array_equal(layer_p["index"][:2],
                                   jnp.asarray(lens, jnp.int32))

    def test_eviction_readmission_roundtrip_no_leak(self):
        kv = PagedKVCacheManager(4, 128, block_size=BS, num_blocks=17)
        rng = np.random.RandomState(9)
        prompts = [rng.randint(0, 100, size=n).tolist()
                   for n in (40, 25, 50)]
        for cycle in range(3):
            slots = []
            for p in prompts:
                s = kv.alloc()
                kv.assign_blocks(s, p)
                kv.set_length(s, len(p) + 1)
                slots.append(s)
            for s in slots:
                assert kv.ensure_decode_page(s) or True
                kv.free(s)
            # pages either free or parked-for-reuse; none leaked
            assert kv.pool.used_count() == kv.prefix.reclaimable_count()
        # cycles 2+ hit every full prefix block of every prompt
        full_blocks = sum(len(p) // BS for p in prompts)
        assert kv.paging.prefix_hit_blocks == 2 * full_blocks

    def test_admission_charge_discounts_cached(self):
        kv = PagedKVCacheManager(2, 128, block_size=BS)
        prompt = list(range(2 * BS + 5))
        new_pages, cached = kv.admission_charge(prompt)
        assert (new_pages, cached) == (3, 0)
        s = kv.alloc(); kv.assign_blocks(s, prompt)
        new_pages, cached = kv.admission_charge(prompt)
        assert (new_pages, cached) == (1, 2 * BS)  # only the private tail
        assert kv.cached_prefix_tokens(prompt) == 2 * BS

    def test_watermark_hysteresis(self):
        kv = PagedKVCacheManager(4, 128, block_size=BS, num_blocks=11,
                                 watermark_high=0.6, watermark_low=0.3)
        s = kv.alloc()
        kv._assign_private(s, 6 * BS)          # 7 of 10 usable pages
        assert kv.admission_blocked()
        kv.free(s)
        s2 = kv.alloc()
        kv._assign_private(s2, 3 * BS)         # 4/10: between low and high
        assert kv.admission_blocked()          # hysteresis: still blocked
        kv.free(s2)
        assert not kv.admission_blocked()      # below low: re-opened
        kv.free_count()                        # base slot API still works

    def test_pool_exhaustion_raises_and_rolls_back(self):
        kv = PagedKVCacheManager(2, 128, block_size=BS, num_blocks=3)
        s = kv.alloc()
        with pytest.raises(RuntimeError):
            kv.assign_blocks(s, list(range(5 * BS)))
        assert kv._nblk[s] == 0                # partial assignment undone
        assert kv.pool.free_count() == 2


# ---------------------------------------------------------------------------
# engine: end-to-end parity + preemption
# ---------------------------------------------------------------------------

def _engines(attn_impl, **paged_kw):
    cfg = smoke_cfg()
    common = dict(num_slots=4, max_context=128, dtype=jnp.float32, seed=0)
    e_d = ServingEngine(cfg, attn_impl=attn_impl, decode_bc=BS, **common)
    e_p = ServingEngine(cfg, params=e_d.params, attn_impl=attn_impl,
                        kv_layout="paged", kv_block_size=BS,
                        **paged_kw, **common)
    return e_d, e_p


def _requests():
    rng = np.random.RandomState(7)
    shared = rng.randint(1, 128, size=40).tolist()
    out = []
    for seed, n in ((1, 5), (2, 12), (3, 3), (4, 21)):
        tail = np.random.RandomState(seed).randint(1, 128, size=n).tolist()
        out.append(Request(prompt=shared + tail, max_new_tokens=6))
    return out


class TestPagedEngine:
    @pytest.mark.parametrize("attn_impl", ["xla", "decode_kernel"])
    def test_token_parity_vs_dense(self, attn_impl):
        e_d, e_p = _engines(attn_impl)
        for r in _requests():
            e_d.submit(r)
        for r in _requests():
            e_p.submit(r)
        fin_d = {len(r.prompt): r.output for r in e_d.run()}
        fin_p = {len(r.prompt): r.output for r in e_p.run()}
        assert fin_d == fin_p
        stats = e_p.paging_stats()
        assert stats["prefix_hit_tokens"] > 0      # shared system prompt
        assert stats["preemptions"] == 0
        assert e_d.paging_stats() is None

    def test_preemption_completes_all(self):
        """Pool sized so concurrent generations MUST preempt: every
        request still finishes with its full output, and preempted ones
        re-prefilled from resume_tokens."""
        cfg = smoke_cfg()
        eng = ServingEngine(cfg, num_slots=4, max_context=128,
                            dtype=jnp.float32, seed=0,
                            kv_layout="paged", kv_block_size=BS,
                            kv_num_blocks=9)
        rng = np.random.RandomState(11)
        reqs = [Request(prompt=rng.randint(1, 128, size=30).tolist(),
                        max_new_tokens=40) for _ in range(3)]
        for r in reqs:
            eng.submit(r)
        fin = eng.run(max_steps=500)
        assert len(fin) == 3
        assert all(r.state is RequestState.FINISHED for r in fin)
        assert all(len(r.output) == 40 for r in fin)
        assert eng.paging_stats()["preemptions"] >= 1
        assert sum(r.preemptions for r in fin) >= 1
        # pool fully drained at the end: nothing leaked
        ps = eng.paging_stats()
        assert ps["blocks_used"] == ps["blocks_reclaimable"]

    def test_oversized_for_pool_rejected(self):
        cfg = smoke_cfg()
        eng = ServingEngine(cfg, num_slots=2, max_context=128,
                            dtype=jnp.float32, kv_layout="paged",
                            kv_block_size=BS, kv_num_blocks=4)
        eng.submit(Request(prompt=list(range(1, 100)), max_new_tokens=2))
        fin = eng.run(max_steps=5)
        assert len(fin) == 1
        assert fin[0].state is RequestState.REJECTED
        assert "pages" in fin[0].error

    def test_paged_guard_rejects_unsupported(self):
        cfg = smoke_cfg(attention="sliding")
        with pytest.raises(ValueError, match="paged"):
            ServingEngine(cfg, kv_layout="paged", dtype=jnp.float32)
