"""Serving engine: continuous batching correctness and lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime import Request, RequestState, ServingEngine
from repro.runtime.sampler import sample


def _greedy_ref(model, params, prompt, n, budget=128):
    lg, caches = model.prefill(params, jnp.asarray([prompt]),
                               seq_budget=budget)
    out = []
    cur = jnp.argmax(lg[0, -1]).astype(jnp.int32)[None, None]
    for _ in range(n):
        out.append(int(cur[0, 0]))
        lg, caches = model.decode_step(params, cur, caches)
        cur = jnp.argmax(lg[0, -1]).astype(jnp.int32)[None, None]
    return out


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "recurrentgemma-9b",
                                  "xlstm-1.3b"])
def test_engine_matches_greedy_reference(arch):
    cfg = get_smoke_config(arch)
    eng = ServingEngine(cfg, num_slots=3, max_context=128,
                        dtype=jnp.float32)
    model = build_model(cfg, dtype=jnp.float32)
    prompt = list(np.random.RandomState(0).randint(
        0, cfg.vocab_size, size=7))
    ref = _greedy_ref(model, eng.params, prompt, 8)
    reqs = [Request(prompt=prompt, max_new_tokens=8) for _ in range(3)]
    for r in reqs:
        eng.submit(r)
    while eng.step() or eng.waiting:
        pass
    for r in reqs:
        assert r.state == RequestState.FINISHED
        assert r.output == ref, (r.output, ref)


def test_continuous_batching_staggered_arrivals():
    """Requests arriving mid-decode must not corrupt running slots."""
    cfg = get_smoke_config("qwen2-1.5b")
    eng = ServingEngine(cfg, num_slots=2, max_context=128,
                        dtype=jnp.float32)
    model = build_model(cfg, dtype=jnp.float32)
    rng = np.random.RandomState(1)
    p1 = list(rng.randint(0, cfg.vocab_size, size=5))
    p2 = list(rng.randint(0, cfg.vocab_size, size=9))
    ref1 = _greedy_ref(model, eng.params, p1, 6)
    ref2 = _greedy_ref(model, eng.params, p2, 6)
    r1 = Request(prompt=p1, max_new_tokens=6)
    r2 = Request(prompt=p2, max_new_tokens=6)
    eng.submit(r1)
    eng.step()
    eng.step()
    eng.submit(r2)          # lands in the other slot mid-flight
    while eng.step() or eng.waiting:
        pass
    assert r1.output == ref1
    assert r2.output == ref2


def test_more_requests_than_slots():
    cfg = get_smoke_config("qwen2-1.5b")
    eng = ServingEngine(cfg, num_slots=2, max_context=64, dtype=jnp.float32)
    rng = np.random.RandomState(2)
    reqs = [Request(prompt=list(rng.randint(0, cfg.vocab_size, size=4)),
                    max_new_tokens=3) for _ in range(5)]
    for r in reqs:
        eng.submit(r)
    while eng.step() or eng.waiting:
        pass
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert all(len(r.output) == 3 for r in reqs)
    assert eng.stats.decode_tokens == 15


def test_run_returns_finished_requests():
    """run() must return the requests evicted during the call (it used to
    always return [])."""
    cfg = get_smoke_config("qwen2-1.5b")
    eng = ServingEngine(cfg, num_slots=2, max_context=64, dtype=jnp.float32)
    rng = np.random.RandomState(3)
    reqs = [Request(prompt=list(rng.randint(0, cfg.vocab_size, size=4)),
                    max_new_tokens=2) for _ in range(3)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run()
    assert sorted(r.request_id for r in finished) == \
        sorted(r.request_id for r in reqs)
    assert all(r.state == RequestState.FINISHED for r in finished)
    assert eng.run() == []          # nothing new finished on a drained engine


def test_sampler_greedy_vs_temperature():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0], [3.0, 0.0, 0.0]])
    t0 = sample(key, logits, jnp.zeros(2))
    np.testing.assert_array_equal(np.asarray(t0), [1, 0])
    # high temperature: sampled tokens valid
    t1 = sample(key, logits, jnp.full((2,), 5.0))
    assert t1.shape == (2,)
    assert bool(jnp.all((t1 >= 0) & (t1 < 3)))
    # top-k=1 equals greedy regardless of temperature
    tk = sample(key, logits, jnp.full((2,), 5.0), top_k=1)
    np.testing.assert_array_equal(np.asarray(tk), [1, 0])
