"""Partition rules: divisibility sanitation, FSDP, batch specs — checked
for every assigned architecture against the production mesh axis sizes
(via a lightweight fake mesh; the real 512-device check is the dry-run)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.models import build_model
from repro.sharding.partition import (apply_fsdp, batch_pspec,
                                      params_pspecs, sanitize_spec)


class FakeMesh:
    """Duck-typed mesh: partition.py only reads .shape and .axis_names."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


def _spec_divides(spec, shape, mesh):
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for dim, e in zip(shape, entries):
        if e is None:
            continue
        prod = 1
        for a in ((e,) if isinstance(e, str) else e):
            prod *= mesh.shape[a]
        if dim % prod != 0:
            return False
    return True


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_all_param_specs_divisible_on_production_mesh(arch):
    """Every generated PartitionSpec must exactly divide its parameter on
    the 16x16 production mesh (jit in_shardings reject padding)."""
    cfg = get_config(arch)
    model = build_model(cfg, scan_layers=cfg.num_layers > 8)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = params_pspecs(params, cfg, mesh=MESH)
    flat_p, _ = jax.tree.flatten(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert _spec_divides(s, p.shape, MESH), (s, p.shape)


def test_sanitize_spec_drops_indivisible():
    assert sanitize_spec(P("model", None), (49155, 64), MESH) == \
        P(None, None)
    assert sanitize_spec(P("model", None), (49152, 64), MESH) == \
        P("model", None)
    assert sanitize_spec(P(("data", "model"), None), (512, 8), MESH) == \
        P(("data", "model"), None)
    assert sanitize_spec(P(("data", "model"), None), (128, 8), MESH) == \
        P(None, None)


def test_apply_fsdp_only_when_large():
    small = apply_fsdp(P(None, "model"), (1024, 1024), MESH)
    assert small == P(None, "model")
    big = apply_fsdp(P(None, "model"), (16384, 53248), MESH)
    assert big == P("data", "model")


def test_batch_pspec_divisibility():
    class M2(FakeMesh):
        pass
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_pspec(256, m) == P(("pod", "data"))
    assert batch_pspec(1, m) == P(None)
    assert batch_pspec(16, m) == P(("data",)) or \
        batch_pspec(16, m) == P(("pod",)) or True  # any valid subset
    spec = batch_pspec(16, m)
    prod = 1
    if spec != P(None):
        entry = spec[0]
        for a in ((entry,) if isinstance(entry, str) else entry):
            prod *= m.shape[a]
    assert 16 % prod == 0


def test_ssm_params_replicated_except_readout():
    cfg = get_config("xlstm-1.3b")
    model = build_model(cfg, scan_layers=True)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = params_pspecs(params, cfg, mesh=MESH, fsdp=False)
    for spec in jax.tree.leaves(specs["layer_groups"],
                                is_leaf=lambda x: isinstance(x, P)):
        assert all(e is None for e in spec), spec


def test_moe_experts_expert_parallel():
    cfg = get_config("qwen2-moe-a2.7b")
    model = build_model(cfg, num_experts_padded=64, scan_layers=True)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = params_pspecs(params, cfg, mesh=MESH)
    moe_specs = specs["layer_groups"][0]["moe"]["experts"]
    for spec in jax.tree.leaves(moe_specs,
                                is_leaf=lambda x: isinstance(x, P)):
        # stacked leading dim None, then expert dim sharded over model
        assert spec[1] == "model", spec
