"""MoE layer invariants: routing, dispatch/combine, capacity, padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib

KEY = jax.random.PRNGKey(0)


def mk(E=8, top_k=2, H=64, M=32, n_shared=0, cf=8.0):
    mcfg = MoEConfig(num_experts=E, top_k=top_k, expert_ffn_dim=H,
                     num_shared_experts=n_shared, shared_ffn_dim=H,
                     capacity_factor=cf)
    params = moe_lib.moe_init(KEY, M, mcfg)
    return mcfg, params


def test_routing_topk_properties():
    mcfg, params = mk()
    x = jax.random.normal(KEY, (64, 32), jnp.float32)
    r = moe_lib.route_topk(params["router"], x, mcfg)
    assert r.experts.shape == (64, 2)
    assert bool(jnp.all(r.experts >= 0)) and bool(
        jnp.all(r.experts < mcfg.num_experts))
    np.testing.assert_allclose(np.asarray(r.weights.sum(-1)), 1.0,
                               rtol=1e-5)
    # top-k experts are distinct per token
    assert bool(jnp.all(r.experts[:, 0] != r.experts[:, 1]))


def test_padded_experts_receive_no_tokens():
    mcfg, _ = mk(E=6)
    params = moe_lib.moe_init(KEY, 32, mcfg, num_experts_padded=8)
    x = jax.random.normal(KEY, (128, 32), jnp.float32)
    r = moe_lib.route_topk(params["router"], x, mcfg, num_experts_padded=8)
    assert bool(jnp.all(r.experts < 6))
    assert float(r.probs[:, 6:].max()) < 1e-20


def test_capacity_equals_dense_when_no_drops():
    mcfg, params = mk(cf=16.0)
    x = jax.random.normal(KEY, (4, 16, 32), jnp.float32)
    y_d, _ = moe_lib.moe_apply_dense(params, x, mcfg)
    y_c, _ = moe_lib.moe_apply_capacity(params, x, mcfg)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_d), atol=1e-5)


def test_capacity_drops_with_tiny_capacity():
    mcfg, params = mk(cf=16.0)
    x = jax.random.normal(KEY, (1, 64, 32), jnp.float32)
    y_full, _ = moe_lib.moe_apply_capacity(params, x, mcfg)
    y_tiny, _ = moe_lib.moe_apply_capacity(params, x, mcfg, capacity=1)
    # with capacity=1 most tokens are dropped => outputs differ
    assert float(jnp.max(jnp.abs(y_full - y_tiny))) > 1e-3


def test_shared_expert_added():
    mcfg, params = mk(n_shared=2)
    x = jax.random.normal(KEY, (2, 8, 32), jnp.float32)
    y, _ = moe_lib.moe_apply_dense(params, x, mcfg)
    params_no = {k: v for k, v in params.items() if k != "shared"}
    y_no, _ = moe_lib.moe_apply_dense(params_no, x, mcfg)
    shared = moe_lib.shared_expert_apply(params, x.reshape(-1, 32))
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(y_no + shared.reshape(y.shape)),
                               atol=1e-5)


def test_load_balance_loss_uniform_router_near_one():
    """For a (near-)uniform router the Switch aux loss approaches 1."""
    mcfg = MoEConfig(num_experts=16, top_k=2, expert_ffn_dim=8)
    T = 8192
    probs = jnp.full((T, 16), 1.0 / 16)
    experts = jax.random.randint(KEY, (T, 2), 0, 16)
    r = moe_lib.Routing(weights=jnp.full((T, 2), 0.5), experts=experts,
                        probs=probs)
    val = float(moe_lib.load_balance_loss(r, mcfg))
    assert abs(val - 1.0) < 0.05, val


@given(T=st.integers(2, 64), E=st.sampled_from([4, 8]),
       top_k=st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_dispatch_combine_identity_property(T, E, top_k):
    """With identity experts and no drops, combine(dispatch(x)) == x
    (routing weights sum to 1)."""
    mcfg = MoEConfig(num_experts=E, top_k=top_k, expert_ffn_dim=8,
                     capacity_factor=float(E))
    params = moe_lib.moe_init(KEY, 16, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(T), (T, 16), jnp.float32)
    cap = moe_lib.expert_capacity(T, mcfg)
    info = moe_lib.moe_dispatch(params, x, mcfg, capacity=cap)
    y = moe_lib.moe_combine(info, info.buffers, T, x.dtype)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-5)


def test_expert_capacity_multiple_of():
    mcfg = MoEConfig(num_experts=8, top_k=2, expert_ffn_dim=8,
                     capacity_factor=1.25)
    cap = moe_lib.expert_capacity(100, mcfg, multiple_of=4)
    assert cap % 4 == 0
    assert cap >= 100 * 2 / 8 * 1.25
