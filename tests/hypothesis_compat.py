"""Optional-dependency shim for ``hypothesis``.

Property-based tests import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly. When hypothesis is installed (see
requirements-dev.txt) the real objects are re-exported and the properties
run; when it is missing, ``@given`` turns the test into a skip instead of
breaking collection of the whole module, so the example-based tests in the
same files still run everywhere.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every strategy factory
        returns None; @given skips the test before they are ever drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        return lambda fn: fn


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
