"""Optimizer, train loop, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, MarkovTextDataset
from repro.training import (AdamWConfig, init_opt_state, load_checkpoint,
                            save_checkpoint, train)
from repro.training.optimizer import apply_updates, global_norm, schedule

KEY = jax.random.PRNGKey(0)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_ratio=1.0)
    state = init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, gn = apply_updates(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                      weight_decay=0.0)
    state = init_opt_state(params, cfg)
    grads = {"w": jnp.array([1e6, 1e6, 1e6])}
    _, _, gn = apply_updates(params, grads, state, cfg)
    assert float(gn) > 1e5  # reported norm is pre-clip


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, 0)) == pytest.approx(0.0)
    assert float(schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(schedule(cfg, 100)) == pytest.approx(0.1)


def test_train_loss_decreases():
    cfg = get_smoke_config("qwen2-1.5b")
    res = train(cfg, steps=60, batch_size=4, seq_len=64, lr=2e-3,
                log_every=0, log_fn=lambda s: None)
    first = float(np.mean(res.losses[:5]))
    assert res.final_loss < first - 0.2, (first, res.final_loss)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones(4, jnp.bfloat16), jnp.zeros((), jnp.int32)]}
    save_checkpoint(str(tmp_path / "ck"), tree, step=7)
    restored, step = load_checkpoint(str(tmp_path / "ck"), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_markov_data_deterministic_and_learnable():
    cfg = DataConfig(vocab_size=128, seq_len=32, batch_size=4, seed=3)
    d1, d2 = MarkovTextDataset(cfg), MarkovTextDataset(cfg)
    b1, b2 = d1.sample_batch(5), d2.sample_batch(5)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (4, 32)
    assert b1.min() >= 0 and b1.max() < 128
    # the chain's entropy floor is far below uniform log(V)
    assert d1.optimal_nll() < np.log(128) * 0.7


def test_gradient_accumulation_matches_full_batch():
    """accum_steps=K must equal the single-batch step (same grads)."""
    from repro.launch.steps import make_train_step
    from repro.models import build_model
    cfg = get_smoke_config("qwen2-1.5b")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(KEY)
    ocfg = AdamWConfig(lr=1e-3)
    tokens = jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size)
    s1 = jax.jit(make_train_step(model, ocfg, accum_steps=1))
    s4 = jax.jit(make_train_step(model, ocfg, accum_steps=4))
    p1, _, m1 = s1(params, init_opt_state(params, ocfg), tokens)
    p4, _, m4 = s4(params, init_opt_state(params, ocfg), tokens)
    assert float(abs(m1["loss"] - m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
