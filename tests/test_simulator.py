"""Exact event-order simulator vs the paper's closed forms (Eq. 13)."""
import pytest
from hypothesis_compat import given, settings, st

from repro.core.analytic import (ORDER_AASS, ORDER_ASAS, StageTimes,
                                 makespan_closed_form, makespan_naive,
                                 makespan_pppipe)
from repro.core.simulator import (non_overlapped_comm_time, simulate_dep,
                                  simulate_naive, simulate_pppipe, _subtract,
                                  _union, total_len)

ST = StageTimes(t_a=0.013, t_s=0.012, t_e=0.011, t_c=0.004)


def test_exact_match_r2_1_asas():
    """For r2 = 1 the paper's Eq. 13 is exact (we verified the recurrences
    collapse); the simulator must agree to float precision."""
    for r1 in (1, 2, 4, 8):
        a = makespan_closed_form(ST, 8, r1, 1, ORDER_ASAS)
        s = simulate_dep(ST, 8, r1, 1, order=ORDER_ASAS).makespan
        assert a == pytest.approx(s, rel=1e-9), (r1,)


@given(t_a=st.floats(1e-4, 5e-2), t_s=st.floats(0.0, 5e-2),
       t_e=st.floats(1e-4, 5e-2), t_c=st.floats(1e-5, 5e-2),
       r1=st.integers(1, 6), r2=st.integers(1, 6), T=st.integers(1, 6))
@settings(max_examples=200, deadline=None)
def test_analytic_upper_bounds_simulation(t_a, t_s, t_e, t_c, r1, r2, T):
    """Eq. 13 is a (tight) conservative model: it never under-estimates the
    exact event-order makespan, and is within 25% of it. (The gap comes
    from the extra (r2-1)Y term in Eq. 13 — see EXPERIMENTS.md.)"""
    stt = StageTimes(t_a=t_a, t_s=t_s, t_e=t_e, t_c=t_c)
    a = makespan_closed_form(stt, T, r1, r2, ORDER_ASAS)
    s = simulate_dep(stt, T, r1, r2, order=ORDER_ASAS).makespan
    assert a >= s - 1e-12
    # Eq. 13's slack is exactly the double-counted (r2-1)*Y term (G already
    # includes it) plus small fill-phase conservatism
    Y = max(t_e, t_c)
    assert a <= s * 1.05 + (r2 - 1) * Y + 1e-9


@given(t_a=st.floats(1e-4, 5e-2), t_s=st.floats(0.0, 5e-2),
       t_e=st.floats(1e-4, 5e-2), t_c=st.floats(1e-5, 5e-2),
       r1=st.integers(1, 6), r2=st.integers(1, 6), T=st.integers(1, 5))
@settings(max_examples=200, deadline=None)
def test_aass_closed_form_bounds(t_a, t_s, t_e, t_c, r1, r2, T):
    stt = StageTimes(t_a=t_a, t_s=t_s, t_e=t_e, t_c=t_c)
    a = makespan_closed_form(stt, T, r1, r2, ORDER_AASS)
    s = simulate_dep(stt, T, r1, r2, order=ORDER_AASS).makespan
    # the AASS closed form is a two-sided approximation (observed in
    # [0.85, 1.0] x exact over 20k random workloads); the solver's hybrid
    # mode re-ranks its top-K with the exact simulator, so this only needs
    # to be a sane ranking heuristic.
    Y = max(t_e, t_c)
    assert a >= 0.8 * s - 1e-12
    assert a <= s * 1.1 + (r2 - 1) * Y + r1 * max(t_a, t_s) + 1e-9


def test_naive_closed_form_exact():
    for T in (1, 4, 9):
        assert makespan_naive(ST, T) == pytest.approx(
            simulate_naive(ST, T).makespan, rel=1e-12)


def test_pppipe_closed_form_exact():
    for T in (1, 3, 8):
        for r1 in (1, 2, 4):
            a = makespan_pppipe(ST, T, r1)
            s = simulate_pppipe(ST, T, r1).makespan
            assert a == pytest.approx(s, rel=1e-9), (T, r1)


def test_resource_exclusivity_and_dependencies():
    """Rules 1-9 of Eq. 5: no overlapping intervals per resource; chunk
    stages in order."""
    res = simulate_dep(ST, 4, 3, 2, order=ORDER_ASAS,
                       record_intervals=True)
    for name, iv in res.intervals.items():
        iv_sorted = sorted(iv)
        for (s1, e1), (s2, e2) in zip(iv_sorted, iv_sorted[1:]):
            assert s2 >= e1 - 1e-12, (name, (s1, e1), (s2, e2))
    # makespan equals the max interval end
    ends = [e for iv in res.intervals.values() for _, e in iv]
    assert res.makespan == pytest.approx(max(ends))


def test_pipelining_beats_sequential():
    """PPPipe < naive; FinDEP (shared not blocking a2e) <= PPPipe at the
    same granularity. NOTE: per-chunk durations must be scaled when
    comparing different r2 (StageTimes are per-chunk)."""
    T = 8
    r1 = 4
    # StageTimes are per-micro-batch: the naive baseline runs the WHOLE
    # mini-batch at once, i.e. r1 x every duration (alpha-free scaling).
    full = StageTimes(t_a=ST.t_a * r1, t_s=ST.t_s * r1, t_e=ST.t_e * r1,
                      t_c=ST.t_c * r1)
    naive = simulate_naive(full, T).makespan
    pp = simulate_pppipe(ST, T, r1).makespan
    fd = simulate_dep(ST, T, r1, 1, order=ORDER_ASAS).makespan
    assert pp < naive
    assert fd <= pp + 1e-12
    # (a specific r2>1 config is NOT pointwise guaranteed to beat PPPipe —
    # only the optimum over FinDEP's search space is; see test_solver.)


def test_interval_algebra():
    assert _union([(0, 1), (0.5, 2), (3, 4)]) == [(0, 2), (3, 4)]
    assert total_len([(0, 2), (3, 4)]) == pytest.approx(3.0)
    a = [(0.0, 10.0)]
    b = [(2.0, 3.0), (5.0, 7.0)]
    assert _subtract(a, b) == [(0.0, 2.0), (3.0, 5.0), (7.0, 10.0)]


def test_non_overlapped_comm_decreases_with_overlap():
    """Table 7's metric: FinDEP exposes less communication than naive."""
    slow_comm = StageTimes(t_a=0.01, t_s=0.008, t_e=0.01, t_c=0.02)
    T = 6
    nv = non_overlapped_comm_time(
        simulate_naive(slow_comm, T, record_intervals=True))
    pp = non_overlapped_comm_time(
        simulate_pppipe(slow_comm, T, 4, record_intervals=True))
    quarter = StageTimes(t_a=slow_comm.t_a, t_s=slow_comm.t_s,
                         t_e=slow_comm.t_e / 4, t_c=slow_comm.t_c / 4)
    fd = non_overlapped_comm_time(
        simulate_dep(quarter, T, 4, 4, order=ORDER_ASAS,
                     record_intervals=True))
    assert fd <= pp <= nv + 1e-12
