"""End-to-end system behaviour: the FinDEP pipeline from planner to
execution, and headline paper claims at CPU scale."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import (FinDEPPlanner, PAPER_A6000, TPU_V5E, best_pppipe,
                        naive_plan, solve)
from repro.core.perf_model import DepModelSpec, build_stage_models
from repro.core.planner import PlannerConfig


def test_planner_end_to_end_deepseek():
    """Offline calibrate -> online solve for the paper's DeepSeek-V2
    backbone; FinDEP plan beats best PPPipe on the same hardware model."""
    cfg = get_config("deepseek-v2-lite")
    cluster = DepClusterConfig(num_devices=8, ag=3, eg=5)
    planner = FinDEPPlanner(cfg, cluster, PAPER_A6000,
                            PlannerConfig(mem_cap_samples=16))
    plan = planner.plan(seq_len=2048)
    assert planner.last_solve_time < 1.0
    models = planner.stage_models(2048)
    T = len(cfg.moe_layer_indices())
    pp = best_pppipe(models, T, 16, r1_cap=16)
    nv = naive_plan(models, T, 16)
    assert plan.throughput >= pp.throughput * (1 - 1e-9)
    assert plan.throughput > nv.throughput
    # caching: the second call must be instant
    t0 = time.perf_counter()
    planner.plan(seq_len=2048)
    assert time.perf_counter() - t0 < 1e-3


def test_planner_qwen3_no_shared():
    """Qwen3-MoE (no shared experts): ASAS == AASS degenerate; still
    solvable and >= PPPipe."""
    cfg = get_config("qwen3-moe")
    cluster = DepClusterConfig(num_devices=8, ag=4, eg=4)
    planner = FinDEPPlanner(cfg, cluster, PAPER_A6000,
                            PlannerConfig(mem_cap_samples=8))
    plan = planner.plan(seq_len=1024)
    models = planner.stage_models(1024)
    T = len(cfg.moe_layer_indices())
    pp = best_pppipe(models, T, 8, r1_cap=8)
    assert plan.throughput >= pp.throughput * (1 - 1e-9)


def test_online_adaptation_changes_plan():
    """Paper §5.5: different arriving sequence lengths should generally
    produce different (r1, r2) schedules."""
    cfg = get_config("deepseek-v2-lite")
    cluster = DepClusterConfig(num_devices=8, ag=3, eg=5)
    planner = FinDEPPlanner(cfg, cluster, PAPER_A6000,
                            PlannerConfig(mem_cap_samples=32))
    plans = {s: planner.plan(seq_len=s) for s in (512, 2048, 8192)}
    configs = {(p.m_a, p.r1, p.r2, p.order) for p in plans.values()}
    assert len(configs) >= 2, configs


def test_speedup_grows_with_sequence_length():
    """Paper Table 5: FinDEP's advantage over PPPipe is largest at long
    sequences — in the paper's regime: memory-capped r1*m_a <= 4 and the
    reduced 8-layer DeepSeek variant (§5.4). At unconstrained memory both
    schedulers saturate the bottleneck resource and the ratio pins to 1.0
    (Amdahl; see EXPERIMENTS.md Note A)."""
    cfg = get_config("deepseek-v2-lite")
    cluster = DepClusterConfig(num_devices=8, ag=3, eg=5)
    speedups = []
    for S in (1024, 8192):
        spec = dataclasses.replace(
            DepModelSpec.from_model_config(cfg, S), T=8)
        models = build_stage_models(PAPER_A6000, spec, cluster)
        fd, _ = solve(models, 8, 4, objective="simulate", r2_cap=16,
                      r1_cap=4)
        pp = best_pppipe(models, 8, 4, r1_cap=4)
        speedups.append(fd.throughput / pp.throughput)
    assert speedups[-1] >= speedups[0] - 1e-6, speedups
    assert speedups[-1] > 1.0


def test_quickstart_train_and_serve_cycle(tmp_path):
    """Mini end-to-end: train a tiny MoE, checkpoint, reload, serve."""
    from repro.runtime import Request, ServingEngine
    from repro.training import load_checkpoint, save_checkpoint, train

    cfg = get_smoke_config("qwen2-moe-a2.7b")
    res = train(cfg, steps=12, batch_size=2, seq_len=32, log_every=0,
                ckpt_path=str(tmp_path / "ck"), log_fn=lambda s: None)
    assert np.isfinite(res.final_loss)

    from repro.models import build_model
    model = build_model(cfg, dtype=jnp.float32)
    like = {"params": model.init(jax.random.PRNGKey(0))}
    restored, step = load_checkpoint(str(tmp_path / "ck"), like)
    assert step == 12
    eng = ServingEngine(cfg, params=restored["params"], num_slots=2,
                        max_context=64, dtype=jnp.float32)
    req = Request(prompt=[1, 2, 3], max_new_tokens=4)
    eng.submit(req)
    while eng.step() or eng.waiting:
        pass
    assert len(req.output) == 4
