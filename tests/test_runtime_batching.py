"""Batch/KV runtime API: batched-prefill parity, KV ledger accounting,
admission policies, rejection, per-slot top-k, and stats lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime import (ADMISSIONS, BatchScheduler, KVCacheManager,
                           Request, RequestState, ServingEngine,
                           make_admission)
from repro.sched import OccupancySummary, bucket_length


def _tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def _prompts(rng, cfg, sizes):
    return [list(rng.randint(0, cfg.vocab_size, size=n)) for n in sizes]


# ---------------------------------------------------------------------------
# batched prefill parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,sizes", [("qwen2-1.5b", (5, 7, 9)),
                                        ("xlstm-1.3b", (7, 7, 7))])
def test_batched_prefill_matches_sequential_bit_for_bit(arch, sizes):
    """N requests prefilled in ONE batched call must produce per-slot
    caches bit-identical to N sequential single-request prefills, and the
    same generated tokens."""
    cfg = get_smoke_config(arch)
    eng_b = ServingEngine(cfg, num_slots=3, max_context=64,
                          dtype=jnp.float32)
    eng_s = ServingEngine(cfg, params=eng_b.params, num_slots=3,
                          max_context=64, dtype=jnp.float32)
    rng = np.random.RandomState(0)
    prompts = _prompts(rng, cfg, sizes)

    reqs_b = [Request(prompt=p, max_new_tokens=4) for p in prompts]
    for r in reqs_b:
        eng_b.submit(r)
    batched = eng_b._admit()
    assert batched.num_prefilled == 3
    assert len(batched.prefills) == 1          # one same-bucket group

    reqs_s = [Request(prompt=p, max_new_tokens=4) for p in prompts]
    for slot, r in enumerate(reqs_s):
        eng_s._prefill_one(slot, r)

    assert _tree_equal(eng_b.kv.caches, eng_s.kv.caches)
    assert np.array_equal(np.asarray(eng_b.last_tokens),
                          np.asarray(eng_s.last_tokens))
    while eng_b.step() or eng_b.waiting:
        pass
    while eng_s.step() or eng_s.waiting:
        pass
    assert [r.output for r in reqs_b] == [r.output for r in reqs_s]


def test_prefill_last_positions_gathers_per_row_logits():
    """Batched prefill with per-row last_positions must reproduce each
    request's single-prefill final logits."""
    cfg = get_smoke_config("qwen2-1.5b")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = _prompts(rng, cfg, (4, 9, 6))
    bucket = 16
    toks = np.zeros((3, bucket), np.int32)
    for j, p in enumerate(prompts):
        toks[j, :len(p)] = p
    last = np.asarray([len(p) - 1 for p in prompts])
    lg_b, _ = model.prefill(params, jnp.asarray(toks), seq_budget=64,
                            last_positions=last)
    for j, p in enumerate(prompts):
        lg_1, _ = model.prefill(params, jnp.asarray([p]), seq_budget=64)
        np.testing.assert_array_equal(np.asarray(lg_b[j]),
                                      np.asarray(lg_1[0]))


# ---------------------------------------------------------------------------
# KV ledger accounting
# ---------------------------------------------------------------------------

def test_kv_ledger_alloc_free_occupancy_churn():
    kv = KVCacheManager(num_slots=4, max_context=512)   # ledger-only
    slots = [kv.alloc() for _ in range(4)]
    assert slots == [0, 1, 2, 3]
    assert kv.alloc() is None and kv.free_count() == 0
    for s, n in zip(slots, (10, 70, 200, 500)):
        kv.set_length(s, n)
    occ = kv.occupancy()
    assert occ == OccupancySummary(live=4, hist=((64, 1), (128, 1),
                                                 (256, 1), (512, 1)))
    kv.free(1)
    kv.free(3)
    assert kv.live_slots() == [0, 2] and kv.free_count() == 2
    assert kv.occupancy().hist == ((64, 1), (256, 1))
    with pytest.raises(ValueError):
        kv.free(1)                       # double free
    with pytest.raises(ValueError):
        kv.take(0)                       # already live
    s = kv.alloc()
    assert s == 1                        # lowest free slot reused
    kv.note_decode([0, 2])
    assert kv.length(0) == 11 and kv.length(2) == 201
    assert kv.stats.allocs == 5 and kv.stats.frees == 2
    assert kv.stats.peak_live == 4
    with pytest.raises(ValueError):
        kv.ensure_caches()               # no model behind this ledger


def test_kv_occupancy_caps_at_max_context():
    kv = KVCacheManager(num_slots=2, max_context=128)
    kv.take(0)
    kv.set_length(0, 100_000)
    assert kv.occupancy().hist == ((128, 1),)


# ---------------------------------------------------------------------------
# admission policies
# ---------------------------------------------------------------------------

def _req(n, **kw):
    return Request(prompt=list(range(1, n + 1)), **kw)


def test_admission_order_fcfs_vs_spf():
    waiting = [_req(40), _req(4), _req(20)]
    assert make_admission("fcfs").admit(waiting, 2) == waiting[:2]
    assert make_admission("spf").admit(waiting, 2) == [waiting[1],
                                                       waiting[2]]


def test_admission_token_budget_defers_but_never_starves():
    pol = make_admission("token_budget", token_budget=32)
    waiting = [_req(20), _req(20), _req(20)]
    first = pol.admit(waiting, 3)
    assert first == waiting[:1]          # second would exceed the budget
    # a single prompt larger than the whole budget is still admitted
    huge = [_req(100), _req(4)]
    assert pol.admit(huge, 2) == huge[:1]
    assert "token_budget" in ADMISSIONS


def test_token_budget_caps_every_admission_policy():
    """The step budget binds independent of HOW requests are ranked —
    fcfs/spf with token_budget must not admit unbounded prefill work."""
    kv = KVCacheManager(num_slots=4, max_context=512)
    sched = BatchScheduler(admission="fcfs", token_budget=32)
    waiting = [_req(20), _req(20), _req(20)]
    plan = sched.build_step(waiting, kv)
    assert plan.num_prefilled == 1 and len(waiting) == 2
    assert plan.prefill_tokens <= 32


def test_build_step_groups_by_bucket_and_allocates():
    kv = KVCacheManager(num_slots=4, max_context=512)
    sched = BatchScheduler(admission="fcfs")
    waiting = [_req(10), _req(200), _req(12), _req(100)]
    plan = sched.build_step(waiting, kv)
    assert waiting == []
    assert plan.num_prefilled == 4
    buckets = {g.bucket: len(g.requests) for g in plan.prefills}
    assert buckets == {bucket_length(9): 2, bucket_length(99): 1,
                       bucket_length(199): 1}
    assert buckets == {64: 2, 128: 1, 256: 1}
    assert sorted(s for g in plan.prefills for s in g.slots) == [0, 1, 2, 3]
    assert plan.decode_slots == [0, 1, 2, 3]


def test_token_budget_engine_end_to_end_matches_fcfs():
    """Admission order must not change any request's greedy output —
    chunked-prefill scheduling is a latency policy, not a numerics one."""
    cfg = get_smoke_config("qwen2-1.5b")
    rng = np.random.RandomState(2)
    prompts = _prompts(rng, cfg, (9, 30, 5, 17))

    def serve(**kw):
        eng = ServingEngine(cfg, num_slots=2, max_context=64,
                            dtype=jnp.float32, **kw)
        reqs = [Request(prompt=p, max_new_tokens=4) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.state == RequestState.FINISHED for r in reqs)
        return [r.output for r in reqs]

    base = serve()
    assert serve(admission="token_budget", token_budget=16) == base
    assert serve(admission="spf") == base


# ---------------------------------------------------------------------------
# rejection, top-k, stats
# ---------------------------------------------------------------------------

def test_oversized_prompt_rejected_not_truncated():
    cfg = get_smoke_config("qwen2-1.5b")
    eng = ServingEngine(cfg, num_slots=2, max_context=32, dtype=jnp.float32)
    rng = np.random.RandomState(3)
    ok = Request(prompt=_prompts(rng, cfg, (8,))[0], max_new_tokens=2)
    huge = Request(prompt=_prompts(rng, cfg, (40,))[0], max_new_tokens=2)
    eng.submit(huge)
    eng.submit(ok)
    finished = eng.run()
    assert huge.state == RequestState.REJECTED
    assert huge.error is not None and "max_context" in huge.error
    assert huge.output == [] and huge in finished
    assert ok.state == RequestState.FINISHED and len(ok.output) == 2
    # boundary: the FULL prompt (incl. the decode-fed last token) must fit
    at_cap = Request(prompt=_prompts(rng, cfg, (32,))[0], max_new_tokens=1)
    over_by_one = Request(prompt=_prompts(rng, cfg, (33,))[0],
                          max_new_tokens=1)
    eng.submit(at_cap)
    eng.submit(over_by_one)
    eng.run()
    assert at_cap.state == RequestState.FINISHED
    assert over_by_one.state == RequestState.REJECTED
    # the single-request shim refuses oversized prompts up front too
    with pytest.raises(ValueError, match="max_context"):
        eng._prefill_one(0, Request(prompt=list(range(40))))
    assert eng.kv.free_count() == eng.num_slots      # slot not leaked


def test_request_top_k_respected_per_slot():
    """top_k=1 at high temperature must reproduce the greedy output while
    a plain high-temperature slot diverges — the per-slot top_k vector is
    actually threaded through decode."""
    cfg = get_smoke_config("qwen2-1.5b")
    rng = np.random.RandomState(4)
    prompt = _prompts(rng, cfg, (7,))[0]

    def serve(**kw):
        eng = ServingEngine(cfg, num_slots=1, max_context=64,
                            dtype=jnp.float32, seed=0)
        req = Request(prompt=prompt, max_new_tokens=8, **kw)
        eng.submit(req)
        eng.run()
        return req.output

    greedy = serve()
    assert serve(temperature=5.0, top_k=1) == greedy
    assert serve(temperature=5.0) != greedy


def test_engine_stats_clock_starts_on_work_and_resets():
    cfg = get_smoke_config("qwen2-1.5b")
    eng = ServingEngine(cfg, num_slots=1, max_context=64, dtype=jnp.float32)
    assert eng.stats.start_t is None         # construction != serving
    assert eng.stats.throughput() == 0.0
    rng = np.random.RandomState(5)
    eng.submit(Request(prompt=_prompts(rng, cfg, (5,))[0],
                       max_new_tokens=2))
    assert eng.stats.start_t is not None     # clock armed by submit
    eng.run()
    assert eng.stats.decode_tokens == 2 and eng.stats.throughput() > 0.0
    eng.stats.reset()                        # benchmark warmup path
    assert eng.stats.start_t is None
    assert eng.stats.decode_tokens == 0 and eng.stats.prefill_tokens == 0
    assert eng.stats.steps == 0 and eng.stats.throughput() == 0.0
