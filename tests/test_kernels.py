"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.moe_gemm.ops import moe_gemm
from repro.kernels.moe_gemm.ref import moe_gemm_ref
from repro.kernels.rg_lru.ops import rg_lru_scan
from repro.kernels.rg_lru.ref import rg_lru_scan_ref

KEY = jax.random.PRNGKey(0)


def tol(dt):
    return dict(rtol=2e-2, atol=5e-2) if dt == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("E,C,M,H", [(2, 128, 128, 256), (4, 256, 256, 512),
                                     (1, 512, 512, 128), (3, 384, 128, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm(E, C, M, H, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (E, C, M), dtype)
    wg = jax.random.normal(ks[1], (E, M, H), dtype) * 0.05
    wu = jax.random.normal(ks[2], (E, M, H), dtype) * 0.05
    wd = jax.random.normal(ks[3], (E, H, M), dtype) * 0.05
    y = moe_gemm(x, wg, wu, wd, bc=128, bh=128)
    r = moe_gemm_ref(x, wg, wu, wd)
    assert y.dtype == x.dtype
    jnp.allclose(y.astype(jnp.float32), r.astype(jnp.float32))
    import numpy as np
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,S,H,Kv,D,win", [
    (2, 256, 4, 2, 64, None), (1, 128, 8, 8, 32, None),
    (2, 256, 4, 1, 64, 48), (1, 512, 2, 2, 128, None),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, Kv, D, win, dtype):
    import numpy as np
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Kv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Kv, D), dtype)
    y = flash_attention(q, k, v, causal=True, window=win, bq=64, bk=64)
    r = flash_attention_ref(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,C,H,Kv,D", [(2, 512, 8, 2, 64),
                                        (1, 1024, 4, 4, 32),
                                        (3, 512, 16, 1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, C, H, Kv, D, dtype):
    import numpy as np
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, C, Kv, D), dtype)
    v = jax.random.normal(ks[2], (B, C, Kv, D), dtype)
    lengths = jnp.asarray([(3 * C) // 4, 1, C][:B], jnp.int32)
    y = decode_attention(q, k, v, lengths, bc=128)
    r = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,S,W,bs,bw", [(2, 512, 512, 128, 128),
                                         (1, 1024, 256, 256, 256),
                                         (3, 256, 1024, 64, 512)])
def test_rg_lru(B, S, W, bs, bw):
    import numpy as np
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, W), jnp.float32, 0.8, 0.999)
    b = jax.random.normal(ks[1], (B, S, W), jnp.float32) * 0.1
    h0 = jax.random.normal(ks[2], (B, W), jnp.float32)
    h, hl = rg_lru_scan(a, b, h0, bs=bs, bw=bw)
    hr, hlr = rg_lru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr),
                               rtol=1e-5, atol=1e-5)


def test_ragged_shapes_fall_back_to_ref():
    """Non-tiling shapes must still produce correct results (ref path)."""
    import numpy as np
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (2, 100, 96), jnp.float32)
    wg = jax.random.normal(ks[1], (2, 96, 100), jnp.float32) * 0.1
    wu = jax.random.normal(ks[2], (2, 96, 100), jnp.float32) * 0.1
    wd = jax.random.normal(ks[3], (2, 100, 96), jnp.float32) * 0.1
    y = moe_gemm(x, wg, wu, wd)
    r = moe_gemm_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("B,H,S,D,bs", [(2, 2, 256, 64, 64),
                                        (1, 4, 128, 32, 128)])
def test_mlstm_scan_kernel(B, H, S, D, bs):
    import numpy as np
    from repro.kernels.mlstm_scan.ops import mlstm_scan
    from repro.kernels.mlstm_scan.ref import mlstm_scan_ref
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D)) / (D ** 0.5)
    v = jax.random.normal(ks[2], (B, H, S, D))
    ig = jax.random.normal(ks[3], (B, H, S))
    lf = -jax.nn.softplus(-jax.random.normal(ks[4], (B, H, S)))
    C0 = jnp.zeros((B, H, D, D))
    n0 = jnp.zeros((B, H, D))
    m0 = jnp.full((B, H), -1e30)
    h1, C1, n1, m1 = mlstm_scan(q, k, v, ig, lf, C0, n0, m0, bs=bs)
    h2, C2, n2, m2 = mlstm_scan_ref(q, k, v, ig, lf, C0, n0, m0)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)


def test_mlstm_block_kernel_path_matches_scan():
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.models import ssm as ssm_lib
    cfg = get_smoke_config("xlstm-1.3b")
    p = ssm_lib.mlstm_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 128, cfg.d_model), jnp.float32)
    y1, s1 = ssm_lib.mlstm_apply(p, cfg, x)
    y2, s2 = ssm_lib.mlstm_apply(p, cfg, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1["C"]), np.asarray(s2["C"]),
                               atol=1e-4)
