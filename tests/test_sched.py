"""The scheduling API: policy semantics, per-shape plan caching, and the
engine's per-(bucket, batch) online planning (the behavior the old engine
docstring promised and never had)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import PAPER_A6000, FinDEPPlanner
from repro.core.planner import PlannerConfig
from repro.core.solver import Plan
from repro.runtime import Request, RequestState, ServingEngine
from repro.sched import (EPSPipelinePolicy, FinDEPPolicy, OccupancySummary,
                         POLICIES, PlanCache, SchedulePolicy,
                         SequentialDEPPolicy, StaticPolicy, bucket_length,
                         make_policy)

CFG = get_smoke_config("qwen2-moe-a2.7b")
CLUSTER = DepClusterConfig(num_devices=8, ag=3, eg=5)


def mk_planner(**kw):
    pc = PlannerConfig(mem_cap_samples=8, **kw)
    return FinDEPPlanner(CFG, CLUSTER, PAPER_A6000, pc)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def test_policies_satisfy_protocol():
    planner = mk_planner()
    for name in POLICIES:
        pol = make_policy(name, planner, static_seq_len=256)
        assert isinstance(pol, SchedulePolicy)
        plan = pol.resolve("prefill", 256, 4)
        assert isinstance(plan, Plan)
        assert plan.r2 >= 1 and plan.m_a >= 1 and plan.r1 >= 1


def test_findep_forced_r2_1_matches_sequential():
    """FinDEP constrained to r2 = 1 IS the sequential coarse schedule:
    identical makespan and configuration under the same objective."""
    planner = mk_planner()
    seq = SequentialDEPPolicy(planner)
    for S, b in ((512, 4), (2048, 4), (2048, None)):
        p_seq = seq.resolve("prefill", S, b)
        p_fd = planner.plan(S, b, r2_cap=1)
        assert p_seq.r2 == 1
        assert p_fd.makespan == pytest.approx(p_seq.makespan)
        assert (p_fd.m_a, p_fd.r1, p_fd.order) == (
            p_seq.m_a, p_seq.r1, p_seq.order)


def test_findep_never_below_fixed_schedules():
    """Per-shape solving dominates both fixed-granularity baselines under
    the shared simulator objective."""
    planner = mk_planner()
    fd = FinDEPPolicy(planner)
    seq = SequentialDEPPolicy(planner)
    eps = EPSPipelinePolicy(planner, granularity=4)
    for S in (512, 2048):
        t_fd = fd.resolve("prefill", S, 4).throughput
        assert t_fd >= seq.resolve("prefill", S, 4).throughput * (1 - 1e-9)
        assert t_fd >= eps.resolve("prefill", S, 4).throughput * (1 - 1e-9)


def test_static_policy_is_shape_blind():
    planner = mk_planner()
    pol = StaticPolicy.from_planner(planner, 256)
    plans = {pol.resolve(ph, S, b)
             for ph in ("prefill", "decode")
             for S in (64, 256, 4096) for b in (1, 4, None)}
    assert len(plans) == 1


def test_eps_policy_fixed_granularity():
    planner = mk_planner()
    pol = EPSPipelinePolicy(planner, granularity=4)
    p = pol.resolve("prefill", 2048, 4)
    assert p.r1 == 1 and p.r2 == 4 and p.order == "AASS"


def test_infeasible_batch_falls_back_to_throughput_mode():
    """A live-batch larger than the memory cap must not crash the policy —
    it falls back to the solver-chosen batch."""
    planner = mk_planner()
    plan = FinDEPPolicy(planner).resolve("decode", 256, 1000)
    assert isinstance(plan, Plan)


def test_make_policy_rejects_unknown_and_bare_static():
    planner = mk_planner()
    with pytest.raises(ValueError):
        make_policy("nope", planner)
    with pytest.raises(ValueError):
        make_policy("static", planner)


# ---------------------------------------------------------------------------
# occupancy-aware resolution
# ---------------------------------------------------------------------------

def test_occupancy_summary_shape():
    occ = OccupancySummary.from_lengths([10, 70, 70, 500], max_bucket=256)
    assert occ.live == 4
    assert occ.hist == ((64, 1), (128, 2), (256, 1))
    # weighted mean (64 + 2*128 + 256) / 4 = 160 -> bucket 256
    assert occ.seq_bucket == bucket_length(160) == 256
    assert occ.max_bucket == 256
    # hashable + ordered: usable as a PlanCache key and sortable
    assert occ == OccupancySummary.from_lengths([70, 500, 10, 70],
                                                max_bucket=256)
    assert sorted([occ, OccupancySummary.from_lengths([5])])[0].live == 1


def test_policies_resolve_on_occupancy():
    """A decode resolve on an occupancy summary solves under the DECODE
    cost model (one token per live slot, attention linear in the
    histogram's mean context) — not the old prefill-style
    (seq_bucket, live) projection, which modeled a full sequence per slot
    and over-predicted a decode step's makespan by orders of magnitude."""
    planner = mk_planner()
    occ = OccupancySummary.from_lengths([100, 100, 400, 400])
    by_occ = FinDEPPolicy(planner).resolve("decode", occupancy=occ)
    assert by_occ == planner.plan_for_occupancy(occ)
    seq = SequentialDEPPolicy(planner).resolve("decode", occupancy=occ)
    assert seq == planner.plan_for_occupancy(occ, r2_cap=1)
    assert seq.r2 == 1
    # the decode-step makespan is far below the prefill-style projection
    proj = planner.plan(occ.seq_bucket, occ.live)
    assert by_occ.makespan < proj.makespan
    # EPS has no online solve; it still projects onto (seq_bucket, live)
    eps = EPSPipelinePolicy(planner, granularity=4)
    assert eps.resolve("decode", occupancy=occ) == \
        eps.resolve("decode", occ.seq_bucket, occ.live)
    # explicit shape arguments win over the summary
    p = FinDEPPolicy(planner).resolve("decode", 2048, occupancy=occ)
    assert p == FinDEPPolicy(planner).resolve("decode", 2048, occ.live)


def test_plan_cache_occupancy_keys():
    planner = mk_planner()
    cache = PlanCache(FinDEPPolicy(planner))
    occ_a = OccupancySummary.from_lengths([100, 100])
    occ_b = OccupancySummary.from_lengths([100, 2000])
    p1 = cache.get("decode", occupancy=occ_a)
    p2 = cache.get("decode", occupancy=occ_a)        # hit: same composition
    assert p1 is p2
    cache.get("decode", occupancy=occ_b)             # miss: new composition
    assert cache.stats.hits == 1 and cache.stats.misses == 2
    assert ("decode", occ_a) in cache.entries()
    with pytest.raises(ValueError):
        cache.get("decode")                           # neither shape nor occ


def test_plan_cache_shims_legacy_policy_signature():
    """A policy without the occupancy kwarg still serves occupancy lookups
    through the deprecated (phase, seq_bucket, batch) projection."""
    pol = CountingPolicy()
    cache = PlanCache(pol)
    occ = OccupancySummary.from_lengths([100, 100, 100])
    with pytest.warns(DeprecationWarning, match="legacy resolve"):
        cache.get("decode", occupancy=occ)
    assert pol.calls == [("decode", occ.seq_bucket, occ.live)]


# ---------------------------------------------------------------------------
# PlanCache
# ---------------------------------------------------------------------------

class CountingPolicy:
    name = "counting"

    def __init__(self):
        self.calls = []

    def resolve(self, phase, seq_bucket, batch_per_device=None):
        self.calls.append((phase, seq_bucket, batch_per_device))
        return Plan(m_a=1, r1=1, m_e=1.0, r2=len(self.calls), order="AASS",
                    throughput=1.0, makespan=1.0)


def test_plan_cache_hit_miss_accounting():
    pol = CountingPolicy()
    cache = PlanCache(pol)
    p1 = cache.get("decode", 256, 4)
    p2 = cache.get("decode", 256, 4)          # hit: same shape
    assert p1 is p2
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    assert len(pol.calls) == 1

    p3 = cache.get("decode", 256, 3)          # miss: batch changed
    p4 = cache.get("prefill", 256, 4)         # miss: phase changed
    p5 = cache.get("decode", 512, 4)          # miss: bucket changed
    assert len({p1.r2, p3.r2, p4.r2, p5.r2}) == 4
    assert cache.stats.misses == 4 and cache.stats.hits == 1
    assert cache.stats.solve_time_total >= 0.0
    assert len(cache) == 4
    assert cache.stats.hit_rate == pytest.approx(0.2)

    cache.clear()
    assert len(cache) == 0 and cache.stats.lookups == 0
    cache.get("decode", 256, 4)               # re-solve after clear
    assert len(pol.calls) == 5


def test_plan_cache_reresolves_on_shape_change_only():
    planner = mk_planner()
    cache = PlanCache(FinDEPPolicy(planner))
    for _ in range(10):
        cache.get("decode", 256, 4)
    assert planner.solve_count == 1
    cache.get("decode", 256, 2)
    assert planner.solve_count == 2


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def _mk_requests(rng, n, lo, hi, max_new=3):
    return [Request(prompt=list(rng.randint(0, CFG.vocab_size,
                                            size=rng.randint(lo, hi))),
                    max_new_tokens=max_new) for _ in range(n)]


def test_engine_resolves_plan_per_prefill_bucket_and_decode_shape():
    """Acceptance: two different request-length mixes must produce >= 2
    distinct plans — the engine consults the policy per shape instead of
    freezing one plan at construction time. Decode plans are keyed by the
    KV ledger's OccupancySummary (the real composition), not the old
    (max_context, live-count) proxy."""
    eng = ServingEngine(CFG, num_slots=2, max_context=256,
                        plan_policy=FinDEPPolicy(mk_planner()),
                        dtype=jnp.float32)
    rng = np.random.RandomState(0)
    # mix 1: short prompts (bucket 64); mix 2: long prompts (bucket 256)
    for r in _mk_requests(rng, 2, 4, 9, max_new=8) + \
            _mk_requests(rng, 2, 150, 200, max_new=8):
        eng.submit(r)
    finished = eng.run()
    assert len(finished) == 4
    keys = eng.resolved_plans().keys()
    prefill_buckets = {k[1] for k in keys if k[0] == "prefill"}
    assert len(prefill_buckets) >= 2, keys
    assert len(eng.plan_cache.distinct_plans()) >= 2
    decode_keys = [k for k in keys if k[0] == "decode"]
    assert len(decode_keys) >= 2, keys            # churn => >= 2 occupancies
    assert all(isinstance(k[1], OccupancySummary) for k in decode_keys)
    # steady-state decode must be served from the cache, not the solver
    assert eng.plan_cache.stats.hits > eng.plan_cache.stats.misses


def test_static_policy_reproduces_unscheduled_engine_bitforbit():
    """Plan threading must not perturb numerics: a StaticPolicy engine
    produces exactly the tokens of an engine with no policy at all."""
    rng = np.random.RandomState(1)
    prompts = [list(rng.randint(0, CFG.vocab_size, size=n))
               for n in (5, 9, 13)]

    def serve(policy):
        eng = ServingEngine(CFG, num_slots=2, max_context=128,
                            plan_policy=policy, dtype=jnp.float32, seed=0)
        reqs = [Request(prompt=p, max_new_tokens=4) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [r.output for r in reqs]

    static = StaticPolicy.from_planner(mk_planner(), 128)
    assert serve(None) == serve(static)


@pytest.mark.parametrize("name", POLICIES)
def test_all_policies_serve_end_to_end(name):
    pol = make_policy(name, mk_planner(), static_seq_len=64)
    eng = ServingEngine(CFG, num_slots=2, max_context=64,
                        plan_policy=pol, dtype=jnp.float32)
    rng = np.random.RandomState(2)
    reqs = _mk_requests(rng, 3, 4, 10, max_new=2)
    for r in reqs:
        eng.submit(r)
    finished = eng.run()
    assert len(finished) == 3
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert len(eng.plan_cache) >= 1


def test_legacy_planner_kwarg_still_works():
    with pytest.warns(DeprecationWarning, match="planner=.*deprecated"):
        eng = ServingEngine(CFG, num_slots=1, max_context=64,
                            planner=mk_planner(), dtype=jnp.float32)
    assert isinstance(eng.policy, FinDEPPolicy)
    rng = np.random.RandomState(3)
    (req,) = _mk_requests(rng, 1, 4, 8, max_new=2)
    eng.submit(req)
    assert eng.run() == [req]


def test_legacy_policy_kwarg_warns_and_works():
    pol = FinDEPPolicy(mk_planner())
    with pytest.warns(DeprecationWarning, match="policy=.*deprecated"):
        eng = ServingEngine(CFG, num_slots=1, max_context=64,
                            policy=pol, dtype=jnp.float32)
    assert eng.plan_policy is pol
    rng = np.random.RandomState(4)
    (req,) = _mk_requests(rng, 1, 4, 8, max_new=2)
    eng.submit(req)
    assert eng.run() == [req]


def test_execution_context_plan_shim_is_gone():
    """PR 1's ``ExecutionContext(plan=)`` shim is removed: plans flow per
    call only (model.forward/prefill/decode_step(plan=...))."""
    from repro.models.transformer import ExecutionContext
    with pytest.raises(TypeError):
        ExecutionContext(plan=Plan(m_a=1, r1=1, m_e=1.0, r2=2,
                                   order="AASS", throughput=0,
                                   makespan=0))
    assert not hasattr(ExecutionContext(), "plan")
