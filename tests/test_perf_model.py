"""alpha-beta performance models (paper Eqs. 7-9, Fig. 7 methodology)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import DepClusterConfig
from repro.core.perf_model import (PAPER_A6000, TPU_V5E, AlphaBeta,
                                   DepModelSpec, build_stage_models,
                                   fit_alpha_beta)

SPEC = DepModelSpec(S=2048, M=2048, H=1408, E=64, top_k=6, n_shared=2,
                    shared_H=1408, T=8, n_heads=16, d_k=128, d_v=128)
CLUSTER = DepClusterConfig(num_devices=8, ag=3, eg=5)


def test_fit_recovers_exact_line():
    xs = np.linspace(1e6, 1e9, 20)
    ts = 1.7e-4 + 8.59e-14 * xs
    model, r2 = fit_alpha_beta(xs, ts)
    assert abs(model.alpha - 1.7e-4) < 1e-9
    assert abs(model.beta - 8.59e-14) / 8.59e-14 < 1e-6
    assert r2 > 0.999999


def test_fit_r2_on_noisy_data():
    rng = np.random.RandomState(0)
    xs = np.linspace(1e6, 1e9, 50)
    ts = 1e-4 + 1e-13 * xs
    ts = ts * (1 + rng.normal(0, 0.01, ts.shape))
    _, r2 = fit_alpha_beta(xs, ts)
    # the paper reports R^2 > 0.994 for its microbenchmarks
    assert r2 > 0.99


@pytest.mark.parametrize("hw", [PAPER_A6000, TPU_V5E])
def test_stage_models_positive_and_monotone(hw):
    models = build_stage_models(hw, SPEC, CLUSTER)
    for m in (models.t_a, models.t_s, models.t_e, models.t_c):
        assert m(1) > 0
        assert m(64) > m(1)


def test_token_conservation_roundtrip():
    models = build_stage_models(PAPER_A6000, SPEC, CLUSTER)
    for m_a in (1, 4, 16):
        for r2 in (1, 2, 8):
            m_e = models.me_from_ma(m_a, r2)
            assert models.ma_from_me(m_e, r2) == pytest.approx(m_a)
            # paper constraint: m_a*ag*top_k*S == m_e*r2*E
            assert m_a * CLUSTER.ag * SPEC.top_k * SPEC.S == pytest.approx(
                m_e * r2 * SPEC.E)


@given(alpha=st.floats(1e-6, 1e-2), beta=st.floats(1e-16, 1e-10),
       x=st.floats(1.0, 1e12))
@settings(max_examples=50, deadline=None)
def test_alpha_beta_affine(alpha, beta, x):
    m = AlphaBeta(alpha, beta)
    assert m(x) == pytest.approx(alpha + beta * x)
    s = m.scaled(3)
    assert s(x) == pytest.approx(3 * alpha + 3 * beta * x)


def test_shared_expert_zero_when_absent():
    spec = DepModelSpec(S=2048, M=2048, H=1408, E=64, top_k=6, n_shared=0,
                        shared_H=0, T=8, n_heads=16, d_k=128, d_v=128)
    models = build_stage_models(PAPER_A6000, spec, CLUSTER)
    assert models.spec.n_shared == 0
