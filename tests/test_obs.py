"""Observability layer (repro.obs): metrics registry, span tracing,
Chrome-trace export, overlap attribution, and the engine wiring."""
import json

import numpy as np
import pytest

from repro.core.taskgraph import (LoweringSpec, TaskCosts, lower,
                                  lower_exec, schedule)
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       TraceRecorder, attribute_overlap, chrome_trace,
                       executed_exposed_comm, interval_subtract,
                       interval_total, interval_union, log_buckets,
                       parse_prometheus, use_tracer,
                       validate_chrome_trace)
from repro.obs.replay import replay_schedule
from repro.obs.trace import Span, active_tracer


class _Plan:
    r1, r2, order, m_e = 2, 3, "ASAS", 4


def _costs():
    return TaskCosts(attn=2e-3, shared=1e-3, exp=3e-3, comm=2.5e-3)


# ---------------------------------------------------------------------------
# metrics: histogram buckets + quantiles
# ---------------------------------------------------------------------------

def test_log_buckets_boundaries():
    b = log_buckets(1e-5, 100.0, per_decade=3)
    assert b[0] == pytest.approx(1e-5)
    assert b[-1] == pytest.approx(100.0)
    # log-spaced: constant ratio of 10^(1/3) between boundaries
    ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
    assert all(r == pytest.approx(10 ** (1 / 3), rel=1e-9)
               for r in ratios)
    # 7 decades at 3 per decade + the endpoint
    assert len(b) == 7 * 3 + 1


def test_histogram_bucket_edges():
    h = Histogram("h", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 1.0, 5.0, 10.0, 50.0, 1000.0):
        h.observe(v)
    # bisect_left: v <= boundary lands in that boundary's bucket
    assert h.bucket_counts == [2, 2, 1, 1]
    assert h.count == 6
    assert h.sum == pytest.approx(1066.5)


def test_histogram_quantiles_vs_numpy():
    rng = np.random.RandomState(7)
    vals = rng.lognormal(mean=-6.0, sigma=1.5, size=5000)
    h = Histogram("h")           # default log buckets, 3 per decade
    for v in vals:
        h.observe(v)
    ratio = 10 ** (1 / 3)        # one bucket width = max interp error
    for q in (0.5, 0.9, 0.99):
        est = h.quantile(q)
        exact = float(np.quantile(vals, q))
        assert exact / ratio <= est <= exact * ratio, \
            f"q={q}: est {est} vs exact {exact}"


def test_histogram_overflow_clamps():
    h = Histogram("h", buckets=(1.0, 2.0))
    for _ in range(10):
        h.observe(100.0)
    assert h.p50 == 2.0 and h.p99 == 2.0


# ---------------------------------------------------------------------------
# metrics: registry snapshot + reset + prometheus round-trip
# ---------------------------------------------------------------------------

def test_counter_gauge_snapshot_roundtrip():
    m = MetricsRegistry()
    c = m.counter("repro_test_events_total", "events")
    g = m.gauge("repro_test_queue_depth", "depth")
    c.inc(); c.inc(3)
    g.set(7.5)
    snap = m.snapshot()
    assert snap["repro_test_events_total"] == 4.0
    assert snap["repro_test_queue_depth"] == 7.5
    with pytest.raises(ValueError):
        c.inc(-1)
    # same (name, labels) returns the same object; mismatched type raises
    assert m.counter("repro_test_events_total") is c
    with pytest.raises(ValueError):
        m.gauge("repro_test_events_total")


def test_registry_source_and_reset_hook():
    m = MetricsRegistry()
    state = {"x": 2.0, "resets": 0}
    m.register_source("repro_src", lambda: {"x": state["x"]})
    m.register_reset(lambda: state.__setitem__("resets",
                                               state["resets"] + 1))
    c = m.counter("repro_test_total")
    c.inc(5)
    snap = m.snapshot()
    assert snap["repro_src_x"] == 2.0
    assert snap["repro_test_total"] == 5.0
    m.reset()
    assert state["resets"] == 1
    assert m.snapshot()["repro_test_total"] == 0.0


def test_prometheus_render_parse_roundtrip_with_escaping():
    m = MetricsRegistry()
    nasty = 'a"b\\c\nd'
    m.counter("repro_test_total", 'help with "quotes"',
              labels={"state": nasty}).inc(3)
    h = m.histogram("repro_test_seconds", buckets=(0.1, 1.0))
    h.observe(0.05); h.observe(0.5); h.observe(5.0)
    text = m.render_prometheus()
    samples = parse_prometheus(text)
    by_name = {}
    for name, labels, value in samples:
        by_name.setdefault(name, []).append((labels, value))
    assert by_name["repro_test_total"] == [({"state": nasty}, 3.0)]
    buckets = {lab["le"]: v
               for lab, v in by_name["repro_test_seconds_bucket"]}
    assert buckets["+Inf"] == 3.0         # cumulative
    assert buckets["0.1"] == 1.0 and buckets["1"] == 2.0
    assert by_name["repro_test_seconds_count"][0][1] == 3.0
    assert by_name["repro_test_seconds_sum"][0][1] == \
        pytest.approx(5.55)


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def test_span_recording_and_disabled_noop():
    t = [0.0]
    rec = TraceRecorder(clock=lambda: t[0])
    with rec.span("phase_a", track="engine", foo=1):
        t[0] = 1.5
    assert len(rec.spans) == 1
    s = rec.spans[0]
    assert (s.name, s.track, s.start, s.end) == ("phase_a", "engine",
                                                 0.0, 1.5)
    assert s.arg("foo") == 1
    off = TraceRecorder(enabled=False)
    with off.span("x"):
        pass
    off.instant("y")
    assert len(off) == 0


def test_active_tracer_scoping():
    assert active_tracer() is None
    rec = TraceRecorder()
    with use_tracer(rec):
        assert active_tracer() is rec
        with use_tracer(None):       # inner None shadows
            assert active_tracer() is None
        assert active_tracer() is rec
    assert active_tracer() is None
    off = TraceRecorder(enabled=False)
    with use_tracer(off):             # disabled recorder -> None
        assert active_tracer() is None


def test_request_lifecycle_spans():
    from repro.runtime.request import Request
    req = Request(prompt=[1, 2, 3], max_new_tokens=4)
    req.arrival_t, req.admit_t = 10.0, 11.0
    req.first_token_t, req.finish_t = 12.0, 14.0
    req.output = [5, 6, 7, 8]
    assert req.ttft == pytest.approx(2.0)
    assert req.tpot == pytest.approx(2.0 / 3)
    rec = TraceRecorder()
    rec.request_lifecycle(req)
    spans = {s.name: s for s in rec.by_cat("request")}
    assert spans["queued"].start == 10.0 and spans["queued"].end == 11.0
    assert spans["prefill"].end == 12.0
    assert spans["decode"].end == 14.0
    assert spans["decode"].arg("tokens") == 4


def test_dep_walk_emits_task_spans_under_tracer():
    from repro.core.dep import _walk_chunk_stream
    graph = lower_exec(3, "ASAS", 1)
    seen = []
    handlers = {k: seen.append
                for k in ("GATE", "A2E", "SHARED", "EXP", "E2A")}
    rec = TraceRecorder()
    with use_tracer(rec):
        _walk_chunk_stream(graph, handlers)
    emitted = rec.task_spans(emitted=True)
    assert len(emitted) == len(seen) == len(graph.exec_walk())
    assert [s.name for s in emitted] == [t.kind for t in seen]
    # without a tracer: same walk, zero spans
    seen2 = []
    _walk_chunk_stream(graph, {k: seen2.append for k in handlers})
    assert [t.kind for t in seen2] == [t.kind for t in seen]
    assert len(rec.task_spans(emitted=True)) == len(emitted)


# ---------------------------------------------------------------------------
# export + validation
# ---------------------------------------------------------------------------

def test_chrome_trace_export_and_validate(tmp_path):
    graph = lower(_Plan, LoweringSpec(T=2))
    res = schedule(graph, _costs())
    rec = TraceRecorder(clock=iter(np.arange(0, 100, 0.5)).__next__)
    with rec.span("step"):
        rec.instant("mark")
    obj = chrome_trace(tracer=rec, schedule=res)
    stats = validate_chrome_trace(obj)
    assert stats["complete"] == len(graph.tasks) + 1
    assert stats["tracks"] == 5          # 4 lanes + engine track
    # JSON string input works too
    validate_chrome_trace(json.dumps(obj))


def test_validate_rejects_partial_overlap_and_missing_keys():
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 0},
        {"name": "b", "ph": "X", "ts": 5, "dur": 10, "pid": 1, "tid": 0},
    ]}
    with pytest.raises(ValueError, match="partially overlaps"):
        validate_chrome_trace(bad)
    with pytest.raises(ValueError, match="missing key"):
        validate_chrome_trace({"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0, "pid": 1, "tid": 0}]})
    with pytest.raises(ValueError):
        validate_chrome_trace({"noTraceEvents": []})
    # nested + disjoint are fine
    validate_chrome_trace({"traceEvents": [
        {"name": "o", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 0},
        {"name": "i", "ph": "X", "ts": 2, "dur": 3, "pid": 1, "tid": 0},
        {"name": "n", "ph": "X", "ts": 20, "dur": 5, "pid": 1, "tid": 0},
    ]})


# ---------------------------------------------------------------------------
# overlap attribution
# ---------------------------------------------------------------------------

def test_interval_algebra():
    u = interval_union([(3.0, 4.0), (0.0, 2.0), (1.0, 2.5), (4.0, 5.0)])
    assert u == [(0.0, 2.5), (3.0, 5.0)]
    assert interval_total(u) == pytest.approx(4.5)
    assert interval_subtract([(0.0, 10.0)], [(2.0, 3.0), (5.0, 7.0)]) \
        == [(0.0, 2.0), (3.0, 5.0), (7.0, 10.0)]
    assert interval_subtract([(0.0, 2.0)], [(0.0, 3.0)]) == []


def _span(kind, lane, s, e):
    return Span(name=kind, track=lane, start=s, end=e, cat="task",
                args=(("kind", kind), ("lane", lane)))


def test_executed_exposed_comm_synthetic():
    spans = [
        _span("ATTN", "AG", 0.0, 2.0),
        _span("A2E", "A2E", 1.0, 3.0),   # 1s beyond AG -> exposed 1s
        _span("EXP", "EG", 3.0, 5.0),
        _span("E2A", "E2A", 4.0, 7.0),   # 2s beyond EG -> exposed 2s
    ]
    exp = executed_exposed_comm(spans)
    assert exp["A2E"] == pytest.approx(1.0)
    assert exp["E2A"] == pytest.approx(2.0)
    assert exp["total"] == pytest.approx(3.0)


def test_attribute_overlap_on_exact_schedule_spans():
    """Feeding the scheduler's own (task, start, end) spans back through
    the attributor must produce gap == 0: both sides reduce the same
    intervals."""
    graph = lower(_Plan, LoweringSpec(T=2))
    res = schedule(graph, _costs())
    spans = [Span(name=t.kind, track=t.resource, start=s, end=e,
                  cat="task", args=(("kind", t.kind),
                                    ("lane", t.resource)))
             for t, s, e in res.spans()]
    rep = attribute_overlap(spans, res)
    assert rep.gap == pytest.approx(0.0, abs=1e-12)
    assert rep.makespan_executed == pytest.approx(res.makespan)
    for lane, busy in res.busy.items():
        assert rep.busy_executed.get(lane, 0.0) == pytest.approx(busy)
    ex = rep.breakdown_executed
    md = rep.breakdown_modeled.as_dict()
    for cls in ("gemm", "attn", "comm"):
        assert ex[cls] == pytest.approx(md[cls])
    d = rep.as_dict()
    assert d["gap"] == rep.gap
    assert d["busy_modeled_AG_s"] == pytest.approx(res.busy["AG"])


def test_schedule_result_spans_and_lane_idle():
    graph = lower(_Plan, LoweringSpec(T=1))
    res = schedule(graph, _costs())
    spans = res.spans()
    assert len(spans) == len(graph.tasks)
    assert all(e >= s for _, s, e in spans)
    idle = res.lane_idle()
    for lane, busy in res.busy.items():
        assert idle[lane] == pytest.approx(res.makespan - busy)


@pytest.mark.slow
def test_replay_matches_schedule_within_eps():
    graph = lower(_Plan, LoweringSpec(T=2))
    rr = replay_schedule(graph, _costs(), max_wall_s=0.3)
    assert len(rr.spans) == len(graph.tasks)
    rep = attribute_overlap(rr.spans, rr.scheduled,
                            time_scale=rr.time_scale)
    # host-thread replay: generous CI bound (typically < 0.01 locally)
    assert rep.within(0.15), (rep.gap, rep.exposed_frac_executed,
                              rep.exposed_frac_modeled)
    assert rep.makespan_executed == pytest.approx(
        rep.makespan_modeled, rel=0.25)


# ---------------------------------------------------------------------------
# engine wiring
# ---------------------------------------------------------------------------

def _mini_engine(**kw):
    from repro.configs import get_smoke_config
    from repro.runtime.engine import ServingEngine
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    return ServingEngine(cfg, num_slots=2, max_context=64, **kw)


def _serve(eng, n=2, max_new=3):
    from repro.runtime.request import Request
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=list(rng.randint(1, 100, size=4 + i)),
                    max_new_tokens=max_new) for i in range(n)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return reqs


@pytest.mark.slow
def test_tracer_off_is_bit_identical_and_compiles_nothing_new():
    """The acceptance lock: tracing changes neither the decoded tokens
    nor the set of compiled decode programs."""
    eng_off = _mini_engine(seed=3)
    eng_on = _mini_engine(seed=3, tracer=TraceRecorder())
    reqs_off = _serve(eng_off)
    reqs_on = _serve(eng_on)
    assert [r.output for r in reqs_off] == [r.output for r in reqs_on]
    assert eng_off._decode_jit._cache_size() \
        == eng_on._decode_jit._cache_size()
    assert len(eng_on.tracer.by_cat("phase")) > 0
    queued = [s for s in eng_on.tracer.by_cat("request")
              if s.name == "queued"]
    assert len(queued) == len(reqs_on)
    eng_off.close(); eng_on.close()


@pytest.mark.slow
def test_engine_metrics_and_registry_reset():
    eng = _mini_engine()
    reqs = _serve(eng)
    m = eng.metrics
    snap = m.snapshot()
    assert snap["repro_engine_decode_step_seconds_count"] >= 1
    assert snap["repro_engine_steps_total"] == float(eng.stats.steps)
    finished = snap['repro_engine_requests_total{state="finished"}']
    assert finished == float(len(reqs))
    assert m.histogram("repro_engine_ttft_seconds").count == len(reqs)
    assert m.histogram("repro_engine_tpot_seconds").count == len(reqs)
    # prometheus text parses and carries the histogram family
    names = {n for n, _, _ in parse_prometheus(m.render_prometheus())}
    assert "repro_engine_ttft_seconds_bucket" in names
    # seed telemetry with an EWMA, then check ONE reset clears all of it
    assert eng.telemetry.phases
    eng.reset_stats()
    assert eng.stats.steps == 0
    assert not eng.telemetry.phases and not eng.telemetry.keys
    assert m.histogram("repro_engine_ttft_seconds").count == 0
    assert m.snapshot()["repro_engine_decode_step_seconds_count"] == 0
    eng.close()


def test_engine_metrics_false_disables():
    eng = _mini_engine(metrics=False)
    assert eng.metrics is None
    eng.reset_stats()       # still resets the direct surfaces
    assert eng.stats.steps == 0
    eng.close()


def test_step_timer_reset_clears_ewma_state():
    from repro.profiling.telemetry import StepTimer
    t = StepTimer(key_warmup=0)
    for _ in range(3):
        t.observe("decode", 2e-3, predicted_s=1e-3, key="k")
    assert t.key_residual("k") is not None
    assert t.snapshot()["decode_count"] == 3
    t.reset()
    assert not t.phases and not t.keys
    assert t.snapshot()["tracked_keys"] == 0


def test_paging_stats_and_tracker_reset():
    from repro.placement.tracker import ExpertLoadTracker
    from repro.runtime.paging import PagingStats
    ps = PagingStats(prefix_hit_tokens=5, prefix_miss_tokens=5,
                     preemptions=2)
    ps.reset()
    assert ps.prefix_hit_rate == 0.0 and ps.preemptions == 0
    tr = ExpertLoadTracker(4)
    tr.observe([4.0, 0.0, 0.0, 0.0])
    assert tr.snapshot()["imbalance"] == pytest.approx(4.0)
    tr.reset()
    assert tr.snapshot()["observations"] == 0.0
