"""Ragged decode attention on the serving path.

Kernel level: the length-aware Pallas decode kernel vs the dense oracle on
mixed-length batches (including length-0 / freshly-freed rows and all-full
rows), non-dividing cache lengths (the old ``C % bc`` AssertionError), and
the STRUCTURAL block-skip guarantee — executed KV blocks per row must be
ceil(length/bc), not C/bc (counted, not timed: CI is CPU interpret mode).

Engine level: decoded tokens are bit-identical with the kernel wired in
(attn_impl="decode_kernel", the default) vs the dense SDPA path
(attn_impl="xla") on a mixed-occupancy batch, and a request generating
past max_context terminates cleanly (LENGTH_CAPPED) instead of clobbering
its last KV row.

Model level: the decode cost model is linear in the occupancy histogram's
mean context, and the DEP shared-expert emission honors the solved order.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import PAPER_A6000, FinDEPPlanner
from repro.core import dep
from repro.core.planner import PlannerConfig
from repro.kernels.decode_attention.kernel import (decode_attention_pallas,
                                                   largest_block_size)
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.runtime import Request, RequestState, ServingEngine
from repro.sched import OccupancySummary

KEY = jax.random.PRNGKey(7)


def _qkv(B, C, H, Kv, D, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, C, Kv, D), dtype)
    v = jax.random.normal(ks[2], (B, C, Kv, D), dtype)
    return q, k, v


def _tol(dt):
    return dict(rtol=2e-2, atol=5e-2) if dt == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# kernel: ragged parity + shapes + block skip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_parity_mixed_lengths(dtype):
    """Mixed lengths including 0 (freshly-freed slot) and C (full row)."""
    B, C, H, Kv, D = 6, 512, 8, 2, 64
    q, k, v = _qkv(B, C, H, Kv, D, dtype)
    lengths = jnp.asarray([0, 1, 37, 128, 300, 512], jnp.int32)
    y = decode_attention_pallas(q, k, v, lengths, bc=128)
    r = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))
    # a freed row's output is exact zeros, not the mean of V
    assert float(jnp.max(jnp.abs(y[0]))) == 0.0


def test_kernel_parity_under_jit_and_ops_wrapper():
    B, C, H, Kv, D = 4, 256, 4, 4, 32
    q, k, v = _qkv(B, C, H, Kv, D)
    lengths = jnp.asarray([5, 64, 200, 256], jnp.int32)
    r = decode_attention_ref(q, k, v, lengths)
    y = jax.jit(lambda *a: decode_attention_pallas(*a, bc=64))(q, k, v,
                                                              lengths)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=1e-5,
                               atol=1e-5)
    y2 = decode_attention(q, k, v, lengths, bc=64)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(r), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("C,bc,expect_bc", [(600, 512, 300), (384, 512, 384),
                                            (384, 128, 128)])
def test_kernel_nondividing_cache_lengths(C, bc, expect_bc):
    """C % bc != 0 used to raise AssertionError after bc = min(bc, C);
    now the kernel runs at the largest block size dividing C."""
    assert largest_block_size(C, bc) == expect_bc
    B, H, Kv, D = 3, 4, 2, 32
    q, k, v = _qkv(B, C, H, Kv, D)
    lengths = jnp.asarray([1, C // 2, C], jnp.int32)
    y = decode_attention_pallas(q, k, v, lengths, bc=bc)
    r = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=1e-5,
                               atol=1e-5)
    # and through the jit'd public wrapper
    y2 = decode_attention(q, k, v, lengths, bc=bc)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(r), rtol=1e-5,
                               atol=1e-5)


def test_kernel_block_skip_counts():
    """Structural acceptance: executed KV blocks per row proportional to
    ceil(length/bc), NOT C/bc (counted — interpret mode has no wall
    clock worth timing)."""
    B, C, H, Kv, D, bc = 5, 1024, 4, 2, 32, 128
    q, k, v = _qkv(B, C, H, Kv, D)
    lengths = jnp.asarray([0, 1, 130, 512, 1024], jnp.int32)
    _, counts = decode_attention_pallas(q, k, v, lengths, bc=bc,
                                        return_block_counts=True)
    counts = np.asarray(counts)                        # [B, Kv]
    expect = [-(-int(l) // bc) for l in lengths]       # ceil(l/bc)
    for kv in range(Kv):
        assert list(counts[:, kv]) == expect, (counts, expect)
    total = C // bc
    # short rows really skip: far fewer executed blocks than the cache has
    assert counts[1].max() == 1 < total
    assert counts[2].max() == 2 < total
    assert counts[4].max() == total


def test_ops_pathological_length_falls_back_to_ref():
    """A prime cache length has no usable block size; the wrapper must
    still be correct (oracle path)."""
    B, C, H, Kv, D = 2, 127, 4, 2, 32
    q, k, v = _qkv(B, C, H, Kv, D)
    lengths = jnp.asarray([50, 127], jnp.int32)
    y = decode_attention(q, k, v, lengths)
    r = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# engine: kernel on the serving path
# ---------------------------------------------------------------------------

def _serve_mixed(attn_impl, prompts, max_new=6):
    cfg = get_smoke_config("qwen2-1.5b")
    eng = ServingEngine(cfg, num_slots=3, max_context=128,
                        attn_impl=attn_impl, dtype=jnp.float32, seed=0)
    reqs = [Request(prompt=p, max_new_tokens=max_new) for p in prompts]
    # staggered arrivals => mixed occupancy (slots at different contexts)
    eng.submit(reqs[0])
    eng.step()
    eng.step()
    for r in reqs[1:]:
        eng.submit(r)
    while eng.step() or eng.waiting:
        pass
    assert all(r.state == RequestState.FINISHED for r in reqs)
    return [r.output for r in reqs]


def test_engine_tokens_identical_with_and_without_kernel():
    """Acceptance: wiring the ragged kernel into the decode path must not
    change a single decoded token on a mixed-occupancy batch."""
    rng = np.random.RandomState(0)
    cfg = get_smoke_config("qwen2-1.5b")
    prompts = [list(rng.randint(0, cfg.vocab_size, size=n))
               for n in (4, 21, 50)]
    assert _serve_mixed("xla", prompts) == \
        _serve_mixed("decode_kernel", prompts)


def test_engine_finishes_request_at_kv_cap():
    """A request generating past max_context terminates cleanly
    (LENGTH_CAPPED) instead of clobbering the last cache row forever."""
    cfg = get_smoke_config("qwen2-1.5b")
    C = 32
    rng = np.random.RandomState(1)
    prompt = list(rng.randint(0, cfg.vocab_size, size=8))

    eng = ServingEngine(cfg, num_slots=1, max_context=C, dtype=jnp.float32,
                        seed=0)
    req = Request(prompt=prompt, max_new_tokens=10_000)
    eng.submit(req)
    steps = 0
    while eng.step() or eng.waiting:
        steps += 1
        assert steps < 200, "engine did not terminate at the KV cap"
        # the ledger never counts past max_context between steps
        assert all(eng.kv.length(s) <= C for s in eng.kv.live_slots())
    assert req.state == RequestState.LENGTH_CAPPED
    # output stops exactly at the cap: the slot's context (prompt + output)
    # fills all C cache rows, each written once
    assert len(req.output) == C - len(prompt) + 1
    assert eng.kv.live_count() == 0                 # slot freed

    # the tokens up to the cap are exactly what an uncapped-length request
    # would have produced — the cap ends generation, it does not corrupt it
    eng2 = ServingEngine(cfg, num_slots=1, max_context=C, dtype=jnp.float32,
                         seed=0)
    req2 = Request(prompt=prompt, max_new_tokens=C - len(prompt) + 1)
    eng2.submit(req2)
    eng2.run()
    assert req2.state == RequestState.FINISHED
    assert req2.output == req.output
    # the last cache row holds the same (single-write) KV in both runs
    for c1, c2 in zip(eng.kv.caches, eng2.kv.caches):
        if isinstance(c1, dict) and "k" in c1:
            np.testing.assert_array_equal(np.asarray(c1["k"][0, C - 1]),
                                          np.asarray(c2["k"][0, C - 1]))


# ---------------------------------------------------------------------------
# DEP shared-expert order (replicated-token decode path)
# ---------------------------------------------------------------------------

def test_shared_schedule_honors_solved_order():
    """ASAS lowers the shared expert as r2 segments at chunk boundaries;
    AASS as one whole-batch task at boundary 0 — the executor walk emits
    exactly those segments (the replicated decode path used to silently
    emit AASS placement for ASAS plans)."""
    from repro.core import taskgraph as tg

    x = jnp.arange(30.0).reshape(10, 3)
    calls = []

    def fn(seg):
        calls.append(int(seg.shape[0]))
        return seg * 2.0

    graph = tg.lower_exec(4, "ASAS")
    shared = [t for t in graph.exec_walk() if t.kind == tg.SHARED]
    assert [t.chunk for t in shared] == [0, 1, 2, 3]
    parts = [dep._shared_part(fn, x, t.chunk, graph.shared_segments)
             for t in shared]
    assert calls == [2, 2, 2, 4]                  # 10 rows over 4 chunks
    np.testing.assert_allclose(np.asarray(jnp.concatenate(parts, axis=0)),
                               np.asarray(x * 2.0))

    calls.clear()
    graph = tg.lower_exec(4, "AASS")
    shared = [t for t in graph.exec_walk() if t.kind == tg.SHARED]
    assert [t.chunk for t in shared] == [0]       # whole batch at chunk 0
    part = dep._shared_part(fn, x, 0, graph.shared_segments)
    assert calls == [10]
    np.testing.assert_allclose(np.asarray(part), np.asarray(x * 2.0))


# ---------------------------------------------------------------------------
# decode cost model: occupancy-proportional
# ---------------------------------------------------------------------------

def _mk_planner():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cluster = DepClusterConfig(num_devices=8, ag=3, eg=5)
    return FinDEPPlanner(cfg, cluster, PAPER_A6000,
                         PlannerConfig(mem_cap_samples=8))


def test_occupancy_mean_std_context():
    occ = OccupancySummary.from_lengths([10, 70, 70, 500], max_bucket=256)
    # bucketed lengths: 64, 128, 128, 256
    assert occ.mean_context == pytest.approx(144.0)
    var = ((64 - 144) ** 2 + 2 * (128 - 144) ** 2 + (256 - 144) ** 2) / 4
    assert occ.std_context == pytest.approx(var ** 0.5)
    empty = OccupancySummary.from_lengths([])
    assert empty.mean_context == 0.0 and empty.std_context == 0.0


def test_decode_attention_term_linear_in_context():
    """The decode attention workload grows linearly with the histogram's
    mean context (the ragged kernel streams ceil(len/bc) blocks per row),
    replacing the prefill-style S^2 term."""
    planner = _mk_planner()
    hw = planner.hardware
    spec1 = planner.stage_models(1, decode_context=256.0)
    spec2 = planner.stage_models(1, decode_context=512.0)
    nh = spec1.spec.n_heads
    dd = spec1.spec.d_k + spec1.spec.d_v
    assert spec2.t_a.beta - spec1.t_a.beta == pytest.approx(
        hw.attn.beta * 256.0 * nh * dd)


def test_decode_plan_makespan_tracks_occupancy():
    """plan_for_occupancy: makespan is monotone in mean context and far
    below the old prefill-style projection (which modeled a full sequence
    per live slot)."""
    planner = _mk_planner()
    occ_lo = OccupancySummary.from_lengths([128] * 4)
    occ_hi = OccupancySummary.from_lengths([2048] * 4)
    p_lo = planner.plan_for_occupancy(occ_lo)
    p_hi = planner.plan_for_occupancy(occ_hi)
    assert p_hi.makespan > p_lo.makespan
    proj = planner.plan(occ_hi.seq_bucket, occ_hi.live)
    assert p_hi.makespan < proj.makespan
    # heterogeneous composition widens the context estimate (same mean)
    occ_mix = OccupancySummary.from_lengths([64, 64, 2048, 2048])
    mid = (occ_mix.mean_context
           + occ_mix.std_context / np.sqrt(occ_mix.live))
    assert mid > occ_mix.mean_context
