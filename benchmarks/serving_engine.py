"""Live CPU serving throughput: the end-to-end engine on a reduced MoE
model (real execution, not simulation) with per-shape online scheduling
through the pluggable policy layer (--policy) and pluggable request
admission (--admission fcfs|spf|token_budget, --token-budget N). Decode
plans are resolved per KV-ledger occupancy summary, so a churn workload
(mixed prompt/output lengths) exercises >= 2 distinct decode solves."""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import FinDEPPlanner, PAPER_A6000
from repro.core.planner import PlannerConfig
from repro.runtime import ADMISSIONS, Request, ServingEngine
from repro.sched import POLICIES, make_policy

MAX_CONTEXT = 128


def run(policy: str = "findep", admission: str = "fcfs",
        token_budget=None):
    rows = []
    info = {}
    for arch in ("qwen2-moe-a2.7b", "qwen2-1.5b"):
        cfg = get_smoke_config(arch)
        pol = None
        if cfg.is_moe:
            planner = FinDEPPlanner(cfg, DepClusterConfig(8, 3, 5),
                                    PAPER_A6000,
                                    PlannerConfig(mem_cap_samples=8))
            pol = make_policy(policy, planner, static_seq_len=MAX_CONTEXT)
        eng = ServingEngine(cfg, num_slots=4, max_context=MAX_CONTEXT,
                            plan_policy=pol, admission=admission,
                            token_budget=token_budget, dtype=jnp.float32)
        # warmup compiles prefill/decode; reset so idle/compile time is
        # not billed to throughput (reset_stats also clears the StepTimer
        # EWMAs the old stats.reset() left carrying warmup samples)
        eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=2))
        eng.run()
        eng.reset_stats()
        rng = np.random.RandomState(0)
        # churn: mixed prompt lengths (buckets 64 and 128) and staggered
        # finishes, so the decode composition actually varies
        reqs = [Request(prompt=list(rng.randint(0, cfg.vocab_size,
                                                size=rng.randint(4, 110))),
                        max_new_tokens=int(rng.randint(8, 24)))
                for _ in range(8)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        tok = eng.stats.decode_tokens
        sched = ""
        if eng.plan_cache is not None:
            s = eng.plan_cache.stats
            decode_keys = [k for k in eng.resolved_plans()
                           if k[0] == "decode"]
            info[f"{arch}.decode_resolutions"] = len(decode_keys)
            sched = (f";policy={policy};admission={admission};"
                     f"plans={len(eng.plan_cache)};"
                     f"decode_resolutions={len(decode_keys)};"
                     f"hit_rate={s.hit_rate:.2f};"
                     f"solve_ms={s.solve_time_total*1e3:.1f}")
        rows.append(csv_row(
            f"serving_engine.{arch}", dt / max(tok, 1) * 1e6,
            f"decode_tokens={tok};tokens_per_s={tok/dt:.1f};"
            f"engine_tps={eng.stats.throughput():.1f};"
            f"ttft_ms={np.mean([r.ttft for r in reqs])*1e3:.1f}" + sched))
    return rows, info


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", choices=POLICIES, default="findep")
    ap.add_argument("--admission", choices=ADMISSIONS, default="fcfs")
    ap.add_argument("--token-budget", type=int, default=None)
    args = ap.parse_args()
    rows, info = run(policy=args.policy, admission=args.admission,
                     token_budget=args.token_budget)
    for r in rows:
        print(r)
    if info:
        print(f"# {info}")
