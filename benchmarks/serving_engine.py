"""Live CPU serving throughput: the end-to-end engine on a reduced MoE
model (real execution, not simulation) with FinDEP online planning."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_smoke_config
from repro.runtime import Request, ServingEngine


def run():
    rows = []
    for arch in ("qwen2-moe-a2.7b", "qwen2-1.5b"):
        cfg = get_smoke_config(arch)
        eng = ServingEngine(cfg, num_slots=4, max_context=128,
                            dtype=jnp.float32)
        rng = np.random.RandomState(0)
        reqs = [Request(prompt=list(rng.randint(0, cfg.vocab_size, size=8)),
                        max_new_tokens=16) for _ in range(8)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        while eng.step() or eng.waiting:
            pass
        dt = time.perf_counter() - t0
        tok = eng.stats.decode_tokens
        rows.append(csv_row(
            f"serving_engine.{arch}", dt / max(tok, 1) * 1e6,
            f"decode_tokens={tok};tokens_per_s={tok/dt:.1f};"
            f"ttft_ms={np.mean([r.ttft for r in reqs])*1e3:.1f}"))
    return rows, {}


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
