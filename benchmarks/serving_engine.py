"""Live CPU serving throughput: the end-to-end engine on a reduced MoE
model (real execution, not simulation) with per-shape online scheduling
through the pluggable policy layer (select with --policy)."""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import FinDEPPlanner, PAPER_A6000
from repro.core.planner import PlannerConfig
from repro.runtime import Request, ServingEngine
from repro.sched import POLICIES, make_policy

MAX_CONTEXT = 128


def run(policy: str = "findep"):
    rows = []
    for arch in ("qwen2-moe-a2.7b", "qwen2-1.5b"):
        cfg = get_smoke_config(arch)
        pol = None
        if cfg.is_moe:
            planner = FinDEPPlanner(cfg, DepClusterConfig(8, 3, 5),
                                    PAPER_A6000,
                                    PlannerConfig(mem_cap_samples=8))
            pol = make_policy(policy, planner, static_seq_len=MAX_CONTEXT)
        eng = ServingEngine(cfg, num_slots=4, max_context=MAX_CONTEXT,
                            policy=pol, dtype=jnp.float32)
        rng = np.random.RandomState(0)
        reqs = [Request(prompt=list(rng.randint(0, cfg.vocab_size, size=8)),
                        max_new_tokens=16) for _ in range(8)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        tok = eng.stats.decode_tokens
        sched = ""
        if eng.plan_cache is not None:
            s = eng.plan_cache.stats
            sched = (f";policy={policy};plans={len(eng.plan_cache)};"
                     f"hit_rate={s.hit_rate:.2f};"
                     f"solve_ms={s.solve_time_total*1e3:.1f}")
        rows.append(csv_row(
            f"serving_engine.{arch}", dt / max(tok, 1) * 1e6,
            f"decode_tokens={tok};tokens_per_s={tok/dt:.1f};"
            f"ttft_ms={np.mean([r.ttft for r in reqs])*1e3:.1f}" + sched))
    return rows, {}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", choices=POLICIES, default="findep")
    args = ap.parse_args()
    for r in run(policy=args.policy)[0]:
        print(r)
