"""Paged-KV capacity benchmark (ISSUE 6 acceptance claim).

Drives the REAL ``PagedKVCacheManager`` ledger (no model arrays — the
block accounting is identical with or without the scatter) through a
shared-system-prompt conversation trace at a fixed HBM budget and
compares against dense allocation at the same budget:

  dense   each conversation reserves a full ``[max_context]`` KV row up
          front, so capacity = budget_tokens // max_context regardless
          of how short conversations actually run
  paged   conversations pin only the pages their live tokens occupy and
          share the system-prompt prefix blocks, so the same budget
          holds far more concurrent conversations

Claims checked (``--check`` exits nonzero on failure, same contract as
perf_model_fit):
  * >= 2x concurrent-conversation capacity at the fixed HBM budget
  * prefix-cache hit rate > 0 on the shared-system-prompt trace
"""
from __future__ import annotations

import sys

from benchmarks.common import csv_row
from repro.runtime import PagedKVCacheManager

MAX_CONTEXT = 4096
BLOCK_SIZE = 32
# fixed HBM budget: 8192 KV positions per layer = 256 pages of 32
BUDGET_TOKENS = 8192
BUDGET_PAGES = BUDGET_TOKENS // BLOCK_SIZE

SHARED_PROMPT_TOKENS = 256   # system prompt shared by every conversation
USER_TOKENS = 32             # unique per-conversation turn
GEN_TOKENS = 96              # decoded tokens per conversation
MAX_SLOTS = 256              # slot-table ceiling (not the HBM budget)

MIN_CAPACITY_RATIO = 2.0


def _paged_capacity(kv: PagedKVCacheManager):
    """Admit + fully decode conversations until the pool refuses one;
    every admitted conversation stays resident, so the count IS the
    concurrent capacity at this budget."""
    shared = list(range(SHARED_PROMPT_TOKENS))
    admitted = 0
    conv = 0
    while True:
        prompt = shared + [10_000 + conv * 131 + i
                           for i in range(USER_TOKENS)]
        conv += 1
        slot = kv.alloc()
        if slot is None:
            break
        Lp = len(prompt) - 1
        try:
            kv.assign_blocks(slot, prompt[:Lp])
        except RuntimeError:
            kv.free(slot)
            break
        kv.set_length(slot, Lp + 1)
        ok = True
        for _ in range(GEN_TOKENS):
            # engine order: page for the write at position length-1
            # first, then advance the ledger
            if not kv.ensure_decode_page(slot):
                ok = False
                break
            kv.set_length(slot, kv.length(slot) + 1)
        if not ok:
            kv.free(slot)
            break
        admitted += 1
    return admitted


def run():
    kv = PagedKVCacheManager(MAX_SLOTS, MAX_CONTEXT,
                             block_size=BLOCK_SIZE,
                             num_blocks=BUDGET_PAGES + 1)  # +1 scratch
    paged = _paged_capacity(kv)
    dense = BUDGET_TOKENS // MAX_CONTEXT
    ratio = paged / max(dense, 1)
    stats = kv.paging_summary()
    hit_rate = stats["prefix_hit_rate"]

    rows = [
        csv_row("paged_kv.capacity", float(paged),
                f"dense={dense};paged={paged};ratio={ratio:.1f}x;"
                f"budget_tokens={BUDGET_TOKENS}"),
        csv_row("paged_kv.prefix", hit_rate * 100.0,
                f"hit_rate={hit_rate:.3f};"
                f"hit_tokens={stats['prefix_hit_tokens']};"
                f"blocks_used={stats['blocks_used']};"
                f"utilization={stats['utilization']:.3f}"),
    ]
    info = {
        "capacity_dense": dense,
        "capacity_paged": paged,
        "capacity_ratio": ratio,
        "prefix_hit_rate": hit_rate,
        "claims_pass": ratio >= MIN_CAPACITY_RATIO and hit_rate > 0.0,
    }
    return rows, info


if __name__ == "__main__":
    rows, info = run()
    for r in rows:
        print(r)
    print(info)
    if "--check" in sys.argv[1:] and not info["claims_pass"]:
        print("paged KV capacity claims FAILED", file=sys.stderr)
        sys.exit(1)
