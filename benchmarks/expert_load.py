"""Expert-load skew benchmark (ISSUE 7 acceptance claim).

Replays a Zipf(1.2)-skewed routing trace (the popularity regime real MoE
gates exhibit) through the placement subsystem and compares three expert
layouts on the DeepSeek backbone:

  uniform      contiguous blocks, no telemetry — what FinDEP's uniform
               cost model silently assumes; the Zipf head piles onto one
               EP rank and the EXP lane is bound by it
  lpt          greedy re-placement (rebalance with no replicas): the
               cold experts spread by longest-processing-time-first
  replicated   LPT + the K hottest experts replicated onto every rank
               (their tokens never cross the A2E/E2A wire: comm shrinks
               by rho and the hot FFN runs as the REP task on AG)

Reported per layout: worst-rank load imbalance (x uniform share) and the
skew-aware solver's modeled makespan (the placement's SkewSummary fed to
``FinDEPPlanner.plan``). Claims checked (``--check`` exits nonzero):

  * LPT + replication flatten the worst rank: imbalance(replicated) <
    imbalance(lpt) < imbalance(uniform)
  * >= 10% modeled-makespan improvement from replication at Zipf(1.2)
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import BACKBONES, csv_row
from repro.configs import get_config
from repro.configs.base import DepClusterConfig
from repro.core.perf_model import PAPER_A6000
from repro.core.planner import FinDEPPlanner, PlannerConfig
from repro.placement import (ExpertLoadTracker, Placement, max_rank_load,
                             rebalance, zipf_loads)

ZIPF_S = 1.2
RANKS = 4                  # EG ranks: divides DeepSeek's 64 experts
HOT_K = 4
TRACE_STEPS = 32
TOKENS_PER_STEP = 4096     # routed assignments sampled per trace step
SHAPE = (2048, 4)          # (seq_len, batch_per_device) solved per layout

MIN_IMPROVEMENT = 0.10


def _trace_tracker(num_experts: int, seed: int = 0) -> ExpertLoadTracker:
    """EWMA tracker fed a noisy Zipf(ZIPF_S) routing trace — multinomial
    draws, so per-step histograms jitter the way finite batches do."""
    rng = np.random.RandomState(seed)
    probs = zipf_loads(num_experts, s=ZIPF_S)
    tracker = ExpertLoadTracker(num_experts)
    for _ in range(TRACE_STEPS):
        tracker.observe(rng.multinomial(TOKENS_PER_STEP, probs))
    return tracker


def run():
    cfg = get_config(BACKBONES["deepseek"])
    E = cfg.moe.num_experts
    assert E % RANKS == 0, (E, RANKS)
    tracker = _trace_tracker(E)
    loads = tracker.aggregate()

    layouts = {
        "uniform": Placement.uniform(E, RANKS),
        "lpt": rebalance(loads, RANKS),
        "replicated": rebalance(loads, RANKS, replicate_hot_k=HOT_K,
                                epoch=1),
    }
    imbalance = {name: max_rank_load(pl, loads) * RANKS
                 for name, pl in layouts.items()}

    planner = FinDEPPlanner(
        cfg, DepClusterConfig(num_devices=2 * RANKS, ag=RANKS, eg=RANKS),
        PAPER_A6000,
        PlannerConfig(mem_cap_samples=4, r1_cap=4, r2_cap=32, T_override=8))
    S, b = SHAPE
    makespan = {}
    for name, pl in layouts.items():
        skew = tracker.summary(placement=pl)
        makespan[name] = planner.plan(S, b, skew=skew).makespan

    improvement = 1.0 - makespan["replicated"] / makespan["uniform"]
    rows = []
    for name in layouts:
        rows.append(csv_row(
            f"expert_load.{name}", makespan[name] * 1e6,
            f"imbalance={imbalance[name]:.2f}x;"
            f"makespan_ms={makespan[name] * 1e3:.3f};"
            f"zipf_s={ZIPF_S};ranks={RANKS};hot_k="
            f"{0 if name != 'replicated' else HOT_K}"))
    rows.append(csv_row(
        "expert_load.improvement", improvement * 100.0,
        f"replicated_vs_uniform={improvement:.1%};"
        f"shape={S}x{b};min={MIN_IMPROVEMENT:.0%}"))

    flattens = (imbalance["replicated"] < imbalance["lpt"]
                < imbalance["uniform"])
    info = {
        "imbalance_uniform": round(imbalance["uniform"], 3),
        "imbalance_lpt": round(imbalance["lpt"], 3),
        "imbalance_replicated": round(imbalance["replicated"], 3),
        "makespan_improvement": round(improvement, 4),
        "claims_pass": bool(flattens and improvement >= MIN_IMPROVEMENT),
    }
    return rows, info


if __name__ == "__main__":
    rows, info = run()
    for r in rows:
        print(r)
    print(info)
    if "--check" in sys.argv[1:] and not info["claims_pass"]:
        print("expert placement claims FAILED", file=sys.stderr)
        sys.exit(1)
