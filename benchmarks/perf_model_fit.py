"""Fig. 7 analogue, rebuilt on ``repro.profiling``: run the on-device
microbenchmark sweeps (GEMM / attention / comm), fit the alpha-beta models
with least squares and report per-primitive R^2 (the paper reports
R^2 > 0.994 on its GPUs; the claim under test is that a linear model with
intercept explains the primitive timings on THIS host too).

CLI (the CI calibration smoke job runs ``--fast --check``):

  --fast       reduced sweeps / fewer timing iters (CPU-friendly)
  --check      exit non-zero when any measured fit has R^2 < --min-r2
  --min-r2 X   quality gate (default 0.9)
  --store DIR  persist the fitted profile to a repro.profiling
               ProfileStore (so serving can --profile it later)
  --name NAME  stored-profile name (default: the host's ProfileKey slug)
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.common import csv_row
from repro.core.perf_model import fit_alpha_beta
from repro.profiling import ProfileKey, ProfileStore, calibrate


def run(fast: bool = False, store_dir=None, name=None, min_r2: float = 0.9):
    # retry-remeasure noisy sweeps up to a floor ABOVE the gate, so a
    # borderline fit gets re-taken instead of failing the smoke job
    result = calibrate(name="host_calibrated", fast=fast,
                       min_r2=min(min_r2 + 0.05, 0.99), max_retries=3)
    rows, info = [], {}
    for kind in ("gemm", "attn", "comm", "decode"):
        if kind not in result.samples:
            continue
        s = result.samples[kind]
        m = getattr(result.profile, kind)
        r2 = result.fit_r2[kind]
        label = f"perf_model_fit.{kind}" + ("_proxy" if s.proxy else "")
        rows.append(csv_row(
            label, float(np.mean(s.ts)) * 1e6,
            f"alpha={m.alpha:.2e};beta={m.beta:.2e};R2={r2:.5f}"))
        info[f"{kind}_r2"] = r2
    # communication: additionally validate the fitting machinery on the
    # paper's published (eg=4, ag=4) alpha-beta points (no multi-NIC path
    # exists on this host, so the live comm sweep above is a proxy there)
    zs = np.array([2 ** i for i in range(16, 24)], float)
    paper = 0.37e-3 + 2.55e-12 * zs
    m3, r23 = fit_alpha_beta(zs, paper)
    rows.append(csv_row(
        "perf_model_fit.comm_paper", float(paper.mean() * 1e6),
        f"alpha={m3.alpha:.2e};beta={m3.beta:.2e};R2={r23:.5f}"))
    if store_dir:
        store = ProfileStore(store_dir)
        key = ProfileKey.for_host()
        entry = store.put_calibration(result, key, name=name)
        rows.append(csv_row("perf_model_fit.stored", result.wall_s * 1e6,
                            f"name={entry.name};root={store.root}"))
    return rows, info


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sweeps (CI smoke)")
    ap.add_argument("--check", action="store_true",
                    help="fail when any measured fit R^2 < --min-r2")
    ap.add_argument("--min-r2", type=float, default=0.9)
    ap.add_argument("--store", default=None,
                    help="ProfileStore root to persist the fit into")
    ap.add_argument("--name", default=None,
                    help="stored profile name (default: host key slug)")
    args = ap.parse_args(argv)
    rows, info = run(fast=args.fast, store_dir=args.store, name=args.name,
                     min_r2=args.min_r2)
    for r in rows:
        print(r)
    if args.check:
        bad = {k: v for k, v in info.items() if v < args.min_r2}
        if bad:
            print(f"FAIL: fit R^2 below {args.min_r2}: "
                  + ", ".join(f"{k}={v:.4f}" for k, v in bad.items()))
            return 1
        print(f"OK: all fits R^2 >= {args.min_r2} "
              + str({k: round(v, 5) for k, v in info.items()}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
