"""Fig. 7 analogue: fit the alpha-beta performance models on THIS host's
measured GEMM / attention timings and report R^2 (the paper reports
R^2 > 0.994 on its GPUs; the claim under test is that a linear model with
intercept explains the primitive timings)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core.perf_model import fit_alpha_beta


def _time_fn(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def measure_gemm():
    xs, ts = [], []
    f = jax.jit(lambda a, b: a @ b)
    key = jax.random.PRNGKey(0)
    for m, k, n in [(128, 256, 256), (256, 512, 512), (512, 512, 1024),
                    (512, 1024, 1024), (1024, 1024, 1024),
                    (1024, 2048, 1024), (2048, 2048, 1024)]:
        a = jax.random.normal(key, (m, k), jnp.float32)
        b = jax.random.normal(key, (k, n), jnp.float32)
        xs.append(m * k * n)
        ts.append(_time_fn(f, a, b))
    return xs, ts


def measure_attention():
    from repro.models.attention import _causal_mask, _sdpa
    xs, ts = [], []
    key = jax.random.PRNGKey(0)
    f = jax.jit(lambda q, k, v, m: _sdpa(q, k, v, m))
    for B, S, H, D in [(1, 128, 4, 64), (1, 256, 4, 64), (2, 256, 4, 64),
                       (2, 512, 4, 64), (4, 512, 4, 64), (4, 512, 8, 64)]:
        q = jax.random.normal(key, (B, S, H, D), jnp.float32)
        k = jax.random.normal(key, (B, S, H, D), jnp.float32)
        v = jax.random.normal(key, (B, S, H, D), jnp.float32)
        mask = _causal_mask(jnp.arange(S), jnp.arange(S), None)
        xs.append(B * S * S * H * (D + D))
        ts.append(_time_fn(f, q, k, v, mask))
    return xs, ts


def run():
    rows = []
    xs, ts = measure_gemm()
    m, r2 = fit_alpha_beta(xs, ts)
    rows.append(csv_row("perf_model_fit.gemm", np.mean(ts) * 1e6,
                        f"alpha={m.alpha:.2e};beta={m.beta:.2e};R2={r2:.5f}"))
    xs, ts = measure_attention()
    m2, r22 = fit_alpha_beta(xs, ts)
    rows.append(csv_row("perf_model_fit.attn", np.mean(ts) * 1e6,
                        f"alpha={m2.alpha:.2e};beta={m2.beta:.2e};R2={r22:.5f}"))
    # communication: validate the fitting machinery on the paper's
    # published (eg=4, ag=4) points (no multi-NIC path exists on this host)
    zs = np.array([2**i for i in range(16, 24)], float)
    paper = 0.37e-3 + 2.55e-12 * zs
    m3, r23 = fit_alpha_beta(zs, paper)
    rows.append(csv_row("perf_model_fit.comm_paper", float(paper.mean() * 1e6),
                        f"alpha={m3.alpha:.2e};beta={m3.beta:.2e};R2={r23:.5f}"))
    return rows, {"gemm_r2": r2, "attn_r2": r22}


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
