"""Observability smoke: drive the engine a few steps with tracing +
metrics on, then validate every export surface end to end.

    PYTHONPATH=src python -m benchmarks.obs_smoke [--check] \
        [--trace-out out.json]

What it exercises (the CI gate for the ``repro.obs`` layer):

  * engine with a ``TraceRecorder`` + ``MetricsRegistry``: phase spans,
    request lifecycle spans, TTFT/TPOT/step histograms, snapshot
    sources;
  * Chrome-trace JSON export of the recorded spans AND a scheduled plan
    track group, gated by ``validate_chrome_trace`` (required keys,
    per-track stack discipline);
  * ``render_prometheus()`` scraped back through ``parse_prometheus``
    (exposition line format + label escaping must round-trip);
  * the threaded replay + overlap attributor on a real solved plan
    (executed exposed-comm within a generous eps of the model);
  * ``reset_stats()`` clearing every surface through the registry.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import csv_row

EPS = 0.2          # replay gap tolerance (fraction of makespan), CI-safe
N_REQS = 3
MAX_NEW = 4


def _engine_pass():
    """A few engine steps with tracing + metrics on; returns the engine
    and its tracer."""
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.obs import TraceRecorder
    from repro.runtime.engine import ServingEngine
    from repro.runtime.request import Request
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    eng = ServingEngine(cfg, num_slots=2, max_context=128,
                        tracer=TraceRecorder())
    rng = np.random.RandomState(0)
    for _ in range(N_REQS):
        eng.submit(Request(
            prompt=list(rng.randint(1, cfg.vocab_size,
                                    size=rng.randint(3, 9))),
            max_new_tokens=MAX_NEW))
    eng.run()
    return eng, eng.tracer


def run(trace_out: str = None):
    from repro.obs import (export_chrome_trace, parse_prometheus,
                           validate_chrome_trace)
    rows = []
    claims = {}

    # -- engine pass + trace export ------------------------------------
    t0 = time.perf_counter()
    eng, tracer = _engine_pass()
    rows.append(csv_row("obs_smoke.engine",
                        (time.perf_counter() - t0) * 1e6,
                        f"spans={len(tracer)};"
                        f"finished={len(eng.finished)}"))
    claims["lifecycle_spans_recorded"] = \
        len(tracer.by_cat("request")) >= N_REQS
    path = trace_out or "/tmp/repro_obs_smoke_trace.json"
    t0 = time.perf_counter()
    obj = export_chrome_trace(path, tracer=tracer)
    stats = validate_chrome_trace(obj)
    rows.append(csv_row("obs_smoke.chrome_trace",
                        (time.perf_counter() - t0) * 1e6,
                        f"events={stats['events']};"
                        f"tracks={stats['tracks']};path={path}"))
    claims["chrome_trace_validates"] = stats["complete"] > 0

    # -- Prometheus exposition round-trip ------------------------------
    t0 = time.perf_counter()
    text = eng.metrics.render_prometheus()
    samples = parse_prometheus(text)
    names = {n for n, _, _ in samples}
    rows.append(csv_row("obs_smoke.prometheus",
                        (time.perf_counter() - t0) * 1e6,
                        f"samples={len(samples)};families={len(names)}"))
    claims["prometheus_roundtrips"] = (
        len(samples) > 0
        and any(n.startswith("repro_engine_ttft_seconds") for n in names)
        and any(n == "repro_engine_requests_total" for n in names))

    # -- registry-level reset clears every surface ---------------------
    eng.reset_stats()
    snap = eng.metrics.snapshot()
    claims["reset_clears_surfaces"] = (
        eng.stats.steps == 0 and not eng.telemetry.phases
        and snap.get("repro_engine_decode_step_seconds_count", 0) == 0)
    eng.close()

    # -- executed replay vs modeled schedule ---------------------------
    from benchmarks.table7_overlap import executed_overlap
    t0 = time.perf_counter()
    rep = executed_overlap(S=1024, T=2)
    rows.append(csv_row("obs_smoke.replay",
                        (time.perf_counter() - t0) * 1e6,
                        f"gap={rep.gap:.4f};"
                        f"time_scale={rep.time_scale:.3g}"))
    claims["executed_overlap_within_eps"] = rep.within(EPS)
    return rows, claims


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every claim holds")
    ap.add_argument("--trace-out", default=None,
                    help="where to write the Chrome-trace JSON artifact")
    args = ap.parse_args()
    rows, claims = run(trace_out=args.trace_out)
    for r in rows:
        print(r)
    for k, v in sorted(claims.items()):
        print(f"# {k} = {v}")
    if args.check and not all(claims.values()):
        sys.exit(1)
