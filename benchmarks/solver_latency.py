"""Paper §5.4 claim: the configuration solver completes in < 1 second,
enabling per-request online re-planning.

Also guards the makespan fast path: the solver's simulate objective runs
``taskgraph.schedule_makespan`` (vectorized lane recurrence) instead of
the generic per-task list scheduler, which carries a ~3x Python-loop
constant (PR 5 perf note). ``fastpath_speedup`` measures the recovered
headroom on a large lowered graph, and the claims fail when the fast
path stops being faster or a mem256 solve regresses past the latency
budget (``--check`` exits nonzero, same contract as perf_model_fit).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.common import csv_row, stage_models_for
from repro.core.analytic import ORDER_ASAS, StageTimes
from repro.core.simulator import simulate_dep, simulate_makespan
from repro.core.solver import solve

# mem256 solves on this host sit around 0.1s; 0.8s leaves headroom for
# slow CI machines while still catching a return of the 3x constant
SOLVE_BUDGET_S = 0.8
MIN_FASTPATH_SPEEDUP = 1.5


def _time_fastpath(models, T, repeats: int = 5):
    st = StageTimes.from_models(models, m_a=8, m_e=models.me_from_ma(8, 8))
    kw = dict(T=T, r1=8, r2=8, order=ORDER_ASAS)
    # warm the lru-cached lowering so both paths time scheduling only
    simulate_makespan(st, **kw)
    generic = min(_timed(lambda: simulate_dep(st, **kw).makespan, repeats),
                  default=0.0)
    fast = min(_timed(lambda: simulate_makespan(st, **kw), repeats),
               default=0.0)
    rel = abs(simulate_dep(st, **kw).makespan - simulate_makespan(st, **kw))
    rel /= max(simulate_dep(st, **kw).makespan, 1e-30)
    return generic, fast, rel


def _timed(fn, repeats):
    out = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        out.append(time.perf_counter() - t0)
    return out


def run():
    rows = []
    worst = 0.0
    models, T = stage_models_for("deepseek", 4096)
    for mem_cap in (16, 64, 256):
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            solve(models, T, mem_cap, objective="hybrid")
            times.append(time.perf_counter() - t0)
        worst = max(worst, max(times))
        rows.append(csv_row(
            f"solver_latency.mem{mem_cap}", float(np.mean(times) * 1e6),
            f"mean_ms={np.mean(times)*1e3:.2f};max_ms={max(times)*1e3:.2f};"
            f"under_1s={max(times) < 1.0}"))

    generic, fast, rel = _time_fastpath(models, T)
    speedup = generic / fast if fast > 0 else float("inf")
    rows.append(csv_row(
        "solver_latency.fastpath", fast * 1e6,
        f"generic_us={generic*1e6:.1f};speedup={speedup:.2f}x;"
        f"rel_err={rel:.2e}"))
    info = {
        "max_solve_s": worst,
        "under_1s": worst < 1.0,
        "fastpath_speedup": speedup,
        "fastpath_rel_err": rel,
        "regression_guard": worst < SOLVE_BUDGET_S
        and speedup >= MIN_FASTPATH_SPEEDUP and rel < 1e-9,
    }
    return rows, info


if __name__ == "__main__":
    rows, info = run()
    for r in rows:
        print(r)
    print(info)
    if "--check" in sys.argv[1:] and not info["regression_guard"]:
        print("solver latency regression guard FAILED", file=sys.stderr)
        sys.exit(1)
