"""Paper §5.4 claim: the configuration solver completes in < 1 second,
enabling per-request online re-planning."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, stage_models_for
from repro.core.solver import solve


def run():
    rows = []
    worst = 0.0
    for mem_cap in (16, 64, 256):
        models, T = stage_models_for("deepseek", 4096)
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            solve(models, T, mem_cap, objective="hybrid")
            times.append(time.perf_counter() - t0)
        worst = max(worst, max(times))
        rows.append(csv_row(
            f"solver_latency.mem{mem_cap}", float(np.mean(times) * 1e6),
            f"mean_ms={np.mean(times)*1e3:.2f};max_ms={max(times)*1e3:.2f};"
            f"under_1s={max(times) < 1.0}"))
    return rows, {"max_solve_s": worst, "under_1s": worst < 1.0}


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
