"""Render dry-run JSONs into the EXPERIMENTS.md §Roofline markdown table.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report \
           dryrun_1pod.json [dryrun_2pod.json]
"""
from __future__ import annotations

import json
import sys


def fmt_table(recs):
    lines = [
        "| arch | shape | mode | compute | memory | collective | dominant "
        "| useful | peak GB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | FAILED "
                         f"{r.get('error','')[:40]} | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} "
            f"| {r['compute_ms']:.1f} ms | {r['memory_ms']:.1f} ms "
            f"| {r['collective_ms']:.1f} ms | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['peak_gb_per_device']:.1f} | {r['compile_s']:.0f} |")
    return "\n".join(lines)


def summarize(recs):
    ok = [r for r in recs if r.get("ok")]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return (f"{len(ok)}/{len(recs)} compiled; dominant terms: {doms}")


def main():
    for path in sys.argv[1:]:
        recs = json.load(open(path))
        print(f"\n### {path} — {summarize(recs)}\n")
        print(fmt_table(recs))


if __name__ == "__main__":
    main()
