"""Paper Table 3: throughput (tokens/s) of DeepSeek-V2 for varying m_a
(r1 = 1) and sequence length, with (m_e, r2, order) optimized per cell.
Validates Theorems 1-2 (monotone in m_a)."""
from __future__ import annotations

import time

from benchmarks.common import TESTBEDS, csv_row, stage_models_for
from repro.core.solver import solve_r2, _throughput


def run():
    rows = []
    mono_ok = True
    for tb_name, (hw, ag, eg, cap) in TESTBEDS.items():
        for S in (2048, 4096):
            models, T = stage_models_for("deepseek", S, hw, ag, eg, T=2)
            prev = 0.0
            cells = []
            t0 = time.perf_counter()
            for m_a in (1, 2, 4):
                best = max(
                    (solve_r2(models, T, m_a, 1, order, "simulate")[:2]
                     + (order,) for order in ("ASAS", "AASS")),
                    key=lambda t: t[1])
                tps = best[1]
                cells.append(f"m_a={m_a}:{tps:.1f}")
                mono_ok &= tps >= prev - 1e-6
                prev = tps
            dt = (time.perf_counter() - t0) * 1e6 / 3
            rows.append(csv_row(f"table3.{tb_name}.S{S}", dt,
                                ";".join(cells) + f";monotone={mono_ok}"))
    return rows, {"monotone_ma": mono_ok}


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
