"""Shared benchmark setup: backbones, clusters, hardware profiles.

The paper's testbeds are GPU boxes; on this CPU-only container the
throughput tables are produced by the exact event-order simulator driven
by (a) the paper's published A6000 alpha-beta constants and (b) the TPU
v5e analytic profile, plus live CPU wall-clock for the small-model
benchmarks. See EXPERIMENTS.md for the mapping.
"""
from __future__ import annotations

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config                       # noqa: E402
from repro.configs.base import DepClusterConfig            # noqa: E402
from repro.core.perf_model import (PAPER_A6000, TPU_V5E,   # noqa: E402
                                   DepModelSpec, build_stage_models)

import dataclasses

BACKBONES = {
    "deepseek": "deepseek-v2-lite",
    "qwen3": "qwen3-moe",
}

# (hardware, ag, eg, mem_cap_samples) — testbed-A analogue and the TPU
# target. The paper's testbeds are memory-constrained: m_a and r1 sweep
# only {1, 2, 4} (Tables 3-4), i.e. r1*m_a <= 4 on testbed A.
TESTBEDS = {
    "A(a6000)": (PAPER_A6000, 3, 5, 4),
    "v5e": (TPU_V5E, 3, 5, 8),
}

# §5.4: "8-layer configuration [of DeepSeek] on testbed A", "24-layer
# [Qwen3] on Testbed A"; Tables 3-4 use a 2-MoE-layer variant.
PAPER_DEPTHS = {"deepseek": 8, "qwen3": 24}


def stage_models_for(backbone: str, S: int, hw=PAPER_A6000, ag=3, eg=5,
                     T=None):
    cfg = get_config(BACKBONES[backbone])
    spec = DepModelSpec.from_model_config(cfg, S)
    if T is not None:
        spec = dataclasses.replace(spec, T=T)
    cluster = DepClusterConfig(num_devices=ag + eg, ag=ag, eg=eg)
    return build_stage_models(hw, spec, cluster), spec.T


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
