"""Shared benchmark setup: backbones, clusters, hardware profiles.

The paper's testbeds are GPU boxes; on this CPU-only container the
throughput tables are produced by the exact event-order simulator driven
by (a) the paper's published A6000 alpha-beta constants and (b) the TPU
v5e analytic profile, plus live CPU wall-clock for the small-model
benchmarks. See EXPERIMENTS.md for the mapping.
"""
from __future__ import annotations

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config                       # noqa: E402
from repro.configs.base import DepClusterConfig            # noqa: E402
from repro.core.perf_model import (PAPER_A6000, TPU_V5E,   # noqa: E402
                                   DepModelSpec, build_stage_models)

import dataclasses

BACKBONES = {
    "deepseek": "deepseek-v2-lite",
    "qwen3": "qwen3-moe",
}

# (hardware, ag, eg, mem_cap_samples) — testbed-A analogue and the TPU
# target. The paper's testbeds are memory-constrained: m_a and r1 sweep
# only {1, 2, 4} (Tables 3-4), i.e. r1*m_a <= 4 on testbed A.
TESTBEDS = {
    "A(a6000)": (PAPER_A6000, 3, 5, 4),
    "v5e": (TPU_V5E, 3, 5, 8),
}

# §5.4: "8-layer configuration [of DeepSeek] on testbed A", "24-layer
# [Qwen3] on Testbed A"; Tables 3-4 use a 2-MoE-layer variant.
PAPER_DEPTHS = {"deepseek": 8, "qwen3": 24}


def stage_models_for(backbone: str, S: int, hw=PAPER_A6000, ag=3, eg=5,
                     T=None):
    cfg = get_config(BACKBONES[backbone])
    spec = DepModelSpec.from_model_config(cfg, S)
    if T is not None:
        spec = dataclasses.replace(spec, T=T)
    cluster = DepClusterConfig(num_devices=ag + eg, ag=ag, eg=eg)
    return build_stage_models(hw, spec, cluster), spec.T


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"


def churn_occupancies(num_slots=4, num_requests=16, admission="fcfs",
                      token_budget=None, max_context=4096, seed=0,
                      prompt_range=(64, 3072), decode_range=(8, 96)):
    """Drive a BatchScheduler + ledger-only KVCacheManager through a
    synthetic arrival/finish trace and return the per-step decode
    ``OccupancySummary`` sequence — the decode-side shapes an online
    scheduler is asked to resolve under the given admission policy
    (no model execution; this is the scheduling-layer workload)."""
    import numpy as np

    from repro.runtime.batching import BatchScheduler
    from repro.runtime.kv import KVCacheManager
    from repro.runtime.request import Request

    rng = np.random.RandomState(seed)
    waiting = [Request(prompt=[0] * int(rng.randint(*prompt_range)),
                       max_new_tokens=int(rng.randint(*decode_range)))
               for _ in range(num_requests)]
    kv = KVCacheManager(num_slots, max_context)
    sched = BatchScheduler(admission=admission, token_budget=token_budget)
    remaining = {}
    occupancies = []
    while waiting or remaining:
        plan = sched.build_step(waiting, kv, max_context=max_context)
        for g in plan.prefills:
            for slot, req in zip(g.slots, g.requests):
                kv.set_length(slot, len(req.prompt))
                remaining[slot] = req.max_new_tokens
        live = kv.live_slots()
        if not live:
            break
        occupancies.append(kv.occupancy())
        kv.note_decode(live)
        for slot in live:
            remaining[slot] -= 1
            if remaining[slot] <= 0:
                del remaining[slot]
                kv.free(slot)
    return occupancies
