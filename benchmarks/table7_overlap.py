"""Paper Table 7: non-overlapped (exposed) communication time for
Naive-DEP / PPPipe / the adaptive policy (FinDEP by default, --policy
selects any) on the DeepSeek backbone, testbed-A constants. The paper
reports FinDEP ~1.7x lower than PPPipe.

The metric is computed from the LOWERED TASK GRAPH's scheduled intervals
(``taskgraph.lower`` + ``taskgraph.schedule``) — the same lowering the
DEP executor walks — so the table and the executor share one source of
truth; the baselines differ only in their lowering spec
(``shared_blocks_a2e=True`` for naive/PPPipe), not in simulator code.

``--executed`` closes ROADMAP item 3's measurement gap: it EXECUTES the
adaptive plan's graph on four host lanes (``repro.obs.replay`` — worker
threads, real dependency waits, time-scaled durations) and reduces the
executed spans with the overlap attributor, reporting per-lane executed
exposed-comm next to the modeled value and the relative gap. It runs
the replay under BOTH executor realizations — interleaved (the IR's
true dependency edges: r1 micro-batch streams overlap, the
``interleave="streams"`` emission) and sequential (each stream retires
before the next starts: ``stream_serial_deps`` + ``stream_major_order``,
the ``interleave="off"`` walk) — and claims the interleaved executed
exposed-comm fraction is no worse. When a multi-device jax mesh is
available the adaptive program additionally runs FOR REAL (eager
fenced DEP layer, ``repro.obs.device``) and the on-device span stream
is checked against the program's emission order; single-device CI
keeps the host-replay gate. ``--check`` exits non-zero when the
interleaved gap exceeds ``--eps`` (fraction-of-makespan units, see
DESIGN.md), when the interleaved arm exposes more than the sequential
arm, or when the device trace disagrees with the program order."""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import csv_row, stage_models_for
from repro.configs import get_config
from repro.configs.base import DepClusterConfig
from repro.core.analytic import StageTimes
from repro.core.baselines import best_pppipe, naive_plan
from repro.core.perf_model import PAPER_A6000
from repro.core.planner import FinDEPPlanner, PlannerConfig
from repro.core.simulator import non_overlapped_comm_time
from repro.core.taskgraph import LoweringSpec, TaskCosts, lower, schedule
from repro.sched import POLICIES, make_policy

MEM_CAP = 4


def exposed_comm(plan, models, T, shared_blocks_a2e=False):
    """Exposed-communication seconds of ``plan``'s lowered graph under
    the measured stage models (link busy while neither AG nor EG
    computes)."""
    st = StageTimes.from_models(models, plan.m_a,
                                models.me_from_ma(plan.m_a, plan.r2))
    graph = lower(plan, LoweringSpec(
        T=T, has_shared=models.spec.n_shared > 0,
        shared_blocks_a2e=shared_blocks_a2e))
    return non_overlapped_comm_time(
        schedule(graph, TaskCosts.from_stage_times(st)))


def adaptive_graph(policy: str = "findep", S: int = 2048, T: int = 4):
    """The adaptive policy's plan for shape ``S`` plus its lowered
    graph and measured-stage costs — the one structure every executed
    arm (host replay, device trace) runs."""
    planner = FinDEPPlanner(
        get_config("deepseek-v2-lite"),
        DepClusterConfig(num_devices=8, ag=3, eg=5), PAPER_A6000,
        PlannerConfig(mem_cap_samples=MEM_CAP, r1_cap=4, r2_cap=32,
                      T_override=T))
    pol = make_policy(policy, planner, static_seq_len=S)
    plan = pol.resolve("prefill", S)
    models, T = stage_models_for("deepseek", S, PAPER_A6000, T=T)
    st = StageTimes.from_models(models, plan.m_a,
                                models.me_from_ma(plan.m_a, plan.r2))
    graph = lower(plan, LoweringSpec(
        T=T, has_shared=models.spec.n_shared > 0))
    return plan, graph, TaskCosts.from_stage_times(st)


def executed_overlap(policy: str = "findep", S: int = 2048, T: int = 4,
                     max_wall_s: float = 0.4,
                     realization: str = "interleaved",
                     repeats: int = 3):
    """Replay the adaptive plan's lowered graph on host lanes and
    attribute executed vs modeled overlap. Returns an
    ``obs.OverlapReport``. ``T`` defaults lower than the table's 8 so
    the replay's span count stays CI-friendly.

    ``realization`` picks the executor being measured: "interleaved"
    replays the IR's true dependency edges (micro-batch streams overlap
    freely — what ``interleave="streams"`` compiles); "sequential" adds
    ``stream_serial_deps`` and serves lanes in ``stream_major_order``
    (stream i+1 starts only after stream i retires — the
    ``interleave="off"`` walk's realization). Both are attributed
    against the SAME unconstrained schedule.

    The replay runs ``repeats`` times and keeps the realization with
    the minimum executed makespan: host-thread scheduling jitter only
    ever ADDS time, so the min is the faithful executor measurement
    (same estimator microbenchmarks use)."""
    from repro.core.taskgraph import stream_major_order, stream_serial_deps
    from repro.obs import attribute_overlap
    from repro.obs.replay import replay_schedule
    _, graph, costs = adaptive_graph(policy, S, T)
    kw = {}
    if realization == "sequential":
        kw = dict(order=stream_major_order(graph),
                  extra_deps=stream_serial_deps(graph))
    elif realization != "interleaved":
        raise ValueError(f"unknown realization {realization!r}")
    best = None
    for _ in range(max(1, repeats)):
        rr = replay_schedule(graph, costs, max_wall_s=max_wall_s, **kw)
        rep = attribute_overlap(rr.spans, rr.scheduled,
                                time_scale=rr.time_scale)
        if best is None or rep.makespan_executed < best.makespan_executed:
            best = rep
    return best


def device_executed(policy: str = "findep", S: int = 2048, T: int = 4):
    """Run the adaptive plan's ``ExecProgram`` for real on the local
    jax mesh (eager fenced DEP layer) and order-check the executed span
    stream against the program's walk. Returns ``None`` when no
    multi-device mesh is available (single-device CI), else
    ``(DeviceTrace, order_ok, program)``."""
    from repro.obs.device import device_mesh, trace_dep_execution
    mesh = device_mesh()
    if mesh is None:
        return None
    plan, _, _ = adaptive_graph(policy, S, T)
    prog = plan.exec_program(interleave="streams")
    dt = trace_dep_execution(prog, mesh, mode="sequence")
    handled = {s.name for s in dt.spans}
    expect = [(t.kind, t.mb, t.chunk) for t in prog.walk()
              if t.kind in handled]
    got = [(s.name, s.arg("mb"), s.arg("chunk")) for s in dt.spans]
    order_ok = bool(dt.spans) and got == expect
    return dt, order_ok, prog


def run(policy: str = "findep"):
    rows = []
    improved = True
    planner = FinDEPPlanner(
        get_config("deepseek-v2-lite"),
        DepClusterConfig(num_devices=8, ag=3, eg=5), PAPER_A6000,
        PlannerConfig(mem_cap_samples=MEM_CAP, r1_cap=4, r2_cap=32,
                      T_override=8))
    pol = make_policy(policy, planner, static_seq_len=2048)
    for S in (1024, 2048, 4096):
        models, T = stage_models_for("deepseek", S, PAPER_A6000, T=8)
        t0 = time.perf_counter()
        # naive: whole mini-batch at once, dispatch blocked on shared
        nv = exposed_comm(naive_plan(models, T, MEM_CAP), models, T,
                          shared_blocks_a2e=True)
        # best PPPipe: same blocking lowering, r1 micro-batches
        pp = exposed_comm(best_pppipe(models, T, MEM_CAP, r1_cap=4),
                          models, T, shared_blocks_a2e=True)
        # the adaptive policy's plan for this shape (FinDEP lowering:
        # shared independent of dispatch)
        fd = exposed_comm(pol.resolve("prefill", S), models, T)
        dt = (time.perf_counter() - t0) * 1e6
        improved &= fd <= pp + 1e-9 <= nv + 1e-9
        rows.append(csv_row(
            f"table7.S{S}", dt,
            f"policy={policy};naive_ms={nv*1e3:.2f};pppipe_ms={pp*1e3:.2f};"
            f"adaptive_ms={fd*1e3:.2f};"
            f"reduction_vs_pppipe={pp/max(fd,1e-12):.2f}x"))
    # executed claim: the interleaved executor realization exposes no
    # more comm than the sequential one on the table's headline shape
    # (host-lane replay of the same graph under both dependency sets)
    t0 = time.perf_counter()
    rep_i = executed_overlap(policy=policy, S=2048, T=4)
    rep_s = executed_overlap(policy=policy, S=2048, T=4,
                             realization="sequential")
    dt = (time.perf_counter() - t0) * 1e6
    # Table 7's metric is ABSOLUTE non-overlapped comm seconds (both
    # replays de-scale by the same schedule-derived time_scale, so the
    # seconds are directly comparable; fractions are not — the
    # sequential arm's longer makespan deflates its ratio)
    exp_i = rep_i.exposed_executed["total"]
    exp_s = rep_s.exposed_executed["total"]
    inter_le_seq = exp_i <= exp_s * 1.02 + 1e-6
    rows.append(csv_row(
        "table7.executed.S2048", dt,
        f"policy={policy};"
        f"interleaved_exposed_ms={exp_i*1e3:.2f};"
        f"sequential_exposed_ms={exp_s*1e3:.2f};"
        f"interleaved_makespan_ms={rep_i.makespan_executed*1e3:.2f};"
        f"sequential_makespan_ms={rep_s.makespan_executed*1e3:.2f};"
        f"gap={rep_i.gap:.4f}"))
    return rows, {"adaptive_exposes_least": improved,
                  "interleaved_exposes_le_sequential": inter_le_seq}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", choices=POLICIES, default="findep")
    ap.add_argument("--executed", action="store_true",
                    help="also replay the adaptive plan's graph on host "
                         "lanes and report executed vs modeled overlap")
    ap.add_argument("--check", action="store_true",
                    help="with --executed: exit 1 when the executed/"
                         "modeled gap exceeds --eps")
    ap.add_argument("--eps", type=float, default=0.15,
                    help="gap tolerance, fraction of makespan")
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()
    for r in run(policy=args.policy)[0]:
        print(r)
    if args.executed:
        rep = executed_overlap(policy=args.policy, S=args.seq,
                               T=args.layers)
        seq_rep = executed_overlap(policy=args.policy, S=args.seq,
                                   T=args.layers,
                                   realization="sequential")
        print(f"# executed replay: policy={args.policy} S={args.seq} "
              f"T={args.layers} time_scale={rep.time_scale:.3g}")
        print(f"#   makespan   modeled={rep.makespan_modeled*1e3:9.3f}ms "
              f"executed={rep.makespan_executed*1e3:9.3f}ms")
        for lane in ("A2E", "E2A", "total"):
            print(f"#   exposed[{lane:>5}] "
                  f"modeled={rep.exposed_modeled[lane]*1e3:9.3f}ms "
                  f"executed={rep.exposed_executed[lane]*1e3:9.3f}ms")
        print(f"#   exposed frac modeled={rep.exposed_frac_modeled:.4f} "
              f"executed={rep.exposed_frac_executed:.4f} "
              f"gap={rep.gap:.4f} (eps={args.eps})")
        exp_i = rep.exposed_executed["total"]
        exp_s = seq_rep.exposed_executed["total"]
        print(f"#   sequential realization: "
              f"exposed={exp_s*1e3:9.3f}ms "
              f"makespan={seq_rep.makespan_executed*1e3:9.3f}ms "
              f"(interleaved exposed {exp_i*1e3:.3f}ms must be <=)")
        dev = device_executed(policy=args.policy, S=args.seq,
                              T=args.layers)
        if dev is None:
            print("# device trace: skipped (needs a multi-device jax "
                  "mesh; host replay is the gate)")
        else:
            dtr, order_ok, prog = dev
            kinds = {}
            for s in dtr.spans:
                kinds[s.name] = kinds.get(s.name, 0.0) + (s.end - s.start)
            per_kind = " ".join(f"{k}={v*1e3:.2f}ms"
                                for k, v in sorted(kinds.items()))
            print(f"# device trace: {len(dtr.spans)} fenced spans, "
                  f"r1={prog.streams} wall={dtr.wall_s*1e3:.1f}ms "
                  f"order_ok={order_ok}")
            print(f"#   per-kind device time: {per_kind}")
        failures = []
        if not rep.within(args.eps):
            failures.append(f"executed/modeled overlap gap {rep.gap:.4f} "
                            f"> eps {args.eps}")
        if exp_i > exp_s * 1.02 + 1e-6:
            failures.append(
                f"interleaved exposed comm {exp_i*1e3:.3f}ms "
                f"> sequential {exp_s*1e3:.3f}ms")
        if dev is not None and not dev[1]:
            failures.append("device span stream disagrees with the "
                            "program's emission order")
        if args.check and failures:
            for f in failures:
                print(f"# FAIL: {f}")
            sys.exit(1)
