"""Paper Table 7: non-overlapped (exposed) communication time for
Naive-DEP / PPPipe / the adaptive policy (FinDEP by default, --policy
selects any) on the DeepSeek backbone, testbed-A constants. The paper
reports FinDEP ~1.7x lower than PPPipe.

The metric is computed from the LOWERED TASK GRAPH's scheduled intervals
(``taskgraph.lower`` + ``taskgraph.schedule``) — the same lowering the
DEP executor walks — so the table and the executor share one source of
truth; the baselines differ only in their lowering spec
(``shared_blocks_a2e=True`` for naive/PPPipe), not in simulator code.

``--executed`` closes ROADMAP item 3's measurement gap: it EXECUTES the
adaptive plan's graph on four host lanes (``repro.obs.replay`` — worker
threads, real dependency waits, time-scaled durations) and reduces the
executed spans with the overlap attributor, reporting per-lane executed
exposed-comm next to the modeled value and the relative gap. Runs on
CPU jax; ``--check`` exits non-zero when the gap exceeds ``--eps``
(fraction-of-makespan units, see DESIGN.md)."""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import csv_row, stage_models_for
from repro.configs import get_config
from repro.configs.base import DepClusterConfig
from repro.core.analytic import StageTimes
from repro.core.baselines import best_pppipe, naive_plan
from repro.core.perf_model import PAPER_A6000
from repro.core.planner import FinDEPPlanner, PlannerConfig
from repro.core.simulator import non_overlapped_comm_time
from repro.core.taskgraph import LoweringSpec, TaskCosts, lower, schedule
from repro.sched import POLICIES, make_policy

MEM_CAP = 4


def exposed_comm(plan, models, T, shared_blocks_a2e=False):
    """Exposed-communication seconds of ``plan``'s lowered graph under
    the measured stage models (link busy while neither AG nor EG
    computes)."""
    st = StageTimes.from_models(models, plan.m_a,
                                models.me_from_ma(plan.m_a, plan.r2))
    graph = lower(plan, LoweringSpec(
        T=T, has_shared=models.spec.n_shared > 0,
        shared_blocks_a2e=shared_blocks_a2e))
    return non_overlapped_comm_time(
        schedule(graph, TaskCosts.from_stage_times(st)))


def executed_overlap(policy: str = "findep", S: int = 2048, T: int = 4,
                     max_wall_s: float = 0.4):
    """Replay the adaptive plan's lowered graph on host lanes and
    attribute executed vs modeled overlap. Returns an
    ``obs.OverlapReport``. ``T`` defaults lower than the table's 8 so
    the replay's span count stays CI-friendly."""
    from repro.obs import attribute_overlap
    from repro.obs.replay import replay_schedule
    planner = FinDEPPlanner(
        get_config("deepseek-v2-lite"),
        DepClusterConfig(num_devices=8, ag=3, eg=5), PAPER_A6000,
        PlannerConfig(mem_cap_samples=MEM_CAP, r1_cap=4, r2_cap=32,
                      T_override=T))
    pol = make_policy(policy, planner, static_seq_len=S)
    plan = pol.resolve("prefill", S)
    models, T = stage_models_for("deepseek", S, PAPER_A6000, T=T)
    st = StageTimes.from_models(models, plan.m_a,
                                models.me_from_ma(plan.m_a, plan.r2))
    graph = lower(plan, LoweringSpec(
        T=T, has_shared=models.spec.n_shared > 0))
    rr = replay_schedule(graph, TaskCosts.from_stage_times(st),
                         max_wall_s=max_wall_s)
    return attribute_overlap(rr.spans, rr.scheduled,
                             time_scale=rr.time_scale)


def run(policy: str = "findep"):
    rows = []
    improved = True
    planner = FinDEPPlanner(
        get_config("deepseek-v2-lite"),
        DepClusterConfig(num_devices=8, ag=3, eg=5), PAPER_A6000,
        PlannerConfig(mem_cap_samples=MEM_CAP, r1_cap=4, r2_cap=32,
                      T_override=8))
    pol = make_policy(policy, planner, static_seq_len=2048)
    for S in (1024, 2048, 4096):
        models, T = stage_models_for("deepseek", S, PAPER_A6000, T=8)
        t0 = time.perf_counter()
        # naive: whole mini-batch at once, dispatch blocked on shared
        nv = exposed_comm(naive_plan(models, T, MEM_CAP), models, T,
                          shared_blocks_a2e=True)
        # best PPPipe: same blocking lowering, r1 micro-batches
        pp = exposed_comm(best_pppipe(models, T, MEM_CAP, r1_cap=4),
                          models, T, shared_blocks_a2e=True)
        # the adaptive policy's plan for this shape (FinDEP lowering:
        # shared independent of dispatch)
        fd = exposed_comm(pol.resolve("prefill", S), models, T)
        dt = (time.perf_counter() - t0) * 1e6
        improved &= fd <= pp + 1e-9 <= nv + 1e-9
        rows.append(csv_row(
            f"table7.S{S}", dt,
            f"policy={policy};naive_ms={nv*1e3:.2f};pppipe_ms={pp*1e3:.2f};"
            f"adaptive_ms={fd*1e3:.2f};"
            f"reduction_vs_pppipe={pp/max(fd,1e-12):.2f}x"))
    return rows, {"adaptive_exposes_least": improved}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", choices=POLICIES, default="findep")
    ap.add_argument("--executed", action="store_true",
                    help="also replay the adaptive plan's graph on host "
                         "lanes and report executed vs modeled overlap")
    ap.add_argument("--check", action="store_true",
                    help="with --executed: exit 1 when the executed/"
                         "modeled gap exceeds --eps")
    ap.add_argument("--eps", type=float, default=0.15,
                    help="gap tolerance, fraction of makespan")
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()
    for r in run(policy=args.policy)[0]:
        print(r)
    if args.executed:
        rep = executed_overlap(policy=args.policy, S=args.seq,
                               T=args.layers)
        print(f"# executed replay: policy={args.policy} S={args.seq} "
              f"T={args.layers} time_scale={rep.time_scale:.3g}")
        print(f"#   makespan   modeled={rep.makespan_modeled*1e3:9.3f}ms "
              f"executed={rep.makespan_executed*1e3:9.3f}ms")
        for lane in ("A2E", "E2A", "total"):
            print(f"#   exposed[{lane:>5}] "
                  f"modeled={rep.exposed_modeled[lane]*1e3:9.3f}ms "
                  f"executed={rep.exposed_executed[lane]*1e3:9.3f}ms")
        print(f"#   exposed frac modeled={rep.exposed_frac_modeled:.4f} "
              f"executed={rep.exposed_frac_executed:.4f} "
              f"gap={rep.gap:.4f} (eps={args.eps})")
        if args.check and not rep.within(args.eps):
            print(f"# FAIL: executed/modeled overlap gap {rep.gap:.4f} "
                  f"> eps {args.eps}")
            sys.exit(1)
