"""Paper Table 7: non-overlapped (exposed) communication time for
Naive-DEP / PPPipe / the adaptive policy (FinDEP by default, --policy
selects any) on the DeepSeek backbone, testbed-A constants. The paper
reports FinDEP ~1.7x lower than PPPipe."""
from __future__ import annotations

import argparse
import time

from benchmarks.common import csv_row, stage_models_for
from repro.configs import get_config
from repro.configs.base import DepClusterConfig
from repro.core.analytic import StageTimes
from repro.core.baselines import best_pppipe
from repro.core.perf_model import PAPER_A6000
from repro.core.planner import FinDEPPlanner, PlannerConfig
from repro.core.simulator import (non_overlapped_comm_time, simulate_dep,
                                  simulate_naive, simulate_pppipe)
from repro.sched import POLICIES, make_policy

MEM_CAP = 4


def run(policy: str = "findep"):
    rows = []
    improved = True
    planner = FinDEPPlanner(
        get_config("deepseek-v2-lite"),
        DepClusterConfig(num_devices=8, ag=3, eg=5), PAPER_A6000,
        PlannerConfig(mem_cap_samples=MEM_CAP, r1_cap=4, r2_cap=32,
                      T_override=8))
    pol = make_policy(policy, planner, static_seq_len=2048)
    for S in (1024, 2048, 4096):
        models, T = stage_models_for("deepseek", S, PAPER_A6000, T=8)
        t0 = time.perf_counter()
        # naive: whole mini-batch at once
        m_a_full = MEM_CAP
        st_full = StageTimes.from_models(models, m_a_full,
                                         models.me_from_ma(m_a_full, 1))
        nv = non_overlapped_comm_time(
            simulate_naive(st_full, T, record_intervals=True))
        # best PPPipe
        pp_cfg = best_pppipe(models, T, MEM_CAP, r1_cap=4)
        st_pp = StageTimes.from_models(models, pp_cfg.m_a,
                                       models.me_from_ma(pp_cfg.m_a, 1))
        pp = non_overlapped_comm_time(
            simulate_pppipe(st_pp, T, pp_cfg.r1, record_intervals=True))
        # the adaptive policy's plan for this shape
        fd_cfg = pol.resolve("prefill", S)
        st_fd = StageTimes.from_models(
            models, fd_cfg.m_a, models.me_from_ma(fd_cfg.m_a, fd_cfg.r2))
        fd = non_overlapped_comm_time(
            simulate_dep(st_fd, T, fd_cfg.r1, fd_cfg.r2, order=fd_cfg.order,
                         record_intervals=True))
        dt = (time.perf_counter() - t0) * 1e6
        improved &= fd <= pp + 1e-9 <= nv + 1e-9
        rows.append(csv_row(
            f"table7.S{S}", dt,
            f"policy={policy};naive_ms={nv*1e3:.2f};pppipe_ms={pp*1e3:.2f};"
            f"adaptive_ms={fd*1e3:.2f};"
            f"reduction_vs_pppipe={pp/max(fd,1e-12):.2f}x"))
    return rows, {"adaptive_exposes_least": improved}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", choices=POLICIES, default="findep")
    args = ap.parse_args()
    for r in run(policy=args.policy)[0]:
        print(r)
