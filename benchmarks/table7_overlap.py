"""Paper Table 7: non-overlapped (exposed) communication time for
Naive-DEP / PPPipe / the adaptive policy (FinDEP by default, --policy
selects any) on the DeepSeek backbone, testbed-A constants. The paper
reports FinDEP ~1.7x lower than PPPipe.

The metric is computed from the LOWERED TASK GRAPH's scheduled intervals
(``taskgraph.lower`` + ``taskgraph.schedule``) — the same lowering the
DEP executor walks — so the table and the executor share one source of
truth; the baselines differ only in their lowering spec
(``shared_blocks_a2e=True`` for naive/PPPipe), not in simulator code."""
from __future__ import annotations

import argparse
import time

from benchmarks.common import csv_row, stage_models_for
from repro.configs import get_config
from repro.configs.base import DepClusterConfig
from repro.core.analytic import StageTimes
from repro.core.baselines import best_pppipe, naive_plan
from repro.core.perf_model import PAPER_A6000
from repro.core.planner import FinDEPPlanner, PlannerConfig
from repro.core.simulator import non_overlapped_comm_time
from repro.core.taskgraph import LoweringSpec, TaskCosts, lower, schedule
from repro.sched import POLICIES, make_policy

MEM_CAP = 4


def exposed_comm(plan, models, T, shared_blocks_a2e=False):
    """Exposed-communication seconds of ``plan``'s lowered graph under
    the measured stage models (link busy while neither AG nor EG
    computes)."""
    st = StageTimes.from_models(models, plan.m_a,
                                models.me_from_ma(plan.m_a, plan.r2))
    graph = lower(plan, LoweringSpec(
        T=T, has_shared=models.spec.n_shared > 0,
        shared_blocks_a2e=shared_blocks_a2e))
    return non_overlapped_comm_time(
        schedule(graph, TaskCosts.from_stage_times(st)))


def run(policy: str = "findep"):
    rows = []
    improved = True
    planner = FinDEPPlanner(
        get_config("deepseek-v2-lite"),
        DepClusterConfig(num_devices=8, ag=3, eg=5), PAPER_A6000,
        PlannerConfig(mem_cap_samples=MEM_CAP, r1_cap=4, r2_cap=32,
                      T_override=8))
    pol = make_policy(policy, planner, static_seq_len=2048)
    for S in (1024, 2048, 4096):
        models, T = stage_models_for("deepseek", S, PAPER_A6000, T=8)
        t0 = time.perf_counter()
        # naive: whole mini-batch at once, dispatch blocked on shared
        nv = exposed_comm(naive_plan(models, T, MEM_CAP), models, T,
                          shared_blocks_a2e=True)
        # best PPPipe: same blocking lowering, r1 micro-batches
        pp = exposed_comm(best_pppipe(models, T, MEM_CAP, r1_cap=4),
                          models, T, shared_blocks_a2e=True)
        # the adaptive policy's plan for this shape (FinDEP lowering:
        # shared independent of dispatch)
        fd = exposed_comm(pol.resolve("prefill", S), models, T)
        dt = (time.perf_counter() - t0) * 1e6
        improved &= fd <= pp + 1e-9 <= nv + 1e-9
        rows.append(csv_row(
            f"table7.S{S}", dt,
            f"policy={policy};naive_ms={nv*1e3:.2f};pppipe_ms={pp*1e3:.2f};"
            f"adaptive_ms={fd*1e3:.2f};"
            f"reduction_vs_pppipe={pp/max(fd,1e-12):.2f}x"))
    return rows, {"adaptive_exposes_least": improved}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", choices=POLICIES, default="findep")
    args = ap.parse_args()
    for r in run(policy=args.policy)[0]:
        print(r)
