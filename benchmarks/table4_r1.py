"""Paper Table 4: throughput for varying r1 (m_a = 1); validates Thm 3."""
from __future__ import annotations

import time

from benchmarks.common import TESTBEDS, csv_row, stage_models_for
from repro.core.solver import solve_r2


def run():
    rows = []
    mono_ok = True
    for tb_name, (hw, ag, eg, cap) in TESTBEDS.items():
        for S in (2048, 4096):
            models, T = stage_models_for("deepseek", S, hw, ag, eg, T=2)
            prev = 0.0
            cells = []
            t0 = time.perf_counter()
            for r1 in (1, 2, 4):
                best = max(
                    (solve_r2(models, T, 1, r1, order, "simulate")[:2]
                     + (order,) for order in ("ASAS", "AASS")),
                    key=lambda t: t[1])
                tps = best[1]
                cells.append(f"r1={r1}:{tps:.1f}")
                mono_ok &= tps >= prev - 1e-6
                prev = tps
            dt = (time.perf_counter() - t0) * 1e6 / 3
            rows.append(csv_row(f"table4.{tb_name}.S{S}", dt,
                                ";".join(cells) + f";monotone={mono_ok}"))
    return rows, {"monotone_r1": mono_ok}


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
