"""Beyond-paper analysis: FinDEP-vs-best-PPPipe speedup as a function of
the comm/compute balance (t_c / t_e) and memory budget.

The paper reports point speedups on four GPU testbeds; this sweep maps the
whole regime, against an idealized schedule-OPTIMAL PPPipe baseline (a
stronger baseline than any real PPPipe implementation): gains concentrate
where (a) memory caps r1*m_a hard, (b) alpha overheads are first-order,
and (c) t_c is within ~2x of t_e."""
from __future__ import annotations

import time

from benchmarks.common import csv_row, stage_models_for
from repro.core.analytic import StageTimes
from repro.core.baselines import best_pppipe
from repro.core.perf_model import PAPER_A6000, AlphaBeta, HardwareProfile
from repro.core.solver import solve


def run():
    rows = []
    best = (0.0, None)
    for beta_c in (1.3e-10, 2.55e-10, 1e-9, 2.55e-9):
        hw = HardwareProfile("sweep", PAPER_A6000.gemm, PAPER_A6000.attn,
                             AlphaBeta(0.37e-3, beta_c))
        for cap in (2, 4):
            t0 = time.perf_counter()
            cells = []
            for S in (1024, 4096, 8192):
                models, T = stage_models_for("deepseek", S, hw, T=8)
                fd, _ = solve(models, T, cap, objective="simulate",
                              r1_cap=cap, r2_cap=32)
                pp = best_pppipe(models, T, cap, r1_cap=cap)
                st = StageTimes.from_models(models, 1,
                                            models.me_from_ma(1, 1))
                sp = fd.throughput / pp.throughput
                if sp > best[0]:
                    best = (sp, (beta_c, cap, S))
                cells.append(f"S{S}:{sp:.3f}@tc/te={st.t_c/st.t_e:.2f}")
            dt = (time.perf_counter() - t0) * 1e6 / 3
            rows.append(csv_row(
                f"regime_sweep.beta{beta_c:.0e}.cap{cap}", dt,
                ";".join(cells)))
    return rows, {"max_speedup": best[0], "at": str(best[1])}


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
