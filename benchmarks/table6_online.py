"""Paper Table 6 (online setting): tokens arrive with varying counts; the
fast solver re-plans (r1, r2, order) per arrival while PPPipe keeps its
static best configuration for the expected shape (S = 2048)."""
from __future__ import annotations

import time

from benchmarks.common import (BACKBONES, PAPER_DEPTHS, TESTBEDS, csv_row,
                               stage_models_for)
from repro.core.analytic import StageTimes
from repro.core.baselines import best_pppipe
from repro.core.simulator import simulate_pppipe
from repro.core.solver import solve

def run():
    rows = []
    speedups = {}
    for backbone in BACKBONES:
        for tb_name, (hw, ag, eg, cap) in TESTBEDS.items():
            # static PPPipe configured for S=2048
            models_ref, T = stage_models_for(backbone, 2048, hw, ag, eg,
                                             T=PAPER_DEPTHS[backbone])
            pp_cfg = best_pppipe(models_ref, T, cap, r1_cap=cap)
            for S in (3072, 6144):
                models, T = stage_models_for(backbone, S, hw, ag, eg,
                                             T=PAPER_DEPTHS[backbone])
                t0 = time.perf_counter()
                fd, _ = solve(models, T, cap, objective="hybrid",
                              fixed_batch=cap, r1_cap=cap, r2_cap=32)
                solve_us = (time.perf_counter() - t0) * 1e6
                # static PPPipe executes its stale (m_a, r1) on the new S
                m_e = models.me_from_ma(pp_cfg.m_a, 1)
                st = StageTimes.from_models(models, pp_cfg.m_a, m_e)
                res = simulate_pppipe(st, T, pp_cfg.r1)
                pp_tps = (pp_cfg.r1 * pp_cfg.m_a * models.cluster.ag
                          * S / res.makespan)
                sp = fd.throughput / pp_tps
                speedups[(backbone, tb_name, S)] = sp
                rows.append(csv_row(
                    f"table6.{backbone}.{tb_name}.tok{S}", solve_us,
                    f"static_pppipe={pp_tps:.1f};findep={fd.throughput:.1f};"
                    f"speedup={sp:.3f}"))
    return rows, {"speedup_max": max(speedups.values()),
                  "speedup_min": min(speedups.values())}


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
