"""Paper Table 6 (online setting): tokens arrive with varying counts; the
adaptive scheduling policy (FinDEP by default; --policy selects any
runnable policy) re-plans per arrival through the sched layer while PPPipe
keeps its static best configuration for the expected shape (S = 2048).

A second section replays the decode side of the online setting: a
synthetic churn trace (arrivals admitted under --admission /
--token-budget, staggered finishes) produces the stream of KV-ledger
occupancy summaries a serving engine would observe, and the policy
resolves a decode plan per distinct composition through the PlanCache."""
from __future__ import annotations

import argparse
import time

from benchmarks.common import (BACKBONES, PAPER_DEPTHS, TESTBEDS,
                               churn_occupancies, csv_row,
                               stage_models_for)
from repro.configs import get_config
from repro.configs.base import DepClusterConfig
from repro.core.analytic import StageTimes
from repro.core.baselines import best_pppipe
from repro.core.planner import FinDEPPlanner, PlannerConfig
from repro.core.simulator import simulate_dep, simulate_pppipe
from repro.sched import POLICIES, PlanCache, make_policy
from repro.runtime import ADMISSIONS


def run(policy: str = "findep", admission: str = "fcfs",
        token_budget=None):
    rows = []
    speedups = {}
    for backbone in BACKBONES:
        for tb_name, (hw, ag, eg, cap) in TESTBEDS.items():
            T = PAPER_DEPTHS[backbone]
            planner = FinDEPPlanner(
                get_config(BACKBONES[backbone]),
                DepClusterConfig(num_devices=ag + eg, ag=ag, eg=eg), hw,
                PlannerConfig(mem_cap_samples=cap, r1_cap=cap, r2_cap=32,
                              T_override=T))
            cache = PlanCache(make_policy(policy, planner,
                                          static_seq_len=2048))
            # static PPPipe configured for S=2048
            models_ref, _ = stage_models_for(backbone, 2048, hw, ag, eg, T=T)
            pp_cfg = best_pppipe(models_ref, T, cap, r1_cap=cap)
            for S in (3072, 6144):
                models, _ = stage_models_for(backbone, S, hw, ag, eg, T=T)
                t0 = time.perf_counter()
                fd = cache.get("prefill", S, cap)
                solve_us = (time.perf_counter() - t0) * 1e6
                # every policy's configuration executes on the ARRIVED S:
                # re-simulate so static/stale plans are scored on the same
                # shape as PPPipe, not on the shape they were solved for
                st_fd = StageTimes.from_models(
                    models, fd.m_a, models.me_from_ma(fd.m_a, fd.r2))
                fd_tps = (fd.r1 * fd.m_a * models.cluster.ag * S
                          / simulate_dep(st_fd, T, fd.r1, fd.r2,
                                         order=fd.order).makespan)
                # static PPPipe executes its stale (m_a, r1) on the new S
                m_e = models.me_from_ma(pp_cfg.m_a, 1)
                st = StageTimes.from_models(models, pp_cfg.m_a, m_e)
                res = simulate_pppipe(st, T, pp_cfg.r1)
                pp_tps = (pp_cfg.r1 * pp_cfg.m_a * models.cluster.ag
                          * S / res.makespan)
                sp = fd_tps / pp_tps
                speedups[(backbone, tb_name, S)] = sp
                rows.append(csv_row(
                    f"table6.{backbone}.{tb_name}.tok{S}", solve_us,
                    f"policy={policy};static_pppipe={pp_tps:.1f};"
                    f"adaptive={fd_tps:.1f};speedup={sp:.3f}"))
            # decode churn: per-occupancy plan resolution through the cache
            occs = churn_occupancies(num_slots=cap, num_requests=12,
                                     admission=admission,
                                     token_budget=token_budget, seed=0)
            t0 = time.perf_counter()
            plans = {occ: cache.get("decode", occupancy=occ)
                     for occ in occs}
            churn_us = (time.perf_counter() - t0) * 1e6
            rows.append(csv_row(
                f"table6.{backbone}.{tb_name}.decode_churn",
                churn_us / max(len(occs), 1),
                f"policy={policy};admission={admission};steps={len(occs)};"
                f"occupancies={len(plans)};"
                f"distinct_plans={len(set(plans.values()))};"
                f"cache_hit_rate={cache.stats.hit_rate:.2f}"))
    return rows, {"speedup_max": max(speedups.values()),
                  "speedup_min": min(speedups.values())}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", choices=POLICIES, default="findep")
    ap.add_argument("--admission", choices=ADMISSIONS, default="fcfs")
    ap.add_argument("--token-budget", type=int, default=None)
    args = ap.parse_args()
    for r in run(policy=args.policy, admission=args.admission,
                 token_budget=args.token_budget)[0]:
        print(r)
