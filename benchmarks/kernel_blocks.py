"""Kernel block-shape sweep: VMEM footprint per BlockSpec configuration
(the structural quantity that matters for the TPU target) plus interpret-
mode wall time (correctness-path cost only — NOT a TPU timing)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.moe_gemm.kernel import moe_gemm_pallas


def vmem_flash(bq, bk, D, dtype_bytes=2):
    q = bq * D * dtype_bytes
    kv = 2 * bk * D * dtype_bytes
    acc = bq * D * 4 + 2 * bq * 4
    logits = bq * bk * 4
    return q + kv + acc + logits


def vmem_moe(bc, bh, M, dtype_bytes=2):
    x = bc * M * dtype_bytes
    w = 2 * M * bh * dtype_bytes + bh * M * dtype_bytes
    acc = bc * M * 4
    act = 2 * bc * bh * 4
    return x + w + acc + act


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    B, S, H, Kv, D = 1, 256, 2, 1, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, Kv, D), jnp.float32)
    v = jax.random.normal(key, (B, S, Kv, D), jnp.float32)
    for bq, bk in [(64, 64), (128, 128), (256, 128)]:
        t0 = time.perf_counter()
        jax.block_until_ready(
            flash_attention_pallas(q, k, v, bq=bq, bk=bk, interpret=True))
        dt = (time.perf_counter() - t0) * 1e6
        rows.append(csv_row(
            f"kernel_blocks.flash.bq{bq}_bk{bk}", dt,
            f"vmem_kb={vmem_flash(bq, bk, 128)//1024}"
            f";mxu_aligned={bq % 128 == 0 and bk % 128 == 0}"))
    E, C, M, Hf = 2, 256, 256, 512
    x = jax.random.normal(key, (E, C, M), jnp.float32)
    wg = jax.random.normal(key, (E, M, Hf), jnp.float32) * 0.05
    wu = jax.random.normal(key, (E, M, Hf), jnp.float32) * 0.05
    wd = jax.random.normal(key, (E, Hf, M), jnp.float32) * 0.05
    for bc, bh in [(128, 128), (128, 256), (256, 512)]:
        t0 = time.perf_counter()
        jax.block_until_ready(
            moe_gemm_pallas(x, wg, wu, wd, bc=bc, bh=bh, interpret=True))
        dt = (time.perf_counter() - t0) * 1e6
        rows.append(csv_row(
            f"kernel_blocks.moe_gemm.bc{bc}_bh{bh}", dt,
            f"vmem_kb={vmem_moe(bc, bh, 2048)//1024}"
            f";mxu_aligned={bc % 128 == 0 and bh % 128 == 0}"))
    return rows, {}


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
