"""CI gate for the static verification layer (``repro.analysis``).

    PYTHONPATH=src python -m benchmarks.analysis_gate [--check] [--full]

Runs all three passes — graphcheck's lowering sweep (fast slice by
default; ``--full`` covers every policy x Table-5/7 shape x r1 x order),
kernelcheck's index_map case matrix, and jitlint over the whole source
tree — and reports per-pass violation counts plus timing as CSV rows.
``--check`` exits non-zero on any violation, same contract as
``python -m repro.analysis --check``.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import csv_row


def run(full: bool = False):
    from repro.analysis import PASSES, run_all

    rows, claims = [], {}
    t0 = time.perf_counter()
    results, info = run_all(PASSES, fast=not full)
    elapsed = time.perf_counter() - t0

    total = 0
    for name in PASSES:
        n = len(results[name])
        total += n
        rows.append(csv_row(f"analysis_gate/{name}", 0.0,
                            f"violations={n}"))
        claims[f"{name}_violations"] = n
    rows.append(csv_row("analysis_gate/all", elapsed * 1e6,
                        f"violations={total}"))
    claims["graphs_checked"] = info.get("graphcheck.graphs_checked", 0)
    claims["kernel_cases"] = info.get("kernelcheck.kernel_cases", 0)
    claims["clean"] = total == 0
    # detail goes to stderr here, not the claim summary — the CLI
    # (python -m repro.analysis) is the full reporter
    for vs in results.values():
        for v in vs:
            print(v, file=sys.stderr)
    return rows, claims


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--check", action="store_true",
                   help="exit non-zero on violations")
    p.add_argument("--full", action="store_true",
                   help="full sweep instead of the fast slice")
    args = p.parse_args()
    rows, claims = run(full=args.full)
    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    for k, v in sorted(claims.items()):
        print(f"# {k} = {v}")
    return 1 if (args.check and not claims["clean"]) else 0


if __name__ == "__main__":
    sys.exit(main())
