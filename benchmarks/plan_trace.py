"""ASCII Gantt dump of a lowered task graph: what one executed step's
schedule looks like under a policy's resolved plan.

    PYTHONPATH=src python -m benchmarks.plan_trace --policy findep \
        --shape 2048x4 --backbone deepseek [--width 100] \
        [--perfetto out.json]

``--perfetto`` additionally writes the scheduled intervals as a
Chrome-trace / Perfetto JSON file (``repro.obs.export``) — the same
Gantt, loadable in https://ui.perfetto.dev instead of rendered in
ASCII.

Lanes are the four DEP resources (AG compute, A2E link, EG compute, E2A
link); glyphs are task kinds (A=attention, S=shared segment, g=gate,
>=dispatch a2e, E=expert FFN, <=combine e2a). The trace is rendered from
``taskgraph.lower`` + ``taskgraph.schedule`` — the same lowering the
simulator, executor, and telemetry consume — so what you see is what the
executor walks. The harness ``run()`` additionally checks the rendered
schedule's makespan against ``simulate_dep`` (graph-vs-simulator parity
as a benchmark claim).
"""
from __future__ import annotations

import argparse

from benchmarks.common import PAPER_DEPTHS, csv_row, stage_models_for
from repro.configs import get_config
from repro.configs.base import DepClusterConfig
from repro.core.analytic import StageTimes
from repro.core.perf_model import PAPER_A6000
from repro.core.planner import FinDEPPlanner, PlannerConfig
from repro.core.simulator import non_overlapped_comm_time, simulate_dep
from repro.core.taskgraph import ascii_gantt
from repro.sched import POLICIES, make_policy

MEM_CAP = 4


def _planner(backbone: str, T: int) -> FinDEPPlanner:
    from benchmarks.common import BACKBONES
    return FinDEPPlanner(
        get_config(BACKBONES[backbone]),
        DepClusterConfig(num_devices=8, ag=3, eg=5), PAPER_A6000,
        PlannerConfig(mem_cap_samples=MEM_CAP, r1_cap=4, r2_cap=32,
                      T_override=T))


def trace(policy: str = "findep", shape: str = "2048x4",
          backbone: str = "deepseek", T: int = 8, width: int = 80):
    """Resolve a plan for ``shape`` ("SEQxBATCH") and return
    (plan, ScheduleResult, gantt string)."""
    S, batch = (int(x) for x in shape.lower().split("x"))
    planner = _planner(backbone, T)
    pol = make_policy(policy, planner, static_seq_len=S)
    plan = pol.resolve("prefill", S, batch or None)
    res = planner.schedule_plan(plan, S)
    return plan, res, ascii_gantt(res, width=width)


def run(policy: str = "findep"):
    rows = []
    parity = True
    for shape in ("1024x4", "2048x4"):
        plan, res, _ = trace(policy=policy, shape=shape)
        S = int(shape.split("x")[0])
        models, T = stage_models_for("deepseek", S, PAPER_A6000, T=8)
        st = StageTimes.from_models(models, plan.m_a,
                                    models.me_from_ma(plan.m_a, plan.r2))
        sim = simulate_dep(st, T, plan.r1, plan.r2, order=plan.order)
        parity &= abs(res.makespan - sim.makespan) <= 1e-9 * sim.makespan
        bd = res.breakdown()
        rows.append(csv_row(
            f"plan_trace.{shape}", res.makespan * 1e6,
            f"policy={policy};r1={plan.r1};r2={plan.r2};order={plan.order};"
            f"tasks={len(res.graph.tasks)};"
            f"exposed_comm_ms={non_overlapped_comm_time(res)*1e3:.2f};"
            f"busy_gemm_ms={bd.gemm*1e3:.2f};busy_attn_ms={bd.attn*1e3:.2f};"
            f"busy_comm_ms={bd.comm*1e3:.2f}"))
    return rows, {"graph_matches_simulator": parity}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", choices=POLICIES, default="findep")
    ap.add_argument("--shape", default="2048x4",
                    help="SEQxBATCH, e.g. 2048x4")
    ap.add_argument("--backbone", choices=("deepseek", "qwen3"),
                    default="deepseek")
    ap.add_argument("--layers", type=int, default=8,
                    help="MoE depth T of the rendered graph")
    ap.add_argument("--width", type=int, default=100)
    ap.add_argument("--perfetto", metavar="OUT.json", default=None,
                    help="also write the schedule as Chrome-trace JSON")
    args = ap.parse_args()
    plan, res, gantt = trace(policy=args.policy, shape=args.shape,
                             backbone=args.backbone, T=args.layers,
                             width=args.width)
    print(f"# plan: m_a={plan.m_a} r1={plan.r1} r2={plan.r2} "
          f"order={plan.order} makespan={res.makespan*1e3:.3f}ms "
          f"tasks={len(res.graph.tasks)}")
    print(gantt)
    if args.perfetto:
        from repro.obs import export_chrome_trace, validate_chrome_trace
        obj = export_chrome_trace(
            args.perfetto, schedule=res,
            meta={"policy": args.policy, "shape": args.shape,
                  "backbone": args.backbone})
        stats = validate_chrome_trace(obj)
        print(f"# wrote {args.perfetto}: {stats['complete']} events on "
              f"{stats['tracks']} lanes")
