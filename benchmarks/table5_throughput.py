"""Paper Table 5: FinDEP vs best-configured PPPipe across sequence lengths
and backbones; the paper reports speedups 1.02x-1.61x, growing with S."""
from __future__ import annotations

import time

from benchmarks.common import (BACKBONES, PAPER_DEPTHS, TESTBEDS, csv_row,
                               stage_models_for)
from repro.core.baselines import best_pppipe
from repro.core.solver import solve


def run():
    rows = []
    speedups = {}
    for backbone in BACKBONES:
        seqs = (1024, 2048, 4096, 8192)
        for tb_name, (hw, ag, eg, cap) in TESTBEDS.items():
            for S in seqs:
                models, T = stage_models_for(backbone, S, hw, ag, eg,
                                             T=PAPER_DEPTHS[backbone])
                t0 = time.perf_counter()
                fd, _ = solve(models, T, cap, objective="hybrid",
                              r1_cap=cap, r2_cap=32)
                solve_us = (time.perf_counter() - t0) * 1e6
                pp = best_pppipe(models, T, cap, r1_cap=cap)
                sp = fd.throughput / pp.throughput
                speedups[(backbone, tb_name, S)] = sp
                rows.append(csv_row(
                    f"table5.{backbone}.{tb_name}.S{S}", solve_us,
                    f"pppipe={pp.throughput:.1f};findep={fd.throughput:.1f};"
                    f"speedup={sp:.3f};plan=r1{fd.r1}xr2{fd.r2}{fd.order}"))
    mx = max(speedups.values())
    mn = min(speedups.values())
    return rows, {"speedup_min": mn, "speedup_max": mx,
                  "all_geq_1": mn >= 1.0 - 1e-9}


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
