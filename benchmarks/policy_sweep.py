"""One-command policy regression check: resolve every scheduling policy on
the smoke MoE config across a few arrival shapes and print a one-line
throughput comparison. Every policy's resolved configuration is
re-simulated on the ARRIVED shape (a stale static plan must be scored on
the shape it executes, not the shape it was solved for). FinDEP solving
per shape must never lose to the fixed-granularity baselines.

Each policy is additionally swept over a decode-churn occupancy trace
(--admission / --token-budget select the admission policy generating it):
distinct KV-ledger compositions => distinct decode resolutions for the
adaptive policies, one frozen plan for static."""
from __future__ import annotations

import argparse

from benchmarks.common import churn_occupancies, csv_row
from repro.configs import get_smoke_config
from repro.configs.base import DepClusterConfig
from repro.core import PAPER_A6000, FinDEPPlanner
from repro.core.analytic import StageTimes
from repro.core.planner import PlannerConfig
from repro.core.simulator import simulate_dep
from repro.runtime import ADMISSIONS
from repro.sched import POLICIES, make_policy

SHAPES = ((512, 4), (2048, 4), (2048, 8))   # (seq_bucket, batch/device)


def _throughput_on_shape(planner, plan, S: int) -> float:
    """Execute ``plan``'s configuration on the arrived shape S."""
    models = planner.stage_models(S)
    st = StageTimes.from_models(models, plan.m_a,
                                models.me_from_ma(plan.m_a, plan.r2))
    ms = simulate_dep(st, planner.num_moe_layers(), plan.r1, plan.r2,
                      order=plan.order).makespan
    return plan.r1 * plan.m_a * models.cluster.ag * S / ms


def run(policies=POLICIES, admission="fcfs", token_budget=None):
    planner = FinDEPPlanner(
        get_smoke_config("qwen2-moe-a2.7b"),
        DepClusterConfig(num_devices=8, ag=3, eg=5), PAPER_A6000,
        PlannerConfig(mem_cap_samples=8))
    occs = churn_occupancies(num_slots=8, num_requests=12,
                             admission=admission,
                             token_budget=token_budget,
                             prompt_range=(32, 1536), seed=0)
    rows = []
    agg = {}
    for name in policies:
        pol = make_policy(name, planner, static_seq_len=2048)
        tput = {}
        for S, b in SHAPES:
            plan = pol.resolve("prefill", S, b)
            tput[(S, b)] = _throughput_on_shape(planner, plan, S)
        agg[name] = sum(tput.values()) / len(tput)
        decode_plans = {pol.resolve("decode", occupancy=occ)
                        for occ in set(occs)}
        detail = ";".join(f"S{S}b{b}={t:.0f}" for (S, b), t in tput.items())
        rows.append(csv_row(
            f"policy_sweep.{name}", 0.0,
            f"mean_tokens_per_s={agg[name]:.0f};"
            f"decode_occupancies={len(set(occs))};"
            f"decode_plans={len(decode_plans)};"
            f"admission={admission};{detail}"))
    line = " ".join(f"{n}={agg[n]:.0f}" for n in policies)
    print(f"# policy throughput sweep (tok/s on arrived shape): {line}")
    info = {}
    if "findep" in agg:
        # static is excluded: its plan's r1*m_a may not match the arrived
        # batch, so its token count differs from the fixed-batch policies
        info["findep_never_loses"] = all(
            agg["findep"] >= v * (1 - 1e-9)
            for n, v in agg.items() if n not in ("findep", "static"))
    return rows, info


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", choices=POLICIES, nargs="*",
                    default=list(POLICIES),
                    help="subset of policies to sweep")
    ap.add_argument("--admission", choices=ADMISSIONS, default="fcfs")
    ap.add_argument("--token-budget", type=int, default=None)
    args = ap.parse_args()
    for r in run(policies=tuple(args.policy), admission=args.admission,
                 token_budget=args.token_budget)[0]:
        print(r)
