"""Ragged decode-attention sweep: occupancy fraction x block size.

Interpret-mode on CPU, so wall times are correctness-path cost only — NOT
a TPU timing. The structural quantity that matters for the TPU target is
the executed-KV-block count per row, which the kernel itself reports:
streamed bytes scale with ceil(length/bc) blocks, not C/bc, so the
derived column tracks the fraction of the dense cache stream a given
occupancy actually pays.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.kernels import on_tpu
from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    B, H, Kv, D, C = 4, 8, 2, 64, 2048
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, C, Kv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, C, Kv, D), jnp.float32)

    proportional = True
    for frac in (0.125, 0.5, 1.0):
        lengths = jnp.full((B,), int(C * frac), jnp.int32)
        for bc in (256, 512):
            fn = jax.jit(lambda q, k, v, ln, bc=bc: decode_attention_pallas(
                q, k, v, ln, bc=bc, interpret=not on_tpu(),
                return_block_counts=True))
            out, counts = fn(q, k, v, lengths)       # compile + warm
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            out, counts = fn(q, k, v, lengths)
            jax.block_until_ready(out)
            dt = (time.perf_counter() - t0) * 1e6
            executed = int(jnp.max(counts))
            total = C // bc
            expect = -(-int(C * frac) // bc)         # ceil(len/bc)
            proportional &= executed == expect
            rows.append(csv_row(
                f"decode_attention.occ{frac}_bc{bc}", dt,
                f"blocks_per_row={executed}/{total};expect={expect};"
                f"stream_frac={executed / total:.3f}"))
    # oracle cost at full cache, for scale
    t0 = time.perf_counter()
    jax.block_until_ready(decode_attention_ref(
        q, k, v, jnp.full((B,), C, jnp.int32)))
    rows.append(csv_row("decode_attention.ref_dense",
                        (time.perf_counter() - t0) * 1e6,
                        f"streams_full_cache=C/{C}"))
    return rows, {"block_skip_proportional": proportional}


if __name__ == "__main__":
    for r in run()[0]:
        print(r)
