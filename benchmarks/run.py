"""Benchmark harness: one module per paper table (+ solver latency,
perf-model fit, live engine, kernel block sweep).

Prints ``name,us_per_call,derived`` CSV rows and a summary of the paper
claims checked. Usage: PYTHONPATH=src python -m benchmarks.run [names...]
"""
from __future__ import annotations

import sys

MODULES = [
    "perf_model_fit",
    "table3_ma",
    "table4_r1",
    "table5_throughput",
    "table6_online",
    "table7_overlap",
    "plan_trace",
    "solver_latency",
    "policy_sweep",
    "regime_sweep",
    "serving_engine",
    "kernel_blocks",
    "decode_attention",
    "paged_kv",
    "expert_load",
    "obs_smoke",
    "analysis_gate",
]


def main() -> None:
    import importlib
    names = sys.argv[1:] or MODULES
    print("name,us_per_call,derived")
    summary = {}
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        rows, info = mod.run()
        for r in rows:
            print(r, flush=True)
        summary.update({f"{name}.{k}": v for k, v in info.items()})
    print("\n# claim summary")
    for k, v in sorted(summary.items()):
        print(f"# {k} = {v}")


if __name__ == '__main__':
    main()
