"""Parameter / activation partition rules per architecture family × mesh.

Rules are name-based over the parameter pytree paths produced by
``repro.models``:

  dense / moe / vlm / audio (attention stacks):
    column-parallel (shard output dim over "model"): wq wk wv w_ukv gate up
        ffn_up w_gates w_if skip lm_head proj
    row-parallel   (shard input dim over "model"):  wo down out ffn_down
    expert-parallel (shard expert dim):             experts.{gate,up,down}
    vocab-sharded:                                  embed.embedding
    replicated: norms, biases of row-parallel, router, small MLA latents
  ssm (xLSTM): weights replicated (matrix-memory recurrence does not
    shard over d_inner without cross-device outer products); batch DP.
  hybrid (RG-LRU): recurrence width W is elementwise => column-parallel
    in-projections, sharded state, row-parallel out.

Stacked-layer params (scan mode) get the same spec with a leading None.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

COL = ("wq", "wk", "wv", "w_ukv", "gate", "up", "ffn_up", "w_gates",
       "w_if", "skip", "lm_head", "proj", "in_x", "in_gate", "w_a", "w_i")
ROW = ("wo", "down", "out", "ffn_down")
# "shared" experts replicate: in DEP they belong to the (data-parallel) AG
REPL = ("w_dkv", "w_kpe", "router", "r_gates", "conv", "shared")


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(f"[{e.idx}]")
        else:
            names.append(str(e))
    return tuple(names)


def _spec_for(names: Tuple[str, ...], leaf, cfg: ModelConfig,
              model_axis: str) -> P:
    nd = leaf.ndim
    joined = set(names)

    def pad_left(spec_tail):
        """Left-pad with None for any stacking/extra leading dims."""
        pad = nd - len(spec_tail)
        return P(*([None] * pad + list(spec_tail)))

    if "embedding" in joined:
        return pad_left([model_axis, None])
    # SSM family: replicate everything but the embedding/readout
    if cfg.family == "ssm":
        if any(n in joined for n in ("lm_head",)):
            return pad_left([None, model_axis])
        return P(*([None] * nd))
    if "experts" in joined:
        return pad_left([model_axis, None, None])
    last = None
    for n in names:
        if n in REPL:
            return P(*([None] * nd))
    for n in names:
        if n in ROW and nd >= 2:
            return pad_left([model_axis, None])
    for n in names:
        if n in COL:
            if nd >= 2:
                return pad_left([None, model_axis])
            return pad_left([model_axis])           # col-parallel bias
    return P(*([None] * nd))


def sanitize_spec(spec: P, shape, mesh: Optional[Mesh]) -> P:
    """Drop axis assignments whose mesh extent does not divide the dim —
    jit in_shardings require exact divisibility (no GSPMD padding)."""
    if mesh is None:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        out.append(entry if dim % prod == 0 else None)
    return P(*out)


FSDP_THRESHOLD_ELEMS = 8 * 1024 * 1024    # shard-further above 16MB bf16


def apply_fsdp(spec: P, shape, mesh: Optional[Mesh],
               fsdp_axis: str = "data",
               threshold_elems: int = FSDP_THRESHOLD_ELEMS) -> P:
    """ZeRO-3-style 2D weight sharding: when a parameter is still larger
    than FSDP_THRESHOLD_ELEMS per device after tensor sharding, shard its
    largest unsharded dim over the data axis too (GSPMD all-gathers it just
    before use). Intra-pod only — never over "pod" (DCI too slow)."""
    if mesh is None or fsdp_axis not in mesh.axis_names or len(shape) < 2:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    shards = 1
    for e in entries:
        if e is None:
            continue
        for a in ((e,) if isinstance(e, str) else e):
            shards *= mesh.shape[a]
    elems = 1
    for d in shape:
        elems *= d
    if elems // shards <= threshold_elems:
        return spec
    df = mesh.shape[fsdp_axis]
    for dim in sorted(range(len(shape)), key=lambda i: -shape[i]):
        if entries[dim] is None and shape[dim] % df == 0:
            entries[dim] = fsdp_axis
            return P(*entries)
    return spec


# Never FSDP the readout/embedding: sharding their contracting dim makes
# GSPMD gather the [tokens, vocab] logits (observed ~1 TB at train_4k with
# a 256k vocab) instead of the (small) weight.
FSDP_EXCLUDE = ("embedding", "lm_head")


def params_pspecs(params, cfg: ModelConfig, model_axis: str = "model",
                  mesh: Optional[Mesh] = None, fsdp: bool = True,
                  fsdp_threshold_elems: int = FSDP_THRESHOLD_ELEMS):
    """PartitionSpec pytree matching ``params``."""
    def one(path, leaf):
        names = _path_names(path)
        spec = _spec_for(names, leaf, cfg, model_axis)
        spec = sanitize_spec(spec, leaf.shape, mesh)
        if fsdp and not any(n in FSDP_EXCLUDE for n in names):
            spec = apply_fsdp(spec, leaf.shape, mesh,
                              threshold_elems=fsdp_threshold_elems)
        return spec
    return jax.tree_util.tree_map_with_path(one, params)


def params_shardings(params, cfg: ModelConfig, mesh: Mesh,
                     model_axis: str = "model"):
    specs = params_pspecs(params, cfg, model_axis, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def batch_pspec(global_batch: int, mesh: Mesh,
                exclude: Tuple[str, ...] = ("model",)) -> P:
    """Shard the batch dim over as many data axes as divide it."""
    axes = []
    prod = 1
    for a in mesh.axis_names:
        if a in exclude:
            continue
        if global_batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return P(tuple(axes) or None)


def cache_pspecs(cache, cfg: ModelConfig, mesh: Mesh, global_batch: int,
                 model_axis: str = "model", stacked: bool = False):
    """KV caches: batch over data axes, kv-heads over model (GSPMD pads
    when they do not divide); SSM states: batch over data axes, width over
    model for RG-LRU. ``stacked`` marks scan-mode caches with a leading
    layer-group dimension (left-padded with None)."""
    bspec = batch_pspec(global_batch, mesh)
    b_axes = bspec[0] if bspec != P(None) else None
    lead = [None] if stacked else []

    def spec(path, leaf):
        names = _path_names(path)
        nd = leaf.ndim - (1 if stacked else 0)
        if nd <= 0:                         # cache index scalar
            return P(*lead) if stacked else P()
        if any(n in ("k", "v") for n in names) and nd == 4:
            C, kv = leaf.shape[-3], leaf.shape[-2]
            mo = mesh.shape[model_axis]
            if kv % mo == 0:    # kv-head sharding when it divides
                return P(*lead, b_axes, None, model_axis, None)
            if C % mo == 0:     # else sequence-sharded: served by the
                                # shard_map distributed-flash decode core
                return P(*lead, b_axes, model_axis, None, None)
            return P(*lead, b_axes, None, None, None)
        if any(n in ("ckv", "kpe") for n in names) and nd == 3:
            return P(*lead, b_axes, None, None)
        if "h" in names and nd == 2 and cfg.family == "hybrid":
            return P(*lead, b_axes, model_axis)
        # ssm states / conv states: batch-sharded only
        return P(*(lead + [b_axes] + [None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec, cache)
