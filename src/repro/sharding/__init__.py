from repro.sharding.partition import (batch_pspec, cache_pspecs,
                                      params_pspecs, params_shardings)

__all__ = ["batch_pspec", "cache_pspecs", "params_pspecs",
           "params_shardings"]
