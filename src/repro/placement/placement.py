"""Expert placement: which EP rank owns each expert, and which hot
experts are replicated onto every rank.

FinDEP solves schedules over a *uniform* expert layout — E/eg experts
per rank, uniform token routing. Real gates route with heavy skew
(Zipf-like popularity), so the EG lane's makespan is governed by the
most-loaded rank, not the mean. This module owns the *place* step of the
observe -> place -> plan loop:

    ExpertLoadTracker (tracker.py)  per-layer [E] EWMA token loads
            |  aggregated loads
            v
    rebalance(loads, ...) -> Placement      (greedy, this module)
            |  assignment + replica set + epoch
            v
    taskgraph.lower(hot_experts=, placement_epoch=)   replica-aware IR
    dep.moe_apply_dep(placement=)                     replicated walk
    FinDEPPlanner.plan(skew=)                         skew-aware solve

A ``Placement`` is frozen and hashable: the ``epoch`` scalar is what
flows into ``TaskGraph`` identity and ``PlanCache`` keys, so a placement
change can never serve a stale replica layout.

Replication model: the top-k hottest experts are replicated onto EVERY
EP rank (MegaScale-style hot replication). Their tokens never cross the
A2E/E2A wire — each attention rank runs the hot FFN on its locally
resident tokens (the REP task on the AG lane) — and the cold experts are
re-assigned to ranks by greedy LPT so the per-rank cold load is as flat
as the equal-slots-per-rank constraint allows (the stacked ``[E, ...]``
weight layout keeps E/eg expert slots per rank).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Placement:
    """Expert -> rank map plus the replica set, one epoch of the
    re-balancer.

    ``assignment[e]`` is the EP rank owning logical expert ``e`` (its
    single home for the A2E dispatch); ``replicated`` lists the logical
    ids of the hot experts additionally materialized on every rank.
    ``perm`` is the logical -> physical slot permutation realizing the
    assignment on the stacked ``[E, ...]`` weight arrays (physical slot
    ``perm[e]`` holds logical expert ``e``'s weights); the identity perm
    means the weights need no movement. ``loads`` records the (mean-one
    normalized) load histogram the placement was solved against — carried
    for telemetry/benchmarks, excluded from identity."""

    num_experts: int
    num_ranks: int
    assignment: Tuple[int, ...]
    replicated: Tuple[int, ...] = ()
    epoch: int = 0
    loads: Tuple[float, ...] = field(default=(), compare=False)

    def __post_init__(self):
        if len(self.assignment) != self.num_experts:
            raise ValueError("assignment must cover every expert")
        if self.num_experts % self.num_ranks:
            raise ValueError("experts must divide evenly across ranks "
                             "(stacked weight layout)")
        per = self.experts_per_rank
        counts = [0] * self.num_ranks
        for r in self.assignment:
            if not 0 <= r < self.num_ranks:
                raise ValueError(f"rank {r} out of range")
            counts[r] += 1
        if any(c != per for c in counts):
            raise ValueError(f"assignment must give every rank exactly "
                             f"{per} experts, got {counts}")
        if len(set(self.replicated)) != len(self.replicated):
            raise ValueError("duplicate replicated expert")
        for e in self.replicated:
            if not 0 <= e < self.num_experts:
                raise ValueError(f"replicated expert {e} out of range")

    @property
    def experts_per_rank(self) -> int:
        return self.num_experts // self.num_ranks

    @property
    def hot_experts(self) -> int:
        return len(self.replicated)

    @property
    def is_uniform(self) -> bool:
        """True when this placement executes exactly the unreplicated
        contiguous layout (rank r owns experts [r*per, (r+1)*per)) —
        the bit-identical fast path in ``dep.moe_apply_dep``."""
        return not self.replicated and self.assignment == tuple(
            e // self.experts_per_rank for e in range(self.num_experts))

    @property
    def perm(self) -> Tuple[int, ...]:
        """Logical expert -> physical slot permutation: rank ``r``'s
        slots ``[r*per, (r+1)*per)`` hold the experts assigned to it, in
        ascending logical order (so the uniform assignment yields the
        identity)."""
        per = self.experts_per_rank
        next_slot = [r * per for r in range(self.num_ranks)]
        out = [0] * self.num_experts
        for e, r in enumerate(self.assignment):
            out[e] = next_slot[r]
            next_slot[r] += 1
        return tuple(out)

    def rank_of(self, expert: int) -> int:
        return self.assignment[expert]

    @staticmethod
    def uniform(num_experts: int, num_ranks: int,
                epoch: int = 0) -> "Placement":
        """The pre-placement layout: contiguous blocks, no replicas."""
        per = num_experts // num_ranks
        return Placement(num_experts=num_experts, num_ranks=num_ranks,
                         assignment=tuple(e // per
                                          for e in range(num_experts)),
                         replicated=(), epoch=epoch)


def _normalize(loads: Sequence[float]) -> np.ndarray:
    arr = np.asarray(loads, dtype=np.float64)
    if arr.ndim != 1:
        raise ValueError("loads must be a [E] histogram")
    total = float(arr.sum())
    if total <= 0.0:
        return np.ones(arr.shape[0]) / arr.shape[0]
    return arr / total


def rank_loads(placement: Placement,
               loads: Sequence[float]) -> np.ndarray:
    """Per-rank cold token-load fractions under ``placement`` —
    replicated experts contribute nothing to the EG lane (their tokens
    stay on the attention ranks)."""
    frac = _normalize(loads)
    hot = set(placement.replicated)
    out = np.zeros(placement.num_ranks)
    for e, r in enumerate(placement.assignment):
        if e not in hot:
            out[r] += frac[e]
    return out


def max_rank_load(placement: Placement, loads: Sequence[float]) -> float:
    """Max per-rank cold load fraction: the EG lane's EXP task time
    scales with this (worst rank bounds the lane, Section 3's mutual
    exclusion)."""
    return float(rank_loads(placement, loads).max())


def modeled_exp_time(placement: Placement, loads: Sequence[float],
                     t_exp_uniform: float) -> float:
    """Modeled worst-rank EXP stage time: the uniform-layout stage time
    scaled by how much the hottest rank exceeds the uniform 1/eg share.
    The quantity ``rebalance`` greedily minimizes."""
    uniform_share = 1.0 / placement.num_ranks
    return t_exp_uniform * max_rank_load(placement, loads) / uniform_share


def rebalance(loads: Sequence[float], num_ranks: int,
              replicate_hot_k: int = 0, epoch: int = 0) -> Placement:
    """Greedy re-placement for an observed [E] load histogram.

    1. The ``replicate_hot_k`` hottest experts are replicated onto every
       rank; their tokens leave the EG lane entirely (REP task on AG).
    2. The cold experts are assigned by LPT (longest processing time
       first) under the equal-slots-per-rank constraint: heaviest expert
       to the currently lightest rank that still has a free slot. The
       replicated experts' slots keep their weights resident where the
       LPT pass parks them (every rank also holds a replica copy), so
       slot counts stay uniform.

    Deterministic: ties break toward the lower expert id / lower rank.
    """
    frac = _normalize(loads)
    E = frac.shape[0]
    if E % num_ranks:
        raise ValueError("experts must divide evenly across ranks")
    k = max(int(replicate_hot_k), 0)
    k = min(k, E - num_ranks)  # keep >= 1 cold expert per rank slot-able
    # hottest k experts, ties to lower id (stable argsort on -load)
    order = np.argsort(-frac, kind="stable")
    hot = tuple(sorted(int(e) for e in order[:k]))
    hot_set = set(hot)

    per = E // num_ranks
    slots = [per] * num_ranks
    bins = [0.0] * num_ranks
    assignment = [0] * E
    # LPT over every expert (hot experts weigh 0 on the EG lane but
    # still occupy a slot — the stacked layout is uniform)
    weights = [(0.0 if e in hot_set else float(frac[e]), e)
               for e in range(E)]
    for w, e in sorted(weights, key=lambda we: (-we[0], we[1])):
        r = min((r for r in range(num_ranks) if slots[r] > 0),
                key=lambda r: (bins[r], r))
        assignment[e] = r
        slots[r] -= 1
        bins[r] += w
    return Placement(num_experts=E, num_ranks=num_ranks,
                     assignment=tuple(assignment), replicated=hot,
                     epoch=epoch,
                     loads=tuple(float(x) for x in frac * E))
