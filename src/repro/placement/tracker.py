"""Expert load telemetry: per-layer EWMA token-load histograms and the
quantized skew summary the planner and ``PlanCache`` key on.

The gate already computes per-expert assignment counts (the routing
onehot in ``models/moe.py``); ``moe_dispatch`` now surfaces them as
``DispatchInfo.load`` and the engine feeds each step's stacked ``[L, E]``
histogram here. The tracker mirrors ``StepTimer``'s shape: EWMA with a
smoothing factor, per-key (here per-layer) state, cheap ``reset``.

``SkewSummary`` is the frozen, ordered, quantized projection of the
tracker + active placement that (a) keys ``PlanCache`` entries and the
planner's solve memo — recurring skew regimes cost a dict lookup, and
(b) carries the three scale factors the skew-aware cost model needs:

    kappa      worst-rank cold load / uniform 1/eg share — multiplies
               the modeled EXP task time (the lane is bound by its
               most-loaded rank, not the mean)
    rho        fraction of routed tokens handled by replicated hot
               experts — they never cross the A2E/E2A wire, so comm
               volume scales by (1 - rho) and the REP task runs rho of
               the uniform-layout expert FLOPs per attention rank
    max_expert single hottest expert's load / uniform 1/E share —
               scales ``expert_capacity`` so the executed dispatch
               keeps the hot expert's tokens instead of dropping them
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from repro.placement.placement import (Placement, _normalize,
                                       max_rank_load)

#: quantization step for SkewSummary fields — coarse enough that a
#: stable skew regime maps to ONE summary (plan-cache hit), fine enough
#: that a real shift re-solves.
_QUANT = 0.125


def _q(x: float) -> float:
    return round(float(x) / _QUANT) * _QUANT


@dataclass(frozen=True, order=True)
class SkewSummary:
    """Quantized routing-skew fingerprint (hashable plan-cache key
    component). ``hot_k`` and ``epoch`` come from the active placement;
    the float fields are quantized to ``_QUANT`` steps."""

    kappa: float = 1.0
    rho: float = 0.0
    max_expert: float = 1.0
    hot_k: int = 0
    epoch: int = 0

    @property
    def is_uniform(self) -> bool:
        return (self.kappa == 1.0 and self.rho == 0.0
                and self.max_expert == 1.0 and self.hot_k == 0)


#: the no-telemetry default: uniform routing, no replication, epoch 0.
UNIFORM_SKEW = SkewSummary()


class ExpertLoadTracker:
    """Per-layer ``[E]`` EWMA of gate token loads.

    ``observe`` takes one step's histogram — ``[E]`` (a single layer or
    an already-aggregated model step) or ``[L, E]`` stacked per layer —
    normalized to fractions internally so prefill (many tokens) and
    decode (one token per slot) steps weigh equally per observation.
    """

    def __init__(self, num_experts: int, smoothing: float = 0.2):
        if not 0.0 < smoothing <= 1.0:
            raise ValueError("smoothing must be in (0, 1]")
        self.num_experts = int(num_experts)
        self.smoothing = float(smoothing)
        self._ewma: Dict[int, np.ndarray] = {}
        self.observations = 0

    def observe(self, loads) -> None:
        arr = np.asarray(loads, dtype=np.float64)
        if arr.ndim == 1:
            arr = arr[None, :]
        if arr.ndim != 2 or arr.shape[1] != self.num_experts:
            raise ValueError(
                f"expected [L, {self.num_experts}] loads, got {arr.shape}")
        a = self.smoothing
        for layer in range(arr.shape[0]):
            frac = _normalize(arr[layer])
            prev = self._ewma.get(layer)
            self._ewma[layer] = (frac if prev is None
                                 else a * frac + (1.0 - a) * prev)
        self.observations += 1

    @property
    def layers(self) -> int:
        return len(self._ewma)

    def layer_loads(self, layer: int) -> Optional[np.ndarray]:
        arr = self._ewma.get(layer)
        return None if arr is None else arr.copy()

    def aggregate(self) -> np.ndarray:
        """Mean of the per-layer EWMA fractions — the [E] histogram the
        (layer-shared) placement is solved against. Uniform before any
        observation."""
        if not self._ewma:
            return np.ones(self.num_experts) / self.num_experts
        return np.mean(list(self._ewma.values()), axis=0)

    def imbalance(self) -> float:
        """Hottest expert's load as a multiple of the uniform 1/E share
        (1.0 = perfectly balanced) — the re-balance trigger metric."""
        agg = self.aggregate()
        return float(agg.max() * self.num_experts)

    def reset(self) -> None:
        self._ewma.clear()
        self.observations = 0

    def snapshot(self) -> Dict[str, float]:
        """Flat numeric dict for the metrics registry."""
        return {"observations": float(self.observations),
                "layers": float(self.layers),
                "imbalance": self.imbalance()}

    def summary(self, placement: Optional[Placement] = None,
                num_ranks: Optional[int] = None) -> SkewSummary:
        """Project the tracked loads (+ active placement) onto the
        quantized ``SkewSummary`` the planner keys on."""
        if self.observations == 0:
            epoch = placement.epoch if placement is not None else 0
            hot = placement.hot_experts if placement is not None else 0
            return SkewSummary(hot_k=hot, epoch=epoch)
        agg = self.aggregate()
        if placement is None:
            ranks = int(num_ranks) if num_ranks else 1
            placement = Placement.uniform(self.num_experts, ranks) \
                if self.num_experts % ranks == 0 else None
        if placement is None:
            return SkewSummary(max_expert=_q(self.imbalance()))
        frac = _normalize(agg)
        rho = float(sum(frac[e] for e in placement.replicated))
        kappa = (max_rank_load(placement, agg)
                 * placement.num_ranks)
        return SkewSummary(kappa=max(_q(kappa), 0.0),
                           rho=min(max(_q(rho), 0.0), 1.0),
                           max_expert=max(_q(self.imbalance()), 0.0),
                           hot_k=placement.hot_experts,
                           epoch=placement.epoch)


def capacity_scale(skew: Optional[SkewSummary],
                   capacity_factor: float) -> float:
    """Multiplier on the executed expert capacity so the observed
    hottest expert's tokens fit its buffer row: the configured
    ``capacity_factor`` already covers ``capacity_factor`` x the uniform
    1/E share, so only the excess ``max_expert / capacity_factor``
    widens it. 1.0 (no change) when routing is within the configured
    headroom."""
    if skew is None or capacity_factor <= 0:
        return 1.0
    return max(1.0, float(skew.max_expert) / float(capacity_factor))


def zipf_loads(num_experts: int, s: float = 1.2,
               permutation: Optional[Sequence[int]] = None) -> np.ndarray:
    """Zipf(s) load histogram over ``num_experts`` (rank r gets
    1/(r+1)^s, normalized) — the skew regime the benchmark and tests
    replay. ``permutation`` shuffles which expert id is hot."""
    ranks = np.arange(1, num_experts + 1, dtype=np.float64)
    frac = ranks ** (-float(s))
    frac /= frac.sum()
    if permutation is not None:
        out = np.zeros(num_experts)
        out[np.asarray(permutation, dtype=np.int64)] = frac
        return out
    return frac
