"""Expert placement subsystem: load telemetry, hot-expert replication,
and skew-aware placement — the observe -> place -> plan loop for
experts (ROADMAP item 2).

    ExpertLoadTracker   per-layer [E] EWMA of gate token loads
    Placement           expert -> rank map + replica set + epoch
    rebalance           greedy LPT + top-k hot replication
    SkewSummary         quantized skew fingerprint for plan-cache keys
"""
from repro.placement.placement import (Placement, max_rank_load,
                                       modeled_exp_time, rank_loads,
                                       rebalance)
from repro.placement.tracker import (UNIFORM_SKEW, ExpertLoadTracker,
                                     SkewSummary, capacity_scale, zipf_loads)

__all__ = [
    "ExpertLoadTracker",
    "Placement",
    "SkewSummary",
    "UNIFORM_SKEW",
    "capacity_scale",
    "max_rank_load",
    "modeled_exp_time",
    "rank_loads",
    "rebalance",
    "zipf_loads",
]
