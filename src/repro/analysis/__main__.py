"""CLI gate: ``python -m repro.analysis [--check] [--fast] [passes...]``.

Prints every violation and a per-pass summary. ``--check`` exits
non-zero on any violation (the CI gate mode); without it the run is
report-only. ``--fast`` restricts graphcheck's sweep and kernelcheck's
case matrix to representative slices (same properties, smaller budget).
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.analysis import PASSES, run_all


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.analysis",
                                description=__doc__)
    p.add_argument("passes", nargs="*", default=[],
                   help=f"passes to run (default all): {', '.join(PASSES)}")
    p.add_argument("--check", action="store_true",
                   help="exit non-zero on violations (CI gate)")
    p.add_argument("--fast", action="store_true",
                   help="representative slice of the sweep/case matrix")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="suppress per-shape progress lines")
    args = p.parse_args(argv)
    passes = tuple(args.passes) or PASSES

    log = None if args.quiet else (lambda m: print(f"  {m}", flush=True))
    t0 = time.perf_counter()
    results, info = run_all(passes, fast=args.fast, log=log)
    elapsed = time.perf_counter() - t0

    total = 0
    for name in passes:
        for v in results[name]:
            print(v)
        total += len(results[name])
        print(f"{name}: {len(results[name])} violation(s)")
    for k, v in sorted(info.items()):
        print(f"# {k} = {v}")
    print(f"# total = {total} violation(s) in {elapsed:.1f}s")
    if total and args.check:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
