"""Static verifier over ``core/taskgraph.py`` artifacts.

The task-graph IR carries the repo's scheduling correctness: the lowered
graph must be acyclic with sound deps, its exact FIFO-lane schedule must
be race-free (no two tasks overlap on one resource lane, no task starts
before a dependency ends), every realization the executor can take must
be deadlock-free, the chunk stream must conserve capacity (each
(mb, chunk) slice produced exactly once, ``capacity_multiple ==
r1*r2*m_e``), and any priority-hint vector must be a dep-consistent
permutation. No runtime test can cover every (policy, r1, r2, m_a, m_e,
order) combination; this module proves the properties on the lowered
structure directly — and ``sweep`` walks the full benchmark shape space
(all four policies x Table-5/7 shapes x r1 in {1,2,4} x ASAS/AASS).

Deadlock detection is wait-for-graph cycle detection, NOT replay: a
realization is a per-lane FIFO service order plus optional extra dep
edges (``stream_serial_deps`` models the sequential executor). A task
waits for its deps and for its lane predecessor; a cycle in that
relation is a schedule that can never complete. Emission order is
deadlock-free even with the cross-stream serial edges (each stream's
tasks precede the next stream's on every lane it shares), so the
canonical NEGATIVE case is a service order that queues a task ahead of
its own dependency on a shared lane — e.g. GATE before its ATTN on the
AG lane, an immediate two-cycle (GATE dep-waits ATTN, ATTN lane-waits
GATE) — which the detector must report with the witness cycle.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, defaultdict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.report import Violation
from repro.core.analytic import ORDER_AASS, ORDER_ASAS
from repro.core.taskgraph import (A2E, ATTN, E2A, EXP, GATE, KIND_RESOURCE,
                                  KINDS, REP, RESOURCES, SHARED, _HINT_COSTS,
                                  ExecProgram, LoweringSpec, ScheduleResult,
                                  TaskCosts, TaskGraph, lower, schedule,
                                  stream_major_order, stream_serial_deps)

PASS = "graphcheck"

#: chunk-stream kinds that must each cover the full (mb, chunk) grid
_CHUNK_KINDS = (A2E, EXP, E2A)


def _where(graph: TaskGraph) -> str:
    return (f"graph(T={graph.T}, r1={graph.r1}, r2={graph.r2}, "
            f"order={graph.order}, m_e={graph.m_e}, "
            f"shared={graph.has_shared}, "
            f"blocks_a2e={graph.shared_blocks_a2e}, "
            f"hot={graph.hot_experts})")


def _desc(graph: TaskGraph, idx: int) -> str:
    t = graph.tasks[idx]
    return (f"{t.kind}(layer={t.layer}, mb={t.mb}, chunk={t.chunk}, "
            f"emission={idx})")


# ---------------------------------------------------------------------------
# structure: dep soundness + field ranges
# ---------------------------------------------------------------------------


def check_structure(graph: TaskGraph) -> List[Violation]:
    """Deps must point to earlier emissions (acyclicity by construction),
    kinds/resources must be known, and (layer, mb, chunk) must lie in the
    lowering's ranges."""
    out: List[Violation] = []
    w = _where(graph)
    for i, t in enumerate(graph.tasks):
        if t.kind not in KINDS:
            out.append(Violation(PASS, "unknown-kind", w,
                                 f"task {i} has unknown kind {t.kind!r}"))
            continue
        if KIND_RESOURCE[t.kind] not in RESOURCES:
            out.append(Violation(PASS, "unknown-resource", w,
                                 f"task {i} ({t.kind}) maps to unknown "
                                 f"resource {KIND_RESOURCE[t.kind]!r}"))
        for d in t.deps:
            if not 0 <= d < i:
                out.append(Violation(
                    PASS, "dep-not-earlier", w,
                    f"{_desc(graph, i)} depends on index {d}, which is "
                    f"not an earlier emission — the tuple is no longer "
                    f"topologically ordered"))
        if not 0 <= t.layer < graph.T:
            out.append(Violation(PASS, "layer-range", w,
                                 f"{_desc(graph, i)} layer out of "
                                 f"[0, {graph.T})"))
        if not 0 <= t.mb < graph.r1:
            out.append(Violation(PASS, "mb-range", w,
                                 f"{_desc(graph, i)} mb out of "
                                 f"[0, {graph.r1})"))
        if t.kind in _CHUNK_KINDS:
            hi = graph.r2
        elif t.kind == SHARED:
            hi = graph.shared_segments
        else:
            hi = 1
        if not 0 <= t.chunk < hi:
            out.append(Violation(PASS, "chunk-range", w,
                                 f"{_desc(graph, i)} chunk out of "
                                 f"[0, {hi})"))
    return out


# ---------------------------------------------------------------------------
# race detector over a ScheduleResult
# ---------------------------------------------------------------------------


def check_schedule_result(res: ScheduleResult) -> List[Violation]:
    """Lane races and dep-order slips in an exact schedule: on every
    resource lane the (start, end) intervals must be non-overlapping in
    service order, and every task must start at/after the end of each of
    its deps (within float epsilon of the makespan scale)."""
    out: List[Violation] = []
    graph = res.graph
    w = _where(graph)
    eps = 1e-9 * max(res.makespan, 1.0)
    prev_end: Dict[str, float] = {}
    prev_idx: Dict[str, int] = {}
    for i, t in enumerate(graph.tasks):
        s, e = res.starts[i], res.ends[i]
        if e < s - eps:
            out.append(Violation(PASS, "negative-duration", w,
                                 f"{_desc(graph, i)} ends before it "
                                 f"starts ({s:.3e} -> {e:.3e})"))
        lane = t.resource
        if lane in prev_end and s < prev_end[lane] - eps:
            out.append(Violation(
                PASS, "lane-race", w,
                f"lane {lane}: {_desc(graph, i)} starts at {s:.3e} while "
                f"{_desc(graph, prev_idx[lane])} still occupies the lane "
                f"until {prev_end[lane]:.3e}"))
        prev_end[lane] = e
        prev_idx[lane] = i
        for d in t.deps:
            if s < res.ends[d] - eps:
                out.append(Violation(
                    PASS, "dep-order", w,
                    f"{_desc(graph, i)} starts at {s:.3e} before its "
                    f"dependency {_desc(graph, d)} ends at "
                    f"{res.ends[d]:.3e}"))
    return out


# ---------------------------------------------------------------------------
# capacity conservation
# ---------------------------------------------------------------------------


def check_capacity(graph: TaskGraph) -> List[Violation]:
    """Every (mb, chunk) slice of the chunk stream is produced exactly
    once per layer for each of A2E/EXP/E2A; ATTN/GATE (and REP when hot
    experts are placed) appear once per (layer, mb); SHARED covers each
    emission boundary once per (layer, mb)."""
    out: List[Violation] = []
    w = _where(graph)
    grid = {(i, j) for i in range(graph.r1) for j in range(graph.r2)}
    by_layer_kind: Dict[Tuple[int, str], Counter] = defaultdict(Counter)
    for t in graph.tasks:
        by_layer_kind[(t.layer, t.kind)][(t.mb, t.chunk)] += 1

    def expect(layer: int, kind: str, want: Dict) -> None:
        got = by_layer_kind.get((layer, kind), Counter())
        if got == want:
            return
        missing = sorted(set(want) - set(got))
        extra = sorted(set(got) - set(want))
        dup = sorted(k for k, n in got.items() if n > want.get(k, 0) and
                     k in want)
        parts = []
        if missing:
            parts.append(f"missing {missing[:4]}")
        if extra:
            parts.append(f"unexpected {extra[:4]}")
        if dup:
            parts.append(f"duplicated {dup[:4]}")
        out.append(Violation(
            PASS, "capacity-conservation", w,
            f"layer {layer} {kind}: (mb, chunk) coverage broken — "
            + ", ".join(parts)))

    for layer in range(graph.T):
        for kind in _CHUNK_KINDS:
            expect(layer, kind, Counter({k: 1 for k in grid}))
        per_mb = Counter({(i, 0): 1 for i in range(graph.r1)})
        expect(layer, ATTN, per_mb)
        expect(layer, GATE, per_mb)
        expect(layer, REP,
               per_mb if graph.hot_experts > 0 else Counter())
        if graph.has_shared:
            expect(layer, SHARED,
                   Counter({(i, k): 1 for i in range(graph.r1)
                            for k in range(graph.shared_segments)}))
        else:
            expect(layer, SHARED, Counter())
    return out


def check_capacity_multiple(program: ExecProgram) -> List[Violation]:
    """``capacity_multiple`` must equal r1*r2*m_e — the alignment that
    makes every (stream, chunk) slice of the dispatch buffers equal
    width (and hence the interleave modes bit-identical)."""
    g = program.graph
    want = g.r1 * g.r2 * g.m_e
    if program.capacity_multiple == want:
        return []
    return [Violation(
        PASS, "capacity-multiple", _where(g),
        f"capacity_multiple {program.capacity_multiple} != "
        f"r1*r2*m_e = {g.r1}*{g.r2}*{g.m_e} = {want}")]


# ---------------------------------------------------------------------------
# deadlock detector: wait-for-graph cycle detection over a realization
# ---------------------------------------------------------------------------


def find_deadlock(graph: TaskGraph,
                  service_order: Optional[Sequence[int]] = None,
                  extra_deps: Optional[Dict[int, Tuple[int, ...]]] = None,
                  ignore_kinds: Iterable[str] = ()
                  ) -> Optional[List[int]]:
    """Cycle in the wait-for graph of one realization, or None.

    A realization is a per-lane FIFO service order (default: emission
    order) plus optional extra dep edges (``stream_serial_deps`` for the
    sequential executor). Task i waits for (a) every dep, (b) the task
    queued immediately before it on its lane. ``ignore_kinds`` treats
    those tasks as already complete (the exec walk runs ATTN outside the
    MoE layer). Returns one witness cycle as task indices."""
    n = len(graph.tasks)
    ignore = set(ignore_kinds)
    live = [i for i in range(n) if graph.tasks[i].kind not in ignore]
    live_set = set(live)
    order = [i for i in (service_order if service_order is not None
                         else range(n)) if i in live_set]
    if set(order) != live_set:
        # a service order that skips or repeats tasks is itself a
        # deadlock of the missing tasks; report them as a "cycle"
        missing = sorted(live_set - set(order))
        if missing:
            return missing[:8]
        order = list(dict.fromkeys(order))
    waits: Dict[int, set] = {i: set() for i in live}
    last: Dict[str, int] = {}
    for i in order:
        lane = graph.tasks[i].resource
        if lane in last:
            waits[i].add(last[lane])
        last[lane] = i
    for i in live:
        waits[i].update(d for d in graph.tasks[i].deps if d in live_set)
    if extra_deps:
        for i, ds in extra_deps.items():
            if i in live_set:
                waits[i].update(d for d in ds if d in live_set)
    # Kahn: peel tasks whose waits are all satisfied
    dependents: Dict[int, List[int]] = defaultdict(list)
    indeg: Dict[int, int] = {}
    for i, ws in waits.items():
        indeg[i] = len(ws)
        for d in ws:
            dependents[d].append(i)
    ready = [i for i, k in indeg.items() if k == 0]
    done = 0
    while ready:
        cur = ready.pop()
        done += 1
        for j in dependents[cur]:
            indeg[j] -= 1
            if indeg[j] == 0:
                ready.append(j)
    if done == len(live):
        return None
    # extract one witness cycle from the stuck subgraph
    stuck = {i for i, k in indeg.items() if k > 0}
    node = min(stuck)
    path, seen_at = [], {}
    while node not in seen_at:
        seen_at[node] = len(path)
        path.append(node)
        node = min(w for w in waits[node] if w in stuck)
    return path[seen_at[node]:]


def _deadlock_violation(graph: TaskGraph, cycle: List[int],
                        realization: str) -> Violation:
    chain = " -> ".join(_desc(graph, i) for i in cycle[:6])
    if len(cycle) > 6:
        chain += f" -> ... ({len(cycle)} tasks)"
    return Violation(
        PASS, "deadlock", _where(graph),
        f"{realization} realization deadlocks: wait-for cycle "
        f"{chain} -> (back to start)")


def check_deadlock(graph: TaskGraph) -> List[Violation]:
    """The realizations the system actually executes must complete:
    emission-order service (the scheduler's and the interleaved walk's
    default) and the sequential executor (stream-major service order +
    cross-stream serial deps)."""
    out: List[Violation] = []
    cycle = find_deadlock(graph)
    if cycle:
        out.append(_deadlock_violation(graph, cycle, "emission-order"))
    cycle = find_deadlock(graph,
                          service_order=stream_major_order(graph),
                          extra_deps=stream_serial_deps(graph))
    if cycle:
        out.append(_deadlock_violation(graph, cycle,
                                       "sequential (stream-major)"))
    return out


# ---------------------------------------------------------------------------
# hint-vector validity
# ---------------------------------------------------------------------------


def check_hints(program: ExecProgram) -> List[Violation]:
    """An interleaved program's hint vector must be a permutation of the
    emission indices whose sorted order respects every dep (a tampered or
    stale vector fails here at plan time rather than mid-trace)."""
    out: List[Violation] = []
    graph = program.graph
    w = _where(graph)
    hints = program.hints
    if program.interleave != "streams":
        return out
    if hints is not None:
        n = len(graph.tasks)
        if len(hints) != n:
            out.append(Violation(
                PASS, "hint-length", w,
                f"hint vector has {len(hints)} entries for {n} tasks"))
            return out
        if any(not isinstance(h, int) for h in hints):
            out.append(Violation(PASS, "hint-type", w,
                                 "hint vector has non-int entries"))
            return out
        if sorted(hints) != list(range(n)):
            out.append(Violation(
                PASS, "hint-not-permutation", w,
                f"hints are not a permutation of 0..{n - 1} "
                f"(priority ranks from ScheduleResult.priority_hints)"))
    try:
        program.graph.exec_interleaved(hints)
    except ValueError as e:
        out.append(Violation(PASS, "hint-dep-order", w, str(e)))
    return out


# ---------------------------------------------------------------------------
# composite checks
# ---------------------------------------------------------------------------


def check_graph(graph: TaskGraph,
                costs: Optional[TaskCosts] = None) -> List[Violation]:
    """All structural properties of one lowered graph: dep soundness,
    capacity conservation, deadlock freedom of the executed realizations,
    and schedule race/dep-order under ``costs`` (structural default when
    None)."""
    out = check_structure(graph)
    if out:
        return out          # downstream checks assume sound indices
    out += check_capacity(graph)
    out += check_deadlock(graph)
    out += check_schedule_result(schedule(graph, costs or _HINT_COSTS))
    return out


def check_exec_program(program: ExecProgram) -> List[Violation]:
    """Everything the DEP executor assumes about a program it is handed:
    graph soundness, capacity alignment, hint validity, full walk
    coverage (each non-ATTN layer-0 task emitted exactly once), and
    deadlock freedom of the emitted op order."""
    graph = program.graph
    out = check_structure(graph)
    if out:
        return out
    out += check_capacity(graph)
    out += check_capacity_multiple(program)
    out += check_deadlock(graph)
    out += check_hints(program)
    if any(v.code.startswith("hint") for v in out):
        return out          # the walk below would raise on bad hints
    w = _where(graph)
    walk = program.walk()
    want = Counter((t.kind, t.mb, t.chunk) for t in graph.tasks
                   if t.layer == 0 and t.kind != ATTN)
    got = Counter((t.kind, t.mb, t.chunk) for t in walk)
    if got != want:
        missing = sorted(set(want) - set(got))
        extra = sorted(set(got) - set(want))
        out.append(Violation(
            PASS, "walk-coverage", w,
            f"walk ({program.interleave}) does not cover the layer slice "
            f"exactly once: missing {missing[:4]}, unexpected "
            f"{extra[:4]}"))
        return out
    # the emitted op order is a realization: lanes serve in walk order
    index_of = {t: i for i, t in enumerate(graph.tasks)}
    cycle = find_deadlock(graph,
                          service_order=[index_of[t] for t in walk],
                          ignore_kinds=(ATTN,))
    if cycle:
        out.append(_deadlock_violation(
            graph, cycle, f"walk ({program.interleave})"))
    return out


# ---------------------------------------------------------------------------
# the exhaustive sweep (CLI / CI gate)
# ---------------------------------------------------------------------------

#: Table-5 shape space: both backbones x paper sequence lengths at the
#: paper depths; Table-7 adds the overlap study's deepseek shapes with
#: the naive/PPPipe lowering semantics (shared_blocks_a2e).
_BACKBONES = {"deepseek": "deepseek-v2-lite", "qwen3": "qwen3-moe"}
_DEPTHS = {"deepseek": 8, "qwen3": 24}
_TABLE5_SEQS = (1024, 2048, 4096, 8192)
_TABLE7_SEQS = (1024, 2048, 4096)
_R1_SWEEP = (1, 2, 4)
_ORDERS = (ORDER_ASAS, ORDER_AASS)


def _testbeds():
    from repro.core.perf_model import PAPER_A6000, TPU_V5E
    return {"A(a6000)": (PAPER_A6000, 3, 5, 4),
            "v5e": (TPU_V5E, 3, 5, 8)}


def _policies(planner, seq_len):
    from repro.sched.policy import POLICIES, make_policy
    return [(name, make_policy(name, planner, static_seq_len=seq_len))
            for name in POLICIES]


def sweep(fast: bool = False, log=None) -> Tuple[List[Violation], int]:
    """Verify every lowering the benchmark tables exercise: all four
    policies x Table-5/7 shapes x r1 in {1,2,4} x both dispatch orders,
    checking the full T-layer graph (both shared_blocks_a2e semantics)
    under the shape's modeled stage costs plus both interleave modes of
    the exec program. Returns (violations, graphs_checked).

    ``fast`` restricts to one testbed, two sequence lengths and
    r1 in {1, 4} — the same properties on a representative slice (test
    and benchmark-harness budget)."""
    from repro.configs import get_config
    from repro.configs.base import DepClusterConfig
    from repro.core.analytic import StageTimes
    from repro.core.planner import FinDEPPlanner, PlannerConfig

    violations: List[Violation] = []
    combos = 0
    checked_graphs: set = set()
    checked_sched: set = set()
    checked_progs: set = set()
    testbeds = _testbeds()
    if fast:
        testbeds = {"A(a6000)": testbeds["A(a6000)"]}
    r1_sweep = (1, 4) if fast else _R1_SWEEP

    for tb_name, (hw, ag, eg, cap) in testbeds.items():
        cluster = DepClusterConfig(num_devices=ag + eg, ag=ag, eg=eg)
        for backbone, cfg_name in _BACKBONES.items():
            cfg = get_config(cfg_name)
            T = _DEPTHS[backbone]
            planner = FinDEPPlanner(
                cfg, cluster, hw,
                PlannerConfig(mem_cap_samples=cap, r2_cap=32, T_override=T))
            seqs = set(_TABLE5_SEQS)
            if backbone == "deepseek":
                seqs |= set(_TABLE7_SEQS)
            if fast:
                seqs = {1024, 4096}
            for S in sorted(seqs):
                models = planner.stage_models(S)
                for pol_name, policy in _policies(planner, S):
                    plan = policy.resolve("prefill", S)
                    st = StageTimes.from_models(models, plan.m_a, plan.m_e)
                    costs = TaskCosts.from_stage_times(st)
                    where = f"{tb_name}/{backbone}/S={S}/{pol_name}"
                    for r1 in r1_sweep:
                        for order in _ORDERS:
                            v = dataclasses.replace(plan, r1=r1,
                                                    order=order)
                            for blocks in (False, True):
                                graph = planner.lower(
                                    v, shared_blocks_a2e=blocks)
                                combos += 1
                                if graph not in checked_graphs:
                                    checked_graphs.add(graph)
                                    violations += check_capacity(graph)
                                    violations += check_deadlock(graph)
                                    violations += check_structure(graph)
                                key = (graph, costs)
                                if key not in checked_sched:
                                    checked_sched.add(key)
                                    violations += check_schedule_result(
                                        schedule(graph, costs))
                            for mode in ("streams", "off"):
                                prog = v.exec_program(interleave=mode)
                                if prog in checked_progs:
                                    continue
                                checked_progs.add(prog)
                                violations += check_exec_program(prog)
                    if log is not None:
                        log(f"{where}: {combos} graphs checked, "
                            f"{len(violations)} violations")
    return violations, combos


def run(fast: bool = False, log=None) -> Tuple[List[Violation], Dict]:
    """CLI entry: the sweep plus its coverage metadata."""
    violations, combos = sweep(fast=fast, log=log)
    return violations, {"graphs_checked": combos, "fast": fast}
