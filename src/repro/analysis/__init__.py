"""Static verification layer: properties no runtime test can
exhaustively cover, proved on the artifacts directly.

Three passes, one CLI (``python -m repro.analysis [--check] [--fast]``):

  graphcheck    task-graph verifier — dep soundness, lane races,
                deadlock (wait-for-graph cycle detection over any
                realization), capacity conservation, hint validity;
                ``sweep`` covers all four policies x Table-5/7 shapes x
                r1 in {1,2,4} x both dispatch orders.
  kernelcheck   Pallas index_map bounds checker — evaluates the
                production index_maps over the full grid x boundary
                ledger states, no kernel launch.
  jitlint       AST + registry lint — mutable/unhashable static args,
                frozen-dataclass hashability, host syncs in traced
                code, tracer-context leaks in the DEP walker.

The planner (``FinDEPPlanner(validate=True)``) and the engine
(``ServingEngine(validate=True)``) run graphcheck opt-in at plan time,
so a bad lowering or a tampered hint vector fails before it reaches a
trace.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.analysis.report import AnalysisError, Violation, codes

PASSES = ("graphcheck", "kernelcheck", "jitlint")

__all__ = ["AnalysisError", "PASSES", "Violation", "codes", "run_all"]


def run_all(passes: Tuple[str, ...] = PASSES, fast: bool = False,
            log=None) -> Tuple[Dict[str, List[Violation]], Dict]:
    """Run the requested passes; returns ({pass: violations}, info)."""
    import importlib

    results: Dict[str, List[Violation]] = {}
    info: Dict = {}
    for name in passes:
        if name not in PASSES:
            raise ValueError(f"unknown pass {name!r}; choose from {PASSES}")
        mod = importlib.import_module(f"repro.analysis.{name}")
        violations, meta = mod.run(fast=fast, log=log)
        results[name] = violations
        info.update({f"{name}.{k}": v for k, v in meta.items()})
    return results, info
