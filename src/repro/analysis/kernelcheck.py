"""Bounds checker for the Pallas ``index_map``s — no kernel launch.

A Pallas ``index_map`` is pure integer math from grid coordinates (plus
scalar-prefetched operands) to a block index; an out-of-range result is
an out-of-bounds HBM stream the interpreter may mask and real hardware
will not. This pass evaluates the PRODUCTION index_maps (the module-
level builders the kernels themselves install: ``dense_kv_index_map``,
``paged_kv_index_map``, ``flash_kv_index_map``) over the full grid for a
ledger of boundary states — length 0, lengths straddling a block edge,
non-dividing C (the ``largest_block_size`` fallback), full block tables,
-1 (unallocated) tail entries — and proves every emitted block index
lands inside the operand's block grid. The paged states come from a real
ledger-only ``PagedKVCacheManager`` (shared prefixes, decode growth), so
the tables checked are the tables the serving path builds.

Beyond raw range checks the pass verifies the *semantic* contracts the
flash bodies rely on:

  * dense: an in-length step c (c*bc < len) maps to block c itself —
    clamping must never redirect a live step;
  * paged: an in-length step dereferences exactly ``table[b, c]`` and
    that entry is an allocated non-scratch page; only past-length /
    unallocated steps may land on the scratch page 0;
  * flash: program bh reads KV row (bh // H) * Kv + (bh % H) // g — the
    GQA fold stays inside the flattened [B*Kv] operand and is constant
    across the g query heads of one KV group.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.report import Violation

PASS = "kernelcheck"


def _ints(tup) -> Tuple[int, ...]:
    """Concretize an index_map result (jnp scalars on CPU) to ints."""
    return tuple(int(x) for x in tup)


# ---------------------------------------------------------------------------
# dense decode: grid (B, Kv, C // bc), k/v [B, C, Kv, D] block (1, bc, 1, D)
# ---------------------------------------------------------------------------


def check_dense_index_map(C: int, bc: int, lengths,
                          Kv: int = 2) -> List[Violation]:
    """Evaluate the dense decode K/V index_map over the full grid for one
    lengths vector (clipped to [0, C] exactly as the wrapper does)."""
    from repro.kernels.decode_attention.kernel import (dense_kv_index_map,
                                                       largest_block_size)
    out: List[Violation] = []
    bc = largest_block_size(C, bc)
    n_c = C // bc
    lens = np.clip(np.asarray(lengths, np.int32), 0, C)
    B = lens.shape[0]
    kv_map = dense_kv_index_map(bc)
    where = f"dense(C={C}, bc={bc}, lens={lens.tolist()})"
    for b in range(B):
        for kv in range(Kv):
            for c in range(n_c):
                bi, ci, kvi, di = _ints(kv_map(b, kv, c, lens))
                if not (bi == b and kvi == kv and di == 0):
                    out.append(Violation(
                        PASS, "dense-block-identity", where,
                        f"grid ({b},{kv},{c}) mapped row/head "
                        f"({bi},{kvi},{di}), expected ({b},{kv},0)"))
                if not 0 <= ci < n_c:
                    out.append(Violation(
                        PASS, "dense-block-range", where,
                        f"grid ({b},{kv},{c}) emits context block {ci} "
                        f"outside [0, {n_c})"))
                elif c * bc < lens[b] and ci != c:
                    out.append(Violation(
                        PASS, "dense-live-step-redirected", where,
                        f"in-length step {c} (len={int(lens[b])}) was "
                        f"clamped to block {ci}; live steps must stream "
                        f"their own block"))
    return out


#: boundary lengths for a (C, bc) case: empty row, one token, both sides
#: of the first block edge, and both sides of the cache capacity.
def _boundary_lengths(C: int, bc: int) -> List[int]:
    cand = [0, 1, bc - 1, bc, bc + 1, C - 1, C, C + 7]
    return sorted({max(min(v, C + 7), 0) for v in cand})


# ---------------------------------------------------------------------------
# paged decode: grid (B, Kv, n_blocks), k/v pools [P, bs, Kv, D]
# ---------------------------------------------------------------------------


def check_paged_index_map(tables, lengths, num_pages: int, bs: int,
                          Kv: int = 2, where: str = "",
                          scratch_page: int = 0) -> List[Violation]:
    """Evaluate the paged K/V index_map over the full grid for one
    (block table, lengths) ledger state. ``tables`` is int [B, n_blocks]
    (< 0 = unallocated); every emitted page must lie in [0, num_pages),
    in-length steps must dereference their own allocated table entry,
    and only dead steps may fall through to the scratch page."""
    from repro.kernels.decode_attention.kernel import paged_kv_index_map
    out: List[Violation] = []
    tbl = np.asarray(tables, np.int32)
    B, n_blocks = tbl.shape
    C = n_blocks * bs
    lens = np.clip(np.asarray(lengths, np.int32), 0, C)
    kv_map = paged_kv_index_map(bs)
    where = where or f"paged(P={num_pages}, bs={bs}, B={B})"
    for b in range(B):
        for kv in range(Kv):
            for c in range(n_blocks):
                pi, off, kvi, di = _ints(kv_map(b, kv, c, lens, tbl))
                if not (off == 0 and kvi == kv and di == 0):
                    out.append(Violation(
                        PASS, "paged-block-identity", where,
                        f"grid ({b},{kv},{c}) mapped offsets "
                        f"({off},{kvi},{di}), expected (0,{kv},0)"))
                if not 0 <= pi < num_pages:
                    out.append(Violation(
                        PASS, "paged-page-range", where,
                        f"grid ({b},{kv},{c}) emits page {pi} outside "
                        f"[0, {num_pages}) (table entry "
                        f"{int(tbl[b, c])}, len={int(lens[b])})"))
                    continue
                if c * bs < lens[b]:
                    want = int(tbl[b, c])
                    if want < 0:
                        out.append(Violation(
                            PASS, "paged-live-step-unallocated", where,
                            f"row {b} len={int(lens[b])}: in-length "
                            f"block {c} has no page (table entry -1) — "
                            f"the ledger promised coverage it did not "
                            f"allocate"))
                    elif pi != want:
                        out.append(Violation(
                            PASS, "paged-live-step-redirected", where,
                            f"row {b} in-length block {c} streamed page "
                            f"{pi}, table says {want}"))
                    elif pi == scratch_page:
                        out.append(Violation(
                            PASS, "paged-live-step-scratch", where,
                            f"row {b} in-length block {c} mapped to the "
                            f"reserved scratch page {scratch_page}"))
    return out


def _ledger_states(bs: int = 16):
    """Boundary ledger states from a REAL ledger-only manager: shared
    prefixes, partial tails, a full-table row, decode growth, a freshly
    reset slot, and a never-touched (all -1, length 0) slot. Returns
    (manager, synthetic_extra_states)."""
    from repro.runtime.paging import PagedKVCacheManager
    kv = PagedKVCacheManager(6, max_context=4 * bs, block_size=bs,
                             num_blocks=24)
    base = list(range(2 * bs))                  # two shareable full blocks
    kv.assign_blocks(0, base + [7] * 3)         # prefix + partial tail
    kv.set_length(0, 2 * bs + 4)
    kv.assign_blocks(1, base + [9] * (bs + 1))  # shares slot 0's prefix
    kv.set_length(1, 3 * bs + 2)
    kv.assign_blocks(2, list(range(4 * bs - 1)))   # full table row
    kv.set_length(2, 4 * bs)
    kv.assign_blocks(3, [5] * bs)               # prompt fills block 0
    kv.set_length(3, bs + 1)                    # next write is block 1
    kv.ensure_decode_page(3)                    # decode-growth tail page
    kv.reset_slot(4)                            # recovered slot, len 1
    # slot 5 never allocated: all -1, length 0
    return kv


def run(fast: bool = False, log=None) -> Tuple[List[Violation], Dict]:
    """All three kernels over their case matrices."""
    out: List[Violation] = []
    cases = 0

    # dense: dividing, non-dividing (largest_block_size fallback),
    # single-block, and prime-C shapes
    dense_shapes = [(64, 16), (60, 16), (16, 512), (13, 8)]
    if fast:
        dense_shapes = [(64, 16), (60, 16)]
    for C, bc in dense_shapes:
        lens = _boundary_lengths(C, bc)
        out += check_dense_index_map(C, bc, lens)
        cases += 1

    # paged: real ledger states + synthetic -1 tails
    bs = 16
    kv = _ledger_states(bs)
    out += check_paged_index_map(kv._tables, kv.lengths(),
                                 kv.pool.num_blocks, bs,
                                 where=f"paged(ledger, bs={bs})")
    cases += 1
    # synthetic: every row unallocated (all -1) at length 0 — the state
    # right after a mass free; only the scratch clamp keeps it in range
    empty = np.full((3, 4), -1, np.int32)
    out += check_paged_index_map(empty, [0, 0, 0], 8, bs,
                                 where="paged(all-unallocated)")
    cases += 1

    # flash: GQA folds including H == Kv (MHA) and single-group
    flash_shapes = [(2, 8, 2, 4, 4), (1, 4, 4, 2, 2), (3, 6, 1, 4, 1)]
    if fast:
        flash_shapes = flash_shapes[:2]
    for B, H, Kv, n_q, n_k in flash_shapes:
        out += check_flash_index_map(B, H, Kv, n_q, n_k)
        cases += 1

    if log is not None:
        log(f"kernelcheck: {cases} cases, {len(out)} violations")
    return out, {"kernel_cases": cases, "fast": fast}


def check_flash_index_map(B: int, H: int, Kv: int, n_q: int,
                          n_k: int) -> List[Violation]:
    """Evaluate the flash K/V index_map over the (B*H, n_q, n_k) grid:
    the GQA fold must stay inside the flattened [B*Kv] KV operand, pick
    the right (batch, kv-head) row, and be constant across the g query
    heads of one group."""
    from repro.kernels.flash_attention.kernel import flash_kv_index_map
    out: List[Violation] = []
    g = H // Kv
    kv_index = flash_kv_index_map(H, Kv)
    where = f"flash(B={B}, H={H}, Kv={Kv})"
    for bh in range(B * H):
        for qi in range(n_q):
            for ki in range(n_k):
                row, kblk, di = _ints(kv_index(bh, qi, ki))
                if not (kblk == ki and di == 0):
                    out.append(Violation(
                        PASS, "flash-block-identity", where,
                        f"grid ({bh},{qi},{ki}) mapped k-block "
                        f"({kblk},{di}), expected ({ki},0)"))
                if not 0 <= row < B * Kv:
                    out.append(Violation(
                        PASS, "flash-row-range", where,
                        f"grid ({bh},{qi},{ki}) emits KV row {row} "
                        f"outside [0, {B * Kv})"))
                    continue
                want = (bh // H) * Kv + (bh % H) // g
                if row != want:
                    out.append(Violation(
                        PASS, "flash-gqa-fold", where,
                        f"program {bh} (batch {bh // H}, head {bh % H}) "
                        f"read KV row {row}, expected {want}"))
    return out
