"""Shared finding types for the static-analysis passes.

Every pass (``graphcheck``, ``kernelcheck``, ``jitlint``) reports
``Violation`` records instead of raising mid-scan, so one run surfaces
every problem at once; callers that want fail-fast semantics (the
planner/engine ``validate=`` knobs, the ``--check`` CLI gate) wrap the
collected list in an ``AnalysisError``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Sequence


@dataclass(frozen=True)
class Violation:
    """One verified-property failure.

    ``pass_name`` is the reporting pass, ``code`` a stable kebab-case
    identifier for the property that failed (tests match on it),
    ``where`` the artifact (graph shape / kernel case / file:line), and
    ``message`` the human-actionable description."""

    pass_name: str
    code: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"[{self.pass_name}:{self.code}] {self.where}: {self.message}"


class AnalysisError(RuntimeError):
    """Raised when a validate-mode caller hits violations: the planner's
    ``validate=True`` solve, the engine's program validation, or the CLI
    ``--check`` gate."""

    def __init__(self, violations: Sequence[Violation]):
        self.violations: List[Violation] = list(violations)
        lines = [f"{len(self.violations)} static-analysis violation(s):"]
        lines += [f"  {v}" for v in self.violations]
        super().__init__("\n".join(lines))


def codes(violations: Iterable[Violation]) -> List[str]:
    """The violation codes, in report order (test helper)."""
    return [v.code for v in violations]
