"""jit-hygiene lint over ``src/repro``.

Two hazard families the runtime tests cannot see until they bite:

1. **Retrace / unhashability.** Everything passed through
   ``static_argnames`` must be hashable with value-equality semantics,
   or ``jax.jit`` either throws (unhashable) or silently retraces per
   object identity (hashable-but-wrong ``__eq__``). The AST pass finds
   every jit site and flags mutable defaults/annotations on static
   parameters; ``check_static_types`` verifies the registry of
   frozen-dataclass static-arg types (``Plan``, ``ExecProgram``,
   ``TaskGraph``, ``Placement``, ``SkewSummary``, ...) field-by-field —
   a ``List``/``ndarray`` field added to any of them breaks hashability
   (or worse, hashes by identity) and this catches it at lint time.

2. **Host sync in traced code.** ``.item()``, ``np.asarray``/
   ``np.array`` on device values, ``jax.block_until_ready`` and
   ``jax.device_get`` inside a jitted function (or anywhere in the hot
   modules the decode step traces through) force a device round-trip
   per call. The engine's host loop legitimately syncs; the lint scans
   only (a) bodies of functions that are jit targets in their module
   and (b) the whole of the known hot (traced) modules. ``jnp.asarray``
   is trace-safe and never flagged.

Plus the dep.py-specific rule: the DEP walker must READ the ambient
tracer (module-level ``active_tracer`` import, called per walk) and
never set or enter tracer context inside traced code — a ``use_tracer``
call or a ContextVar ``.set`` there bakes one recorder into a cached
trace (a tracer-context leak).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import typing
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.report import Violation

PASS = "jitlint"

#: modules whose whole body executes under trace when the engine's
#: decode/prefill step runs (relative to the ``repro`` package root).
HOT_MODULES = (
    "core/dep.py",
    "models/moe.py",
    "models/attention.py",
    "models/layers.py",
    "models/transformer.py",
)

#: modules under the dep-walker tracer-context rule
TRACER_MODULES = ("core/dep.py",)

#: jax.<attr> calls that synchronize with the device
_JAX_SYNC = {"block_until_ready", "device_get"}
#: numpy.<attr> calls that materialize a device value on host
_NP_SYNC = {"asarray", "array", "frombuffer", "copy"}

#: frozen-dataclass types used as jit static args anywhere in the repo
STATIC_ARG_TYPES: Tuple[Tuple[str, str], ...] = (
    ("repro.core.solver", "Plan"),
    ("repro.core.taskgraph", "Task"),
    ("repro.core.taskgraph", "TaskGraph"),
    ("repro.core.taskgraph", "ExecProgram"),
    ("repro.core.taskgraph", "TaskCosts"),
    ("repro.core.taskgraph", "CostBreakdown"),
    ("repro.placement.placement", "Placement"),
    ("repro.placement.tracker", "SkewSummary"),
)

_HASH_SAFE_LEAVES = (int, float, str, bool, bytes, type(None))
_MUTABLE_ANNOTATIONS = {"list", "dict", "set", "List", "Dict", "Set",
                        "ndarray", "Array", "bytearray", "DeviceArray"}


# ---------------------------------------------------------------------------
# runtime registry check: static-arg dataclasses stay hashable
# ---------------------------------------------------------------------------


def _type_hash_problem(tp, seen: Set) -> Optional[str]:
    """Why ``tp`` is not safely hashable as a static-arg field type
    (None = fine). Recurses through Optional/Union/Tuple/FrozenSet and
    nested frozen dataclasses."""
    if tp in seen:
        return None
    seen = seen | {tp}
    if tp in _HASH_SAFE_LEAVES or tp is typing.Any:
        return None
    origin = typing.get_origin(tp)
    if origin in (tuple, frozenset):
        for a in typing.get_args(tp):
            if a is Ellipsis:
                continue
            why = _type_hash_problem(a, seen)
            if why:
                return why
        return None
    if origin is typing.Union:
        for a in typing.get_args(tp):
            why = _type_hash_problem(a, seen)
            if why:
                return why
        return None
    if origin in (list, dict, set):
        return f"{tp} is a mutable container"
    if isinstance(tp, type):
        if dataclasses.is_dataclass(tp):
            return _dataclass_hash_problem(tp, seen)
        if issubclass(tp, _HASH_SAFE_LEAVES):
            return None
        if tp.__hash__ is None:
            return f"{tp.__name__} is unhashable"
        if tp.__eq__ is object.__eq__:
            return (f"{tp.__name__} hashes by identity (no __eq__) — "
                    f"every instance keys a fresh trace")
        return None
    return f"unrecognized annotation {tp!r}"


def _dataclass_hash_problem(cls, seen: Set) -> Optional[str]:
    params = getattr(cls, "__dataclass_params__", None)
    if params is None or not params.frozen:
        return f"{cls.__name__} is not a frozen dataclass"
    if cls.__hash__ is None:
        return (f"{cls.__name__} has eq but no hash "
                f"(frozen=False or eq without frozen)")
    try:
        hints = typing.get_type_hints(cls)
    except Exception as e:              # unresolvable forward ref
        return f"{cls.__name__}: cannot resolve field types ({e})"
    for f in dataclasses.fields(cls):
        if not f.compare:
            continue        # excluded from __eq__/__hash__ by field()
        why = _type_hash_problem(hints.get(f.name, typing.Any), seen)
        if why:
            return f"{cls.__name__}.{f.name}: {why}"
    return None


def check_static_types(extra: Sequence[type] = ()) -> List[Violation]:
    """Verify every registered jit-static type (plus ``extra`` classes,
    for tests) is a frozen, hashable dataclass whose compared fields are
    recursively hash-safe."""
    out: List[Violation] = []
    classes: List[Tuple[str, type]] = []
    for mod_name, cls_name in STATIC_ARG_TYPES:
        mod = __import__(mod_name, fromlist=[cls_name])
        classes.append((f"{mod_name}.{cls_name}",
                        getattr(mod, cls_name)))
    classes += [(f"{c.__module__}.{c.__name__}", c) for c in extra]
    for where, cls in classes:
        why = _dataclass_hash_problem(cls, set())
        if why:
            out.append(Violation(PASS, "static-type-unhashable", where,
                                 why))
    return out


# ---------------------------------------------------------------------------
# AST pass
# ---------------------------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _ModuleScan(ast.NodeVisitor):
    """One pass collecting import aliases, jit sites, and function
    defs."""

    def __init__(self):
        self.np_aliases: Set[str] = set()
        self.jax_aliases: Set[str] = set()
        self.jnp_aliases: Set[str] = set()
        self.partial_names: Set[str] = {"functools.partial"}
        self.local_trace_imports: List[ast.ImportFrom] = []
        self.funcs: Dict[str, ast.FunctionDef] = {}
        # function-name -> static_argnames from a jit site targeting it
        self.jit_targets: Dict[str, Tuple[str, ...]] = {}
        self.calls: List[ast.Call] = []
        self._depth = 0

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            name = a.asname or a.name
            if a.name == "numpy":
                self.np_aliases.add(name)
            elif a.name == "jax":
                self.jax_aliases.add(name)
            elif a.name == "jax.numpy":
                self.jnp_aliases.add(name)
            elif a.name == "functools":
                self.partial_names.add(f"{name}.partial")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "jax" and any(a.name == "numpy"
                                        for a in node.names):
            for a in node.names:
                if a.name == "numpy":
                    self.jnp_aliases.add(a.asname or "numpy")
        if node.module == "functools":
            for a in node.names:
                if a.name == "partial":
                    self.partial_names.add(a.asname or "partial")
        if node.module and "obs.trace" in node.module and self._depth:
            self.local_trace_imports.append(node)
        self.generic_visit(node)

    def _visit_func(self, node) -> None:
        if self._depth == 0 or node.name not in self.funcs:
            self.funcs[node.name] = node
        for dec in node.decorator_list:
            statics = self._jit_static_names(dec)
            if statics is not None:
                self.jit_targets[node.name] = statics
        self._depth += 1
        self.generic_visit(node)
        self._depth -= 1

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _is_jit_ref(self, node: ast.AST) -> bool:
        d = _dotted(node)
        return d is not None and (
            any(d == f"{j}.jit" for j in self.jax_aliases)
            or d == "jit")

    def _jit_static_names(self, node: ast.AST) -> Optional[Tuple[str, ...]]:
        """If ``node`` is a jit expression (``jax.jit``,
        ``jax.jit(...)``, ``partial(jax.jit, ...)``), the static arg
        names it declares (possibly empty); else None."""
        if self._is_jit_ref(node):
            return ()
        if not isinstance(node, ast.Call):
            return None
        if self._is_jit_ref(node.func):
            return self._static_kw(node)
        d = _dotted(node.func)
        if d in self.partial_names and node.args \
                and self._is_jit_ref(node.args[0]):
            return self._static_kw(node)
        return None

    @staticmethod
    def _static_kw(call: ast.Call) -> Tuple[str, ...]:
        for kw in call.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                names = []
                v = kw.value
                elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) \
                    else [v]
                for e in elts:
                    if isinstance(e, ast.Constant):
                        names.append(e.value)
                return tuple(str(n) for n in names
                             if isinstance(n, str))
        return ()

    def visit_Call(self, node: ast.Call) -> None:
        self.calls.append(node)
        # jax.jit(f, ...) / jax.jit(self.f, ...): mark f as a jit target
        if self._is_jit_ref(node.func) and node.args:
            target = node.args[0]
            name = None
            if isinstance(target, ast.Name):
                name = target.id
            elif isinstance(target, ast.Attribute):
                name = target.attr
            if name:
                self.jit_targets[name] = self._static_kw(node)
        self.generic_visit(node)


def _mutable_annotation(node: Optional[ast.AST]) -> Optional[str]:
    if node is None:
        return None
    base = node.value if isinstance(node, ast.Subscript) else node
    d = _dotted(base)
    if d is None:
        return None
    leaf = d.split(".")[-1]
    if leaf in _MUTABLE_ANNOTATIONS:
        return d
    return None


def _scan_host_sync(scan: _ModuleScan, body: ast.AST, where: str,
                    out: List[Violation]) -> None:
    for node in ast.walk(body):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func)
        if d:
            root, _, attr = d.rpartition(".")
            if root in scan.np_aliases and attr in _NP_SYNC:
                out.append(Violation(
                    PASS, "host-sync", f"{where}:{node.lineno}",
                    f"{d}() materializes a device value on host inside "
                    f"traced code — use jnp instead, or hoist to the "
                    f"host loop"))
            elif root in scan.jax_aliases and attr in _JAX_SYNC:
                out.append(Violation(
                    PASS, "host-sync", f"{where}:{node.lineno}",
                    f"{d}() forces a device round-trip inside traced "
                    f"code"))
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "item" and not node.args \
                and not node.keywords:
            out.append(Violation(
                PASS, "host-sync", f"{where}:{node.lineno}",
                ".item() blocks on the device inside traced code"))


def lint_source(src: str, filename: str, hot: bool = False,
                tracer_module: bool = False) -> List[Violation]:
    """Lint one module's source. ``hot`` scans the whole module for host
    syncs (a traced module); otherwise only jit-target function bodies
    are scanned. ``tracer_module`` applies the dep-walker tracer-context
    rules."""
    out: List[Violation] = []
    try:
        tree = ast.parse(src, filename=filename)
    except SyntaxError as e:
        return [Violation(PASS, "syntax-error", filename, str(e))]
    scan = _ModuleScan()
    scan.visit(tree)

    # static params: mutable defaults / mutable annotations / typos
    for fname, statics in scan.jit_targets.items():
        fn = scan.funcs.get(fname)
        if fn is None or not statics:
            continue
        args = list(fn.args.posonlyargs) + list(fn.args.args) \
            + list(fn.args.kwonlyargs)
        by_name = {a.arg: a for a in args}
        defaults = dict(zip([a.arg for a in args[-len(fn.args.defaults):]]
                            if fn.args.defaults else [],
                            fn.args.defaults))
        defaults.update({a.arg: d for a, d in
                         zip(fn.args.kwonlyargs, fn.args.kw_defaults)
                         if d is not None})
        for s in statics:
            if not isinstance(s, str):
                continue
            if s not in by_name:
                out.append(Violation(
                    PASS, "static-arg-unknown",
                    f"{filename}:{fn.lineno}",
                    f"static_argnames names {s!r} but {fname}() has no "
                    f"such parameter"))
                continue
            ann = _mutable_annotation(by_name[s].annotation)
            if ann:
                out.append(Violation(
                    PASS, "static-arg-mutable",
                    f"{filename}:{fn.lineno}",
                    f"static param {s!r} of {fname}() is annotated "
                    f"{ann} — unhashable/mutable types cannot be jit "
                    f"static args"))
            dflt = defaults.get(s)
            if isinstance(dflt, (ast.List, ast.Dict, ast.Set)):
                out.append(Violation(
                    PASS, "static-arg-mutable",
                    f"{filename}:{fn.lineno}",
                    f"static param {s!r} of {fname}() defaults to a "
                    f"mutable literal"))

    # host syncs: whole module when hot, else only jit-target bodies
    if hot:
        _scan_host_sync(scan, tree, filename, out)
    else:
        for fname in scan.jit_targets:
            fn = scan.funcs.get(fname)
            if fn is not None:
                _scan_host_sync(scan, fn, f"{filename}::{fname}", out)

    if tracer_module:
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                d = _dotted(node.func) or ""
                if d.split(".")[-1] == "use_tracer":
                    out.append(Violation(
                        PASS, "tracer-context-leak",
                        f"{filename}:{node.lineno}",
                        "use_tracer() inside the DEP walker module — "
                        "entering tracer context in traced code bakes "
                        "one recorder into the cached trace; read "
                        "active_tracer() instead"))
                elif d.endswith(".set") and "tracer" in d.lower():
                    out.append(Violation(
                        PASS, "tracer-context-leak",
                        f"{filename}:{node.lineno}",
                        f"{d}() mutates tracer context inside the DEP "
                        f"walker module"))
        for imp in scan.local_trace_imports:
            out.append(Violation(
                PASS, "tracer-context-leak",
                f"{filename}:{imp.lineno}",
                "function-local import of repro.obs.trace — the walker "
                "must bind active_tracer at module level so traced "
                "code never touches import state"))
    return out


def lint_tree(root: Optional[str] = None) -> List[Violation]:
    """Lint every module under ``src/repro`` (default: the package this
    file lives in)."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out: List[Violation] = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, "r", encoding="utf-8") as f:
                src = f.read()
            out += lint_source(src, rel, hot=rel in HOT_MODULES,
                               tracer_module=rel in TRACER_MODULES)
    return out


def run(fast: bool = False, log=None) -> Tuple[List[Violation], Dict]:
    out = lint_tree()
    out += check_static_types()
    if log is not None:
        log(f"jitlint: {len(out)} violations")
    return out, {"fast": fast}
