"""Attention variants: full causal, sliding-window/local, GQA decode with
full or ring-buffer KV caches, and MLA (DeepSeek-V2 latent attention).

All apply functions are pure; KV caches are explicit pytrees:
  full cache: {"k": [B,C,Kv,Dh], "v": [B,C,Kv,Dh], "index": i32[]}
  ring cache: same arrays with C == window; writes wrap at C.
MLA cache:    {"ckv": [B,C,r], "kpe": [B,C,Dh], "index": i32[]}
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_apply, dense_init

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig):
    hd = cfg.head_dim
    if cfg.mla_kv_lora_rank:
        kq, kkv, kup, kpe, ko = jax.random.split(key, 5)
        r = cfg.mla_kv_lora_rank
        return {
            "wq": dense_init(kq, cfg.d_model, cfg.num_heads * hd,
                             bias=cfg.qkv_bias),
            "w_dkv": dense_init(kkv, cfg.d_model, r),
            "w_ukv": dense_init(kup, r, cfg.num_heads * 2 * hd),
            "w_kpe": dense_init(kpe, cfg.d_model, hd),
            "wo": dense_init(ko, cfg.num_heads * hd, cfg.d_model),
        }
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, cfg.d_model, cfg.num_heads * hd, bias=cfg.qkv_bias),
        "wk": dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": dense_init(kv, cfg.d_model, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.num_heads * hd, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# cache management
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, capacity: int,
                  dtype=jnp.bfloat16):
    hd = cfg.head_dim
    if cfg.mla_kv_lora_rank:
        return {
            "ckv": jnp.zeros((batch, capacity, cfg.mla_kv_lora_rank), dtype),
            "kpe": jnp.zeros((batch, capacity, hd), dtype),
            "index": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, capacity, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, capacity, cfg.num_kv_heads, hd), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def cache_capacity(cfg: ModelConfig, seq_len: int) -> int:
    """Ring caches (sliding/local attention) cap capacity at the window."""
    if cfg.attention in ("sliding", "local"):
        return min(seq_len, cfg.sliding_window)
    return seq_len


# ---------------------------------------------------------------------------
# masking helpers
# ---------------------------------------------------------------------------

def _causal_mask(q_pos, k_pos, window: Optional[int]):
    """q_pos: [S_q], k_pos: [S_k] (absolute). True == attend."""
    m = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def _flash_sdpa_xla(q, k, v, q_pos, k_pos, window: Optional[int],
                    q_chunk: int = 512, k_chunk: int = 1024):
    """Flash-attention structured as pure XLA: outer scan over query chunks,
    inner scan over key chunks with online softmax. Never materializes more
    than [B, Kv, g, q_chunk, k_chunk] of logits. Used for long sequences
    where the [S, S] score matrix of `_sdpa` would not fit.

    q: [B,S,H,D]; k/v: [B,S,Kv,D]; q_pos/k_pos: [S] absolute positions.
    """
    B, S, H, D = q.shape
    Kv = k.shape[2]
    g = H // Kv
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, S)
    nq = (S + q_chunk - 1) // q_chunk
    nk = (S + k_chunk - 1) // k_chunk
    Sp_q, Sp_k = nq * q_chunk, nk * k_chunk
    scale = 1.0 / math.sqrt(D)

    def pad_seq(x, Sp):
        return jnp.pad(x, ((0, 0), (0, Sp - x.shape[1])) + ((0, 0),) *
                       (x.ndim - 2))

    qp = pad_seq(q, Sp_q).reshape(B, nq, q_chunk, Kv, g, D)
    kp = pad_seq(k, Sp_k).reshape(B, nk, k_chunk, Kv, D)
    vp = pad_seq(v, Sp_k).reshape(B, nk, k_chunk, Kv, D)
    qpos = jnp.pad(q_pos, (0, Sp_q - S), constant_values=-1)
    qpos = qpos.reshape(nq, q_chunk)
    kpos = jnp.pad(k_pos, (0, Sp_k - S), constant_values=2**30)
    kpos = kpos.reshape(nk, k_chunk)

    def outer(_, qc):
        q_blk, qp_blk = qc                       # [B,c,Kv,g,D], [c]

        def inner(carry, kc):
            m, l, acc = carry
            k_blk, v_blk, kp_blk = kc
            logits = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk,
                                preferred_element_type=jnp.float32) * scale
            msk = qp_blk[:, None] >= kp_blk[None, :]
            if window is not None:
                msk &= (qp_blk[:, None] - kp_blk[None, :]) < window
            logits = jnp.where(msk[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_blk.dtype),
                            v_blk).astype(jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Kv, g, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            inner, (m0, l0, a0),
            (kp.swapaxes(0, 1), vp.swapaxes(0, 1), kpos))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return None, out.astype(q.dtype)         # [B,Kv,g,c,D]

    _, outs = jax.lax.scan(outer, None,
                           (qp.swapaxes(0, 1), qpos))      # [nq,B,Kv,g,c,D]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp_q, H, D)
    return out[:, :S]


def _sdpa(q, k, v, mask):
    """q: [B,Sq,H,Dh], k/v: [B,Sk,Kv,Dh] (GQA broadcast), mask [Sq,Sk] or
    [B,Sq,Sk]."""
    B, Sq, H, Dh = q.shape
    Kv = k.shape[2]
    groups = H // Kv
    q = q.reshape(B, Sq, Kv, groups, Dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(Dh)
    if mask.ndim == 2:
        mask = mask[None, None, None]
    else:
        mask = mask[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, Dh)


# ---------------------------------------------------------------------------
# full-sequence (train / prefill) attention
# ---------------------------------------------------------------------------

def _project_qkv(params, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = dense_apply(params["wq"], x).reshape(B, S, cfg.num_heads, hd)
    if cfg.mla_kv_lora_rank:
        ckv = dense_apply(params["w_dkv"], x)                    # [B,S,r]
        kv = dense_apply(params["w_ukv"], ckv)
        kv = kv.reshape(B, S, cfg.num_heads, 2 * hd)
        k_nope, v = jnp.split(kv, 2, axis=-1)
        kpe = dense_apply(params["w_kpe"], x)[:, :, None, :]     # [B,S,1,hd]
        kpe = apply_rope(kpe, positions, cfg.rope_theta)
        k = k_nope + kpe                                         # MHA (Kv == H)
        q = apply_rope(q, positions, cfg.rope_theta)
        return q, k, v, {"ckv": ckv, "kpe": kpe[:, :, 0, :]}
    k = dense_apply(params["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = dense_apply(params["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v, {"k": k, "v": v}


def attention_fullseq(params, cfg: ModelConfig, x, positions,
                      cache: Optional[dict] = None, impl: str = "xla"):
    """Training / prefill attention over the whole sequence.

    If ``cache`` is given it is filled with this segment's K/V (prefill);
    returns (out, cache_or_None).
    """
    B, S, _ = x.shape
    window = cfg.sliding_window if cfg.attention in ("sliding", "local") else None
    q, k, v, to_cache = _project_qkv(params, cfg, x, positions)

    if impl == "flash" and cfg.mla_kv_lora_rank == 0:
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(q, k, v, causal=True, window=window)
    elif impl == "chunked" or (impl == "xla" and S > 2048):
        out = _flash_sdpa_xla(q, k, v, positions[0], positions[0], window)
    else:
        mask = _causal_mask(positions[0], positions[0], window)
        out = _sdpa(q, k, v, mask)

    out = dense_apply(params["wo"], out.reshape(B, S, -1))
    new_cache = None
    if cache is not None:
        new_cache = _prefill_cache(cfg, cache, to_cache, S)
    return out, new_cache


def _prefill_cache(cfg: ModelConfig, cache, to_cache, S: int):
    C = (cache["ckv"] if cfg.mla_kv_lora_rank else cache["k"]).shape[1]
    new = dict(cache)
    keep = min(S, C)
    for name, val in to_cache.items():
        seg = val[:, S - keep:S]
        if keep == C and S % C:
            # ring-cache invariant: slot s holds absolute position == s (mod
            # C). Token at absolute pos p lands in slot p % C; the kept
            # segment covers positions [S-C, S), so roll by (S-C) % C == S%C.
            seg = jnp.roll(seg, S % C, axis=1)
        new[name] = jax.lax.dynamic_update_slice_in_dim(
            cache[name], seg.astype(cache[name].dtype), 0, axis=1)
    new["index"] = jnp.asarray(S, jnp.int32)
    return new


# ---------------------------------------------------------------------------
# distributed decode over a sequence-sharded KV cache
# ---------------------------------------------------------------------------

def use_seqsharded_decode(cfg: ModelConfig, mesh, axis: str,
                          capacity: int) -> bool:
    """Sequence-shard the decode cache over the model axis when the KV-head
    count does not divide it (GQA with few KV heads). The attention core
    then runs as a distributed flash combine (local partial softmax stats +
    psum), implemented in shard_map — GSPMD's fallback for this pattern is
    a per-layer all-gather of the whole cache."""
    if mesh is None or axis not in mesh.axis_names:
        return False
    mo = mesh.shape[axis]
    if cfg.mla_kv_lora_rank:
        return False
    return (cfg.num_kv_heads % mo != 0) and capacity % mo == 0


def _decode_core_seqsharded(q, k_new, v_new, cache_k, cache_v, index,
                            mesh, axis: str, batch_axes, is_ring: bool):
    """q: [B,1,H,Dh]; k_new/v_new: [B,1,Kv,Dh]; cache_[kv]: [B,C,Kv,Dh]
    sequence-sharded over ``axis``. Returns (out [B,1,H,Dh], new caches).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mo = mesh.shape[axis]
    B, C = cache_k.shape[0], cache_k.shape[1]
    Kv, Dh = cache_k.shape[2], cache_k.shape[3]
    H = q.shape[2]
    g = H // Kv
    C_loc = C // mo
    b = batch_axes
    scale = 1.0 / math.sqrt(Dh)

    def local(q, k_new, v_new, ck, cv, index):
        i = jax.lax.axis_index(axis)
        slot = index % C if is_ring else jnp.minimum(index, C - 1)
        loc = slot - i * C_loc
        in_range = (loc >= 0) & (loc < C_loc)
        loc_c = jnp.clip(loc, 0, C_loc - 1)
        upd_k = jax.lax.dynamic_update_slice_in_dim(
            ck, k_new.astype(ck.dtype), loc_c, 1)
        upd_v = jax.lax.dynamic_update_slice_in_dim(
            cv, v_new.astype(cv.dtype), loc_c, 1)
        ck = jnp.where(in_range, upd_k, ck)
        cv = jnp.where(in_range, upd_v, cv)

        slots = i * C_loc + jnp.arange(C_loc, dtype=jnp.int32)
        if is_ring:
            base = ((index - slots) // C) * C + slots
            k_pos = jnp.where(base > index, base - C, base)
            valid = (k_pos >= 0) & (k_pos <= index) & (index - k_pos < C)
        else:
            valid = slots <= index

        qh = q.reshape(q.shape[0], Kv, g, Dh)
        logits = jnp.einsum("bkgd,bskd->bkgs", qh, ck.astype(qh.dtype),
                            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(valid[None, None, None], logits, NEG_INF)
        m_loc = logits.max(-1)
        m = jax.lax.pmax(m_loc, axis)
        p = jnp.exp(logits - m[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        l = jax.lax.psum(p.sum(-1), axis)
        o = jnp.einsum("bkgs,bskd->bkgd", p.astype(cv.dtype), cv)
        o = jax.lax.psum(o.astype(jnp.float32), axis)
        out = (o / jnp.maximum(l, 1e-30)[..., None])
        return out.reshape(q.shape[0], 1, H, Dh).astype(q.dtype), ck, cv

    cache_spec = P(b, axis, None, None)
    out, ck, cv = shard_map(
        local, mesh=mesh,
        in_specs=(P(b, None, None), P(b, None, None, None),
                  P(b, None, None, None), cache_spec, cache_spec, P()),
        out_specs=(P(b, None, None, None), cache_spec, cache_spec),
        check_rep=False,
    )(q[:, 0], k_new, v_new, cache_k, cache_v, index)
    return out, ck, cv


# ---------------------------------------------------------------------------
# single-token decode
# ---------------------------------------------------------------------------

def attention_decode(params, cfg: ModelConfig, x, cache, impl: str = "xla",
                     ctx=None, lengths=None, block_table=None):
    """x: [B, 1, M]; cache index == number of tokens already cached.
    ``lengths`` ([B] int, optional) is the KV ledger's per-slot context
    length — the positions THIS step attends over. When given (the
    continuous-batching engine passes it once per step), the attention
    mask comes from the ledger instead of being recomputed per layer
    from the cache index, and the ragged Pallas decode kernel can skip
    KV blocks past each row's length. ``block_table`` (int
    [B, max_blocks], optional) switches the cache to the PAGED layout
    (``repro.runtime.paging``): k/v are physical page pools and each
    row's KV stream follows its page chain. Returns (out [B,1,M],
    updated cache)."""
    if block_table is not None:
        return _attention_decode_paged(params, cfg, x, cache, impl, ctx,
                                       lengths, block_table)
    B = x.shape[0]
    hd = cfg.head_dim
    index = jnp.asarray(cache["index"])
    positions = (jnp.full((B, 1), index, jnp.int32) if index.ndim == 0
                 else index[:, None].astype(jnp.int32))
    q, k, v, to_cache = _project_qkv(params, cfg, x, positions)

    C = (cache["ckv"] if cfg.mla_kv_lora_rank else cache["k"]).shape[1]
    is_ring = cfg.attention in ("sliding", "local")

    mesh = getattr(ctx, "mesh", None)
    axis = getattr(ctx, "expert_axis", "model")
    if index.ndim == 0 and use_seqsharded_decode(cfg, mesh, axis, C):
        from repro.sharding.partition import batch_pspec
        bspec = batch_pspec(cache["k"].shape[0], mesh)
        b_axes = bspec[0] if bspec != jax.sharding.PartitionSpec(None) else None
        out, ck, cv = _decode_core_seqsharded(
            q, to_cache["k"], to_cache["v"], cache["k"], cache["v"], index,
            mesh, axis, b_axes, is_ring)
        new_cache = dict(cache, k=ck, v=cv, index=index + 1)
        out = dense_apply(params["wo"], out.reshape(B, 1, -1))
        return out, new_cache

    slot = jnp.where(jnp.asarray(is_ring), index % C, jnp.minimum(index, C - 1))

    new_cache = dict(cache)
    if index.ndim == 0:
        for name, val in to_cache.items():
            new_cache[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], val.astype(cache[name].dtype), slot, axis=1)
    else:
        # per-sample indices (continuous batching): scatter one row each
        batch_ix = jnp.arange(B)
        for name, val in to_cache.items():
            new_cache[name] = cache[name].at[batch_ix, slot].set(
                val[:, 0].astype(cache[name].dtype))
    new_cache["index"] = index + 1

    # per-row attended prefix (non-ring): the ledger's context length when
    # plumbed in, else recovered from the cache index (index counts the
    # tokens cached BEFORE this step's write, so the attended prefix —
    # including the row just written — is index + 1)
    slots = jnp.arange(C, dtype=jnp.int32)
    if is_ring:
        # slot s holds absolute pos: the latest write to s at or before index
        idx = index if index.ndim == 0 else index[:, None]     # [] or [B,1]
        base = ((idx - slots) // C) * C + slots
        k_pos = jnp.where(base > idx, base - C, base)
        valid = (k_pos >= 0) & (k_pos <= idx) & (idx - k_pos < C)
        mask = valid[None, :] if index.ndim == 0 else valid[:, None, :]
        lens = None
    else:
        if lengths is not None:
            lens = jnp.clip(jnp.asarray(lengths, jnp.int32), 0, C)   # [B]
        elif index.ndim == 0:
            lens = jnp.full((B,), jnp.minimum(index + 1, C), jnp.int32)
        else:
            lens = jnp.minimum(index.astype(jnp.int32) + 1, C)
        mask = slots[None, None, :] < lens[:, None, None]      # [B, 1, C]

    if cfg.mla_kv_lora_rank:
        ckv_all, kpe_all = new_cache["ckv"], new_cache["kpe"]
        kv = dense_apply(params["w_ukv"], ckv_all.astype(x.dtype))
        kv = kv.reshape(B, C, cfg.num_heads, 2 * hd)
        k_all, v_all = jnp.split(kv, 2, axis=-1)
        k_all = k_all + kpe_all.astype(x.dtype)[:, :, None, :]
    else:
        k_all, v_all = (new_cache["k"].astype(x.dtype),
                        new_cache["v"].astype(x.dtype))

    if impl == "decode_kernel" and cfg.mla_kv_lora_rank == 0 and not is_ring:
        # the serving path: ragged Pallas kernel streams ceil(len/bc)
        # blocks per row instead of the dense [B, C] cache
        from repro.kernels.decode_attention import ops as dec_ops
        bc = getattr(ctx, "decode_bc", None)
        out = dec_ops.decode_attention(q[:, 0], k_all, v_all, lens,
                                       bc=bc or 512)
        out = out[:, None]
    else:
        out = _sdpa(q, k_all, v_all, mask)
    out = dense_apply(params["wo"], out.reshape(B, 1, -1))
    return out, new_cache


def _attention_decode_paged(params, cfg: ModelConfig, x, cache, impl, ctx,
                            lengths, block_table):
    """Paged-KV decode: the cache's k/v are physical page pools
    ``[P, bs, Kv, D]`` shared by every slot, and ``block_table`` (int
    [B, max_blocks]) maps each row's logical blocks to pages (< 0 =
    unallocated). The new token scatters into its row's tail page at
    ``index % bs``; dead rows (no pages) clamp to the reserved scratch
    page 0, so they never corrupt live KV. The engine gates
    ``kv_layout='paged'`` to full-attention GQA — no MLA, no ring, no
    seq-sharded decode."""
    if cfg.mla_kv_lora_rank or cfg.attention in ("sliding", "local"):
        raise NotImplementedError(
            "paged KV decode requires full-attention GQA "
            f"(attention={cfg.attention!r}, mla={cfg.mla_kv_lora_rank})")
    B = x.shape[0]
    bs = cache["k"].shape[1]
    C = block_table.shape[1] * bs
    index = jnp.asarray(cache["index"])
    if index.ndim == 0:
        index = jnp.full((B,), index, jnp.int32)
    positions = index[:, None].astype(jnp.int32)
    q, _, _, to_cache = _project_qkv(params, cfg, x, positions)

    tbl = jnp.asarray(block_table, jnp.int32)
    pos = jnp.minimum(index.astype(jnp.int32), C - 1)
    phys = jnp.maximum(tbl[jnp.arange(B), pos // bs], 0)
    new_cache = dict(cache)
    for name, val in to_cache.items():
        new_cache[name] = cache[name].at[phys, pos % bs].set(
            val[:, 0].astype(cache[name].dtype))
    new_cache["index"] = index + 1

    if lengths is not None:
        lens = jnp.clip(jnp.asarray(lengths, jnp.int32), 0, C)
    else:
        lens = jnp.minimum(index.astype(jnp.int32) + 1, C)

    if impl == "decode_kernel":
        from repro.kernels.decode_attention import ops as dec_ops
        out = dec_ops.decode_attention_paged(q[:, 0], new_cache["k"],
                                             new_cache["v"], lens, tbl)
        out = out[:, None]
    else:
        from repro.kernels.decode_attention.ref import gather_pages
        k_all = gather_pages(new_cache["k"], tbl).astype(x.dtype)
        v_all = gather_pages(new_cache["v"], tbl).astype(x.dtype)
        mask = (jnp.arange(C, dtype=jnp.int32)[None, None, :]
                < lens[:, None, None])                         # [B, 1, C]
        out = _sdpa(q, k_all, v_all, mask)
    out = dense_apply(params["wo"], out.reshape(B, 1, -1))
    return out, new_cache


# ---------------------------------------------------------------------------
# cross attention (encoder-decoder)
# ---------------------------------------------------------------------------

def cross_attention_init(key, cfg: ModelConfig):
    kq, kk, kv, ko = jax.random.split(key, 4)
    hd = cfg.head_dim
    return {
        "wq": dense_init(kq, cfg.d_model, cfg.num_heads * hd),
        "wk": dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd),
        "wv": dense_init(kv, cfg.d_model, cfg.num_kv_heads * hd),
        "wo": dense_init(ko, cfg.num_heads * hd, cfg.d_model),
    }


def cross_attention_apply(params, cfg: ModelConfig, x, memory):
    """x: [B,Sq,M] decoder states; memory: [B,Sk,M] encoder output."""
    B, Sq, _ = x.shape
    Sk = memory.shape[1]
    hd = cfg.head_dim
    q = dense_apply(params["wq"], x).reshape(B, Sq, cfg.num_heads, hd)
    k = dense_apply(params["wk"], memory).reshape(B, Sk, cfg.num_kv_heads, hd)
    v = dense_apply(params["wv"], memory).reshape(B, Sk, cfg.num_kv_heads, hd)
    mask = jnp.ones((Sq, Sk), bool)
    out = _sdpa(q, k, v, mask)
    return dense_apply(params["wo"], out.reshape(B, Sq, -1))
