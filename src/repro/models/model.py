"""Model registry: build a Model (and its input specs) from a ModelConfig."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import ExecutionContext, Model


def build_model(cfg: ModelConfig, ctx: Optional[ExecutionContext] = None,
                num_experts_padded: int = 0, scan_layers: bool = False,
                dtype=jnp.bfloat16, plan=None) -> Model:
    """``ctx`` is an immutable distribution template (mesh / impls);
    ``plan`` is the model's *default* MoE schedule for static pipelines.
    Serving stacks leave it None and pass policy-resolved plans per call."""
    return Model(cfg, ctx=ctx, num_experts_padded=num_experts_padded,
                 scan_layers=scan_layers, dtype=dtype, plan=plan)


def frontend_shape(cfg: ModelConfig, shape: ShapeConfig):
    """Stub modality frontend output shape (vlm patch embeds / audio frames).

    This is the one allowed stub: ``input_specs`` provides precomputed
    embeddings of the right shape instead of running a ViT / conv codec.
    """
    if cfg.family == "vlm":
        n = cfg.frontend_tokens or 256
        return (shape.global_batch, n, cfg.d_model)
    if cfg.family == "audio":
        # ~6.25 frames/sec after the conv feature extractor; scale with seq
        n = cfg.frontend_tokens or max(64, min(shape.seq_len // 8, 4096))
        return (shape.global_batch, n, cfg.d_model)
    return None
