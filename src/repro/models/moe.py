"""Mixture-of-Experts layer: top-k router, routed experts (stacked weights,
expert-parallel friendly), optional shared experts (paper Fig. 1).

Two execution paths:
  * ``moe_apply_dense``    — exact all-experts einsum (oracle / tiny models)
  * ``moe_apply_capacity`` — GShard-style capacity dispatch with drops; the
    same dispatch/combine structure is what ``repro.core.dep`` shards with
    all_to_all (A2E/E2A) and chunks with FinDEP's r2.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_apply, dense_init, mlp_apply, mlp_init


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def moe_init(key, d_model: int, mcfg: MoEConfig, num_experts_padded: int = 0):
    """``num_experts_padded`` >= num_experts pads the expert dimension so it
    divides the expert-parallel mesh axis; padded experts are masked out in
    the router and receive no tokens."""
    E = num_experts_padded or mcfg.num_experts
    H = mcfg.expert_ffn_dim
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d_model)
    params = {
        "router": dense_init(kr, d_model, E, scale=scale),
        "experts": {
            "gate": jax.random.normal(kg, (E, d_model, H), jnp.float32) * scale,
            "up": jax.random.normal(ku, (E, d_model, H), jnp.float32) * scale,
            "down": jax.random.normal(kd, (E, H, d_model), jnp.float32)
                    * (1.0 / math.sqrt(H)),
        },
    }
    if mcfg.num_shared_experts > 0:
        shared_H = (mcfg.shared_ffn_dim or H) * mcfg.num_shared_experts
        params["shared"] = mlp_init(ks, d_model, shared_H)
    return params


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

class Routing(NamedTuple):
    weights: jax.Array       # [T, k]  combine weights (post-softmax, renorm)
    experts: jax.Array       # [T, k]  int32 expert ids
    probs: jax.Array         # [T, E]  full softmax (for aux loss)


def route_topk(router_params, x_flat, mcfg: MoEConfig,
               num_experts_padded: int = 0) -> Routing:
    """x_flat: [T, M] -> top-k routing per token (paper §2.1)."""
    E_pad = num_experts_padded or mcfg.num_experts
    logits = dense_apply(router_params, x_flat).astype(jnp.float32)
    if E_pad > mcfg.num_experts:                  # mask padded experts
        neg = jnp.full((E_pad - mcfg.num_experts,), -1e30, jnp.float32)
        logits = logits.at[..., mcfg.num_experts:].set(neg)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, mcfg.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return Routing(weights=weights, experts=experts.astype(jnp.int32),
                   probs=probs)


def load_balance_loss(routing: Routing, mcfg: MoEConfig) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e over real experts."""
    E = mcfg.num_experts
    probs = routing.probs[..., :E]
    onehot = jax.nn.one_hot(routing.experts, probs.shape[-1])[..., :E]
    f = onehot.sum(axis=(-3, -2)) / (routing.experts.shape[0] * mcfg.top_k)
    p = probs.mean(axis=0)
    return E * jnp.sum(f * p)


# ---------------------------------------------------------------------------
# expert FFN (stacked einsum over the expert dimension)
# ---------------------------------------------------------------------------

def expert_ffn(expert_params, x):
    """x: [E, C, M] -> [E, C, M] (one SwiGLU FFN per expert, Eq. 3)."""
    dt = x.dtype
    g = jnp.einsum("ecm,emh->ech", x, expert_params["gate"].astype(dt))
    u = jnp.einsum("ecm,emh->ech", x, expert_params["up"].astype(dt))
    return jnp.einsum("ech,ehm->ecm", jax.nn.silu(g) * u,
                      expert_params["down"].astype(dt))


def shared_expert_apply(params, x):
    """Dense shared-expert path (paper Eq. 2); fused over N_shared."""
    return mlp_apply(params["shared"], x)


# ---------------------------------------------------------------------------
# execution path 1: exact dense combine (oracle)
# ---------------------------------------------------------------------------

def moe_apply_dense(params, x, mcfg: MoEConfig, num_experts_padded: int = 0,
                    return_stats: bool = False):
    """Computes every expert on every token and combines with routing
    weights. Exact (no capacity drops); O(E) compute. Returns (y, aux),
    or (y, aux, MoEStats) with ``return_stats``."""
    B, S, M = x.shape
    xf = x.reshape(-1, M)
    routing = route_topk(params["router"], xf, mcfg, num_experts_padded)
    E_pad = num_experts_padded or mcfg.num_experts
    # combine weights per (token, expert): [T, E]
    cw = jnp.zeros((xf.shape[0], E_pad), x.dtype)
    cw = cw.at[jnp.arange(xf.shape[0])[:, None],
               routing.experts].add(routing.weights.astype(x.dtype))
    all_out = expert_ffn(params["experts"],
                         jnp.broadcast_to(xf, (E_pad,) + xf.shape))
    y = jnp.einsum("te,etm->tm", cw, all_out)
    if "shared" in params:
        y = y + shared_expert_apply(params, xf)
    aux = load_balance_loss(routing, mcfg)
    y = y.reshape(B, S, M)
    if return_stats:
        load = jax.nn.one_hot(routing.experts, E_pad,
                              dtype=jnp.float32).sum(axis=(0, 1))
        stats = MoEStats(load=load, dropped=jnp.int32(0))
        return y, aux, stats
    return y, aux


# ---------------------------------------------------------------------------
# execution path 2: capacity-based dispatch (GShard) — shardable
# ---------------------------------------------------------------------------

class DispatchInfo(NamedTuple):
    buffers: jax.Array        # [E, C, M] dispatched tokens
    combine: jax.Array        # [T, k] combine weights (drops zeroed)
    slot: jax.Array           # [T, k] slot within expert buffer
    experts: jax.Array        # [T, k] PHYSICAL expert (buffer row) ids
    aux: jax.Array
    load: jax.Array           # [E] token-assignment counts, LOGICAL ids
    dropped: jax.Array        # []  capacity-overflow assignments (int32)


class MoEStats(NamedTuple):
    """Per-layer routing telemetry surfaced alongside (y, aux): the [E]
    token-load histogram (logical expert ids, float32 so meshes can
    psum-average it) and the count of capacity-overflow assignments that
    were dropped (previously silent — ISSUE 7 satellite bugfix)."""

    load: jax.Array           # [E] float32
    dropped: jax.Array        # []  int32


def expert_capacity(num_tokens: int, mcfg: MoEConfig,
                    num_experts_padded: int = 0, multiple_of: int = 1,
                    scale: float = 1.0) -> int:
    """``scale`` > 1 widens the per-expert buffer beyond the configured
    capacity factor — the skew-aware path sets it from the observed
    hottest-expert load so hot tokens are kept instead of dropped."""
    E = num_experts_padded or mcfg.num_experts
    cap = math.ceil(num_tokens * mcfg.top_k / E
                    * mcfg.capacity_factor * max(float(scale), 1.0))
    cap = max(cap, 1)
    return ((cap + multiple_of - 1) // multiple_of) * multiple_of


def moe_dispatch(params, xf, mcfg: MoEConfig, capacity: int,
                 num_experts_padded: int = 0,
                 expert_map: Optional[jax.Array] = None) -> DispatchInfo:
    """Route and scatter tokens into per-expert buffers [E, C, M].

    ``expert_map`` is an optional [E_pad] logical -> physical permutation
    (the active ``Placement.perm``): tokens routed to logical expert e
    land in buffer row ``expert_map[e]``, where that expert's weights
    live after a re-placement swap. ``load`` is always reported in
    LOGICAL ids (what the tracker and re-balancer reason about), and
    ``dropped`` counts the capacity-overflow assignments this dispatch
    silently zeroed before ISSUE 7."""
    T, M = xf.shape
    E_pad = num_experts_padded or mcfg.num_experts
    routing = route_topk(params["router"], xf, mcfg, num_experts_padded)
    experts = routing.experts
    load = jax.nn.one_hot(experts, E_pad,
                          dtype=jnp.float32).sum(axis=(0, 1))          # [E]
    if expert_map is not None:
        experts = expert_map.astype(jnp.int32)[experts]
    # position of each (token, k) within its expert, in token order
    onehot = jax.nn.one_hot(experts, E_pad, dtype=jnp.int32)          # [T,k,E]
    flat = onehot.reshape(T * mcfg.top_k, E_pad)
    pos = jnp.cumsum(flat, axis=0) - flat                              # [Tk,E]
    slot = (pos * flat).sum(-1).reshape(T, mcfg.top_k)                 # [T,k]
    keep = slot < capacity
    weights = jnp.where(keep, routing.weights, 0.0)
    slot_c = jnp.where(keep, slot, capacity)     # drops -> scratch slot C
    dropped = (~keep).sum().astype(jnp.int32)
    buffers = jnp.zeros((E_pad, capacity + 1, M), xf.dtype)
    buffers = buffers.at[experts.reshape(-1),
                         slot_c.reshape(-1)].add(
        jnp.repeat(xf[:, None], mcfg.top_k, 1).reshape(-1, M))
    aux = load_balance_loss(routing, mcfg)
    return DispatchInfo(buffers=buffers[:, :capacity], combine=weights,
                        slot=slot_c, experts=experts, aux=aux,
                        load=load, dropped=dropped)


def moe_combine(info: DispatchInfo, expert_out: jax.Array, T: int,
                dtype) -> jax.Array:
    """Gather expert outputs back per token and apply combine weights."""
    M = expert_out.shape[-1]
    C = expert_out.shape[1]
    padded = jnp.concatenate(
        [expert_out, jnp.zeros((expert_out.shape[0], 1, M),
                               expert_out.dtype)], axis=1)
    gathered = padded[info.experts.reshape(-1),
                      info.slot.reshape(-1)]                 # [Tk, M]
    gathered = gathered.reshape(T, -1, M)
    y = jnp.einsum("tk,tkm->tm", info.combine.astype(dtype),
                   gathered.astype(dtype))
    return y


def moe_apply_capacity(params, x, mcfg: MoEConfig,
                       num_experts_padded: int = 0,
                       capacity: Optional[int] = None,
                       return_stats: bool = False):
    """Single-device capacity-based MoE layer; the sharded/chunked variant
    lives in repro.core.dep. Returns (y, aux), or (y, aux, MoEStats)
    with ``return_stats``."""
    B, S, M = x.shape
    xf = x.reshape(-1, M)
    cap = capacity or expert_capacity(xf.shape[0], mcfg, num_experts_padded)
    info = moe_dispatch(params, xf, mcfg, cap, num_experts_padded)
    out = expert_ffn(params["experts"], info.buffers)
    y = moe_combine(info, out, xf.shape[0], x.dtype)
    if "shared" in params:
        y = y + shared_expert_apply(params, xf)
    y = y.reshape(B, S, M)
    if return_stats:
        return y, info.aux, MoEStats(load=info.load, dropped=info.dropped)
    return y, info.aux
