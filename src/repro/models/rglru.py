"""RecurrentGemma recurrent block — RG-LRU gated linear recurrence plus
causal conv1d (arXiv:2402.19427).

The RG-LRU recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
is linear in h, so full sequences run with ``jax.lax.associative_scan``
(parallel, O(log S) depth) — the TPU-native adaptation of the paper's
GPU scan kernel. Decode is a single-step update.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (conv1d_apply, conv1d_init, dense_apply,
                                 dense_init)

_C = 8.0  # RG-LRU exponent constant from the paper


def rglru_init(key, cfg: ModelConfig):
    M = cfg.d_model
    W = cfg.recurrent.lru_width or M
    ks = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(lam)^c is in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / _C) / (1.0 - u ** (1.0 / _C)))
    return {
        "in_x": dense_init(ks[1], M, W),
        "in_gate": dense_init(ks[2], M, W),
        "conv": conv1d_init(ks[3], W, cfg.recurrent.conv1d_width),
        "w_a": dense_init(ks[4], W, W),    # recurrence gate r_t
        "w_i": dense_init(ks[5], W, W),    # input gate i_t
        "lam": lam,
        "out": dense_init(jax.random.fold_in(ks[5], 1), W, M),
    }


def rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    W = cfg.recurrent.lru_width or cfg.d_model
    cw = cfg.recurrent.conv1d_width
    return {
        "h": jnp.zeros((batch, W), dtype),
        "conv": jnp.zeros((batch, cw - 1, W), dtype),
    }


def _gates(params, xw):
    """a_t (log-space) and gated input; xw: [B,S,W] conv output."""
    r = jax.nn.sigmoid(dense_apply(params["w_a"], xw).astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(params["w_i"], xw).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(-params["lam"])   # log sigmoid(lam)^(c r)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i * xw.astype(jnp.float32))
    return a, b


def rglru_apply(params, cfg: ModelConfig, x, state=None,
                use_kernel: bool = False):
    """Full-sequence scan. x: [B,S,M] -> (y, final_state).

    use_kernel=False: jax.lax.associative_scan (parallel, O(log S) depth);
    use_kernel=True:  the Pallas rg_lru kernel (sequential within VMEM
    chunks, one HBM round-trip total) — the TPU-native form."""
    B, S, M = x.shape
    if state is None:
        state = rglru_state(cfg, B)
    branch_x = dense_apply(params["in_x"], x)
    gate = jax.nn.gelu(dense_apply(params["in_gate"], x))
    xc, conv_state = conv1d_apply(params["conv"], branch_x, state["conv"])
    a, b = _gates(params, xc)                       # [B,S,W] each, f32

    if use_kernel:
        from repro.kernels.rg_lru.ops import rg_lru_scan
        hs, h_last = rg_lru_scan(a, b, state["h"].astype(jnp.float32))
        final = {"h": h_last, "conv": conv_state}
        y = dense_apply(params["out"], hs.astype(x.dtype) * gate)
        return y, final

    # prepend carried state as an extra step: h_0' = state, a_0 = 1
    a0 = jnp.ones((B, 1, a.shape[-1]), a.dtype)
    b0 = state["h"][:, None, :].astype(b.dtype)
    a_all = jnp.concatenate([a0, a], axis=1)
    b_all = jnp.concatenate([b0, b], axis=1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, hs = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    h = hs[:, 1:]                                   # drop the seed step
    final = {"h": h[:, -1], "conv": conv_state}
    y = dense_apply(params["out"], h.astype(x.dtype) * gate)
    return y, final


def rglru_step(params, cfg: ModelConfig, x, state):
    """Single-token decode. x: [B,1,M]."""
    branch_x = dense_apply(params["in_x"], x)
    gate = jax.nn.gelu(dense_apply(params["in_gate"], x))
    xc, conv_state = conv1d_apply(params["conv"], branch_x, state["conv"])
    a, b = _gates(params, xc)                       # [B,1,W]
    h = a[:, 0] * state["h"] + b[:, 0]
    y = dense_apply(params["out"], h[:, None].astype(x.dtype) * gate)
    return y, {"h": h, "conv": conv_state}


def rglru_block_pattern(cfg: ModelConfig):
    """RecurrentGemma interleave: (rec, rec, attn) repeating (1:2)."""
    pat = (cfg.recurrent.block_pattern if cfg.recurrent
           and cfg.recurrent.block_pattern else ("rec", "rec", "attn"))
    return tuple(pat[i % len(pat)] for i in range(cfg.num_layers))
