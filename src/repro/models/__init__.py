"""Model substrate: layers, attention variants, MoE, SSM, RG-LRU, assembly."""
from repro.models.model import build_model, frontend_shape
from repro.models.transformer import ExecutionContext, Model, layer_kinds

__all__ = ["build_model", "frontend_shape", "ExecutionContext", "Model",
           "layer_kinds"]
