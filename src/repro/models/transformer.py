"""Model assembly for all architecture families.

One ``Model`` class covers:
  dense / moe / vlm   — causal transformer (GQA or MLA), MLP or MoE FFN
  ssm                 — xLSTM stacks (mLSTM / sLSTM pattern)
  hybrid              — RecurrentGemma (RG-LRU + local attention, 1:2)
  audio               — encoder-decoder (encoder consumes stub frame embeds)

Execution modes:
  forward()      full-sequence (training forward / loss)
  prefill()      full-sequence + cache fill
  decode_step()  one token with cache
Layers run as a Python loop (``scan_layers=False``, default: simplest,
exact) or as ``lax.scan`` over stacked per-pattern-group parameters
(``scan_layers=True``: small HLO for the 126-layer dry-runs).

MoE layers dispatch through ``moe_impl``:
  "dense"     exact all-experts oracle
  "capacity"  GShard capacity dispatch (single device)
  "dep"       FinDEP-scheduled expert-parallel path (repro.core.dep);
              requires an ExecutionContext with a mesh; the schedule
              ``Plan`` is passed per call (forward/prefill/decode_step all
              take ``plan=``) so one compiled model serves every schedule
              a repro.sched.SchedulePolicy resolves.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (dense_apply, dense_init, embedding_apply,
                                 embedding_attend, embedding_init, mlp_apply,
                                 mlp_init, rmsnorm_apply, rmsnorm_init)


@dataclass(frozen=True)
class ExecutionContext:
    """Immutable distribution template threaded to layers that need
    collectives. Schedules are NOT part of the context: the per-shape
    ``Plan``/``ExecProgram`` flows through the model call
    (``forward(..., plan=...)``), resolved by a
    ``repro.sched.SchedulePolicy``."""

    mesh: Optional[Any] = None          # jax Mesh (None = single device)
    expert_axis: str = "model"          # mesh axis used for EP / A2E-E2A
    data_axes: Tuple[str, ...] = ("data",)
    attn_impl: str = "xla"              # "xla" | "flash" | "decode_kernel"
    moe_impl: str = "capacity"          # "dense" | "capacity" | "dep"
    remat: bool = False
    #: decode-kernel KV block size override (None = kernel default). The
    #: paged engine pins its DENSE comparison runs to the page size so
    #: paged-vs-dense parity is bitwise (same block order, same flash
    #: accumulation grouping).
    decode_bc: Optional[int] = None


# ---------------------------------------------------------------------------
# layer kinds per architecture family
# ---------------------------------------------------------------------------

def layer_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family == "ssm":
        return ssm_lib.xlstm_layer_kinds(cfg)
    if cfg.family == "hybrid":
        return rglru_lib.rglru_block_pattern(cfg)
    moe_set = set(cfg.moe_layer_indices())
    return tuple("attn_moe" if i in moe_set else "attn_mlp"
                 for i in range(cfg.num_layers))


def pattern_group(cfg: ModelConfig) -> Tuple[str, ...]:
    """Smallest repeating unit of layer kinds (for scanned stacking)."""
    kinds = layer_kinds(cfg)
    for size in range(1, len(kinds) + 1):
        if len(kinds) % size == 0 and kinds == kinds[:size] * (len(kinds) // size):
            return kinds[:size]
    return kinds


# ---------------------------------------------------------------------------
# single layer init/apply
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str,
               num_experts_padded: int = 0, cross: bool = False):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    if kind in ("attn_mlp", "attn_moe"):
        p["ln1"] = rmsnorm_init(cfg.d_model)
        p["attn"] = attn.attention_init(ks[0], cfg)
        p["ln2"] = rmsnorm_init(cfg.d_model)
        if kind == "attn_mlp":
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.ffn_dim)
        else:
            p["moe"] = moe_lib.moe_init(ks[1], cfg.d_model, cfg.moe,
                                        num_experts_padded)
        if cross:
            p["ln_x"] = rmsnorm_init(cfg.d_model)
            p["cross"] = attn.cross_attention_init(ks[2], cfg)
    elif kind == "mlstm":
        p["ln1"] = rmsnorm_init(cfg.d_model)
        p["mlstm"] = ssm_lib.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["ln1"] = rmsnorm_init(cfg.d_model)
        p["slstm"] = ssm_lib.slstm_init(ks[0], cfg)
    elif kind == "rec":
        p["ln1"] = rmsnorm_init(cfg.d_model)
        p["rglru"] = rglru_lib.rglru_init(ks[0], cfg)
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.ffn_dim)
    elif kind == "attn":  # hybrid local-attention block
        p["ln1"] = rmsnorm_init(cfg.d_model)
        p["attn"] = attn.attention_init(ks[0], cfg)
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.ffn_dim)
    else:
        raise ValueError(kind)
    return p


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                     dtype=jnp.bfloat16):
    if kind in ("attn_mlp", "attn_moe", "attn"):
        cap = attn.cache_capacity(cfg, seq_len)
        return attn.init_kv_cache(cfg, batch, cap, dtype)
    if kind == "mlstm":
        return ssm_lib.mlstm_state(cfg, batch)
    if kind == "slstm":
        return ssm_lib.slstm_state(cfg, batch)
    if kind == "rec":
        return rglru_lib.rglru_state(cfg, batch)
    raise ValueError(kind)


def _apply_moe(p, cfg: ModelConfig, h, ctx: ExecutionContext,
               num_experts_padded: int, plan=None, placement=None,
               collect_stats: bool = False, capacity_scale: float = 1.0):
    """Returns (y, aux), or (y, aux, moe.MoEStats) with
    ``collect_stats``. ``placement`` (a ``repro.placement.Placement``)
    and ``capacity_scale`` (skew-aware dispatch-buffer widening) only
    reach the DEP path — the single-device impls execute the logical
    layout directly."""
    if ctx.moe_impl == "dense":
        return moe_lib.moe_apply_dense(p["moe"], h, cfg.moe,
                                       num_experts_padded,
                                       return_stats=collect_stats)
    if ctx.moe_impl == "capacity":
        return moe_lib.moe_apply_capacity(p["moe"], h, cfg.moe,
                                          num_experts_padded,
                                          return_stats=collect_stats)
    if ctx.moe_impl == "dep":
        from repro.core import dep as dep_lib
        return dep_lib.moe_apply_dep(p["moe"], h, cfg.moe, ctx,
                                     num_experts_padded, plan=plan,
                                     placement=placement,
                                     return_stats=collect_stats,
                                     capacity_scale=capacity_scale)
    raise ValueError(ctx.moe_impl)


def apply_layer(p, cfg: ModelConfig, kind: str, x, positions,
                cache, mode: str, ctx: ExecutionContext,
                num_experts_padded: int = 0, memory=None, plan=None,
                lengths=None, block_table=None, placement=None,
                stats_sink=None, capacity_scale: float = 1.0):
    """Returns (x, new_cache, aux_loss). ``lengths`` is the decode-mode
    per-slot KV ledger vector, shared by every attention layer;
    ``block_table`` is the decode-mode paged-KV page map (also shared —
    one table addresses every layer's page pool). ``stats_sink`` is an
    optional Python list MoE layers append their ``moe.MoEStats`` to
    (load telemetry; Python-loop layer paths only)."""
    aux = jnp.zeros((), jnp.float32)
    local_cfg = cfg
    if kind == "attn" and cfg.family == "hybrid":
        local_cfg = dataclasses.replace(cfg, attention="local")

    if kind in ("attn_mlp", "attn_moe", "attn"):
        h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        if mode == "decode":
            a, cache = attn.attention_decode(p["attn"], local_cfg, h, cache,
                                             impl=ctx.attn_impl, ctx=ctx,
                                             lengths=lengths,
                                             block_table=block_table)
        else:
            a, cache = attn.attention_fullseq(p["attn"], local_cfg, h,
                                              positions, cache,
                                              impl=ctx.attn_impl)
        x = x + a
        if memory is not None and "cross" in p:
            hx = rmsnorm_apply(p["ln_x"], x, cfg.norm_eps)
            x = x + attn.cross_attention_apply(p["cross"], cfg, hx, memory)
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if kind == "attn_moe":
            if stats_sink is not None:
                y, aux, st = _apply_moe(p, cfg, h, ctx, num_experts_padded,
                                        plan, placement, collect_stats=True,
                                        capacity_scale=capacity_scale)
                stats_sink.append(st)
            else:
                y, aux = _apply_moe(p, cfg, h, ctx, num_experts_padded,
                                    plan, placement,
                                    capacity_scale=capacity_scale)
        else:
            y = mlp_apply(p["mlp"], h)
        return x + y, cache, aux

    if kind in ("mlstm", "slstm"):
        h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        fn = ssm_lib.mlstm_apply if kind == "mlstm" else ssm_lib.slstm_apply
        if cache is None:
            cache = init_layer_cache(cfg, kind, x.shape[0], 0)
        y, cache = fn(p[kind], cfg, h, cache)
        return x + y, cache, aux

    if kind == "rec":
        h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        if cache is None:
            cache = rglru_lib.rglru_state(cfg, x.shape[0])
        if mode == "decode":
            y, cache = rglru_lib.rglru_step(p["rglru"], cfg, h, cache)
        else:
            y, cache = rglru_lib.rglru_apply(p["rglru"], cfg, h, cache)
        x = x + y
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        return x + mlp_apply(p["mlp"], h), cache, aux

    raise ValueError(kind)


def _stack_moe_stats(sink):
    """Collapse a stats_sink list into one ``moe.MoEStats`` with
    ``load`` stacked to [L_moe, E] and ``dropped`` summed over layers.
    Returns None for an empty sink (no MoE layers, or scan_layers)."""
    sink = [s for s in sink if s is not None]
    if not sink:
        return None
    return moe_lib.MoEStats(
        load=jnp.stack([s.load for s in sink]),
        dropped=functools.reduce(jnp.add, [s.dropped for s in sink]))


# ---------------------------------------------------------------------------
# the Model
# ---------------------------------------------------------------------------

class Model:
    """Causal LM (all families); encoder-decoder when cfg.is_encoder_decoder."""

    def __init__(self, cfg: ModelConfig, ctx: Optional[ExecutionContext] = None,
                 num_experts_padded: int = 0, scan_layers: bool = False,
                 dtype=jnp.bfloat16, plan=None):
        self.cfg = cfg
        self.ctx = ctx or ExecutionContext()
        # default schedule for static pipelines (dry-runs, training); the
        # serving engine overrides it per call with policy-resolved plans
        self.plan = plan
        self.E_pad = num_experts_padded or (cfg.moe.num_experts if cfg.moe else 0)
        self.scan_layers = scan_layers
        self.dtype = dtype
        self.kinds = layer_kinds(cfg)
        self.group = pattern_group(cfg)
        self.num_groups = len(self.kinds) // len(self.group)

    # ---- init -----------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[1], cfg.d_model,
                                           cfg.vocab_size)
        cross = cfg.is_encoder_decoder
        if self.scan_layers:
            def init_group(gkey):
                gks = jax.random.split(gkey, len(self.group))
                return [init_layer(gks[i], cfg, kind, self.E_pad, cross)
                        for i, kind in enumerate(self.group)]
            gkeys = jax.random.split(keys[2], self.num_groups)
            params["layer_groups"] = jax.vmap(init_group)(gkeys)
        else:
            lkeys = jax.random.split(keys[2], len(self.kinds))
            params["layers"] = [init_layer(lkeys[i], cfg, kind, self.E_pad,
                                           cross)
                                for i, kind in enumerate(self.kinds)]
        if cfg.is_encoder_decoder:
            ekeys = jax.random.split(keys[3], cfg.num_encoder_layers + 1)
            params["enc_layers"] = [init_layer(ekeys[i], cfg, "attn_mlp")
                                    for i in range(cfg.num_encoder_layers)]
            params["enc_norm"] = rmsnorm_init(cfg.d_model)
        if cfg.family == "vlm":
            params["proj"] = dense_init(keys[4], cfg.d_model, cfg.d_model)
        return params

    # ---- encoder (audio family) ------------------------------------------
    def encode(self, params, frame_embeds):
        """frame_embeds: [B, S_enc, M] from the (stubbed) modality frontend."""
        cfg = self.cfg
        x = frame_embeds.astype(self.dtype)
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
        bidir_cfg = dataclasses.replace(cfg, attention="full")
        for p in params["enc_layers"]:
            h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
            a = _encoder_self_attention(p["attn"], bidir_cfg, h, positions)
            x = x + a
            h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
            x = x + mlp_apply(p["mlp"], h)
        return rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)

    # ---- embeddings -------------------------------------------------------
    def _embed_inputs(self, params, tokens, extra_embeds):
        x = embedding_apply(params["embed"], tokens, self.dtype)
        if extra_embeds is not None and self.cfg.family == "vlm":
            vis = dense_apply(params["proj"], extra_embeds.astype(self.dtype))
            x = jnp.concatenate([vis, x], axis=1)
        return x

    # ---- full-sequence forward -------------------------------------------
    def forward(self, params, tokens, extra_embeds=None, memory=None,
                caches=None, plan=None, placement=None, stats_sink=None,
                capacity_scale: float = 1.0):
        """tokens: [B, S]. extra_embeds: vlm patch embeds [B, P, M].
        memory: encoder output for enc-dec. caches: list to fill (prefill).
        plan: per-call schedule for DEP MoE layers (defaults to the model's
        static plan); placement: active expert ``Placement`` for the DEP
        path; stats_sink: optional list collecting per-MoE-layer
        ``moe.MoEStats`` (Python-loop path only — scanned layers skip
        collection). Returns (logits, new_caches, aux)."""
        cfg = self.cfg
        plan = plan if plan is not None else self.plan
        if cfg.is_encoder_decoder and memory is None and extra_embeds is not None:
            memory = self.encode(params, extra_embeds)
            extra_embeds = None
        x = self._embed_inputs(params, tokens, extra_embeds)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = [None] * len(self.kinds)
        if self.scan_layers:
            stats_sink = None               # no per-layer sink under scan

        def layer_fn(p, kind, x, cache):
            return apply_layer(p, cfg, kind, x, positions, cache, "forward",
                               self.ctx, self.E_pad, memory, plan,
                               placement=placement, stats_sink=stats_sink,
                               capacity_scale=capacity_scale)

        if self.scan_layers:
            x, new_caches, aux_total = self._scan_groups(
                params, x, caches, layer_fn)
        else:
            for i, kind in enumerate(self.kinds):
                cache = caches[i] if caches is not None else None
                fn = layer_fn
                if self.ctx.remat:
                    fn = jax.checkpoint(layer_fn, static_argnums=(1,))
                x, new_caches[i], aux = fn(params["layers"][i], kind, x, cache)
                aux_total = aux_total + aux

        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        logits = self._readout(params, x)
        return logits, new_caches, aux_total

    def _scan_groups(self, params, x, caches, layer_fn):
        """lax.scan over stacked pattern groups."""
        gsize = len(self.group)
        stacked_caches = caches  # already stacked by init_cache(scan=True)

        def body(carry, inputs):
            x, aux = carry
            gparams, gcaches = inputs
            new_gcaches = []
            for j, kind in enumerate(self.group):
                c = gcaches[j] if gcaches is not None else None
                x, nc, a = layer_fn(gparams[j], kind, x, c)
                new_gcaches.append(nc)
                aux = aux + a
            return (x, aux), new_gcaches

        body_fn = body
        if self.ctx.remat:
            body_fn = jax.checkpoint(body)
        (x, aux), new_caches = jax.lax.scan(
            body_fn, (x, jnp.zeros((), jnp.float32)),
            (params["layer_groups"], stacked_caches))
        return x, new_caches, aux

    def _readout(self, params, x):
        if self.cfg.tie_embeddings:
            return embedding_attend(params["embed"], x)
        return dense_apply(params["lm_head"],
                           x.astype(jnp.float32))

    # ---- caches ------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        if self.scan_layers:
            def one_group(_):
                return [init_layer_cache(self.cfg, kind, batch, seq_len, dtype)
                        for kind in self.group]
            return jax.vmap(one_group)(jnp.arange(self.num_groups))
        return [init_layer_cache(self.cfg, kind, batch, seq_len, dtype)
                for kind in self.kinds]

    # ---- prefill / decode ---------------------------------------------------
    def prefill(self, params, tokens, extra_embeds=None, memory=None,
                seq_budget: Optional[int] = None, cache_dtype=None,
                plan=None, last_positions=None, placement=None,
                return_moe_stats: bool = False,
                capacity_scale: float = 1.0):
        """tokens: [B, S] (right-padded when batching multiple requests).
        ``last_positions`` ([B] int, optional) gathers each row's logits
        at its own last REAL token instead of the padded bucket end —
        the batched multi-request prefill path, where rows share one
        bucket but differ in true prompt length. ``return_moe_stats``
        appends a stacked ``moe.MoEStats`` ([L_moe, E] loads + total
        dropped count; None under scan_layers) to the return."""
        B, S = tokens.shape
        budget = seq_budget or S
        off = 0
        if extra_embeds is not None and self.cfg.family == "vlm":
            budget += extra_embeds.shape[1]     # image tokens share the cache
            off = extra_embeds.shape[1]         # logits include image slots
        caches = self.init_cache(B, budget, cache_dtype or self.dtype)
        sink = [] if return_moe_stats else None
        logits, caches, _ = self.forward(params, tokens, extra_embeds,
                                         memory, caches, plan=plan,
                                         placement=placement,
                                         stats_sink=sink,
                                         capacity_scale=capacity_scale)
        if last_positions is not None:
            pos = jnp.asarray(last_positions, jnp.int32) + off
            last = logits[jnp.arange(B), pos][:, None]      # [B, 1, V]
        else:
            last = logits[:, -1:]
        if return_moe_stats:
            return last, caches, _stack_moe_stats(sink)
        return last, caches

    def decode_step(self, params, tokens, caches, memory=None, plan=None,
                    lengths=None, block_tables=None, placement=None,
                    return_moe_stats: bool = False,
                    capacity_scale: float = 1.0):
        """tokens: [B, 1] -> (logits [B,1,V], new caches), plus a stacked
        ``moe.MoEStats`` when ``return_moe_stats`` (None under
        scan_layers, where the per-layer Python sink cannot run).

        ``lengths`` ([B] int, optional): per-slot context lengths from the
        KV ledger — computed once by the engine and shared by every
        attention layer (mask source + ragged-kernel block skip) instead
        of being recomputed per layer from each cache index.
        ``block_tables`` (int [B, max_blocks], optional): paged-KV page
        map; ONE table serves every attention layer, since page p of each
        layer's pool belongs to the same logical block. None = dense."""
        cfg = self.cfg
        plan = plan if plan is not None else self.plan
        x = embedding_apply(params["embed"], tokens, self.dtype)
        aux = jnp.zeros((), jnp.float32)
        positions = None  # decode positions come from cache index
        sink = ([] if (return_moe_stats and not self.scan_layers) else None)

        def layer_fn(p, kind, x, cache):
            return apply_layer(p, cfg, kind, x, positions, cache, "decode",
                               self.ctx, self.E_pad, memory, plan,
                               lengths=lengths, block_table=block_tables,
                               placement=placement, stats_sink=sink,
                               capacity_scale=capacity_scale)

        if self.scan_layers:
            x, new_caches, aux = self._scan_groups(params, x, caches, layer_fn)
        else:
            new_caches = []
            for i, kind in enumerate(self.kinds):
                x, nc, a = layer_fn(params["layers"][i], kind, x, caches[i])
                new_caches.append(nc)
                aux = aux + a
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        logits = self._readout(params, x)
        if return_moe_stats:
            return logits, new_caches, _stack_moe_stats(sink or [])
        return logits, new_caches

    # ---- loss ----------------------------------------------------------------
    def loss(self, params, tokens, extra_embeds=None, ce_chunk: int = 512,
             plan=None):
        """Next-token CE (shift-by-one) + MoE aux loss.

        Uses a chunked fused linear+softmax-xent: the [tokens, vocab] f32
        logits are never materialized in full (vocab up to 256k makes the
        full tensor the dominant training-memory term); each sequence chunk
        is projected, reduced and rematerialized in the backward pass.
        """
        cfg = self.cfg
        plan = plan if plan is not None else self.plan
        memory = None
        if cfg.is_encoder_decoder and extra_embeds is not None:
            memory = self.encode(params, extra_embeds)
            extra_embeds = None
        x = self._embed_inputs(params, tokens, extra_embeds)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        aux_total = jnp.zeros((), jnp.float32)

        def layer_fn(p, kind, x, cache):
            return apply_layer(p, cfg, kind, x, positions, cache, "forward",
                               self.ctx, self.E_pad, memory, plan)

        if self.scan_layers:
            x, _, aux_total = self._scan_groups(params, x, None, layer_fn)
        else:
            for i, kind in enumerate(self.kinds):
                fn = layer_fn
                if self.ctx.remat:
                    fn = jax.checkpoint(layer_fn, static_argnums=(1,))
                x, _, aux = fn(params["layers"][i], kind, x, None)
                aux_total = aux_total + aux

        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        off = (extra_embeds.shape[1]
               if (extra_embeds is not None and cfg.family == "vlm") else 0)
        pred = x[:, off:off + tokens.shape[1] - 1]
        tgt = tokens[:, 1:]
        if cfg.tie_embeddings:
            W = params["embed"]["embedding"].T
        else:
            W = params["lm_head"]["kernel"]
        nll_mean = chunked_softmax_xent(pred, W, tgt, chunk=ce_chunk)
        coef = cfg.moe.router_aux_loss_coef if cfg.moe else 0.0
        return nll_mean + coef * aux_total


def chunked_softmax_xent(x, readout, targets, chunk: int = 512):
    """Fused linear + softmax cross-entropy over sequence chunks.

    x: [B, T, M] final hidden states; readout: [M, V]; targets: [B, T].
    Never materializes more than [B, chunk, V] of logits; each chunk is
    jax.checkpoint'ed so backward recomputes its logits.
    Returns mean NLL over all B*T positions.
    """
    B, T, M = x.shape
    n = max((T + chunk - 1) // chunk, 1)
    Tp = n * chunk
    if Tp != T:
        x = jnp.pad(x, ((0, 0), (0, Tp - T), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, Tp - T)))
    mask = (jnp.arange(Tp) < T).astype(jnp.float32)         # [Tp]
    xs = x.reshape(B, n, chunk, M).swapaxes(0, 1)           # [n,B,c,M]
    ts = targets.reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask.reshape(n, chunk)

    @jax.checkpoint
    def body(carry, inp):
        xc, tc, mc = inp
        logits = (xc.astype(jnp.float32)
                  @ readout.astype(jnp.float32))            # [B,c,V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        # vocab-parallel gold logit: take_along_axis over a vocab-sharded
        # logits tensor makes GSPMD all-gather the FULL [B,c,V] f32 logits
        # (~1 TB for 256k vocab at train_4k); a one-hot masked reduction is
        # elementwise over the sharded dim and reduces with a psum instead.
        onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
                  == tc[..., None])
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        return carry + jnp.sum((lse - gold) * mc[None, :]), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ts, ms))
    return total / (B * T)


def _encoder_self_attention(p, cfg: ModelConfig, h, positions):
    """Bidirectional self-attention for the encoder stack."""
    B, S, _ = h.shape
    hd = cfg.head_dim
    q = dense_apply(p["wq"], h).reshape(B, S, cfg.num_heads, hd)
    k = dense_apply(p["wk"], h).reshape(B, S, cfg.num_kv_heads, hd)
    v = dense_apply(p["wv"], h).reshape(B, S, cfg.num_kv_heads, hd)
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    k = attn.apply_rope(k, positions, cfg.rope_theta)
    mask = jnp.ones((S, S), bool)
    out = attn._sdpa(q, k, v, mask)
    return dense_apply(p["wo"], out.reshape(B, S, -1))
