"""xLSTM blocks (sLSTM + mLSTM) — arXiv:2405.04517.

Both blocks are true recurrences executed with ``jax.lax.scan`` over time
for full sequences and with a single-step update for decode. State is the
decode "cache" (no KV cache for SSM layers — this is what makes the
long_500k shape natively feasible).

mLSTM: matrix memory C in R^{dv x dk} per head, exponential input gate,
stabilized as in the paper (m_t running max of log-gates).
sLSTM: scalar memory per cell with recurrent gate connections (block-
diagonal per head), exponential gating with the same stabilizer.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (conv1d_apply, conv1d_init, dense_apply,
                                 dense_init, rmsnorm_apply, rmsnorm_init)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig):
    M = cfg.d_model
    H = cfg.num_heads
    d_inner = 2 * M
    hd = d_inner // H
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], M, 2 * d_inner),        # -> (u, z)
        "conv": conv1d_init(ks[1], d_inner, 4),
        "wq": dense_init(ks[2], d_inner, d_inner),
        "wk": dense_init(ks[3], d_inner, d_inner),
        "wv": dense_init(ks[4], d_inner, d_inner),
        "w_if": dense_init(ks[5], d_inner, 2 * H, bias=True),
        "out_norm": rmsnorm_init(d_inner),
        "down": dense_init(ks[6], d_inner, M),
        "skip": dense_init(ks[7], d_inner, d_inner),
    }


def mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner = 2 * cfg.d_model
    H = cfg.num_heads
    hd = d_inner // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), dtype),
        "n": jnp.zeros((batch, H, hd), dtype),
        "m": jnp.full((batch, H), -1e30, dtype),
        "conv": jnp.zeros((batch, 3, d_inner), dtype),
    }


def _mlstm_cell(state, qkvif):
    """One timestep. q,k,v: [B,H,hd]; i_t,f_t raw gates: [B,H]."""
    q, k, v, it, ft = qkvif
    C, n, m = state["C"], state["n"], state["m"]
    log_f = -jax.nn.softplus(-ft)                     # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, it)
    i_p = jnp.exp(it - m_new)[..., None]              # [B,H,1]
    f_p = jnp.exp(log_f + m - m_new)[..., None]
    n_new = f_p * n + i_p * k
    C_new = f_p[..., None] * C + (i_p * v)[..., None] * k[..., None, :]
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)), 1.0)
    h = jnp.einsum("bhvd,bhd->bhv", C_new, q) / denom[..., None]
    return {"C": C_new, "n": n_new, "m": m_new, "conv": state["conv"]}, h


def _mlstm_qkvif(params, cfg: ModelConfig, u_conv, u):
    """Project conv activations to per-head q,k,v and gates."""
    B, S, d_inner = u_conv.shape
    H = cfg.num_heads
    hd = d_inner // H
    q = dense_apply(params["wq"], u_conv).reshape(B, S, H, hd)
    k = dense_apply(params["wk"], u_conv).reshape(B, S, H, hd) / math.sqrt(hd)
    v = dense_apply(params["wv"], u).reshape(B, S, H, hd)
    gates = dense_apply(params["w_if"], u_conv).astype(jnp.float32)
    it, ft = gates[..., :H], gates[..., H:]
    return q, k, v, it, ft


def mlstm_apply(params, cfg: ModelConfig, x, state=None,
                use_kernel: bool = False):
    """Full-sequence scan. x: [B,S,M] -> (y, final_state).

    use_kernel=True runs the Pallas mlstm_scan kernel (state resident in
    VMEM across timesteps — one HBM round-trip total instead of one per
    step; see kernels/mlstm_scan)."""
    B, S, M = x.shape
    uz = dense_apply(params["up"], x)
    u, z = jnp.split(uz, 2, axis=-1)
    if state is None:
        state = mlstm_state(cfg, B, jnp.float32)
    u_conv, conv_state = conv1d_apply(params["conv"],
                                      jax.nn.silu(u), state["conv"])
    q, k, v, it, ft = _mlstm_qkvif(params, cfg, u_conv, u)

    if use_kernel:
        from repro.kernels.mlstm_scan.ops import mlstm_scan
        log_f = -jax.nn.softplus(-ft)                      # [B,S,H]
        h4, C, n, m = mlstm_scan(
            q.transpose(0, 2, 1, 3).astype(jnp.float32),
            k.transpose(0, 2, 1, 3).astype(jnp.float32),
            v.transpose(0, 2, 1, 3).astype(jnp.float32),
            it.transpose(0, 2, 1), log_f.transpose(0, 2, 1),
            state["C"], state["n"], state["m"])
        final = {"C": C, "n": n, "m": m, "conv": conv_state}
        h = h4.transpose(0, 2, 1, 3).reshape(B, S, -1).astype(x.dtype)
        h = rmsnorm_apply(params["out_norm"], h, cfg.norm_eps)
        h = h + dense_apply(params["skip"], u_conv)
        y = dense_apply(params["down"], h * jax.nn.silu(z))
        return y, final

    def step(carry, xs):
        return _mlstm_cell(carry, xs)

    xs = (q.swapaxes(0, 1).astype(jnp.float32),
          k.swapaxes(0, 1).astype(jnp.float32),
          v.swapaxes(0, 1).astype(jnp.float32),
          it.swapaxes(0, 1), ft.swapaxes(0, 1))
    final, hs = jax.lax.scan(step, state, xs)
    final = dict(final, conv=conv_state)
    h = hs.swapaxes(0, 1).reshape(B, S, -1).astype(x.dtype)   # [B,S,d_inner]
    h = rmsnorm_apply(params["out_norm"], h, cfg.norm_eps)
    h = h + dense_apply(params["skip"], u_conv)
    y = dense_apply(params["down"], h * jax.nn.silu(z))
    return y, final


def mlstm_step(params, cfg: ModelConfig, x, state):
    """Single-token decode. x: [B,1,M]."""
    y, new_state = mlstm_apply(params, cfg, x, state)
    return y, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig):
    M = cfg.d_model
    H = cfg.num_heads
    hd = M // H
    ks = jax.random.split(key, 4)
    return {
        "conv": conv1d_init(ks[0], M, 4),
        "w_gates": dense_init(ks[1], M, 4 * M, bias=True),   # i,f,z,o
        # block-diagonal recurrent weights: [H, hd, 4*hd]
        "r_gates": jax.random.normal(ks[2], (H, hd, 4 * hd), jnp.float32)
                   / math.sqrt(hd),
        "out_norm": rmsnorm_init(M),
        "ffn_up": dense_init(ks[3], M, int(M * 4 / 3) * 2),
        "ffn_down": dense_init(jax.random.fold_in(ks[3], 1),
                               int(M * 4 / 3), M),
    }


def slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    M = cfg.d_model
    return {
        "c": jnp.zeros((batch, M), dtype),
        "n": jnp.zeros((batch, M), dtype),
        "m": jnp.full((batch, M), -1e30, dtype),
        "h": jnp.zeros((batch, M), dtype),
        "conv": jnp.zeros((batch, 3, M), dtype),
    }


def _slstm_cell(params, cfg: ModelConfig, state, wx_t):
    """wx_t: [B, 4M] input contribution to gates at time t."""
    B = wx_t.shape[0]
    M = cfg.d_model
    H = cfg.num_heads
    hd = M // H
    h_prev = state["h"].reshape(B, H, hd)
    rec = jnp.einsum("bhd,hdg->bhg", h_prev,
                     params["r_gates"]).reshape(B, 4 * M)
    g = (wx_t + rec).astype(jnp.float32)
    it, ft, zt, ot = jnp.split(g, 4, axis=-1)
    log_f = -jax.nn.softplus(-ft)
    m_new = jnp.maximum(log_f + state["m"], it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + state["m"] - m_new)
    c_new = f_p * state["c"] + i_p * jnp.tanh(zt)
    n_new = f_p * state["n"] + i_p
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new,
            "conv": state["conv"]}, h_new


def slstm_apply(params, cfg: ModelConfig, x, state=None):
    B, S, M = x.shape
    if state is None:
        state = slstm_state(cfg, B)
    x_conv, conv_state = conv1d_apply(params["conv"], jax.nn.silu(x),
                                      state["conv"])
    wx = dense_apply(params["w_gates"], x_conv)              # [B,S,4M]

    def step(carry, wx_t):
        return _slstm_cell(params, cfg, carry, wx_t)

    final, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    final = dict(final, conv=conv_state)
    h = hs.swapaxes(0, 1).astype(x.dtype)                    # [B,S,M]
    h = rmsnorm_apply(params["out_norm"], h, cfg.norm_eps)
    # gated FFN (proj factor 4/3, as in the xLSTM paper's post-up-proj)
    gu = dense_apply(params["ffn_up"], h)
    g, u = jnp.split(gu, 2, axis=-1)
    y = dense_apply(params["ffn_down"], jax.nn.gelu(g) * u)
    return y, final


def slstm_step(params, cfg: ModelConfig, x, state):
    return slstm_apply(params, cfg, x, state)


# ---------------------------------------------------------------------------
# block pattern helper (xLSTM 7:1 mLSTM:sLSTM by default)
# ---------------------------------------------------------------------------

def xlstm_layer_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    pat = (cfg.recurrent.block_pattern if cfg.recurrent
           and cfg.recurrent.block_pattern else ("mlstm",) * 7 + ("slstm",))
    return tuple(pat[i % len(pat)] for i in range(cfg.num_layers))
