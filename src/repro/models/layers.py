"""Primitive layers (pure JAX, pytree-of-arrays parameters).

Parameters are nested dicts of jnp arrays. Every layer provides
``init_*(key, ...) -> params`` and a pure apply function. Weights are
created in float32 and cast to the compute dtype at apply time by the
caller (mixed-precision policy lives in repro.models.transformer).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _normal(key, shape, scale):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)


def dense_init(key, in_dim: int, out_dim: int, bias: bool = False,
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"kernel": _normal(key, (in_dim, out_dim), scale)}
    if bias:
        p["bias"] = jnp.zeros((out_dim,), dtype=jnp.float32)
    return p


def dense_apply(p, x):
    y = x @ p["kernel"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


def embedding_init(key, vocab: int, dim: int):
    return {"embedding": _normal(key, (vocab, dim), 1.0 / math.sqrt(dim))}


def embedding_apply(p, tokens, dtype=jnp.bfloat16):
    return jnp.take(p["embedding"].astype(dtype), tokens, axis=0)


def embedding_attend(p, x):
    """Tied readout: logits = x @ E^T (computed in float32)."""
    return jnp.asarray(x, jnp.float32) @ p["embedding"].astype(jnp.float32).T


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), dtype=jnp.float32)}


def rmsnorm_apply(p, x, eps: float = 1e-5):
    dtype = x.dtype
    x = jnp.asarray(x, jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dtype)


def layernorm_init(dim: int):
    return {"scale": jnp.ones((dim,), dtype=jnp.float32),
            "bias": jnp.zeros((dim,), dtype=jnp.float32)}


def layernorm_apply(p, x, eps: float = 1e-5):
    dtype = x.dtype
    x = jnp.asarray(x, jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                      # [hd/2]


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                    # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [...,S,hd/2]
    angles = angles[..., None, :]                          # [...,S,1,hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP (gate/up/down; paper Eq. 2 structure)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, ffn_dim: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, ffn_dim),
        "up": dense_init(k2, d_model, ffn_dim),
        "down": dense_init(k3, ffn_dim, d_model),
    }


def mlp_apply(p, x):
    g = dense_apply(p["gate"], x)
    u = dense_apply(p["up"], x)
    return dense_apply(p["down"], jax.nn.silu(g) * u)


# ---------------------------------------------------------------------------
# causal depthwise conv1d (used by xLSTM / RecurrentGemma blocks)
# ---------------------------------------------------------------------------

def conv1d_init(key, dim: int, width: int):
    return {"kernel": _normal(key, (width, dim), 1.0 / math.sqrt(width)),
            "bias": jnp.zeros((dim,), dtype=jnp.float32)}


def conv1d_apply(p, x, state=None):
    """Causal depthwise conv. x: [B, S, D]. ``state``: [B, width-1, D] tail
    of the previous segment (decode); returns (y, new_state). Compute runs
    in x.dtype; new_state keeps the incoming state's dtype (scan-carry
    stability)."""
    w = p["kernel"].astype(x.dtype)                        # [W, D]
    width = w.shape[0]
    if state is None:
        state = jnp.zeros(x.shape[:-2] + (width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=-2)
    ys = sum(xp[..., i:i + x.shape[-2], :] * w[i] for i in range(width))
    new_state = (xp[..., -(width - 1):, :].astype(state.dtype)
                 if width > 1 else state)
    return ys + p["bias"].astype(x.dtype), new_state
