"""Measured cost models: microbench calibration, profile persistence,
runtime telemetry, and drift-triggered plan refresh.

The paper fits its alpha-beta cost models on measured microbenchmarks
(Fig. 7, R^2 > 0.994); this package closes that loop for the repro:

  microbench   measure the three primitives (GEMM, attention,
               all_to_all) on THIS host/mesh in perf_model units
  store        persist fitted HardwareProfiles keyed by (device kind,
               mesh shape, dtype) so calibration runs once per host
  telemetry    StepTimer: measured prefill/decode wall-times vs each
               plan's modeled makespan -> residuals
  refresh      DriftMonitor + PlanRefresher: a residual breach
               invalidates one PlanCache entry and re-solves it on a
               worker thread while the stale plan keeps serving;
               PeriodicRecalibrator re-runs the microbenchmarks when the
               stored profile goes stale (cron-style, off-path)
  attribution  per-primitive drift attribution: fit gemm/attn/comm scale
               factors from task-graph-tagged residuals so a comm
               slowdown retunes alpha_c/beta_c without inflating the
               compute terms
"""
from repro.profiling.microbench import (ATTN_SWEEP, ATTN_SWEEP_FAST,
                                        COMM_SWEEP_BYTES,
                                        COMM_SWEEP_BYTES_FAST,
                                        CalibrationResult, DECODE_SWEEP,
                                        DECODE_SWEEP_FAST, GEMM_SWEEP,
                                        GEMM_SWEEP_FAST, MICROBENCH_KINDS,
                                        MicrobenchSamples, calibrate,
                                        measure_all_to_all,
                                        measure_attention,
                                        measure_decode_attention,
                                        measure_gemm, run_microbenchmarks,
                                        time_fn)
from repro.profiling.attribution import (PRIMITIVES, attribution_rows,
                                         fit_primitive_scales)
from repro.profiling.refresh import (DriftMonitor, DriftStats,
                                     PeriodicRecalibrator, PlanRefresher,
                                     planner_of, rescale_policy_hardware,
                                     rescale_policy_hardware_by)
from repro.profiling.store import (DEFAULT_STORE_DIR, ProfileKey,
                                   ProfileStore, SCHEMA_VERSION,
                                   StoredProfile)
from repro.profiling.telemetry import KeyStats, PhaseStats, StepTimer

__all__ = [
    "MicrobenchSamples", "CalibrationResult", "calibrate",
    "measure_gemm", "measure_attention", "measure_all_to_all",
    "measure_decode_attention", "run_microbenchmarks", "time_fn",
    "GEMM_SWEEP", "GEMM_SWEEP_FAST", "ATTN_SWEEP", "ATTN_SWEEP_FAST",
    "COMM_SWEEP_BYTES", "COMM_SWEEP_BYTES_FAST",
    "DECODE_SWEEP", "DECODE_SWEEP_FAST", "MICROBENCH_KINDS",
    "ProfileKey", "ProfileStore", "StoredProfile", "SCHEMA_VERSION",
    "DEFAULT_STORE_DIR",
    "StepTimer", "PhaseStats", "KeyStats",
    "DriftMonitor", "DriftStats", "PlanRefresher", "PeriodicRecalibrator",
    "planner_of", "rescale_policy_hardware", "rescale_policy_hardware_by",
    "PRIMITIVES", "attribution_rows", "fit_primitive_scales",
]
