"""Drift-triggered plan refresh: invalidate + re-solve off the critical
path.

The closing arc of the measure->fit->plan->observe loop (and the ROADMAP
follow-up "cost-aware cache eviction + background re-solve so a solver
hiccup can never stall a decode step"):

  * ``StepTimer`` (telemetry) accumulates per-plan-key EWMA residuals;
  * ``DriftMonitor.observe`` compares each key's residual against a
    threshold; a breach optionally rescales the planner's hardware
    profile onto the measured wall-times (uniform rescale — argmax
    preserved, predictions corrected) and hands the key to the
    ``PlanRefresher``;
  * ``PlanRefresher`` runs ``PlanCache.refresh(key)`` on a worker thread:
    the STALE PLAN KEEPS SERVING — the cache entry is only replaced when
    the new solve lands, so no decode step ever waits on Algorithm 1.

Two refinements ride on the task-graph IR and the profile store:

  * per-primitive drift retuning: observations tagged with the lowered
    graph's gemm/attn/comm breakdown let a recalibrating episode fit
    per-primitive scale factors (``repro.profiling.attribution``) and
    rescale alpha_c/beta_c (comm) separately from the compute terms;
    the uniform whole-profile rescale remains the fallback whenever the
    tags are missing or cannot identify the scales;
  * ``PeriodicRecalibrator``: cron-style background re-calibration — when
    the stored profile for this host goes stale
    (``StoredProfile.is_stale``), re-run ``microbench.calibrate()`` on
    the worker pool and refresh every cached plan, instead of waiting
    for drift to trip.

Thread-safety: the refresh worker only touches ``PlanCache`` /
``FinDEPPlanner`` dicts (GIL-atomic ops); a concurrent engine-thread miss
can at worst duplicate one solve, never corrupt state.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, Mapping, Optional

from repro.profiling.attribution import (attribution_rows,
                                         fit_primitive_scales)
from repro.profiling.telemetry import StepTimer


def planner_of(policy):
    """The FinDEPPlanner behind a planner-backed policy (None for
    planner-free policies such as StaticPolicy)."""
    return getattr(policy, "planner", None)


def rescale_policy_hardware(policy, ratio: float,
                            clamp: float = 10.0) -> bool:
    """Uniformly rescale the policy's hardware profile by ``ratio``
    (measured/predicted) and drop the planner memo, so subsequent solves
    predict the observed wall-times. Returns False when the policy has no
    planner to retune."""
    planner = planner_of(policy)
    if planner is None or not hasattr(planner, "set_hardware"):
        return False
    ratio = min(max(ratio, 1.0 / clamp), clamp)
    planner.set_hardware(planner.hardware.scaled(ratio))
    return True


def rescale_policy_hardware_by(policy, scales: Mapping[str, float]) -> bool:
    """Per-primitive rescale (``HardwareProfile.scaled_by``): retune each
    alpha-beta model by its own measured/predicted ratio. Unlike the
    uniform rescale this can move the solver's argmax — that is the
    point of task-tagged attribution."""
    planner = planner_of(policy)
    if planner is None or not hasattr(planner, "set_hardware"):
        return False
    planner.set_hardware(planner.hardware.scaled_by(dict(scales)))
    return True


class PlanRefresher:
    """Background executor for ``PlanCache.refresh``; one in-flight
    refresh per key (duplicate requests while a solve is running are
    dropped, not queued)."""

    def __init__(self, cache, max_workers: int = 1,
                 on_done: Optional[Callable[[Hashable], None]] = None,
                 metrics=None):
        self.cache = cache
        self.on_done = on_done
        self._max_workers = max_workers
        self._pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.Lock()
        self._inflight: Dict[Hashable, Future] = {}
        self.requested = 0
        self.completed = 0
        self.failed = 0
        # optional obs.MetricsRegistry: completion/failure become counted
        # events instead of attributes a reader must poll
        self._m_completed = self._m_failed = None
        if metrics is not None:
            self._m_completed = metrics.counter(
                "repro_plan_refresh_completed_total",
                "background plan re-solves that landed")
            self._m_failed = metrics.counter(
                "repro_plan_refresh_failed_total",
                "background plan re-solves that raised or were cancelled")

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._max_workers,
                thread_name_prefix="plan-refresh")
        return self._pool

    def request(self, key: Hashable) -> bool:
        """Schedule a background re-solve of ``key``; returns False when
        one is already in flight. Never blocks on the solve."""
        return self.request_job(key, lambda: self.cache.refresh(key))

    def request_job(self, key: Hashable, fn: Callable[[], object]) -> bool:
        """Schedule an arbitrary background job under ``key`` with the
        same one-in-flight-per-key dedup as ``request`` (used by
        ``PeriodicRecalibrator`` to run microbenchmarks off the critical
        path). Returns False when ``key`` is already in flight."""
        with self._lock:
            if key in self._inflight:
                return False
            fut = self._ensure_pool().submit(fn)
            self._inflight[key] = fut
            self.requested += 1
        fut.add_done_callback(lambda f, k=key: self._finish(k, f))
        return True

    def _finish(self, key: Hashable, fut: Future) -> None:
        with self._lock:
            self._inflight.pop(key, None)
            if fut.cancelled() or fut.exception() is not None:
                self.failed += 1
                counter = self._m_failed
            else:
                self.completed += 1
                counter = self._m_completed
        if counter is not None:
            counter.inc()
        if self.on_done is not None:
            self.on_done(key)

    def in_flight(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._inflight

    def pending(self) -> int:
        with self._lock:
            return len(self._inflight)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Wait for every in-flight refresh (tests / shutdown)."""
        while True:
            with self._lock:
                futs = list(self._inflight.values())
            if not futs:
                return
            for f in futs:
                f.exception(timeout=timeout)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None


@dataclass
class DriftStats:
    observations: int = 0
    drift_events: int = 0
    last_drift_key: Optional[Hashable] = None
    last_drift_residual: Optional[float] = None
    per_key_events: Dict[Hashable, int] = field(default_factory=dict)
    #: per-primitive scales applied by the last recalibrating episode
    #: (None = the uniform whole-profile rescale was used)
    last_scales: Optional[Dict[str, float]] = None


class DriftMonitor:
    """Watches per-key residuals and triggers at most one background
    re-solve per drift episode.

    ``threshold`` is on |EWMA residual| (0.5 = the measured step ran 50%
    off the model); ``min_samples`` observations must accrue before a key
    can trigger. After triggering, the key is quiet until its refresh
    lands (in-flight dedup) AND its residual history restarts from zero
    samples (``timer.reset_key`` on completion), so one drift episode
    costs exactly one solve.

    ``recalibrate=True`` additionally rescales the policy's hardware
    profile onto the measured wall-times before re-solving, so the
    refreshed plans' predictions match reality and the episode converges
    instead of re-triggering forever. Since a rescale invalidates every
    cached plan's modeled makespan, a recalibrating episode refreshes ALL
    cache entries (one worker pass) and restarts every key's residual
    history.

    ``per_primitive=True`` (default) makes a recalibrating episode try
    task-tagged attribution first: when the accumulated observations
    carry per-primitive breakdowns (plans lowered through the task-graph
    IR tag their predictions with gemm/attn/comm splits) and the key
    compositions identify the scales, each alpha-beta model is retuned
    by its OWN measured/predicted ratio (``scaled_by``) instead of the
    uniform whole-profile rescale; the uniform rescale stays as the
    fallback when tags are missing or unidentifiable.
    """

    def __init__(self, cache, *, timer: Optional[StepTimer] = None,
                 refresher: Optional[PlanRefresher] = None,
                 threshold: float = 0.5, min_samples: int = 3,
                 recalibrate: bool = True, per_primitive: bool = True,
                 metrics=None):
        assert threshold > 0.0
        self.cache = cache
        self.timer = timer if timer is not None else StepTimer()
        self.refresher = (refresher if refresher is not None
                          else PlanRefresher(cache, metrics=metrics))
        if self.refresher.on_done is None:
            self.refresher.on_done = self._on_refresh_done
        self.threshold = threshold
        self.min_samples = min_samples
        self.recalibrate = recalibrate
        self.per_primitive = per_primitive
        self.stats = DriftStats()
        self._m_drift = None
        if metrics is not None:
            self._m_drift = metrics.counter(
                "repro_drift_events_total",
                "per-key residual EWMA breaches that scheduled a refresh")

    def _on_refresh_done(self, key: Hashable) -> None:
        # the replaced plan's residuals describe the OLD model; start the
        # new episode from a clean slate
        self.timer.reset_key(key)

    def _rescale(self, ewma: float) -> Optional[Dict[str, float]]:
        """Retune the policy's hardware profile onto the measured
        wall-times: per-primitive when task-tagged breakdowns identify
        the scales, uniform otherwise. Returns the applied per-primitive
        scales (None = uniform fallback)."""
        if self.per_primitive:
            scales = fit_primitive_scales(attribution_rows(self.timer.keys))
            if scales is not None and rescale_policy_hardware_by(
                    self.cache.policy, scales):
                return scales
        rescale_policy_hardware(self.cache.policy, 1.0 + ewma)
        return None

    def observe(self, key: Hashable, measured_s: float,
                predicted_s: Optional[float], phase: str = "decode",
                breakdown: Optional[Mapping[str, float]] = None) -> bool:
        """Record one measured step against its prediction (``breakdown``
        = the plan's modeled per-primitive split, for attribution);
        returns True when this observation tripped the drift threshold
        and a background refresh was scheduled."""
        self.stats.observations += 1
        self.timer.observe(phase, measured_s, predicted_s=predicted_s,
                           key=key, breakdown=breakdown)
        st = self.timer.keys.get(key)
        if st is None or st.count < self.min_samples:
            return False
        ewma = st.residual_ewma
        if ewma is None or abs(ewma) < self.threshold:
            return False
        if self.refresher.in_flight(key):
            return False              # already refreshing this key
        if self.recalibrate:
            # a recalibrating episode must not START while any refresh
            # (or background calibration sharing this pool) is still in
            # flight: the stale entries keep serving their OLD predicted
            # makespans until their re-solve lands, so a key could
            # re-breach on the same hardware shift and COMPOUND the
            # rescale (2x -> 4x -> ...) before the first correction ever
            # reaches a prediction
            if self.refresher.pending() > 0:
                return False
            # the rescale invalidates EVERY cached plan's prediction (all
            # were solved under the old fit), not just this key's: refresh
            # them all and restart every residual history — otherwise each
            # remaining stale key would re-breach on the same hardware
            # shift and compound the correction
            self.stats.last_scales = self._rescale(ewma)
            for k in self.timer.keys:
                self.timer.reset_key(k)
            if not any([self.refresher.request(k)
                        for k in self.cache.entries()]):
                return False
        elif not self.refresher.request(key):
            return False
        self.stats.drift_events += 1
        self.stats.last_drift_key = key
        self.stats.last_drift_residual = ewma
        self.stats.per_key_events[key] = \
            self.stats.per_key_events.get(key, 0) + 1
        if self._m_drift is not None:
            self._m_drift.inc()
        return True

    def close(self) -> None:
        self.refresher.close()


class PeriodicRecalibrator:
    """Cron-style background re-calibration: when the stored profile for
    this host goes stale (``StoredProfile.is_stale(max_age_s)``), re-run
    the microbenchmarks on the refresh worker pool, persist the new fit,
    reprofile the policy, and refresh every cached plan — instead of
    waiting for drift to trip. Complements ``DriftMonitor``: drift reacts
    to observed residuals, this one to calendar age.

    ``maybe_recalibrate()`` is cheap enough to call once per engine step:
    store reads are throttled to ``poll_interval_s`` and the calibration
    itself runs as a deduplicated background job (one in flight at a
    time; the serving loop never waits on a microbenchmark).

    CAVEAT: the microbenchmarks time the SAME device the engine serves
    on, so a sweep that overlaps live traffic measures contended
    primitives and fits a pessimistic profile. Prefer a ``max_age_s``
    long enough that re-calibration lands in natural idle gaps, or call
    ``maybe_recalibrate(force=True)`` from a maintenance window. Sharing
    the ``DriftMonitor``'s refresher (the engine wiring does) at least
    keeps drift episodes from firing off the contended wall-times while
    the calibration job is in flight.

    ``calibrate_fn`` defaults to ``microbench.calibrate(fast=True)`` on
    this host/mesh; tests inject a stub.
    """

    _JOB_KEY = ("__recalibrate__",)

    def __init__(self, cache, store, *, key=None, name: Optional[str] = None,
                 max_age_s: float = 3600.0, mesh=None, fast: bool = True,
                 refresher: Optional[PlanRefresher] = None,
                 timer: Optional[StepTimer] = None,
                 calibrate_fn: Optional[Callable[[], object]] = None,
                 poll_interval_s: float = 30.0, metrics=None):
        from repro.profiling.store import ProfileKey
        self.cache = cache
        self.store = store
        self.key = key if key is not None else ProfileKey.for_host(mesh)
        self.name = name or self.key.slug()
        self.max_age_s = max_age_s
        self.mesh = mesh
        self.fast = fast
        self.refresher = (refresher if refresher is not None
                          else PlanRefresher(cache))
        self._owns_refresher = refresher is None
        self.timer = timer
        self.calibrate_fn = calibrate_fn
        self.poll_interval_s = poll_interval_s
        self._last_poll: Optional[float] = None
        self.recalibrations = 0
        self._m_recal = None
        if metrics is not None:
            self._m_recal = metrics.counter(
                "repro_recalibrations_total",
                "completed background microbenchmark re-calibrations")

    def due(self) -> bool:
        """True when no stored profile exists for this host's key or the
        newest one is older than ``max_age_s``."""
        try:
            return self.store.get_for_key(self.key).is_stale(self.max_age_s)
        except KeyError:
            return True

    def maybe_recalibrate(self, force: bool = False) -> bool:
        """Kick off a background re-calibration when due; returns True
        when a job was scheduled. Never blocks on the microbenchmarks."""
        now = time.monotonic()
        if not force:
            if (self._last_poll is not None
                    and now - self._last_poll < self.poll_interval_s):
                return False
            self._last_poll = now
            if not self.due():
                return False
        return self.refresher.request_job(self._JOB_KEY, self._recalibrate)

    def _recalibrate(self) -> None:
        if self.calibrate_fn is not None:
            result = self.calibrate_fn()
        else:
            from repro.profiling.microbench import calibrate
            result = calibrate(name=self.name, fast=self.fast,
                               mesh=self.mesh)
        self.store.put_calibration(result, self.key, name=self.name)
        reprofile = getattr(self.cache.policy, "reprofile", None)
        if callable(reprofile):
            reprofile(result.profile)
        # every cached plan was solved under the old fit: re-solve them
        # all in place (stale plans keep serving) and restart residual
        # histories, same as a drift-recalibrating episode
        for k in self.cache.entries():
            self.cache.refresh(k)
        if self.timer is not None:
            for k in list(self.timer.keys):
                self.timer.reset_key(k)
        self.recalibrations += 1
        if self._m_recal is not None:
            self._m_recal.inc()

    def drain(self, timeout: Optional[float] = None) -> None:
        self.refresher.drain(timeout=timeout)

    def close(self) -> None:
        if self._owns_refresher:
            self.refresher.close()
