"""Drift-triggered plan refresh: invalidate + re-solve off the critical
path.

The closing arc of the measure->fit->plan->observe loop (and the ROADMAP
follow-up "cost-aware cache eviction + background re-solve so a solver
hiccup can never stall a decode step"):

  * ``StepTimer`` (telemetry) accumulates per-plan-key EWMA residuals;
  * ``DriftMonitor.observe`` compares each key's residual against a
    threshold; a breach optionally rescales the planner's hardware
    profile onto the measured wall-times (uniform rescale — argmax
    preserved, predictions corrected) and hands the key to the
    ``PlanRefresher``;
  * ``PlanRefresher`` runs ``PlanCache.refresh(key)`` on a worker thread:
    the STALE PLAN KEEPS SERVING — the cache entry is only replaced when
    the new solve lands, so no decode step ever waits on Algorithm 1.

Thread-safety: the refresh worker only touches ``PlanCache`` /
``FinDEPPlanner`` dicts (GIL-atomic ops); a concurrent engine-thread miss
can at worst duplicate one solve, never corrupt state.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, Optional

from repro.profiling.telemetry import StepTimer


def planner_of(policy):
    """The FinDEPPlanner behind a planner-backed policy (None for
    planner-free policies such as StaticPolicy)."""
    return getattr(policy, "planner", None)


def rescale_policy_hardware(policy, ratio: float,
                            clamp: float = 10.0) -> bool:
    """Uniformly rescale the policy's hardware profile by ``ratio``
    (measured/predicted) and drop the planner memo, so subsequent solves
    predict the observed wall-times. Returns False when the policy has no
    planner to retune."""
    planner = planner_of(policy)
    if planner is None or not hasattr(planner, "set_hardware"):
        return False
    ratio = min(max(ratio, 1.0 / clamp), clamp)
    planner.set_hardware(planner.hardware.scaled(ratio))
    return True


class PlanRefresher:
    """Background executor for ``PlanCache.refresh``; one in-flight
    refresh per key (duplicate requests while a solve is running are
    dropped, not queued)."""

    def __init__(self, cache, max_workers: int = 1,
                 on_done: Optional[Callable[[Hashable], None]] = None):
        self.cache = cache
        self.on_done = on_done
        self._max_workers = max_workers
        self._pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.Lock()
        self._inflight: Dict[Hashable, Future] = {}
        self.requested = 0
        self.completed = 0
        self.failed = 0

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._max_workers,
                thread_name_prefix="plan-refresh")
        return self._pool

    def request(self, key: Hashable) -> bool:
        """Schedule a background re-solve of ``key``; returns False when
        one is already in flight. Never blocks on the solve."""
        with self._lock:
            if key in self._inflight:
                return False
            fut = self._ensure_pool().submit(self.cache.refresh, key)
            self._inflight[key] = fut
            self.requested += 1
        fut.add_done_callback(lambda f, k=key: self._finish(k, f))
        return True

    def _finish(self, key: Hashable, fut: Future) -> None:
        with self._lock:
            self._inflight.pop(key, None)
            if fut.cancelled() or fut.exception() is not None:
                self.failed += 1
            else:
                self.completed += 1
        if self.on_done is not None:
            self.on_done(key)

    def in_flight(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._inflight

    def pending(self) -> int:
        with self._lock:
            return len(self._inflight)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Wait for every in-flight refresh (tests / shutdown)."""
        while True:
            with self._lock:
                futs = list(self._inflight.values())
            if not futs:
                return
            for f in futs:
                f.exception(timeout=timeout)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None


@dataclass
class DriftStats:
    observations: int = 0
    drift_events: int = 0
    last_drift_key: Optional[Hashable] = None
    last_drift_residual: Optional[float] = None
    per_key_events: Dict[Hashable, int] = field(default_factory=dict)


class DriftMonitor:
    """Watches per-key residuals and triggers at most one background
    re-solve per drift episode.

    ``threshold`` is on |EWMA residual| (0.5 = the measured step ran 50%
    off the model); ``min_samples`` observations must accrue before a key
    can trigger. After triggering, the key is quiet until its refresh
    lands (in-flight dedup) AND its residual history restarts from zero
    samples (``timer.reset_key`` on completion), so one drift episode
    costs exactly one solve.

    ``recalibrate=True`` additionally rescales the policy's hardware
    profile onto the measured wall-times before re-solving, so the
    refreshed plans' predictions match reality and the episode converges
    instead of re-triggering forever. Since a rescale invalidates every
    cached plan's modeled makespan, a recalibrating episode refreshes ALL
    cache entries (one worker pass) and restarts every key's residual
    history.
    """

    def __init__(self, cache, *, timer: Optional[StepTimer] = None,
                 refresher: Optional[PlanRefresher] = None,
                 threshold: float = 0.5, min_samples: int = 3,
                 recalibrate: bool = True):
        assert threshold > 0.0
        self.cache = cache
        self.timer = timer if timer is not None else StepTimer()
        self.refresher = (refresher if refresher is not None
                          else PlanRefresher(cache))
        if self.refresher.on_done is None:
            self.refresher.on_done = self._on_refresh_done
        self.threshold = threshold
        self.min_samples = min_samples
        self.recalibrate = recalibrate
        self.stats = DriftStats()

    def _on_refresh_done(self, key: Hashable) -> None:
        # the replaced plan's residuals describe the OLD model; start the
        # new episode from a clean slate
        self.timer.reset_key(key)

    def observe(self, key: Hashable, measured_s: float,
                predicted_s: Optional[float], phase: str = "decode") -> bool:
        """Record one measured step against its prediction; returns True
        when this observation tripped the drift threshold and a background
        refresh was scheduled."""
        self.stats.observations += 1
        self.timer.observe(phase, measured_s, predicted_s=predicted_s,
                           key=key)
        st = self.timer.keys.get(key)
        if st is None or st.count < self.min_samples:
            return False
        ewma = st.residual_ewma
        if ewma is None or abs(ewma) < self.threshold:
            return False
        if self.refresher.in_flight(key):
            return False              # already refreshing this key
        if self.recalibrate:
            # the rescale invalidates EVERY cached plan's prediction (all
            # were solved under the old fit), not just this key's: refresh
            # them all and restart every residual history — otherwise each
            # remaining stale key would re-breach on the same hardware
            # shift and compound the correction
            rescale_policy_hardware(self.cache.policy, 1.0 + ewma)
            for k in self.timer.keys:
                self.timer.reset_key(k)
            if not any([self.refresher.request(k)
                        for k in self.cache.entries()]):
                return False
        elif not self.refresher.request(key):
            return False
        self.stats.drift_events += 1
        self.stats.last_drift_key = key
        self.stats.last_drift_residual = ewma
        self.stats.per_key_events[key] = \
            self.stats.per_key_events.get(key, 0) + 1
        return True

    def close(self) -> None:
        self.refresher.close()
