"""On-device microbenchmarks for the three alpha-beta primitives.

Each runner times a jit-compiled primitive over a workload sweep and emits
``(x, t)`` samples in EXACTLY the units ``repro.core.perf_model`` fits
(module header there):

  * GEMM      x = m * k * n            (product of the three GEMM dims)
  * attention y = N_h * B * S^2 * (d_k + d_v)
  * comm      z = bytes on the wire per device (a2e/e2a path)

``run_microbenchmarks`` bundles the three sweeps into the ``measured``
dict ``fit_profile`` / ``calibrated_stage_models`` consume;
``calibrate`` goes one step further and returns the fitted
``HardwareProfile`` plus per-primitive R^2 (the paper reports
R^2 > 0.994 on its GPUs — Fig. 7).

The all_to_all runner needs a live mesh whose expert axis spans > 1
device; without one (single-device CPU hosts, unit tests) it falls back
to a bytes-proportional on-device copy proxy and marks the result
``proxy=True`` so stores/reports can flag that the comm fit is not a
wire measurement.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.perf_model import (HardwareProfile, fit_alpha_beta,
                                   fit_profile)

# (m, k, n) GEMM sweeps: products span ~3 decades so the intercept
# (launch overhead) and slope (per-unit time) are both identifiable.
GEMM_SWEEP: Tuple[Tuple[int, int, int], ...] = (
    (128, 256, 256), (256, 512, 512), (512, 512, 1024), (512, 1024, 1024),
    (1024, 1024, 1024), (1024, 2048, 1024), (2048, 2048, 1024),
)
GEMM_SWEEP_FAST: Tuple[Tuple[int, int, int], ...] = (
    (128, 256, 256), (256, 256, 512), (256, 512, 512), (512, 512, 512),
    (512, 1024, 512), (512, 1024, 1024), (1024, 1024, 1024),
)

# (B, S, N_h, d) attention sweeps (d_k = d_v = d).
ATTN_SWEEP: Tuple[Tuple[int, int, int, int], ...] = (
    (1, 128, 4, 64), (1, 256, 4, 64), (2, 256, 4, 64), (2, 512, 4, 64),
    (4, 512, 4, 64), (4, 512, 8, 64),
)
ATTN_SWEEP_FAST: Tuple[Tuple[int, int, int, int], ...] = (
    (1, 64, 4, 64), (1, 128, 4, 64), (2, 128, 4, 64), (2, 256, 4, 64),
    (4, 256, 4, 64),
)

# per-device payload sizes (bytes) for the comm sweep
COMM_SWEEP_BYTES: Tuple[int, ...] = tuple(2 ** i for i in range(16, 26))
COMM_SWEEP_BYTES_FAST: Tuple[int, ...] = tuple(2 ** i for i in range(20, 26))

# (B, C, fill) single-query ragged decode sweeps at fixed (Kv, D): B rows
# each attending fill*C cached positions. The unit is BYTES STREAMED
# (sum(lengths) * Kv * 2D * itemsize) — decode attention is
# bandwidth-bound, so its alpha-beta lives on a different line than the
# compute-bound prefill attention fit.
DECODE_SWEEP: Tuple[Tuple[int, int, float], ...] = (
    (1, 256, 1.0), (2, 256, 0.5), (2, 512, 1.0), (4, 512, 0.5),
    (4, 1024, 1.0), (8, 1024, 0.75), (8, 2048, 0.5),
)
DECODE_SWEEP_FAST: Tuple[Tuple[int, int, float], ...] = (
    (1, 128, 1.0), (2, 128, 0.5), (2, 256, 1.0), (4, 256, 0.5),
    (4, 512, 0.5),
)
DECODE_KV_HEADS = 4
DECODE_HEAD_DIM = 64


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call of a jit-compiled ``fn`` (blocks on the
    result, so device async dispatch does not leak into the sample; the
    median discards scheduler hiccups that would poison a mean on shared
    CI hosts)."""
    import jax
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    n = len(ts)
    return ts[n // 2] if n % 2 else 0.5 * (ts[n // 2 - 1] + ts[n // 2])


@dataclass
class MicrobenchSamples:
    """One primitive's measured sweep: ``xs`` in perf_model units, ``ts``
    in seconds. ``proxy`` flags a stand-in measurement (e.g. the comm
    sweep on a single-device host)."""

    kind: str
    xs: List[float] = field(default_factory=list)
    ts: List[float] = field(default_factory=list)
    proxy: bool = False

    def as_xt(self) -> Tuple[List[float], List[float]]:
        return self.xs, self.ts


def measure_gemm(shapes: Optional[Sequence[Tuple[int, int, int]]] = None,
                 dtype=None, warmup: int = 2, iters: int = 5
                 ) -> MicrobenchSamples:
    """x = m*k*n for a [m,k] @ [k,n] matmul."""
    import jax
    import jax.numpy as jnp
    dtype = dtype or jnp.float32
    shapes = GEMM_SWEEP if shapes is None else shapes
    out = MicrobenchSamples("gemm")
    f = jax.jit(lambda a, b: a @ b)
    key = jax.random.PRNGKey(0)
    for m, k, n in shapes:
        a = jax.random.normal(key, (m, k), dtype)
        b = jax.random.normal(key, (k, n), dtype)
        out.xs.append(float(m * k * n))
        out.ts.append(time_fn(f, a, b, warmup=warmup, iters=iters))
    return out


def measure_attention(shapes: Optional[Sequence[Tuple[int, int, int, int]]]
                      = None, dtype=None, warmup: int = 2, iters: int = 5
                      ) -> MicrobenchSamples:
    """y = N_h * B * S^2 * (d_k + d_v) for causal SDPA."""
    import jax
    import jax.numpy as jnp
    from repro.models.attention import _causal_mask, _sdpa
    dtype = dtype or jnp.float32
    shapes = ATTN_SWEEP if shapes is None else shapes
    out = MicrobenchSamples("attn")
    key = jax.random.PRNGKey(0)
    f = jax.jit(lambda q, k, v, m: _sdpa(q, k, v, m))
    for B, S, H, D in shapes:
        q = jax.random.normal(key, (B, S, H, D), dtype)
        k = jax.random.normal(key, (B, S, H, D), dtype)
        v = jax.random.normal(key, (B, S, H, D), dtype)
        mask = _causal_mask(jnp.arange(S), jnp.arange(S), None)
        out.xs.append(float(H * B * S * S * (D + D)))
        out.ts.append(time_fn(f, q, k, v, mask, warmup=warmup, iters=iters))
    return out


def measure_all_to_all(mesh=None, axis: str = "model",
                       sizes_bytes: Optional[Sequence[int]] = None,
                       dtype=None, warmup: int = 2, iters: int = 5
                       ) -> MicrobenchSamples:
    """z = bytes per device moved by one tiled all_to_all on ``mesh``'s
    ``axis`` — the live-wire a2e/e2a measurement. Falls back to an
    on-device copy proxy (``proxy=True``) when the axis spans one device.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    dtype = dtype or jnp.float32
    sizes = COMM_SWEEP_BYTES if sizes_bytes is None else sizes_bytes
    itemsize = jnp.dtype(dtype).itemsize
    mo = mesh.shape[axis] if (mesh is not None and axis in mesh.shape) else 1

    out = MicrobenchSamples("comm", proxy=mo <= 1)
    key = jax.random.PRNGKey(0)
    for z in sizes:
        elems = max(int(z) // itemsize, mo * mo)
        if mo > 1:
            # local [mo, c]: all_to_all exchanges the full local buffer
            # (z bytes per device) across the expert axis, like one a2e
            # chunk of Eq. 4
            c = max(elems // mo, 1)
            x = jax.random.normal(key, (mo * mo, c), dtype)

            def a2a(xl):
                return jax.lax.all_to_all(xl, axis, split_axis=0,
                                          concat_axis=1, tiled=True)

            f = jax.jit(shard_map(a2a, mesh=mesh, in_specs=P(axis),
                                  out_specs=P(axis)))
            z_dev = float(mo * c * itemsize)
        else:
            # proxy: a bytes-proportional on-device copy. Keeps the fit
            # machinery exercised on hosts with no multi-device axis; the
            # resulting beta is HBM-ish, NOT a wire bandwidth.
            x = jax.random.normal(key, (elems,), dtype)
            f = jax.jit(lambda a: a + 1)
            z_dev = float(elems * itemsize)
        out.xs.append(z_dev)
        out.ts.append(time_fn(f, x, warmup=warmup, iters=iters))
    return out


def measure_decode_attention(shapes: Optional[Sequence[Tuple[int, int, float]]]
                             = None, dtype=None, warmup: int = 2,
                             iters: int = 5) -> MicrobenchSamples:
    """z = sum(lengths) * Kv * (d_k + d_v) * itemsize — the KV bytes one
    ragged decode step streams. Times the Pallas kernel on TPU; on other
    hosts the jnp reference stands in (``proxy=True``) because interpret
    mode measures the interpreter, not the memory system."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import on_tpu
    from repro.kernels.decode_attention import ops as dec_ops
    from repro.kernels.decode_attention.ref import decode_attention_ref
    dtype = dtype or jnp.float32
    shapes = DECODE_SWEEP if shapes is None else shapes
    kv, d = DECODE_KV_HEADS, DECODE_HEAD_DIM
    itemsize = jnp.dtype(dtype).itemsize
    use_kernel = on_tpu()
    out = MicrobenchSamples("decode", proxy=not use_kernel)
    key = jax.random.PRNGKey(0)
    if use_kernel:
        f = jax.jit(lambda q, k, v_, l: dec_ops.decode_attention(q, k, v_, l))
    else:
        f = jax.jit(decode_attention_ref)
    for B, C, fill in shapes:
        # size the cache to the occupied length (rather than masking a
        # full-C cache): the jnp reference computes all C positions and
        # masks, which would decouple its time from the bytes unit; the
        # kernel skips past-length blocks anyway, so both paths stream
        # exactly the bytes the sample claims
        c_eff = max(int(C * fill), 16)
        q = jax.random.normal(key, (B, kv, d), dtype)
        k = jax.random.normal(key, (B, c_eff, kv, d), dtype)
        v = jax.random.normal(key, (B, c_eff, kv, d), dtype)
        lens = jnp.full((B,), c_eff, jnp.int32)
        out.xs.append(float(B * c_eff * kv * 2 * d * itemsize))
        out.ts.append(time_fn(f, q, k, v, lens, warmup=warmup, iters=iters))
    return out


def _measure_kind(kind: str, fast: bool, mesh, axis: str, dtype,
                  warmup: int, iters: int) -> MicrobenchSamples:
    if kind == "gemm":
        return measure_gemm(GEMM_SWEEP_FAST if fast else GEMM_SWEEP,
                            dtype=dtype, warmup=warmup, iters=iters)
    if kind == "attn":
        return measure_attention(ATTN_SWEEP_FAST if fast else ATTN_SWEEP,
                                 dtype=dtype, warmup=warmup, iters=iters)
    if kind == "comm":
        # comm samples are the cheapest to take and (on the copy proxy)
        # the most scheduler-noise-prone — buy stability with extra iters
        return measure_all_to_all(mesh, axis,
                                  COMM_SWEEP_BYTES_FAST if fast
                                  else COMM_SWEEP_BYTES,
                                  dtype=dtype, warmup=warmup,
                                  iters=max(3 * iters, 15))
    if kind == "decode":
        return measure_decode_attention(
            DECODE_SWEEP_FAST if fast else DECODE_SWEEP,
            dtype=dtype, warmup=warmup, iters=iters)
    raise ValueError(f"unknown microbench kind {kind!r}")


#: the full primitive set ``calibrate`` sweeps (decode rides along as the
#: optional fourth alpha-beta — ``fit_profile`` treats it as such)
MICROBENCH_KINDS = ("gemm", "attn", "comm", "decode")


def run_microbenchmarks(fast: bool = False, mesh=None, axis: str = "model",
                        dtype=None, warmup: Optional[int] = None,
                        iters: Optional[int] = None,
                        kinds: Tuple[str, ...] = MICROBENCH_KINDS
                        ) -> Dict[str, MicrobenchSamples]:
    """The full sweep set, keyed by primitive — ``{k: v.as_xt() ...}`` is
    exactly the ``measured`` dict ``calibrated_stage_models`` expects."""
    warmup = (1 if fast else 2) if warmup is None else warmup
    iters = (5 if fast else 9) if iters is None else iters
    return {kind: _measure_kind(kind, fast, mesh, axis, dtype, warmup,
                                iters)
            for kind in kinds}


@dataclass
class CalibrationResult:
    profile: HardwareProfile
    fit_r2: Dict[str, float]             # per primitive
    samples: Dict[str, MicrobenchSamples]
    wall_s: float

    @property
    def comm_is_proxy(self) -> bool:
        return self.samples["comm"].proxy

    def min_r2(self) -> float:
        return min(self.fit_r2.values())


def calibrate(name: str = "calibrated", fast: bool = False, mesh=None,
              axis: str = "model", dtype=None, min_r2: float = 0.9,
              max_retries: int = 2, warmup: Optional[int] = None,
              iters: Optional[int] = None) -> CalibrationResult:
    """Measure -> fit: the paper's offline phase on THIS host. Returns the
    fitted profile + the R^2 quality of each primitive fit.

    A primitive whose fit lands below ``min_r2`` (scheduler noise hit the
    sweep — a transient, not a property of the hardware) is re-measured up
    to ``max_retries`` times, keeping the best-R^2 sweep. ``min_r2=0``
    disables retries."""
    t0 = time.perf_counter()
    warmup_ = (1 if fast else 2) if warmup is None else warmup
    iters_ = (5 if fast else 9) if iters is None else iters
    samples = run_microbenchmarks(fast=fast, mesh=mesh, axis=axis,
                                  dtype=dtype, warmup=warmup_, iters=iters_)
    profile, r2s = fit_profile({k: v.as_xt() for k, v in samples.items()},
                               name=name)
    for _ in range(max_retries):
        bad = [k for k, v in r2s.items() if v < min_r2]
        if not bad:
            break
        for kind in bad:
            retaken = _measure_kind(kind, fast, mesh, axis, dtype,
                                    warmup_, iters_)
            _, r2_new = fit_alpha_beta(*retaken.as_xt())
            if r2_new > r2s[kind]:
                samples[kind] = retaken
        profile, r2s = fit_profile(
            {k: v.as_xt() for k, v in samples.items()}, name=name)
    return CalibrationResult(profile=profile, fit_r2=r2s, samples=samples,
                             wall_s=time.perf_counter() - t0)
