"""Per-primitive drift attribution: solve for gemm/attn/comm scale
factors from task-tagged residuals.

The solver predicts each plan's makespan as a composition of three
alpha-beta primitives; the lowered task graph tags every prediction with
its per-primitive split (``Plan.breakdown``). When measured wall-times
drift, keys with DIFFERENT compositions (a GEMM-bound prefill bucket vs
a comm-bound decode occupancy) over- or under-shoot differently — that
contrast is enough to solve, in least squares,

    measured_k  ~=  s_gemm * b_gemm_k + s_attn * b_attn_k + s_comm * b_comm_k

for the per-primitive scale factors ``s`` across the observed keys k.
``DriftMonitor`` applies them via ``HardwareProfile.scaled_by`` so a comm
slowdown retunes alpha_c/beta_c without inflating the compute terms
(which would mis-rank plans whose bottleneck is compute).

When the observations cannot identify the scales — fewer independent
compositions than active primitives, a singular fit, or non-physical
(non-positive) solutions — ``fit_primitive_scales`` returns None and the
caller falls back to the uniform whole-profile rescale.
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

import numpy as np

PRIMITIVES = ("gemm", "attn", "comm")

#: an attribution row: (per-primitive predicted seconds, measured seconds)
Row = Tuple[Mapping[str, float], float]


def fit_primitive_scales(rows: Iterable[Row], *, clamp: float = 10.0,
                         min_rows: int = 2,
                         primitives: Sequence[str] = PRIMITIVES
                         ) -> Optional[Dict[str, float]]:
    """Least-squares fit of measured = sum_p s_p * predicted_p over
    observation rows. Returns {primitive: scale} with every scale
    clamped to [1/clamp, clamp], or None when the system is not
    identifiable (too few rows, rank-deficient compositions, or a
    non-physical fit) — the caller should then fall back to a uniform
    rescale.

    Primitives whose predicted column is (near) zero everywhere carry no
    signal; they are excluded from the solve and returned with scale 1.0.
    """
    data = [(dict(b), float(m)) for b, m in rows if b]
    if len(data) < min_rows:
        return None
    M = np.asarray([[row.get(p, 0.0) for p in primitives]
                    for row, _ in data], dtype=np.float64)
    m = np.asarray([meas for _, meas in data], dtype=np.float64)
    if not (np.all(np.isfinite(M)) and np.all(np.isfinite(m))):
        return None
    # drop zero-signal columns (scale unidentifiable -> keep at 1.0)
    col_mag = np.abs(M).sum(axis=0)
    active = col_mag > 1e-12 * max(col_mag.max(), 1e-300)
    if not active.any():
        return None
    Ma = M[:, active]
    sol, _, rank, _ = np.linalg.lstsq(Ma, m, rcond=None)
    if rank < Ma.shape[1] or not np.all(np.isfinite(sol)):
        return None
    if np.any(sol <= 0.0):
        # a negative/zero time scale is non-physical: the compositions
        # were too collinear to separate the primitives
        return None
    scales = {p: 1.0 for p in primitives}
    for p, s in zip(np.asarray(primitives)[active], sol):
        scales[str(p)] = float(min(max(s, 1.0 / clamp), clamp))
    return scales


def attribution_rows(key_stats: Mapping) -> list:
    """Extract attribution rows from a ``StepTimer.keys`` mapping: one
    (per-step mean breakdown, per-step mean measured) row per key that
    accumulated task-tagged observations past warmup.

    Rows are normalized by each key's observation count so a hot key
    (thousands of decode steps) does not outweigh a rarely-observed
    composition by count² in the least-squares objective — the fit
    should be driven by the CONTRAST between compositions, not by how
    often each one ran."""
    rows = []
    for st in key_stats.values():
        if getattr(st, "breakdown", None) and st.count > 0:
            n = st.count
            rows.append(({k: v / n for k, v in st.breakdown.items()},
                         st.measured_s / n))
    return rows
