"""Runtime telemetry: measured step wall-times vs the cost model.

The solver picks plans by *modeled* makespan; the ``StepTimer`` is the
observability half of the loop — the serving engine drives it once per
prefill chunk / decode step with the measured wall-time and the plan's
predicted makespan, and it exposes predicted-vs-measured residuals

    residual = (measured - predicted) / predicted

aggregated two ways:

  * per phase  ("prefill" / "decode")      — coarse health dashboard;
  * per plan-cache key (EWMA)              — the signal drift detection
    (``repro.profiling.refresh``) consumes to decide that ONE cached
    plan's cost model has gone stale.

Observations may carry a per-primitive ``breakdown`` — the lowered task
graph's modeled gemm/attn/comm seconds (``Plan.breakdown``, from
``taskgraph.ScheduleResult.breakdown``). Per-key breakdown and measured
sums accumulate alongside the EWMA so drift attribution
(``repro.profiling.attribution``) can solve for per-primitive scale
factors instead of rescaling the whole profile uniformly.

Feeding the timer the model's own predictions yields residual 0 by
construction — that identity is the subsystem's unit-test anchor.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Hashable, Mapping, Optional


@dataclass
class PhaseStats:
    """Aggregate of every observation for one phase."""

    count: int = 0
    measured_s: float = 0.0
    predicted_s: float = 0.0     # only observations that carried a prediction
    predicted_count: int = 0
    last_measured_s: float = 0.0
    last_residual: Optional[float] = None

    @property
    def residual(self) -> Optional[float]:
        """Relative residual over all predicted observations:
        (sum measured - sum predicted) / sum predicted."""
        if self.predicted_count == 0 or self.predicted_s <= 0.0:
            return None
        return (self.measured_s - self.predicted_s) / self.predicted_s

    def as_dict(self) -> dict:
        return dict(count=self.count, measured_s=self.measured_s,
                    predicted_s=self.predicted_s, residual=self.residual,
                    last_residual=self.last_residual)


@dataclass
class KeyStats:
    """Per plan-cache-key residual tracking (EWMA-smoothed).

    The first ``warmup_left`` observations are discarded: a key's first
    execution typically includes jit compilation, and seconds of XLA
    compile measured against a millisecond makespan would poison the EWMA
    (and, downstream, trigger a bogus drift rescale).

    ``measured_s`` / ``predicted_s`` / ``breakdown`` are post-warmup sums;
    ``breakdown`` holds the summed per-primitive (gemm/attn/comm)
    predicted seconds from the plan's lowered task graph, the rows
    per-primitive drift attribution fits its scale factors on."""

    count: int = 0
    residual_ewma: Optional[float] = None
    last_residual: Optional[float] = None
    warmup_left: int = 0
    measured_s: float = 0.0
    predicted_s: float = 0.0
    breakdown: Dict[str, float] = field(default_factory=dict)

    def update(self, residual: float, smoothing: float,
               measured_s: float = 0.0, predicted_s: float = 0.0,
               breakdown: Optional[Mapping[str, float]] = None) -> None:
        self.last_residual = residual
        if self.warmup_left > 0:
            self.warmup_left -= 1
            return
        self.count += 1
        self.measured_s += measured_s
        self.predicted_s += predicted_s
        if breakdown:
            for k, v in breakdown.items():
                self.breakdown[k] = self.breakdown.get(k, 0.0) + float(v)
        if self.residual_ewma is None:
            self.residual_ewma = residual
        else:
            a = smoothing
            self.residual_ewma = a * residual + (1 - a) * self.residual_ewma

    def reset(self, warmup: int = 0) -> None:
        self.count = 0
        self.residual_ewma = None
        self.last_residual = None
        self.warmup_left = warmup
        self.measured_s = 0.0
        self.predicted_s = 0.0
        self.breakdown = {}


class StepTimer:
    """Per-phase / per-plan-key predicted-vs-measured accounting.

    ``smoothing`` is the EWMA weight of the newest per-key residual
    (1.0 = no smoothing); ``key_warmup`` observations per key are
    excluded from the EWMA (first-call jit compilation)."""

    def __init__(self, smoothing: float = 0.5, key_warmup: int = 1):
        assert 0.0 < smoothing <= 1.0
        self.smoothing = smoothing
        self.key_warmup = key_warmup
        self.phases: Dict[str, PhaseStats] = {}
        self.keys: Dict[Hashable, KeyStats] = {}

    def observe(self, phase: str, measured_s: float,
                predicted_s: Optional[float] = None,
                key: Optional[Hashable] = None,
                breakdown: Optional[Mapping[str, float]] = None
                ) -> Optional[float]:
        """Record one measured interval; returns the observation's relative
        residual (None when there was no usable prediction).
        ``breakdown`` optionally tags the prediction with its modeled
        per-primitive (gemm/attn/comm) split from the plan's lowered
        task graph."""
        ph = self.phases.setdefault(phase, PhaseStats())
        ph.count += 1
        ph.measured_s += measured_s
        ph.last_measured_s = measured_s
        residual = None
        if predicted_s is not None and predicted_s > 0.0:
            ph.predicted_s += predicted_s
            ph.predicted_count += 1
            residual = (measured_s - predicted_s) / predicted_s
            ph.last_residual = residual
            if key is not None:
                self.keys.setdefault(
                    key, KeyStats(warmup_left=self.key_warmup)).update(
                    residual, self.smoothing, measured_s=measured_s,
                    predicted_s=predicted_s, breakdown=breakdown)
        return residual

    @contextmanager
    def measure(self, phase: str, predicted_s: Optional[float] = None,
                key: Optional[Hashable] = None,
                breakdown: Optional[Mapping[str, float]] = None):
        """Context manager timing a block and recording it. The caller is
        responsible for blocking on device results inside the block."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.observe(phase, time.perf_counter() - t0,
                         predicted_s=predicted_s, key=key,
                         breakdown=breakdown)

    # -- readers --------------------------------------------------------
    def residuals(self) -> Dict[str, Optional[float]]:
        """Per-phase relative residuals (None where nothing was
        predicted)."""
        return {ph: st.residual for ph, st in self.phases.items()}

    def key_residual(self, key: Hashable) -> Optional[float]:
        st = self.keys.get(key)
        return st.residual_ewma if st is not None else None

    def reset_key(self, key: Hashable) -> None:
        """Forget a key's residual history (after its plan was refreshed —
        old residuals described the replaced plan's model; the warmup also
        re-arms, since a refreshed schedule may retrace)."""
        st = self.keys.get(key)
        if st is not None:
            st.reset(warmup=self.key_warmup)

    def reset(self) -> None:
        """Forget ALL history: phase aggregates and every per-key EWMA.
        This is what the metrics registry's reset hook calls — before it
        existed, ``EngineStats.reset()`` left the EWMAs (and their
        consumed warmups) leaking across a warmup/measure boundary."""
        self.phases = {}
        self.keys = {}

    def summary(self) -> Dict[str, dict]:
        return {ph: st.as_dict() for ph, st in self.phases.items()}

    def snapshot(self) -> Dict[str, float]:
        """Flat numeric dict for the metrics registry: per-phase counts,
        measured/predicted sums and residuals, plus cross-key residual
        EWMA extrema (key objects themselves are not label-safe)."""
        out: Dict[str, float] = {}
        for ph, st in self.phases.items():
            out[f"{ph}_count"] = st.count
            out[f"{ph}_measured_s"] = st.measured_s
            out[f"{ph}_predicted_s"] = st.predicted_s
            if st.residual is not None:
                out[f"{ph}_residual"] = st.residual
        ewmas = [st.residual_ewma for st in self.keys.values()
                 if st.residual_ewma is not None]
        out["tracked_keys"] = len(self.keys)
        if ewmas:
            out["key_residual_ewma_max"] = max(ewmas)
            out["key_residual_ewma_min"] = min(ewmas)
        return out

    def __repr__(self) -> str:
        parts = []
        for ph, st in sorted(self.phases.items()):
            r = st.residual
            parts.append(f"{ph}: n={st.count} measured={st.measured_s:.3f}s"
                         + (f" residual={r:+.1%}" if r is not None else ""))
        return f"StepTimer({'; '.join(parts) or 'empty'})"
