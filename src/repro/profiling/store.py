"""Versioned on-disk store for calibrated ``HardwareProfile``s.

Calibration (``repro.profiling.microbench.calibrate``) is a measure+fit
that takes seconds to minutes on a real mesh; it should run once per
host, not once per process. The ``ProfileStore`` persists each fitted
profile as one JSON file keyed by (device kind, mesh shape, dtype) plus a
human-chosen name, with enough metadata to judge staleness:

  * ``schema``      — bumped when the on-disk layout changes; files with
                      an unknown schema are ignored, never misparsed;
  * ``created_at``  — unix seconds; ``StoredProfile.age_s`` /
                      ``is_stale(max_age_s)`` gate re-calibration;
  * ``fit_r2``      — the per-primitive fit quality at calibration time;
  * ``comm_proxy``  — whether the comm fit came from the on-device copy
                      proxy rather than a live all_to_all.

JSON floats serialize via ``repr`` which round-trips IEEE doubles
exactly, so a load returns the profile bit-for-bit — plans solved from a
loaded profile equal plans solved from the freshly fitted one.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core.perf_model import HardwareProfile

SCHEMA_VERSION = 1

DEFAULT_STORE_DIR = os.environ.get("REPRO_PROFILE_DIR", ".repro-profiles")


def _mesh_shape_of(mesh) -> Tuple[int, ...]:
    if mesh is None:
        return (1,)
    return tuple(int(mesh.shape[a]) for a in mesh.axis_names)


@dataclass(frozen=True)
class ProfileKey:
    """What a calibration is valid for: the device kind it ran on, the
    mesh shape whose collectives it measured, and the activation dtype."""

    device_kind: str
    mesh_shape: Tuple[int, ...]
    dtype: str

    @staticmethod
    def for_host(mesh=None, dtype: str = "float32") -> "ProfileKey":
        import jax
        kind = jax.devices()[0].device_kind
        return ProfileKey(device_kind=str(kind),
                          mesh_shape=_mesh_shape_of(mesh), dtype=dtype)

    def slug(self) -> str:
        mesh = "x".join(str(d) for d in self.mesh_shape)
        kind = "".join(c if c.isalnum() else "-" for c in self.device_kind)
        return f"{kind}_{mesh}_{self.dtype}".lower()

    def as_dict(self) -> dict:
        return {"device_kind": self.device_kind,
                "mesh_shape": list(self.mesh_shape), "dtype": self.dtype}

    @staticmethod
    def from_dict(d: dict) -> "ProfileKey":
        return ProfileKey(device_kind=str(d["device_kind"]),
                          mesh_shape=tuple(int(x) for x in d["mesh_shape"]),
                          dtype=str(d["dtype"]))


@dataclass
class StoredProfile:
    name: str
    profile: HardwareProfile
    key: ProfileKey
    fit_r2: Dict[str, float] = field(default_factory=dict)
    samples: Dict[str, Tuple[List[float], List[float]]] = \
        field(default_factory=dict)
    comm_proxy: bool = False
    created_at: float = 0.0
    schema: int = SCHEMA_VERSION

    @property
    def age_s(self) -> float:
        return max(time.time() - self.created_at, 0.0)

    def is_stale(self, max_age_s: float) -> bool:
        return self.age_s > max_age_s

    def as_dict(self) -> dict:
        return {
            "schema": self.schema,
            "name": self.name,
            "profile": self.profile.as_dict(),
            "key": self.key.as_dict(),
            "fit_r2": dict(self.fit_r2),
            "samples": {k: [list(xs), list(ts)]
                        for k, (xs, ts) in self.samples.items()},
            "comm_proxy": self.comm_proxy,
            "created_at": self.created_at,
        }

    @staticmethod
    def from_dict(d: dict) -> "StoredProfile":
        return StoredProfile(
            name=str(d["name"]),
            profile=HardwareProfile.from_dict(d["profile"]),
            key=ProfileKey.from_dict(d["key"]),
            fit_r2={k: float(v) for k, v in d.get("fit_r2", {}).items()},
            samples={k: (list(map(float, xs)), list(map(float, ts)))
                     for k, (xs, ts) in d.get("samples", {}).items()},
            comm_proxy=bool(d.get("comm_proxy", False)),
            created_at=float(d.get("created_at", 0.0)),
            schema=int(d.get("schema", 0)),
        )


class ProfileStore:
    """One JSON file per stored profile under ``root``."""

    def __init__(self, root: str = DEFAULT_STORE_DIR):
        self.root = Path(root).expanduser()

    def _path(self, name: str) -> Path:
        safe = "".join(c if (c.isalnum() or c in "._-") else "-"
                       for c in name)
        return self.root / f"{safe}.json"

    # -- write ----------------------------------------------------------
    def put(self, profile: HardwareProfile, key: ProfileKey, *,
            name: Optional[str] = None,
            fit_r2: Optional[Dict[str, float]] = None,
            samples: Optional[Dict[str, Tuple[List[float], List[float]]]]
            = None, comm_proxy: bool = False) -> StoredProfile:
        entry = StoredProfile(name=name or key.slug(), profile=profile,
                              key=key, fit_r2=dict(fit_r2 or {}),
                              samples=dict(samples or {}),
                              comm_proxy=comm_proxy,
                              created_at=time.time())
        self.root.mkdir(parents=True, exist_ok=True)
        path = self._path(entry.name)
        tmp = path.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(entry.as_dict(), indent=1))
        os.replace(tmp, path)
        return entry

    def put_calibration(self, result, key: ProfileKey, *,
                        name: Optional[str] = None) -> StoredProfile:
        """Persist a ``microbench.CalibrationResult``."""
        return self.put(result.profile, key, name=name,
                        fit_r2=result.fit_r2,
                        samples={k: v.as_xt()
                                 for k, v in result.samples.items()},
                        comm_proxy=result.comm_is_proxy)

    # -- read -----------------------------------------------------------
    def names(self) -> List[str]:
        if not self.root.is_dir():
            return []
        out = []
        for p in sorted(self.root.glob("*.json")):
            try:
                d = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError):
                continue
            if int(d.get("schema", -1)) == SCHEMA_VERSION:
                out.append(str(d["name"]))
        return out

    def get(self, name: str) -> StoredProfile:
        path = self._path(name)
        if not path.is_file():
            raise KeyError(f"no stored profile {name!r} under {self.root} "
                           f"(have: {self.names()})")
        d = json.loads(path.read_text())
        if int(d.get("schema", -1)) != SCHEMA_VERSION:
            raise KeyError(f"stored profile {name!r} has schema "
                           f"{d.get('schema')!r}, expected {SCHEMA_VERSION} "
                           "— recalibrate")
        return StoredProfile.from_dict(d)

    def get_for_key(self, key: ProfileKey) -> StoredProfile:
        """Newest stored profile calibrated under exactly ``key``."""
        best: Optional[StoredProfile] = None
        for name in self.names():
            entry = self.get(name)
            if entry.key == key and (best is None
                                     or entry.created_at > best.created_at):
                best = entry
        if best is None:
            raise KeyError(f"no stored profile for {key} under {self.root}")
        return best

    def has(self, name: str) -> bool:
        try:
            self.get(name)
            return True
        except KeyError:
            return False

    def load_profile(self, name: str) -> HardwareProfile:
        return self.get(name).profile

    def __len__(self) -> int:
        return len(self.names())

    def __repr__(self) -> str:
        return f"ProfileStore(root={str(self.root)!r}, n={len(self)})"
