"""Unified observability layer: span tracing, executed-vs-scheduled
overlap attribution, and a metrics registry.

Three concerns, one package (DESIGN.md "Observability dataflow"):

  * ``trace``    — ``TraceRecorder``: host-side span enter/exit on a
                   monotonic clock. The engine opens phase spans around
                   its step phases, records request lifecycle spans
                   (submit -> admit -> first token -> finish), and scopes
                   an *active tracer* (contextvar) around its model calls
                   so the DEP executor's task walk emits one span per
                   ATTN/SHARED/GATE/A2E/EXP/E2A/REP task.
  * ``export``   — Chrome-trace/Perfetto JSON: executed spans and the
                   plan's ``ScheduleResult`` intervals as two aligned
                   track groups (predicted-vs-executed Gantt as a
                   loadable artifact), plus the schema validator CI runs.
  * ``overlap``  — reduce executed task spans to per-lane busy/idle and
                   exposed-comm time and diff them against the lowered
                   graph's schedule/``CostBreakdown`` (the "executed
                   overlap == scheduled overlap within eps" metric);
    ``replay``   — execute a scheduled graph for real on four host lanes
                   (worker threads, per-task fencing) so the attribution
                   has executed spans to chew on even without a TPU mesh.
  * ``metrics``  — ``Counter``/``Gauge``/``Histogram`` (fixed log-spaced
                   buckets, p50/p99) behind one ``MetricsRegistry`` that
                   every existing stat surface registers into: one
                   ``snapshot()`` dict, JSONL append export, Prometheus
                   text exposition, and one registry-level ``reset()``
                   that actually clears EWMA residual state everywhere.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               log_buckets, parse_prometheus)
from repro.obs.trace import (Span, TraceRecorder, active_tracer,
                             use_tracer)
from repro.obs.export import (chrome_trace, export_chrome_trace,
                              validate_chrome_trace)
from repro.obs.overlap import (LaneOccupancy, OverlapReport,
                               attribute_overlap, executed_exposed_comm,
                               interval_total, interval_subtract,
                               interval_union, lane_intervals)
from repro.obs.replay import ReplayResult, replay_schedule
from repro.obs.device import DeviceTrace, device_mesh, trace_dep_execution

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "log_buckets",
    "parse_prometheus",
    "Span", "TraceRecorder", "active_tracer", "use_tracer",
    "chrome_trace", "export_chrome_trace", "validate_chrome_trace",
    "LaneOccupancy", "OverlapReport", "attribute_overlap",
    "executed_exposed_comm", "interval_total", "interval_subtract",
    "interval_union", "lane_intervals",
    "ReplayResult", "replay_schedule",
    "DeviceTrace", "device_mesh", "trace_dep_execution",
]
