"""Chrome-trace / Perfetto JSON export of executed spans and scheduled
plans.

``chrome_trace`` renders two aligned track groups into one JSON object
in the Chrome Trace Event format (loadable in Perfetto / chrome://
tracing):

  * process ``scheduled``  — the plan's ``ScheduleResult`` intervals:
    one thread per resource lane (AG / A2E / EG / E2A), one complete
    ("X") event per task, tagged layer/mb/chunk. Modeled seconds map to
    trace microseconds at t=0.
  * process ``executed``   — a ``TraceRecorder``'s spans: one thread per
    span track (engine phases, per-lane executed tasks, per-request
    lifecycle rows), timestamps relative to the recorder's origin.

Loading both groups side by side IS the predicted-vs-executed Gantt the
overlap attributor quantifies.

``validate_chrome_trace`` is the schema gate CI runs on the artifact:
required keys per event, and per-track span sanity — events sorted by
timestamp must be disjoint or properly nested (stack discipline), which
is what makes the Perfetto rendering unambiguous.
"""
from __future__ import annotations

import json
from typing import Dict, List, Mapping, Optional, Tuple

from repro.obs.trace import TraceRecorder

#: fixed process ids for the two track groups
PID_EXECUTED = 1
PID_SCHEDULED = 2

_US = 1e6


def _meta(pid: int, tid: Optional[int], name_key: str, name: str) -> dict:
    ev = {"ph": "M", "pid": pid, "name": name_key,
          "args": {"name": name}}
    if tid is not None:
        ev["tid"] = tid
    return ev


class _TidMap:
    """Stable thread-id assignment per track name within one process."""

    def __init__(self, pid: int, events: List[dict]):
        self.pid = pid
        self.events = events
        self._tids: Dict[str, int] = {}

    def tid(self, track: str) -> int:
        t = self._tids.get(track)
        if t is None:
            t = len(self._tids)
            self._tids[track] = t
            self.events.append(_meta(self.pid, t, "thread_name", track))
        return t


def scheduled_events(result, events: Optional[List[dict]] = None,
                     pid: int = PID_SCHEDULED) -> List[dict]:
    """Complete events for a ``taskgraph.ScheduleResult``: one per task
    on its resource lane's thread, modeled seconds -> microseconds."""
    events = events if events is not None else []
    events.append(_meta(pid, None, "process_name", "scheduled"))
    tids = _TidMap(pid, events)
    for task, start, end in result.spans():
        events.append({
            "name": task.kind, "cat": "scheduled", "ph": "X",
            "ts": start * _US, "dur": (end - start) * _US,
            "pid": pid, "tid": tids.tid(task.resource),
            "args": {"kind": task.kind, "layer": task.layer,
                     "mb": task.mb, "chunk": task.chunk,
                     "lane": task.resource},
        })
    return events


def executed_events(tracer: TraceRecorder,
                    events: Optional[List[dict]] = None,
                    pid: int = PID_EXECUTED) -> List[dict]:
    """Complete events for a recorder's spans, one thread per track,
    timestamps relative to the recorder's origin."""
    events = events if events is not None else []
    events.append(_meta(pid, None, "process_name", "executed"))
    tids = _TidMap(pid, events)
    for s in tracer.spans:
        ev = {
            "name": s.name, "cat": s.cat, "ph": "X",
            "ts": (s.start - tracer.origin) * _US,
            "dur": s.duration * _US,
            "pid": pid, "tid": tids.tid(s.track),
            "args": dict(s.args),
        }
        if s.end == s.start and s.cat == "instant":
            ev["ph"] = "i"
            ev["s"] = "t"
            del ev["dur"]
        events.append(ev)
    return events


def chrome_trace(tracer: Optional[TraceRecorder] = None,
                 schedule=None,
                 meta: Optional[Mapping] = None) -> dict:
    """The full trace object: executed and/or scheduled track groups."""
    events: List[dict] = []
    if schedule is not None:
        scheduled_events(schedule, events)
    if tracer is not None:
        executed_events(tracer, events)
    obj = {"traceEvents": events, "displayTimeUnit": "ms"}
    if meta:
        obj["otherData"] = dict(meta)
    return obj


def export_chrome_trace(path, tracer: Optional[TraceRecorder] = None,
                        schedule=None,
                        meta: Optional[Mapping] = None) -> dict:
    """Write ``chrome_trace(...)`` to ``path``; returns the object."""
    obj = chrome_trace(tracer=tracer, schedule=schedule, meta=meta)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


# ---------------------------------------------------------------------------
# validation (the CI schema gate)
# ---------------------------------------------------------------------------

_X_REQUIRED = ("name", "ts", "dur", "pid", "tid")


def validate_chrome_trace(obj, eps_us: float = 0.5) -> Dict[str, int]:
    """Validate a trace object (or JSON string): top-level shape, the
    required keys per complete event, and per-(pid, tid) track
    discipline — spans sorted by start must be disjoint or properly
    nested; partial overlap within a track is a schema error. Returns
    counting stats; raises ValueError on any violation.

    ``eps_us`` absorbs float rounding at span edges (microseconds).
    """
    if isinstance(obj, (str, bytes)):
        obj = json.loads(obj)
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    tracks: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    n_complete = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"event {i}: not an object with 'ph'")
        ph = ev["ph"]
        if ph in ("M", "i", "I"):
            continue
        if ph != "X":
            raise ValueError(f"event {i}: unsupported phase {ph!r}")
        for k in _X_REQUIRED:
            if k not in ev:
                raise ValueError(f"event {i}: missing key {k!r}")
        ts, dur = float(ev["ts"]), float(ev["dur"])
        if dur < 0:
            raise ValueError(f"event {i}: negative duration {dur}")
        tracks.setdefault((ev["pid"], ev["tid"]), []).append((ts, ts + dur))
        n_complete += 1
    for (pid, tid), spans in tracks.items():
        spans.sort()
        stack: List[Tuple[float, float]] = []
        for s, e in spans:
            while stack and s >= stack[-1][1] - eps_us:
                stack.pop()
            if stack and e > stack[-1][1] + eps_us:
                raise ValueError(
                    f"track (pid={pid}, tid={tid}): span [{s}, {e}] "
                    f"partially overlaps [{stack[-1][0]}, {stack[-1][1]}]")
            stack.append((s, e))
    return {"events": len(events), "complete": n_complete,
            "tracks": len(tracks)}
