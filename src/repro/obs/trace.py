"""Host-side span tracing on a monotonic clock.

A ``Span`` is (name, track, cat, start, end, args); a ``TraceRecorder``
is an append-only list of them plus the clock that stamps them. Three
span families (taxonomy table in DESIGN.md):

  * ``cat="phase"``   engine step phases (admit / prefill_chunk /
                      decode_step), opened by the engine's host loop
                      around its already-fenced device calls — real
                      wall-clock intervals;
  * ``cat="task"``    one span per executed task (ATTN/SHARED/GATE/A2E/
                      EXP/E2A/REP), tagged kind/layer/mb/chunk/lane.
                      Two producers: the DEP executor's walk records the
                      op-*emission* of each task (``args["emit"]=True``
                      — trace-time, once per compiled program: the
                      executed program order, not durations), and
                      ``obs.replay`` records genuinely executed,
                      per-task-fenced spans the overlap attributor
                      reduces;
  * ``cat="request"`` request lifecycle segments (queued / prefill /
                      decode) reconstructed from the request's
                      timestamps when it finishes — TTFT/TPOT live here.

The *active tracer* is a context variable: the engine scopes it around
its model calls (``use_tracer``), and ``core.dep``'s walker asks
``active_tracer()`` per walk with zero coupling to the engine. With no
tracer set (or a disabled one) every hook is None/no-op and the executor
emits the exact same ops — tracing off compiles the identical program
(test-locked).

``fence=True`` opts into extra ``jax.block_until_ready`` fencing at
chunk boundaries (``maybe_fence``) so phase spans bound device work
instead of async dispatch; it is off by default because extra syncs cost
wall time (the compiled program is identical either way).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Span:
    """One traced interval. ``start``/``end`` are seconds on the
    recorder's clock (``end == start`` for instant events)."""

    name: str
    track: str
    start: float
    end: float
    cat: str = "phase"
    args: Tuple[Tuple[str, Any], ...] = ()

    @property
    def duration(self) -> float:
        return self.end - self.start

    def arg(self, key: str, default=None):
        for k, v in self.args:
            if k == key:
                return v
        return default


class TraceRecorder:
    """Append-only span sink on one monotonic clock.

    ``enabled=False`` turns every hook into a no-op (the engine keeps the
    object wired so flipping tracing on needs no re-plumbing).
    ``origin`` is the construction timestamp exports are made relative
    to, so multiple recorders/export groups align.
    """

    def __init__(self, enabled: bool = True, fence: bool = False,
                 clock=time.perf_counter):
        self.enabled = enabled
        self.fence = fence
        self.clock = clock
        self.origin = clock()
        self.spans: List[Span] = []
        self.dropped = 0

    # -- recording ------------------------------------------------------
    def add_span(self, name: str, track: str, start: float, end: float,
                 cat: str = "phase", **args) -> None:
        if not self.enabled:
            return
        self.spans.append(Span(name=name, track=track, start=start,
                               end=end, cat=cat,
                               args=tuple(sorted(args.items()))))

    @contextmanager
    def span(self, name: str, track: str = "engine", cat: str = "phase",
             **args):
        """Time a block: records one span on exit (even on error)."""
        if not self.enabled:
            yield self
            return
        t0 = self.clock()
        try:
            yield self
        finally:
            self.add_span(name, track, t0, self.clock(), cat=cat, **args)

    def instant(self, name: str, track: str = "engine",
                cat: str = "instant", **args) -> None:
        if not self.enabled:
            return
        t = self.clock()
        self.add_span(name, track, t, t, cat=cat, **args)

    def task_span(self, task, start: float, end: float,
                  emit: bool = False, **args) -> None:
        """One span for an executed (or emitted) IR task, tagged with the
        graph coordinates the overlap attributor groups by."""
        self.add_span(task.kind, task.resource, start, end, cat="task",
                      kind=task.kind, layer=task.layer, mb=task.mb,
                      chunk=task.chunk, lane=task.resource, emit=emit,
                      **args)

    def request_lifecycle(self, req, finish_t: Optional[float] = None
                          ) -> None:
        """Record a finished request's lifecycle segments from its
        timestamps: queued (submit -> admit), prefill (admit -> first
        token), decode (first token -> finish). Missing stamps collapse
        their segment."""
        if not self.enabled:
            return
        finish = finish_t if finish_t is not None else \
            (req.finish_t if req.finish_t is not None else self.clock())
        track = f"req-{req.request_id}"
        admit = req.admit_t if getattr(req, "admit_t", None) is not None \
            else finish
        first = req.first_token_t if req.first_token_t is not None \
            else finish
        rid = req.request_id
        state = getattr(req.state, "value", str(req.state))
        self.add_span("queued", track, req.arrival_t, admit,
                      cat="request", request_id=rid, state=state)
        if admit < first:
            self.add_span("prefill", track, admit, first, cat="request",
                          request_id=rid, state=state)
        if first < finish:
            self.add_span("decode", track, first, finish, cat="request",
                          request_id=rid, state=state,
                          tokens=len(req.output))

    # -- fencing --------------------------------------------------------
    def maybe_fence(self, x) -> None:
        """Opt-in chunk-boundary fence: block on ``x`` so the enclosing
        phase span bounds device work, not async dispatch. No-op unless
        this recorder was built with ``fence=True``."""
        if self.enabled and self.fence and x is not None:
            import jax
            jax.block_until_ready(x)

    # -- readers --------------------------------------------------------
    def task_spans(self, emitted: Optional[bool] = None) -> List[Span]:
        """Task-category spans; ``emitted`` filters trace-time emission
        records (True), executed spans (False), or returns both (None)."""
        out = [s for s in self.spans if s.cat == "task"]
        if emitted is None:
            return out
        return [s for s in out if bool(s.arg("emit")) == emitted]

    def by_cat(self, cat: str) -> List[Span]:
        return [s for s in self.spans if s.cat == cat]

    def clear(self) -> None:
        self.spans = []
        self.dropped = 0
        self.origin = self.clock()

    def __len__(self) -> int:
        return len(self.spans)

    def __repr__(self) -> str:
        cats: Dict[str, int] = {}
        for s in self.spans:
            cats[s.cat] = cats.get(s.cat, 0) + 1
        body = ", ".join(f"{k}={v}" for k, v in sorted(cats.items()))
        state = "on" if self.enabled else "off"
        return f"TraceRecorder({state}; {body or 'empty'})"


# ---------------------------------------------------------------------------
# active-tracer scoping (how the executor finds the engine's recorder)
# ---------------------------------------------------------------------------

_ACTIVE: ContextVar[Optional[TraceRecorder]] = ContextVar(
    "repro_obs_active_tracer", default=None)


def active_tracer() -> Optional[TraceRecorder]:
    """The recorder scoped by the innermost ``use_tracer`` (None when
    none is scoped or it is disabled) — what ``core.dep``'s task walk
    consults. Must stay cheap: it runs once per executor walk."""
    t = _ACTIVE.get()
    return t if (t is not None and t.enabled) else None


@contextmanager
def use_tracer(tracer: Optional[TraceRecorder]):
    """Scope ``tracer`` as the active tracer for the block (None scopes
    tracing OFF, shadowing any outer tracer)."""
    token = _ACTIVE.set(tracer)
    try:
        yield tracer
    finally:
        _ACTIVE.reset(token)
