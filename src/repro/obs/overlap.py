"""Executed-vs-scheduled overlap attribution.

The solver optimizes a *modeled* makespan; ROADMAP item 3's win claim is
"executed overlap == scheduled overlap within eps". This module computes
both sides of that equation from one pair of inputs:

  * executed: ``cat="task"`` spans from a ``TraceRecorder`` (produced by
    ``obs.replay`` on host lanes, or by any future on-device profiler
    that tags spans with the IR's kind/lane coordinates);
  * scheduled: the lowered graph's ``taskgraph.ScheduleResult``.

Reductions (same interval algebra as ``core.simulator``'s Table 7
metric, reimplemented here over spans):

  * per-lane busy / idle occupancy within the executed window;
  * exposed communication — link (A2E/E2A) busy while neither compute
    lane (AG/EG) runs — total and per comm lane;
  * per-primitive-class busy (gemm/attn/comm via ``KIND_CLASS``), the
    executed counterpart of the plan's ``CostBreakdown``.

``attribute_overlap`` diffs the two sides into an ``OverlapReport``.
Because a host replay runs time-scaled, the headline gap metric is the
difference of exposed-comm *fractions of makespan* (scale cancels);
absolute executed seconds are de-scaled for side-by-side reporting.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.taskgraph import (KIND_CLASS, RESOURCES, CostBreakdown,
                                  ScheduleResult)
from repro.obs.trace import Span

Interval = Tuple[float, float]

COMM_LANES = ("A2E", "E2A")
COMPUTE_LANES = ("AG", "EG")


# ---------------------------------------------------------------------------
# interval algebra
# ---------------------------------------------------------------------------


def interval_union(intervals: Iterable[Interval]) -> List[Interval]:
    """Merge overlapping/adjacent intervals into a disjoint sorted list."""
    out: List[Interval] = []
    for s, e in sorted((s, e) for s, e in intervals if e > s):
        if out and s <= out[-1][1]:
            if e > out[-1][1]:
                out[-1] = (out[-1][0], e)
        else:
            out.append((s, e))
    return out


def interval_subtract(a: Sequence[Interval],
                      b: Sequence[Interval]) -> List[Interval]:
    """``a - b`` for disjoint sorted interval lists (see
    ``interval_union``)."""
    out: List[Interval] = []
    j = 0
    for s, e in a:
        cur = s
        while j < len(b) and b[j][1] <= cur:
            j += 1
        k = j
        while k < len(b) and b[k][0] < e:
            bs, be = b[k]
            if bs > cur:
                out.append((cur, bs))
            cur = max(cur, be)
            if be >= e:
                break
            k += 1
        if cur < e:
            out.append((cur, e))
    return out


def interval_total(intervals: Iterable[Interval]) -> float:
    return sum(e - s for s, e in intervals)


# ---------------------------------------------------------------------------
# span reductions
# ---------------------------------------------------------------------------


def lane_intervals(spans: Iterable[Span]) -> Dict[str, List[Interval]]:
    """Executed task spans grouped into per-lane merged busy intervals
    (lane = the span's ``lane`` arg, falling back to its track)."""
    raw: Dict[str, List[Interval]] = {}
    for s in spans:
        lane = s.arg("lane", s.track)
        raw.setdefault(lane, []).append((s.start, s.end))
    return {lane: interval_union(iv) for lane, iv in raw.items()}


@dataclass(frozen=True)
class LaneOccupancy:
    """Busy/idle seconds of one lane within the executed window."""

    lane: str
    busy: float
    idle: float
    first: float
    last: float

    @property
    def utilization(self) -> float:
        span = self.busy + self.idle
        return self.busy / span if span > 0 else 0.0


def lane_occupancy(spans: Iterable[Span],
                   window: Optional[Interval] = None
                   ) -> Dict[str, LaneOccupancy]:
    """Per-lane busy/idle within ``window`` (default: first span start to
    last span end over ALL lanes, so idle includes waiting for other
    lanes)."""
    lanes = lane_intervals(spans)
    if not lanes:
        return {}
    if window is None:
        lo = min(iv[0][0] for iv in lanes.values() if iv)
        hi = max(iv[-1][1] for iv in lanes.values() if iv)
        window = (lo, hi)
    out = {}
    for lane, iv in lanes.items():
        busy = interval_total(iv)
        out[lane] = LaneOccupancy(
            lane=lane, busy=busy,
            idle=max(window[1] - window[0] - busy, 0.0),
            first=iv[0][0] if iv else window[0],
            last=iv[-1][1] if iv else window[0])
    return out


def executed_exposed_comm(spans: Iterable[Span]) -> Dict[str, float]:
    """Exposed-communication seconds from executed task spans: per comm
    lane and total, each = lane busy time not covered by any compute
    lane's busy time."""
    lanes = lane_intervals(spans)
    compute = interval_union(
        [iv for lane in COMPUTE_LANES for iv in lanes.get(lane, [])])
    out: Dict[str, float] = {}
    total = 0.0
    for lane in COMM_LANES:
        exp = interval_total(
            interval_subtract(lanes.get(lane, []), compute))
        out[lane] = exp
        total += exp
    out["total"] = total
    return out


def scheduled_exposed_comm(result: ScheduleResult) -> Dict[str, float]:
    """The modeled counterpart, from the schedule's per-lane intervals
    (same algebra as ``simulator.non_overlapped_comm_time``, here kept
    per comm lane)."""
    iv = result.intervals
    compute = interval_union(
        [x for lane in COMPUTE_LANES for x in iv.get(lane, [])])
    out: Dict[str, float] = {}
    total = 0.0
    for lane in COMM_LANES:
        exp = interval_total(
            interval_subtract(interval_union(iv.get(lane, [])), compute))
        out[lane] = exp
        total += exp
    out["total"] = total
    return out


def class_busy(spans: Iterable[Span]) -> Dict[str, float]:
    """Executed busy seconds per hardware-primitive class — the executed
    counterpart of ``CostBreakdown`` (sums span durations by the kind
    tag's ``KIND_CLASS``)."""
    out = {"gemm": 0.0, "attn": 0.0, "comm": 0.0}
    for s in spans:
        cls = KIND_CLASS.get(s.arg("kind", s.name))
        if cls is not None:
            out[cls] += s.duration
    return out


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------


@dataclass
class OverlapReport:
    """Executed vs scheduled, side by side. All executed seconds are
    de-scaled by the replay's ``time_scale`` so they are directly
    comparable to the modeled values; ``gap`` is the difference of
    exposed-comm fractions of makespan (dimensionless, scale-free):

        gap = | exposed_exec / makespan_exec
              - exposed_model / makespan_model |
    """

    makespan_modeled: float
    makespan_executed: float
    exposed_modeled: Dict[str, float]
    exposed_executed: Dict[str, float]
    busy_modeled: Dict[str, float]
    busy_executed: Dict[str, float]
    idle_executed: Dict[str, float]
    breakdown_modeled: CostBreakdown
    breakdown_executed: Dict[str, float] = field(default_factory=dict)
    time_scale: float = 1.0

    @property
    def exposed_frac_modeled(self) -> float:
        if self.makespan_modeled <= 0:
            return 0.0
        return self.exposed_modeled["total"] / self.makespan_modeled

    @property
    def exposed_frac_executed(self) -> float:
        if self.makespan_executed <= 0:
            return 0.0
        return self.exposed_executed["total"] / self.makespan_executed

    @property
    def gap(self) -> float:
        return abs(self.exposed_frac_executed - self.exposed_frac_modeled)

    def within(self, eps: float) -> bool:
        """The win-claim predicate: executed overlap matches scheduled
        overlap to ``eps`` (fraction of makespan)."""
        return self.gap <= eps

    def as_dict(self) -> Dict[str, float]:
        out = {
            "makespan_modeled_s": self.makespan_modeled,
            "makespan_executed_s": self.makespan_executed,
            "exposed_frac_modeled": self.exposed_frac_modeled,
            "exposed_frac_executed": self.exposed_frac_executed,
            "gap": self.gap,
            "time_scale": self.time_scale,
        }
        for lane in COMM_LANES + ("total",):
            out[f"exposed_modeled_{lane}_s"] = self.exposed_modeled[lane]
            out[f"exposed_executed_{lane}_s"] = self.exposed_executed[lane]
        for lane in RESOURCES:
            if lane in self.busy_executed:
                out[f"busy_executed_{lane}_s"] = self.busy_executed[lane]
                out[f"idle_executed_{lane}_s"] = self.idle_executed[lane]
            out[f"busy_modeled_{lane}_s"] = self.busy_modeled.get(lane, 0.0)
        for cls, v in self.breakdown_executed.items():
            out[f"busy_executed_{cls}_s"] = v
        for cls, v in self.breakdown_modeled.as_dict().items():
            out[f"busy_modeled_{cls}_s"] = v
        return out


def attribute_overlap(spans: Iterable[Span], result: ScheduleResult,
                      time_scale: float = 1.0) -> OverlapReport:
    """Reduce executed task ``spans`` and diff against the scheduled
    ``result``. ``time_scale`` is the replay's duration multiplier
    (executed seconds are divided by it for reporting; the gap metric is
    scale-free either way)."""
    spans = list(spans)
    occ = lane_occupancy(spans)
    exec_exposed = executed_exposed_comm(spans)
    makespan_exec = 0.0
    if occ:
        lo = min(o.first for o in occ.values())
        hi = max(o.last for o in occ.values())
        makespan_exec = hi - lo
    k = 1.0 / time_scale if time_scale > 0 else 1.0
    return OverlapReport(
        makespan_modeled=result.makespan,
        makespan_executed=makespan_exec * k,
        exposed_modeled=scheduled_exposed_comm(result),
        exposed_executed={lane: v * k for lane, v in exec_exposed.items()},
        busy_modeled=dict(result.busy),
        busy_executed={lane: o.busy * k for lane, o in occ.items()},
        idle_executed={lane: o.idle * k for lane, o in occ.items()},
        breakdown_modeled=result.breakdown(),
        breakdown_executed={cls: v * k
                            for cls, v in class_busy(spans).items()},
        time_scale=time_scale)
