"""Execute a scheduled task graph for real on host lanes.

The overlap attributor needs genuinely *executed* spans — real
wall-clock concurrency across the four resource lanes — but per-task
timing inside the jitted DEP step is impossible (the walker runs at
trace time) and CI has no TPU mesh. This module closes that gap: it
runs a ``ScheduleResult`` on one worker thread per resource lane
(AG / A2E / EG / E2A), honoring the IR's dependency edges with real
synchronization, and records one executed ``cat="task"`` span per task
into a ``TraceRecorder``.

Mechanics:

  * each lane thread serves its tasks in the graph's emission order
    (the same FIFO discipline the scheduler models);
  * every task owns a ``threading.Event`` set at completion; a task
    begins only after all its deps' events — cross-lane waits are real
    blocking waits, so overlap/serialization emerges from execution,
    not from replaying the modeled start times;
  * each task then occupies its lane for ``duration * time_scale``
    wall seconds (sleep for the bulk, spin the tail — ``time.sleep``
    releases the GIL, the short spin gives sub-ms edge accuracy);
  * ``payloads`` optionally maps a kind class to a thunk returning a
    jax value that is ``block_until_ready``-fenced inside the span, so
    the harness can also exercise real device dispatch per task.

Durations are time-scaled so the whole replay runs in a fraction of a
second regardless of the modeled makespan; ``attribute_overlap`` is
scale-free on its headline gap metric and de-scales absolute seconds.

Fidelity bound: the GIL serializes the *bookkeeping* between tasks but
not the sleeps, so with default scaling executed lane occupancy tracks
the model to a few percent of makespan — CI asserts a generous eps,
not equality (see DESIGN.md).
"""
from __future__ import annotations

import sys
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.taskgraph import (KIND_CLASS, RESOURCES, ScheduleResult,
                                  TaskCosts, TaskGraph, schedule)
from repro.obs.trace import Span, TraceRecorder

#: wall-clock length (seconds) a replay aims for when auto-scaling
DEFAULT_MAX_WALL_S = 0.4
#: never stretch a fast plan beyond this factor (keeps tiny graphs fast)
_MAX_SCALE = 1e7
#: spin (not sleep) the last stretch of each task for edge accuracy
_SPIN_TAIL_S = 2e-4


@dataclass
class ReplayResult:
    """Executed spans plus the schedule they replayed.

    ``spans`` are in recorder order (wall-clock seconds, SCALED — divide
    by ``time_scale`` to compare against the modeled schedule; the
    attributor does this). ``wall_s`` is the measured replay makespan.
    """

    spans: List[Span]
    scheduled: ScheduleResult
    time_scale: float
    wall_s: float


def _occupy_until(clock, deadline: float) -> None:
    """Hold the lane until ``deadline``: sleep the bulk (releases the
    GIL so other lanes run), spin the tail for edge accuracy."""
    while True:
        rem = deadline - clock()
        if rem <= 0:
            return
        if rem > _SPIN_TAIL_S:
            time.sleep(rem - _SPIN_TAIL_S)
        # tail: busy-wait
        while clock() < deadline:
            pass
        return


def replay_schedule(graph: TaskGraph, costs: TaskCosts, *,
                    tracer: Optional[TraceRecorder] = None,
                    time_scale: Optional[float] = None,
                    max_wall_s: float = DEFAULT_MAX_WALL_S,
                    payloads: Optional[Dict[str, Callable[[], object]]]
                    = None,
                    order: Optional[Sequence[int]] = None,
                    extra_deps: Optional[Dict[int, Tuple[int, ...]]]
                    = None) -> ReplayResult:
    """Schedule ``graph`` under ``costs`` and execute it on one worker
    thread per resource lane. Returns the executed spans alongside the
    schedule they should match.

    ``time_scale`` multiplies every modeled duration into wall seconds;
    by default it is chosen so the replay takes ~``max_wall_s``.
    ``payloads`` maps a ``KIND_CLASS`` value ("gemm"/"attn"/"comm") to a
    zero-arg callable whose jax result is fenced inside the task's span.

    ``order`` overrides the per-lane FIFO service order (a permutation
    of task indices; each lane serves its tasks in this order instead of
    emission order) and ``extra_deps`` adds dependency edges
    {task index: (must-complete-first indices, ...)} on top of the IR's.
    Together they realize ALTERNATE executors of the same graph — e.g.
    ``taskgraph.stream_major_order`` + ``taskgraph.stream_serial_deps``
    replay the sequential (non-interleaved) micro-batch walk so its
    executed overlap can be compared against the interleaved one. The
    returned ``scheduled`` is always the unconstrained schedule — the
    target the executed spans are attributed against.
    """
    sched = schedule(graph, costs)
    if time_scale is None:
        ms = sched.makespan
        time_scale = min(max_wall_s / ms, _MAX_SCALE) if ms > 0 else 1.0
    rec = tracer if tracer is not None else TraceRecorder()
    clock = rec.clock

    tasks = graph.tasks
    done = [threading.Event() for _ in tasks]
    by_lane: Dict[str, List[int]] = {r: [] for r in RESOURCES}
    service = order if order is not None else range(len(tasks))
    for i in service:
        by_lane[tasks[i].resource].append(i)
    extra = extra_deps or {}
    durs = costs.per_kind(graph)
    from repro.core.taskgraph import _KIND_IDX
    errors: List[BaseException] = []

    def lane_worker(lane: str) -> None:
        try:
            for i in by_lane[lane]:
                task = tasks[i]
                for d in task.deps:
                    done[d].wait()
                for d in extra.get(i, ()):
                    done[d].wait()
                t0 = clock()
                if payloads:
                    thunk = payloads.get(KIND_CLASS[task.kind])
                    if thunk is not None:
                        x = thunk()
                        if x is not None:
                            import jax
                            jax.block_until_ready(x)
                dur = durs[_KIND_IDX[task.kind]] * time_scale
                if dur > 0:
                    _occupy_until(clock, t0 + dur)
                rec.task_span(task, t0, clock(), emit=False)
                done[i].set()
        except BaseException as e:   # surface to caller, don't deadlock
            errors.append(e)
            for i in by_lane[lane]:
                done[i].set()

    # a short switch interval tightens cross-thread wakeup latency while
    # lanes hand off; restore the default afterwards
    old_switch = sys.getswitchinterval()
    sys.setswitchinterval(5e-5)
    t_start = clock()
    threads = [threading.Thread(target=lane_worker, args=(r,),
                                name=f"replay-{r}", daemon=True)
               for r in RESOURCES if by_lane[r]]
    try:
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    finally:
        sys.setswitchinterval(old_switch)
    if errors:
        raise errors[0]
    wall = clock() - t_start
    spans = [s for s in rec.task_spans(emitted=False)]
    return ReplayResult(spans=spans, scheduled=sched,
                        time_scale=time_scale, wall_s=wall)
