"""On-device executed task spans via fenced eager emission.

The host-lane replay (``obs.replay``) gives the attributor executed
spans without a mesh, but they are *synthetic* — modeled durations run
on worker threads. This module produces spans from the REAL executor:
it runs ``core.dep.moe_apply_dep`` eagerly (outside jit) on a device
mesh under a fence-enabled ``TraceRecorder``. Eager ``shard_map``
executes the walk per-primitive, and with ``fence=True`` the walker
blocks on each task's output (``maybe_fence``) before closing its span,
so every A2E/EXP/E2A/SHARED/GATE span bounds actual device work for
that chunk — the on-device trace ``benchmarks.table7_overlap
--executed`` consumes when a multi-device mesh is available.

Fidelity bound: fencing serializes the dispatch stream at every task
boundary, so cross-lane *overlap* is deliberately sacrificed for
per-task attribution accuracy — the spans order-check the executed
emission and cost-attribute per kind; the overlap claim itself is
gated on the dependency-faithful lane replay (``replay_schedule`` with
``stream_serial_deps``/``stream_major_order`` for the sequential arm).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.obs.trace import Span, TraceRecorder, use_tracer

#: capacity factor generous enough that the proxy layer drops nothing —
#: drops are routing noise the span trace should not depend on
_PROXY_CF = 8.0


def device_mesh(min_devices: int = 2):
    """A ("data", "model") mesh over the local devices, or None when the
    platform cannot host a DEP exchange (fewer than ``min_devices`` or
    an odd device count — the model axis takes 2, data the rest)."""
    n = jax.device_count()
    if n < min_devices or n % 2:
        return None
    return jax.make_mesh((n // 2, 2), ("data", "model"))


@dataclass
class DeviceTrace:
    """Executed spans from one eager fenced DEP layer run."""

    spans: List[Span]          # cat="task" spans in emission order
    out: object                # the layer output (already fenced)
    recorder: TraceRecorder
    wall_s: float


def trace_dep_execution(program, mesh, *, mode: str = "sequence",
                        d_model: int = 32,
                        mcfg: Optional[MoEConfig] = None,
                        dtype=jnp.float32, seed: int = 0) -> DeviceTrace:
    """Run one DEP MoE layer for real on ``mesh`` under ``program`` and
    return the fenced per-task spans.

    The layer is a scaled-down proxy (small d_model, generous capacity):
    the spans' *structure* — emission order, per-kind device cost, one
    span per (stream, chunk) task — is what the attribution consumes,
    and that is fixed by the program, not the layer width. ``mode``
    picks the dispatch path: "sequence" (tokens split over the model
    axis, chunked all_to_all) or "replicated" (decode-style S=1,
    local-expert slices + psum combine).
    """
    from repro.core.dep import moe_apply_dep
    from repro.models.moe import moe_init
    from repro.models.transformer import ExecutionContext

    mo = mesh.shape["model"]
    dp = mesh.size // mo
    E_pad = 2 * mo
    if mcfg is None:
        mcfg = MoEConfig(num_experts=E_pad, top_k=2,
                         expert_ffn_dim=2 * d_model,
                         num_shared_experts=1, shared_ffn_dim=d_model,
                         capacity_factor=_PROXY_CF)
    B = 2 * dp
    S = 4 * mo if mode == "sequence" else 1
    k_p, k_x = jax.random.split(jax.random.PRNGKey(seed))
    params = moe_init(k_p, d_model, mcfg, E_pad)
    params = jax.tree.map(lambda a: a.astype(dtype), params)
    x = jax.random.normal(k_x, (B, S, d_model), dtype)
    ctx = ExecutionContext(mesh=mesh, moe_impl="dep")

    rec = TraceRecorder(fence=True)
    t0 = rec.clock()
    with use_tracer(rec):
        # eager (outside jit): shard_map executes per-primitive, so the
        # walker's fenced spans time real device work per task
        out = moe_apply_dep(params, x, mcfg, ctx, E_pad, plan=program)
    jax.block_until_ready(out)
    wall = rec.clock() - t0
    return DeviceTrace(spans=rec.task_spans(emitted=True), out=out,
                       recorder=rec, wall_s=wall)
