"""Metrics primitives and the registry every stat surface feeds.

Before this module the repo had six disjoint stat surfaces
(``EngineStats``, ``StepTimer``, ``PlanCacheStats``, ``paging_summary``,
``ExpertLoadTracker``, drift/refresh counters) each with its own ad-hoc
dict shape. The ``MetricsRegistry`` unifies them behind three primitive
types and two export formats:

  * ``Counter``    monotone float; ``inc()``.
  * ``Gauge``      last-set float, or a pull callback (``fn=``) for
                   values that live elsewhere.
  * ``Histogram``  fixed log-spaced bucket boundaries (``log_buckets``)
                   with count/sum and p50/p99 summaries interpolated
                   within the owning bucket — bounded memory, no sample
                   retention, quantile error bounded by the bucket ratio.

Existing stat objects don't migrate onto the primitives; they register a
*source* — a zero-arg callable returning a flat ``{name: number}`` dict —
and the registry folds each source into every ``snapshot()`` under its
prefix. One ``snapshot()`` therefore sees the engine counters, plan-cache
accounting, telemetry residuals, paging occupancy, and expert-load skew
in a single namespace (metric-name table in DESIGN.md).

Exports:

  * ``snapshot()``            one flat dict (prometheus-style sample
                              names, ``name{label="v"}``);
  * ``export_jsonl(path)``    append one timestamped JSON line;
  * ``render_prometheus()``   text exposition format (HELP/TYPE lines,
                              escaped label values, cumulative histogram
                              buckets) — ``parse_prometheus`` is the
                              matching reference parser the tests and the
                              CI smoke scrape it back through.

``reset()`` is the one warmup boundary: it zeroes every counter and
histogram AND runs the registered reset hooks, so state that lives
outside the registry (``StepTimer`` EWMA residuals, expert-load EWMAs,
paging counters) is cleared in the same call — benchmark warmup can no
longer leak into post-reset drift or re-balance decisions.
"""
from __future__ import annotations

import json
import math
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

Number = float

# ---------------------------------------------------------------------------
# bucket layout
# ---------------------------------------------------------------------------


def log_buckets(lo: float = 1e-5, hi: float = 100.0,
                per_decade: int = 3) -> Tuple[float, ...]:
    """Fixed log-spaced bucket boundaries from ``lo`` to at least ``hi``
    with ``per_decade`` boundaries per decade. The default (1e-5 s ..
    1e2 s) spans microbenchmark primitives to whole-benchmark walls with
    a ~2.15x ratio between adjacent boundaries."""
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
    if per_decade < 1:
        raise ValueError("per_decade must be >= 1")
    n = math.ceil(round(math.log10(hi / lo) * per_decade, 9)) + 1
    return tuple(lo * 10.0 ** (i / per_decade) for i in range(n))


DEFAULT_BUCKETS = log_buckets()


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


@dataclass
class Counter:
    """Monotone event counter."""

    name: str
    help: str = ""
    labels: Tuple[Tuple[str, str], ...] = ()
    value: float = 0.0
    kind: str = "counter"

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        self.value += n

    def reset(self) -> None:
        self.value = 0.0


@dataclass
class Gauge:
    """Last-set value, or a pull callback for externally-owned state."""

    name: str
    help: str = ""
    labels: Tuple[Tuple[str, str], ...] = ()
    fn: Optional[Callable[[], float]] = None
    _value: float = 0.0
    kind: str = "gauge"

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        if self.fn is not None:
            return float(self.fn())
        return self._value

    def reset(self) -> None:
        # callback gauges mirror external state; nothing to clear here
        if self.fn is None:
            self._value = 0.0


@dataclass
class Histogram:
    """Fixed-boundary histogram with interpolated quantile summaries.

    ``bucket_counts[i]`` counts observations v with
    ``buckets[i-1] < v <= buckets[i]`` (``i == 0``: ``v <= buckets[0]``);
    the final slot counts the overflow ``v > buckets[-1]``. ``quantile``
    walks the cumulative counts and interpolates inside the owning bucket
    (geometrically, matching the log-spaced layout), so its error is
    bounded by one bucket ratio — test-locked against numpy quantiles.
    """

    name: str
    help: str = ""
    labels: Tuple[Tuple[str, str], ...] = ()
    buckets: Tuple[float, ...] = DEFAULT_BUCKETS
    bucket_counts: List[int] = field(default_factory=list)
    count: int = 0
    sum: float = 0.0
    kind: str = "histogram"

    def __post_init__(self) -> None:
        if list(self.buckets) != sorted(self.buckets) or not self.buckets:
            raise ValueError("bucket boundaries must be sorted, non-empty")
        if not self.bucket_counts:
            self.bucket_counts = [0] * (len(self.buckets) + 1)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.bucket_counts[self._bucket_index(v)] += 1

    def _bucket_index(self, v: float) -> int:
        import bisect
        return bisect.bisect_left(self.buckets, v)

    def quantile(self, q: float) -> Optional[float]:
        """Value at quantile ``q`` in [0, 1] (None when empty)."""
        if self.count == 0:
            return None
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        target = q * self.count
        cum = 0.0
        for i, c in enumerate(self.bucket_counts):
            if c == 0:
                continue
            prev = cum
            cum += c
            if cum >= target:
                if i >= len(self.buckets):       # overflow: clamp
                    return self.buckets[-1]
                hi = self.buckets[i]
                lo = self.buckets[i - 1] if i > 0 else 0.0
                frac = min(max((target - prev) / c, 0.0), 1.0)
                if lo > 0.0:
                    return lo * (hi / lo) ** frac     # geometric interp
                return lo + (hi - lo) * frac
        return self.buckets[-1]

    @property
    def p50(self) -> Optional[float]:
        return self.quantile(0.50)

    @property
    def p99(self) -> Optional[float]:
        return self.quantile(0.99)

    def reset(self) -> None:
        self.bucket_counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0


# ---------------------------------------------------------------------------
# name / label formatting (prometheus exposition conventions)
# ---------------------------------------------------------------------------


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def sample_name(name: str, labels: Mapping[str, str] = ()) -> str:
    """``name{k="v",...}`` with exposition-format label escaping — the
    key format ``snapshot()`` and the JSONL export use."""
    items = sorted(dict(labels).items()) if labels else []
    if not items:
        return name
    body = ",".join(f'{k}="{_escape_label(str(v))}"' for k, v in items)
    return f"{name}{{{body}}}"


def parse_prometheus(text: str) -> List[Tuple[str, Dict[str, str], float]]:
    """Reference parser for the text exposition format: returns
    ``(name, labels, value)`` samples, skipping comments/blank lines.
    Handles escaped quotes/backslashes/newlines in label values; raises
    ValueError on malformed lines (CI scrapes ``render_prometheus()``
    through this)."""
    out: List[Tuple[str, Dict[str, str], float]] = []
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        try:
            out.append(_parse_sample_line(line))
        except Exception as e:
            raise ValueError(f"line {lineno}: {line!r}: {e}") from e
    return out


def _parse_sample_line(line: str) -> Tuple[str, Dict[str, str], float]:
    i = 0
    n = len(line)
    while i < n and (line[i].isalnum() or line[i] in "_:"):
        i += 1
    name = line[:i]
    if not name:
        raise ValueError("missing metric name")
    labels: Dict[str, str] = {}
    if i < n and line[i] == "{":
        i += 1
        while True:
            while i < n and line[i] in ", ":
                i += 1
            if i < n and line[i] == "}":
                i += 1
                break
            j = i
            while j < n and line[j] not in "=":
                j += 1
            key = line[i:j].strip()
            if j >= n or not key:
                raise ValueError("malformed label")
            i = j + 1
            if i >= n or line[i] != '"':
                raise ValueError("label value must be quoted")
            i += 1
            buf = []
            while i < n and line[i] != '"':
                c = line[i]
                if c == "\\":
                    i += 1
                    esc = line[i] if i < n else ""
                    buf.append({"n": "\n", '"': '"', "\\": "\\"}.get(
                        esc, "\\" + esc))
                else:
                    buf.append(c)
                i += 1
            if i >= n:
                raise ValueError("unterminated label value")
            i += 1                                    # closing quote
            labels[key] = "".join(buf)
    value = float(line[i:].split()[0])
    return name, labels, value


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_Metric = (Counter, Gauge, Histogram)


class MetricsRegistry:
    """One namespace over every metric and stat surface.

    ``counter``/``gauge``/``histogram`` create-or-return by
    ``(name, labels)`` identity (same name with different label sets is
    one family, prometheus-style). ``register_source(prefix, fn)``
    attaches a pull-based surface: ``fn()`` returns a flat numeric dict
    folded into every ``snapshot()`` as ``{prefix}_{key}`` gauges.
    ``register_reset(fn)`` attaches external state to the registry-level
    ``reset()``.
    """

    def __init__(self) -> None:
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                            object] = {}
        self._sources: List[Tuple[str, Callable[[], Mapping[str, float]]]] \
            = []
        self._reset_hooks: List[Callable[[], None]] = []
        self._lock = threading.Lock()

    # -- construction ---------------------------------------------------
    def _get(self, cls, name: str, help: str,
             labels: Optional[Mapping[str, str]], **kw):
        key = (name, tuple(sorted((labels or {}).items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name=name, help=help, labels=key[1], **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"{name} already registered as {type(m).__name__}")
            return m

    def counter(self, name: str, help: str = "",
                labels: Optional[Mapping[str, str]] = None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Mapping[str, str]] = None,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get(Gauge, name, help, labels)
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Mapping[str, str]] = None,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        kw = {"buckets": tuple(buckets)} if buckets is not None else {}
        return self._get(Histogram, name, help, labels, **kw)

    def register_source(self, prefix: str,
                        fn: Callable[[], Mapping[str, float]]) -> None:
        self._sources.append((prefix, fn))

    def register_reset(self, fn: Callable[[], None]) -> None:
        self._reset_hooks.append(fn)

    def metrics(self) -> List[object]:
        return list(self._metrics.values())

    # -- the one reset --------------------------------------------------
    def reset(self) -> None:
        """Zero every counter/histogram/set-gauge AND run the registered
        reset hooks — the single warmup boundary. Stat surfaces whose
        state lives outside the registry (StepTimer EWMAs, expert-load
        EWMAs, paging counters, EngineStats) clear in the same call."""
        for m in self.metrics():
            m.reset()
        for fn in self._reset_hooks:
            fn()

    # -- export ---------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """One flat ``{sample_name: value}`` dict over every metric and
        source. Histograms contribute ``_count``/``_sum``/``_p50``/
        ``_p99`` samples; source values that are None/non-numeric are
        skipped."""
        out: Dict[str, float] = {}
        for m in self.metrics():
            labels = dict(m.labels)
            if isinstance(m, Histogram):
                out[sample_name(m.name + "_count", labels)] = float(m.count)
                out[sample_name(m.name + "_sum", labels)] = m.sum
                for q, tag in ((0.50, "_p50"), (0.99, "_p99")):
                    v = m.quantile(q)
                    if v is not None:
                        out[sample_name(m.name + tag, labels)] = v
            else:
                out[sample_name(m.name, labels)] = float(m.value)
        for prefix, fn in self._sources:
            try:
                vals = fn()
            except Exception:
                continue                     # a dead source never breaks
            for k, v in dict(vals).items():  # the whole snapshot
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                if not math.isfinite(float(v)):
                    continue
                out[f"{prefix}_{k}"] = float(v)
        return out

    def export_jsonl(self, path, extra: Optional[Mapping] = None) -> dict:
        """Append one timestamped snapshot line to ``path`` (JSONL)."""
        rec = {"ts": time.time(), "metrics": self.snapshot()}
        if extra:
            rec.update(extra)
        with open(path, "a") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
        return rec

    def render_prometheus(self) -> str:
        """Prometheus text exposition of every metric and source."""
        lines: List[str] = []
        seen_family: set = set()

        def family(name: str, kind: str, help: str) -> None:
            if name in seen_family:
                return
            seen_family.add(name)
            if help:
                lines.append(f"# HELP {name} {_escape_help(help)}")
            lines.append(f"# TYPE {name} {kind}")

        for m in self.metrics():
            labels = dict(m.labels)
            if isinstance(m, Histogram):
                family(m.name, "histogram", m.help)
                cum = 0
                for b, c in zip(m.buckets, m.bucket_counts):
                    cum += c
                    lab = dict(labels, le=f"{b:g}")
                    lines.append(
                        f"{sample_name(m.name + '_bucket', lab)} {cum}")
                lab = dict(labels, le="+Inf")
                lines.append(
                    f"{sample_name(m.name + '_bucket', lab)} {m.count}")
                lines.append(f"{sample_name(m.name + '_sum', labels)} "
                             f"{m.sum!r}")
                lines.append(f"{sample_name(m.name + '_count', labels)} "
                             f"{m.count}")
            else:
                family(m.name, m.kind, m.help)
                lines.append(f"{sample_name(m.name, labels)} "
                             f"{float(m.value)!r}")
        for prefix, fn in self._sources:
            try:
                vals = dict(fn())
            except Exception:
                continue
            for k, v in vals.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)) \
                        or not math.isfinite(float(v)):
                    continue
                name = f"{prefix}_{k}"
                family(name, "gauge", "")
                lines.append(f"{name} {float(v)!r}")
        return "\n".join(lines) + "\n"
