"""Occupancy summaries: the decode-side scheduling shape.

The serving engine used to resolve decode plans on a coarse proxy —
(max_context bucket, live-slot count) — so the solver never saw the batch
it actually executed. ``OccupancySummary`` is the real composition, backed
by the ``KVCacheManager`` ledger: the number of live decode slots plus a
histogram of their context lengths, bucketed so recurring compositions
hash to the same plan-cache key.

The summary is frozen/ordered, so it can key a ``PlanCache`` entry and be
sorted for reporting.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Tuple

DEFAULT_BUCKETS = (64, 128, 256, 512, 1024, 2048, 4096)


def bucket_length(n: int, buckets: Tuple[int, ...] = DEFAULT_BUCKETS) -> int:
    """Round ``n`` up to a scheduling bucket (multiples of the largest
    bucket beyond the table)."""
    for b in buckets:
        if n <= b:
            return b
    top = buckets[-1]
    return ((n + top - 1) // top) * top


@dataclass(frozen=True, order=True)
class OccupancySummary:
    """Live decode-batch composition: ``live`` slots whose context lengths
    fall into ``hist`` — a sorted tuple of (context_bucket, num_slots)."""

    live: int
    hist: Tuple[Tuple[int, int], ...] = ()
    #: paged-KV pool pressure (fraction of pages pinned by live refs);
    #: 0.0 under the dense layout. Excluded from eq/hash/order so
    #: recurring compositions still share one plan-cache entry — pressure
    #: informs ADMISSION, not the decode plan.
    block_pressure: float = field(default=0.0, compare=False)

    @classmethod
    def from_lengths(cls, lengths: Iterable[int], *, max_bucket: int = 0,
                     block_pressure: float = 0.0) -> "OccupancySummary":
        counts: dict = {}
        n = 0
        for length in lengths:
            b = bucket_length(max(int(length), 1))
            if max_bucket:
                b = min(b, max_bucket)
            counts[b] = counts.get(b, 0) + 1
            n += 1
        return cls(live=n, hist=tuple(sorted(counts.items())),
                   block_pressure=block_pressure)

    @property
    def tokens(self) -> int:
        """Upper bound on live context tokens (sum of bucketed lengths)."""
        return sum(b * c for b, c in self.hist)

    @property
    def max_bucket(self) -> int:
        return max((b for b, _ in self.hist), default=bucket_length(1))

    @property
    def mean_context(self) -> float:
        """Occupancy-weighted mean of the bucketed context lengths — the
        per-sample KV positions a ragged decode step actually streams."""
        if not self.live:
            return 0.0
        return self.tokens / self.live

    @property
    def std_context(self) -> float:
        """Dispersion of the bucketed context lengths: how well the mean
        represents the composition (heterogeneous batches have rows far
        from the mean; the decode cost model widens its context estimate
        by the standard error)."""
        if not self.live:
            return 0.0
        m = self.mean_context
        var = sum(c * (b - m) ** 2 for b, c in self.hist) / self.live
        return math.sqrt(max(var, 0.0))

    @property
    def seq_bucket(self) -> int:
        """Representative per-sample context: occupancy-weighted mean of
        the bucketed lengths, re-bucketed. This is what a decode solve
        uses as its sequence length."""
        n = sum(c for _, c in self.hist)
        if n == 0:
            return bucket_length(1)
        return bucket_length(math.ceil(self.tokens / n))

    def __repr__(self) -> str:
        h = ",".join(f"{b}:{c}" for b, c in self.hist)
        return f"Occupancy(live={self.live}, hist=[{h}])"
