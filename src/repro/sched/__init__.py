"""First-class scheduling layer: pluggable policies + per-shape plan cache.

``SchedulePolicy.resolve(phase, seq_bucket, batch_per_device,
occupancy=...) -> Plan`` is the single interface through which the engine,
the DEP executor, the benchmarks and the examples obtain schedules;
``PlanCache`` memoizes resolved plans per shape (prefill buckets) or per
``OccupancySummary`` (decode solved on the real live-slot composition) so
steady-state decode pays ~zero solver cost.
"""
from repro.sched.cache import EntryMeta, PlanCache, PlanCacheStats, PlanKey
from repro.sched.occupancy import (DEFAULT_BUCKETS, OccupancySummary,
                                   bucket_length)
from repro.sched.policy import (EPSPipelinePolicy, FinDEPPolicy, POLICIES,
                                SchedulePolicy, SequentialDEPPolicy,
                                StaticPolicy, make_policy)

__all__ = [
    "PlanCache", "PlanCacheStats", "PlanKey", "EntryMeta", "SchedulePolicy",
    "FinDEPPolicy", "StaticPolicy", "SequentialDEPPolicy",
    "EPSPipelinePolicy", "POLICIES", "make_policy",
    "OccupancySummary", "DEFAULT_BUCKETS", "bucket_length",
]
