"""First-class scheduling layer: pluggable policies + per-shape plan cache.

``SchedulePolicy.resolve(phase, seq_bucket, batch_per_device) -> Plan`` is
the single interface through which the engine, the DEP executor, the
benchmarks and the examples obtain schedules; ``PlanCache`` memoizes
resolved plans per shape so steady-state decode pays ~zero solver cost.
"""
from repro.sched.cache import PlanCache, PlanCacheStats, PlanKey
from repro.sched.policy import (EPSPipelinePolicy, FinDEPPolicy, POLICIES,
                                SchedulePolicy, SequentialDEPPolicy,
                                StaticPolicy, make_policy)

__all__ = [
    "PlanCache", "PlanCacheStats", "PlanKey", "SchedulePolicy",
    "FinDEPPolicy", "StaticPolicy", "SequentialDEPPolicy",
    "EPSPipelinePolicy", "POLICIES", "make_policy",
]
