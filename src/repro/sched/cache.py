"""Per-shape plan cache for the scheduling layer.

The paper's online phase re-solves (m_a, r1, r2, order) on every batch
arrival (Fig. 6); in a serving loop the same execution shape recurs
thousands of times, so the engine memoizes resolved ``Plan``s here.
A hit costs a dict lookup (~100 ns); a miss invokes the policy's solver
(Algorithm 1, typically < 10 ms) and records its latency, so decode steps
pay ~zero scheduling cost while genuine shape changes still re-solve.

Two key spaces coexist:

  * shape keys ``(phase, seq_bucket, batch_per_device)`` — the prefill
    surface (a padded bucket IS the executed shape) and the legacy decode
    proxy;
  * occupancy keys ``(phase, OccupancySummary)`` — decode plans solved on
    the real live-slot composition from the KV ledger.

Policies that predate the ``occupancy=`` argument are still served: the
cache detects the old ``resolve(phase, seq_bucket, batch)`` signature and
falls back to it (with a DeprecationWarning) by projecting the summary
onto its (seq_bucket, live) shape.
"""
from __future__ import annotations

import inspect
import time
import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

from repro.core.solver import Plan
from repro.sched.occupancy import OccupancySummary

# ("prefill"|"decode"|custom, seq_bucket, batch_per_device) for shape keys,
# or (phase, OccupancySummary) for occupancy-resolved decode plans.
PlanKey = Union[Tuple[str, int, Optional[int]],
                Tuple[str, OccupancySummary]]


@dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    solve_time_total: float = 0.0   # seconds spent inside policy.resolve
    solve_time_last: float = 0.0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self):
        return dict(hits=self.hits, misses=self.misses,
                    hit_rate=self.hit_rate,
                    solve_time_total=self.solve_time_total,
                    solve_time_last=self.solve_time_last)


def _takes_occupancy(policy) -> bool:
    try:
        return "occupancy" in inspect.signature(policy.resolve).parameters
    except (TypeError, ValueError):    # builtins / exotic callables
        return True


class PlanCache:
    """Memoizes ``policy.resolve`` per execution shape.

    The cache is the component that replaces the old static
    ``ExecutionContext.plan``: instead of one plan frozen at engine
    construction, every distinct execution shape owns one cached plan.

    Layering note: planner-backed policies keep their own memo inside
    ``FinDEPPlanner`` (keyed without ``phase``; relied on by offline
    callers). A miss here therefore means "the policy was consulted", not
    necessarily "Algorithm 1 ran" — ``solve_time_*`` records the actual
    resolve latency either way, and planner-level solves are counted in
    ``FinDEPPlanner.solve_count``.
    """

    def __init__(self, policy):
        self.policy = policy
        self._plans: Dict[PlanKey, Plan] = {}
        self.stats = PlanCacheStats()
        self._occupancy_aware = _takes_occupancy(policy)

    def get(self, phase: str, seq_bucket: Optional[int] = None,
            batch_per_device: Optional[int] = None, *,
            occupancy: Optional[OccupancySummary] = None) -> Plan:
        if occupancy is not None:
            key: PlanKey = (phase, occupancy)
        else:
            if seq_bucket is None:
                raise ValueError("PlanCache.get needs seq_bucket or "
                                 "occupancy")
            key = (phase, int(seq_bucket), batch_per_device)
        plan = self._plans.get(key)
        if plan is not None:
            self.stats.hits += 1
            return plan
        t0 = time.perf_counter()
        plan = self._resolve(phase, seq_bucket, batch_per_device, occupancy)
        dt = time.perf_counter() - t0
        self.stats.misses += 1
        self.stats.solve_time_last = dt
        self.stats.solve_time_total += dt
        self._plans[key] = plan
        return plan

    def _resolve(self, phase, seq_bucket, batch_per_device, occupancy):
        if occupancy is None:
            return self.policy.resolve(phase, seq_bucket, batch_per_device)
        if self._occupancy_aware:
            return self.policy.resolve(phase, seq_bucket, batch_per_device,
                                       occupancy=occupancy)
        warnings.warn(
            f"policy {getattr(self.policy, 'name', self.policy)!r} has a "
            "legacy resolve(phase, seq_bucket, batch) signature; occupancy "
            "summaries are projected onto (seq_bucket, live). Add an "
            "occupancy= keyword to resolve() to schedule on the real "
            "composition.", DeprecationWarning, stacklevel=3)
        return self.policy.resolve(
            phase, seq_bucket if seq_bucket is not None
            else occupancy.seq_bucket,
            batch_per_device if batch_per_device is not None
            else occupancy.live)

    def entries(self) -> Dict[PlanKey, Plan]:
        return dict(self._plans)

    def distinct_plans(self):
        """Unique resolved plans (Plan is a frozen dataclass => hashable)."""
        return set(self._plans.values())

    def clear(self) -> None:
        self._plans.clear()
        self.stats = PlanCacheStats()

    def __len__(self) -> int:
        return len(self._plans)

    def __repr__(self) -> str:
        s = self.stats
        return (f"PlanCache(policy={getattr(self.policy, 'name', '?')}, "
                f"entries={len(self)}, hits={s.hits}, misses={s.misses}, "
                f"solve_total={s.solve_time_total * 1e3:.1f}ms)")
