"""Per-shape plan cache for the scheduling layer.

The paper's online phase re-solves (m_a, r1, r2, order) on every batch
arrival (Fig. 6); in a serving loop the same (phase, bucket, batch) shape
recurs thousands of times, so the engine memoizes resolved ``Plan``s here.
A hit costs a dict lookup (~100 ns); a miss invokes the policy's solver
(Algorithm 1, typically < 10 ms) and records its latency, so decode steps
pay ~zero scheduling cost while genuine shape changes still re-solve.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.solver import Plan

# (phase, seq_bucket, batch_per_device); phase is "prefill" | "decode"
# (free-form strings are allowed for custom pipelines).
PlanKey = Tuple[str, int, Optional[int]]


@dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    solve_time_total: float = 0.0   # seconds spent inside policy.resolve
    solve_time_last: float = 0.0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self):
        return dict(hits=self.hits, misses=self.misses,
                    hit_rate=self.hit_rate,
                    solve_time_total=self.solve_time_total,
                    solve_time_last=self.solve_time_last)


class PlanCache:
    """Memoizes ``policy.resolve`` per (phase, seq_bucket, batch_per_device).

    The cache is the component that replaces the old static
    ``ExecutionContext.plan``: instead of one plan frozen at engine
    construction, every distinct execution shape owns one cached plan.

    Layering note: planner-backed policies keep their own memo inside
    ``FinDEPPlanner`` (keyed without ``phase``; relied on by offline
    callers). A miss here therefore means "the policy was consulted", not
    necessarily "Algorithm 1 ran" — ``solve_time_*`` records the actual
    resolve latency either way, and planner-level solves are counted in
    ``FinDEPPlanner.solve_count``.
    """

    def __init__(self, policy):
        self.policy = policy
        self._plans: Dict[PlanKey, Plan] = {}
        self.stats = PlanCacheStats()

    def get(self, phase: str, seq_bucket: int,
            batch_per_device: Optional[int] = None) -> Plan:
        key: PlanKey = (phase, int(seq_bucket), batch_per_device)
        plan = self._plans.get(key)
        if plan is not None:
            self.stats.hits += 1
            return plan
        t0 = time.perf_counter()
        plan = self.policy.resolve(phase, seq_bucket, batch_per_device)
        dt = time.perf_counter() - t0
        self.stats.misses += 1
        self.stats.solve_time_last = dt
        self.stats.solve_time_total += dt
        self._plans[key] = plan
        return plan

    def entries(self) -> Dict[PlanKey, Plan]:
        return dict(self._plans)

    def distinct_plans(self):
        """Unique resolved plans (Plan is a frozen dataclass => hashable)."""
        return set(self._plans.values())

    def clear(self) -> None:
        self._plans.clear()
        self.stats = PlanCacheStats()

    def __len__(self) -> int:
        return len(self._plans)

    def __repr__(self) -> str:
        s = self.stats
        return (f"PlanCache(policy={getattr(self.policy, 'name', '?')}, "
                f"entries={len(self)}, hits={s.hits}, misses={s.misses}, "
                f"solve_total={s.solve_time_total * 1e3:.1f}ms)")
