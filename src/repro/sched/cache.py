"""Per-shape plan cache for the scheduling layer.

The paper's online phase re-solves (m_a, r1, r2, order) on every batch
arrival (Fig. 6); in a serving loop the same execution shape recurs
thousands of times, so the engine memoizes resolved ``Plan``s here.
A hit costs a dict lookup (~100 ns); a miss invokes the policy's solver
(Algorithm 1, typically < 10 ms) and records its latency, so decode steps
pay ~zero scheduling cost while genuine shape changes still re-solve.

Two key spaces coexist:

  * shape keys ``(phase, seq_bucket, batch_per_device)`` — the prefill
    surface (a padded bucket IS the executed shape) and the legacy decode
    proxy;
  * occupancy keys ``(phase, OccupancySummary)`` — decode plans solved on
    the real live-slot composition from the KV ledger.

Policies that predate the ``occupancy=`` argument are still served: the
cache detects the old ``resolve(phase, seq_bucket, batch)`` signature and
falls back to it (with a DeprecationWarning) by projecting the summary
onto its (seq_bucket, live) shape.

Beyond memoization the cache is the refresh surface of the profiling
subsystem (``repro.profiling``):

  * ``capacity=`` bounds the entry count with cost-aware eviction — the
    victim is the entry with the lowest hit-count x solve-latency score
    (cheap-to-resolve, rarely-reused shapes go first), LRU tie-break;
  * ``invalidate(key)`` drops one entry so the next lookup re-solves;
  * ``refresh(key)`` re-resolves one entry IN PLACE: the stale plan keeps
    serving every lookup until the replacement is computed, which is what
    lets drift-triggered re-solves run off the critical path.
"""
from __future__ import annotations

import inspect
import time
import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

from repro.core.solver import Plan
from repro.placement import SkewSummary
from repro.sched.occupancy import OccupancySummary

# ("prefill"|"decode"|custom, seq_bucket, batch_per_device) for shape keys,
# or (phase, OccupancySummary) for occupancy-resolved decode plans. Either
# form is suffixed with a SkewSummary when the engine resolves under
# observed non-uniform routing skew — the summary carries the placement
# epoch, so a re-balance (epoch bump) keys NEW entries and the engine
# invalidates the stale ones.
PlanKey = Union[Tuple[str, int, Optional[int]],
                Tuple[str, OccupancySummary],
                Tuple[str, int, Optional[int], SkewSummary],
                Tuple[str, OccupancySummary, SkewSummary]]


@dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    solve_time_total: float = 0.0   # seconds spent inside policy.resolve
    solve_time_last: float = 0.0
    evictions: int = 0              # capacity-pressure removals
    invalidations: int = 0          # explicit invalidate() calls
    refreshes: int = 0              # in-place re-solves (drift refresh)

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self):
        return dict(hits=self.hits, misses=self.misses,
                    hit_rate=self.hit_rate,
                    solve_time_total=self.solve_time_total,
                    solve_time_last=self.solve_time_last,
                    evictions=self.evictions,
                    invalidations=self.invalidations,
                    refreshes=self.refreshes)


@dataclass
class EntryMeta:
    """Per-entry bookkeeping driving cost-aware eviction."""

    hits: int = 0
    solve_s: float = 0.0
    last_used: int = 0          # monotonic lookup tick (LRU tie-break)

    @property
    def score(self) -> float:
        """Cost-aware retention value: hit-count x solve-latency. An
        entry that was expensive to solve AND gets reused is worth
        keeping; either factor at zero makes it the cheapest victim."""
        return self.hits * self.solve_s


def _takes_kwarg(policy, kwarg: str) -> bool:
    try:
        return kwarg in inspect.signature(policy.resolve).parameters
    except (TypeError, ValueError):    # builtins / exotic callables
        return True


def _takes_occupancy(policy) -> bool:
    return _takes_kwarg(policy, "occupancy")


class PlanCache:
    """Memoizes ``policy.resolve`` per execution shape.

    The cache is the component that replaces the old static
    ``ExecutionContext.plan``: instead of one plan frozen at engine
    construction, every distinct execution shape owns one cached plan.

    Layering note: planner-backed policies keep their own memo inside
    ``FinDEPPlanner`` (keyed without ``phase``; relied on by offline
    callers). A miss here therefore means "the policy was consulted", not
    necessarily "Algorithm 1 ran" — ``solve_time_*`` records the actual
    resolve latency either way, and planner-level solves are counted in
    ``FinDEPPlanner.solve_count``.
    """

    def __init__(self, policy, capacity: Optional[int] = None):
        assert capacity is None or capacity >= 1
        self.policy = policy
        self.capacity = capacity
        self._plans: Dict[PlanKey, Plan] = {}
        self._meta: Dict[PlanKey, EntryMeta] = {}
        self._tick = 0
        self.stats = PlanCacheStats()
        self._occupancy_aware = _takes_occupancy(policy)
        self._skew_aware = _takes_kwarg(policy, "skew")

    @staticmethod
    def _key(phase: str, seq_bucket, batch_per_device, occupancy,
             skew=None) -> PlanKey:
        if occupancy is not None:
            key: Tuple = (phase, occupancy)
        elif seq_bucket is None:
            raise ValueError("PlanCache.get needs seq_bucket or occupancy")
        else:
            key = (phase, int(seq_bucket), batch_per_device)
        if skew is not None:
            key = key + (skew,)
        return key

    def get(self, phase: str, seq_bucket: Optional[int] = None,
            batch_per_device: Optional[int] = None, *,
            occupancy: Optional[OccupancySummary] = None,
            skew: Optional[SkewSummary] = None) -> Plan:
        if skew is not None and skew.is_uniform:
            skew = None         # uniform routing == the legacy key space
        key = self._key(phase, seq_bucket, batch_per_device, occupancy, skew)
        self._tick += 1
        plan = self._plans.get(key)
        if plan is not None:
            self.stats.hits += 1
            meta = self._meta.get(key)
            if meta is not None:
                meta.hits += 1
                meta.last_used = self._tick
            return plan
        t0 = time.perf_counter()
        plan = self._resolve(phase, seq_bucket, batch_per_device, occupancy,
                             skew)
        dt = time.perf_counter() - t0
        self.stats.misses += 1
        self.stats.solve_time_last = dt
        self.stats.solve_time_total += dt
        self._plans[key] = plan
        self._meta[key] = EntryMeta(solve_s=dt, last_used=self._tick)
        self._evict_over_capacity(keep=key)
        return plan

    def _evict_over_capacity(self, keep: PlanKey) -> None:
        if self.capacity is None:
            return
        while len(self._plans) > self.capacity:
            victim = min(
                (k for k in self._plans if k != keep),
                key=lambda k: (self._meta[k].score,
                               self._meta[k].last_used))
            del self._plans[victim]
            del self._meta[victim]
            self.stats.evictions += 1

    # ------------------------------------------------------------------
    # refresh hooks (repro.profiling.refresh drives these)
    # ------------------------------------------------------------------
    def invalidate(self, key: PlanKey) -> bool:
        """Drop one entry; the next lookup of this shape re-solves."""
        if self._plans.pop(key, None) is None:
            return False
        self._meta.pop(key, None)
        self.stats.invalidations += 1
        return True

    def refresh(self, key: PlanKey) -> Plan:
        """Re-resolve ``key`` and swap the result in atomically. The old
        entry keeps serving concurrent ``get``s for the whole duration of
        the solve — this is the off-critical-path half of drift refresh
        (call it from a worker thread; dict replacement is GIL-atomic).

        Planner-backed policies memoize solves internally, so the policy
        is asked to ``invalidate()`` first when it knows how — otherwise a
        "re-solve" would be a memo hit returning the identical plan."""
        phase, *rest = key
        skew = rest.pop() if rest and isinstance(rest[-1], SkewSummary) \
            else None
        if len(rest) == 1 and isinstance(rest[0], OccupancySummary):
            seq_bucket, batch, occupancy = None, None, rest[0]
        else:
            seq_bucket, batch, occupancy = rest[0], rest[1], None
        inval = getattr(self.policy, "invalidate", None)
        if callable(inval):
            inval()
        t0 = time.perf_counter()
        plan = self._resolve(phase, seq_bucket, batch, occupancy, skew)
        dt = time.perf_counter() - t0
        self.stats.refreshes += 1
        self.stats.solve_time_last = dt
        self.stats.solve_time_total += dt
        meta = self._meta.get(key)
        if meta is not None:
            meta.solve_s = dt
        else:
            self._tick += 1
            self._meta[key] = EntryMeta(solve_s=dt, last_used=self._tick)
        self._plans[key] = plan
        self._evict_over_capacity(keep=key)
        return plan

    def _resolve(self, phase, seq_bucket, batch_per_device, occupancy,
                 skew=None):
        # legacy policies without a skew= keyword solve under the uniform
        # assumption — the entry still keys on the summary, so a skew
        # regime shift re-consults the policy rather than serving stale
        kw = {"skew": skew} if (skew is not None and self._skew_aware) else {}
        if occupancy is None:
            return self.policy.resolve(phase, seq_bucket, batch_per_device,
                                       **kw)
        if self._occupancy_aware:
            return self.policy.resolve(phase, seq_bucket, batch_per_device,
                                       occupancy=occupancy, **kw)
        warnings.warn(
            f"policy {getattr(self.policy, 'name', self.policy)!r} has a "
            "legacy resolve(phase, seq_bucket, batch) signature; occupancy "
            "summaries are projected onto (seq_bucket, live). Add an "
            "occupancy= keyword to resolve() to schedule on the real "
            "composition.", DeprecationWarning, stacklevel=3)
        return self.policy.resolve(
            phase, seq_bucket if seq_bucket is not None
            else occupancy.seq_bucket,
            batch_per_device if batch_per_device is not None
            else occupancy.live)

    def entries(self) -> Dict[PlanKey, Plan]:
        return dict(self._plans)

    def distinct_plans(self):
        """Unique resolved plans (Plan is a frozen dataclass => hashable)."""
        return set(self._plans.values())

    def clear(self) -> None:
        self._plans.clear()
        self._meta.clear()
        self._tick = 0
        self.stats = PlanCacheStats()

    def __len__(self) -> int:
        return len(self._plans)

    def __repr__(self) -> str:
        s = self.stats
        return (f"PlanCache(policy={getattr(self.policy, 'name', '?')}, "
                f"entries={len(self)}, hits={s.hits}, misses={s.misses}, "
                f"solve_total={s.solve_time_total * 1e3:.1f}ms)")
