"""Pluggable scheduling policies: one interface, every schedule family.

A ``SchedulePolicy`` maps an observed execution shape — (phase, sequence
bucket, per-device batch), or for decode an ``OccupancySummary`` of the
real live-slot composition — to a fully-specified ``Plan`` (m_a, r1, r2,
order). The serving engine, the DEP executor, the benchmarks and the
examples all consume schedules through this one surface, so the paper's
baselines are runnable systems rather than analytic curves:

  FinDEPPolicy        Algorithm 1 per shape (the paper's online phase)
  StaticPolicy        one frozen plan for every shape (the old
                      ExecutionContext.plan behavior)
  SequentialDEPPolicy r2 = 1 coarse schedule, MegaScale-Infer style:
                      micro-batch pipelining but no intra-layer chunking
  EPSPipelinePolicy   EPS-MoE style fixed-granularity expert pipeline:
                      whole batch, fixed r2 chosen offline

Policies that solve under a fixed arrived batch fall back to the
throughput-mode solve when the batch admits no feasible (m_a, r1)
decomposition under the memory cap (e.g. live-slot counts larger than the
per-device sample capacity).

A resolved ``Plan`` is consumed through the task-graph IR
(``repro.core.taskgraph``): the DEP executor walks
``plan.exec_program()`` (the r1-stream ``ExecProgram`` whose emission
order follows the scheduled start order; ``plan.exec_graph()`` is its
single-stream structural view), and solver/baseline plans carry a
graph-derived per-primitive ``breakdown`` that telemetry uses for drift
attribution and the interleaved emission uses for priority hints.
``FinDEPPlanner.lower``/``schedule_plan`` expose the full T-layer graph
behind a planner-backed policy's plans.
"""
from __future__ import annotations

from typing import Optional, Protocol, Tuple, runtime_checkable

from repro.core.baselines import eps_pipeline_plan
from repro.core.planner import FinDEPPlanner
from repro.core.solver import Plan
from repro.sched.occupancy import OccupancySummary


@runtime_checkable
class SchedulePolicy(Protocol):
    """Resolve an execution shape to a schedule ``Plan``.

    ``occupancy`` carries the decode batch's real composition (live slots
    + context-length histogram from the KV ledger); when given, it fills
    any shape argument the caller omitted. Shape-keyed calls
    (``resolve(phase, seq_bucket, batch)``) remain the prefill surface.
    ``skew`` (a quantized ``repro.placement.SkewSummary``) carries the
    observed routing skew so planner-backed policies solve under
    worst-rank EXP costs rather than the uniform assumption; policies
    without a cost model ignore it.
    """

    name: str

    def resolve(self, phase: str, seq_bucket: Optional[int] = None,
                batch_per_device: Optional[int] = None, *,
                occupancy: Optional[OccupancySummary] = None,
                skew=None) -> Plan:
        ...


def _shape(seq_bucket: Optional[int], batch_per_device: Optional[int],
           occupancy: Optional[OccupancySummary]
           ) -> Tuple[int, Optional[int]]:
    """The (seq, batch) a solver runs on: explicit arguments win; an
    occupancy summary fills in whatever was omitted."""
    if occupancy is not None:
        if seq_bucket is None:
            seq_bucket = occupancy.seq_bucket
        if batch_per_device is None:
            batch_per_device = occupancy.live
    if seq_bucket is None:
        raise ValueError("resolve() needs seq_bucket or occupancy")
    return int(seq_bucket), batch_per_device


def _solve_with_fallback(planner: FinDEPPlanner, seq_bucket: int,
                         batch_per_device: Optional[int],
                         r2_cap: Optional[int] = None, skew=None) -> Plan:
    try:
        return planner.plan(seq_bucket, batch_per_device, r2_cap=r2_cap,
                            skew=skew)
    except ValueError:
        # arrived batch infeasible under the memory cap: solver picks r1*m_a
        return planner.plan(seq_bucket, None, r2_cap=r2_cap, skew=skew)


class _PlannerBackedPolicy:
    """Shared refresh/recalibration hooks for policies that own a
    ``FinDEPPlanner`` (the surface ``repro.profiling`` retunes):

      invalidate()   drop the planner's solve memo so the next resolve
                     genuinely re-runs Algorithm 1 (PlanCache.refresh
                     calls this before re-resolving a drifted entry);
      reprofile(hw)  swap in a (re)calibrated HardwareProfile — also
                     drops the memo, since every cached plan was solved
                     under the old alpha-beta fit.
    """

    planner: FinDEPPlanner

    def invalidate(self) -> None:
        self.planner.clear_cache()

    def reprofile(self, hardware) -> None:
        self.planner.set_hardware(hardware)


def _is_decode_occupancy(phase: str, seq_bucket, batch_per_device,
                         occupancy) -> bool:
    """A decode resolve carrying only an occupancy summary solves under
    the decode cost model (``FinDEPPlanner.plan_for_occupancy``: one token
    per slot, attention linear in the histogram's mean context). Explicit
    shape arguments keep the prefill-style (seq_bucket, batch) solve."""
    return (phase == "decode" and occupancy is not None
            and seq_bucket is None and batch_per_device is None)


class FinDEPPolicy(_PlannerBackedPolicy):
    """The paper's online scheduler: Algorithm 1 re-solved per shape."""

    name = "findep"

    def __init__(self, planner: FinDEPPlanner):
        self.planner = planner

    def resolve(self, phase: str, seq_bucket: Optional[int] = None,
                batch_per_device: Optional[int] = None, *,
                occupancy: Optional[OccupancySummary] = None,
                skew=None) -> Plan:
        if _is_decode_occupancy(phase, seq_bucket, batch_per_device,
                                occupancy):
            return self.planner.plan_for_occupancy(occupancy, skew=skew)
        S, b = _shape(seq_bucket, batch_per_device, occupancy)
        return _solve_with_fallback(self.planner, S, b, skew=skew)


class StaticPolicy:
    """One plan for every shape — subsumes the old engine behavior of
    solving once at construction time for ``max_context``."""

    name = "static"

    def __init__(self, plan: Plan):
        self.plan = plan

    @classmethod
    def from_planner(cls, planner: FinDEPPlanner, seq_len: int,
                     batch_per_device: Optional[int] = None) -> "StaticPolicy":
        return cls(_solve_with_fallback(planner, seq_len, batch_per_device))

    def resolve(self, phase: str, seq_bucket: Optional[int] = None,
                batch_per_device: Optional[int] = None, *,
                occupancy: Optional[OccupancySummary] = None,
                skew=None) -> Plan:
        return self.plan


class SequentialDEPPolicy(_PlannerBackedPolicy):
    """MegaScale-Infer style coarse DEP: the solver still picks (m_a, r1)
    per shape, but r2 is pinned to 1 — each MoE layer's A2E, expert FFN and
    E2A run as whole-capacity stages with no intra-layer chunk overlap.
    Evaluated under the same objective as FinDEP, so a FinDEP solve with
    r2_cap=1 is makespan-identical by construction."""

    name = "sequential"

    def __init__(self, planner: FinDEPPlanner):
        self.planner = planner

    def resolve(self, phase: str, seq_bucket: Optional[int] = None,
                batch_per_device: Optional[int] = None, *,
                occupancy: Optional[OccupancySummary] = None,
                skew=None) -> Plan:
        if _is_decode_occupancy(phase, seq_bucket, batch_per_device,
                                occupancy):
            return self.planner.plan_for_occupancy(occupancy, r2_cap=1,
                                                   skew=skew)
        S, b = _shape(seq_bucket, batch_per_device, occupancy)
        return _solve_with_fallback(self.planner, S, b, r2_cap=1, skew=skew)


class EPSPipelinePolicy(_PlannerBackedPolicy):
    """EPS-MoE style fixed-granularity pipeline: no online solve at all —
    the whole arrived batch goes through at once (r1 = 1) and the expert
    capacity is split into a fixed ``granularity`` chunks."""

    name = "eps"

    def __init__(self, planner: FinDEPPlanner, granularity: int = 4):
        self.planner = planner
        self.granularity = granularity

    def resolve(self, phase: str, seq_bucket: Optional[int] = None,
                batch_per_device: Optional[int] = None, *,
                occupancy: Optional[OccupancySummary] = None,
                skew=None) -> Plan:
        seq_bucket, batch_per_device = _shape(seq_bucket, batch_per_device,
                                              occupancy)
        cap = self.planner.cfg.mem_cap_samples
        m_a = min(batch_per_device or cap, cap)
        models = self.planner.stage_models(seq_bucket)
        return eps_pipeline_plan(models, self.planner.num_moe_layers(),
                                 m_a, r2=self.granularity)


POLICIES = ("findep", "static", "sequential", "eps")


def make_policy(name: str, planner: FinDEPPlanner, *,
                static_seq_len: Optional[int] = None,
                eps_granularity: int = 4) -> SchedulePolicy:
    """Build a policy by CLI name. ``static`` solves once for
    ``static_seq_len`` (required) and never re-plans."""
    if name == "findep":
        return FinDEPPolicy(planner)
    if name == "sequential":
        return SequentialDEPPolicy(planner)
    if name == "eps":
        return EPSPipelinePolicy(planner, granularity=eps_granularity)
    if name == "static":
        if static_seq_len is None:
            raise ValueError("StaticPolicy needs static_seq_len (the shape "
                             "it is tuned for)")
        return StaticPolicy.from_planner(planner, static_seq_len)
    raise ValueError(f"unknown policy {name!r}; choose from {POLICIES}")
