from repro.runtime.engine import EngineStats, ServingEngine
from repro.runtime.request import Request, RequestState
from repro.runtime.sampler import sample

__all__ = ["EngineStats", "ServingEngine", "Request", "RequestState",
           "sample"]
