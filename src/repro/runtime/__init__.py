from repro.runtime.batching import (ADMISSIONS, AdmissionPolicy,
                                    BatchScheduler, FCFSAdmission,
                                    PrefillGroup, ShortestPromptFirst,
                                    StepPlan, TokenBudgetAdmission,
                                    make_admission)
from repro.runtime.engine import EngineStats, ServingEngine
from repro.runtime.kv import KVCacheManager, KVStats
from repro.runtime.paging import (BlockPool, PagedKVCacheManager,
                                  PagingStats, PrefixCache, chunk_keys)
from repro.runtime.request import Request, RequestState
from repro.runtime.sampler import sample

__all__ = ["EngineStats", "ServingEngine", "Request", "RequestState",
           "sample", "KVCacheManager", "KVStats", "BatchScheduler",
           "StepPlan", "PrefillGroup", "AdmissionPolicy", "FCFSAdmission",
           "ShortestPromptFirst", "TokenBudgetAdmission", "ADMISSIONS",
           "make_admission", "BlockPool", "PrefixCache",
           "PagedKVCacheManager", "PagingStats", "chunk_keys"]
