"""Iteration-level batch scheduling (Orca-style): admission policies +
``StepPlan`` construction.

Every engine iteration asks the ``BatchScheduler`` what to run:

    build_step(waiting, kv) -> StepPlan

The scheduler rejects oversized prompts, admits waiting requests under
the configured admission policy (bounded by free KV slots and an optional
per-step prefill token budget), allocates their slots from the
``KVCacheManager``, and groups admitted requests by padded prefill bucket
so several requests run as ONE batched ``model.prefill`` call. The engine
then executes each ``PrefillGroup`` (chunked by the resolved plan's
r1·m_a granularity) and decodes the full live batch.

Admission policies:
  fcfs          arrival order, fill every free slot
  spf           shortest-prompt-first (minimizes mean TTFT under load)
  token_budget  FCFS order, but stop admitting once the step's prefill
                tokens would exceed the budget (Sarathi-style chunked
                prefill at request granularity: long prompts no longer
                stall the decode batch for many consecutive steps; the
                first admitted request is always let through so a prompt
                larger than the budget cannot starve)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, runtime_checkable

from repro.runtime.kv import KVCacheManager
from repro.runtime.request import Request
from repro.sched.occupancy import bucket_length


@dataclass
class PrefillGroup:
    """Same-bucket requests prefilled in one padded batch. ``bucket`` is
    the padded prompt length (0 => nothing to prefill: empty or
    single-token prompts that go straight to decode)."""

    bucket: int
    slots: List[int] = field(default_factory=list)
    requests: List[Request] = field(default_factory=list)

    @property
    def prefill_tokens(self) -> int:
        return sum(max(len(r.prompt) - 1, 0) for r in self.requests)


@dataclass
class StepPlan:
    """What one engine iteration executes."""

    prefills: List[PrefillGroup] = field(default_factory=list)
    decode_slots: List[int] = field(default_factory=list)
    rejected: List[Request] = field(default_factory=list)

    @property
    def num_prefilled(self) -> int:
        return sum(len(g.requests) for g in self.prefills)

    @property
    def prefill_tokens(self) -> int:
        return sum(g.prefill_tokens for g in self.prefills)


@runtime_checkable
class AdmissionPolicy(Protocol):
    """Pick which waiting requests to admit this step (does not mutate
    ``waiting``; returns a subset, at most ``free_slots`` long)."""

    name: str

    def admit(self, waiting: Sequence[Request], free_slots: int,
              token_budget: Optional[int] = None) -> List[Request]:
        ...


def _prefill_cost(req: Request) -> int:
    return max(len(req.prompt) - 1, 0)


class FCFSAdmission:
    name = "fcfs"

    def admit(self, waiting, free_slots, token_budget=None):
        return list(waiting[:max(free_slots, 0)])


class ShortestPromptFirst:
    name = "spf"

    def admit(self, waiting, free_slots, token_budget=None):
        ranked = sorted(waiting, key=lambda r: (_prefill_cost(r),
                                                r.arrival_t, r.request_id))
        return ranked[:max(free_slots, 0)]


class TokenBudgetAdmission:
    """FCFS order under a per-step prefill token budget."""

    name = "token_budget"

    def __init__(self, token_budget: int = 512):
        self.token_budget = token_budget

    def admit(self, waiting, free_slots, token_budget=None):
        budget = self.token_budget if token_budget is None else token_budget
        out: List[Request] = []
        total = 0
        for req in waiting:
            if len(out) >= free_slots:
                break
            cost = _prefill_cost(req)
            if out and total + cost > budget:
                break
            out.append(req)
            total += cost
        return out


ADMISSIONS = ("fcfs", "spf", "token_budget")


def make_admission(name: str, *,
                   token_budget: Optional[int] = None) -> AdmissionPolicy:
    if name == "fcfs":
        return FCFSAdmission()
    if name == "spf":
        return ShortestPromptFirst()
    if name == "token_budget":
        return TokenBudgetAdmission(token_budget or 512)
    raise ValueError(f"unknown admission policy {name!r}; "
                     f"choose from {ADMISSIONS}")


class BatchScheduler:
    """Builds one ``StepPlan`` per engine iteration.

    ``admission`` is a name from ``ADMISSIONS`` or any
    ``AdmissionPolicy``. ``token_budget`` (when set) bounds the prefill
    tokens any single step admits, independent of the policy.
    """

    def __init__(self, admission="fcfs",
                 token_budget: Optional[int] = None):
        if isinstance(admission, str):
            admission = make_admission(admission, token_budget=token_budget)
        self.admission = admission
        self.token_budget = token_budget

    def build_step(self, waiting: List[Request], kv: KVCacheManager, *,
                   max_context: Optional[int] = None,
                   exact_length: bool = False) -> StepPlan:
        """Admit from (and pop out of) ``waiting``, allocate slots, group
        by bucket. ``exact_length`` disables bucket padding (recurrent
        states would be corrupted by padded prefill tokens, so SSM/hybrid
        prompts group by exact length)."""
        max_context = max_context or kv.max_context
        plan = StepPlan()

        keep = []
        for req in waiting:
            # the full prompt (the last token is fed through decode) must
            # fit the per-slot cache, else decode writes clamp/overwrite
            if len(req.prompt) > max_context:
                req.error = (f"prompt of {len(req.prompt)} tokens exceeds "
                             f"max_context={max_context}; refusing to "
                             "truncate")
                plan.rejected.append(req)
            else:
                keep.append(req)
        waiting[:] = keep

        admitted = self.admission.admit(waiting, kv.free_count(),
                                        self.token_budget)
        if self.token_budget is not None:
            # the budget bounds every step regardless of admission policy
            # (TokenBudgetAdmission additionally uses it to pick WHICH
            # requests to admit); the first request always passes so a
            # prompt larger than the budget cannot starve
            capped: List[Request] = []
            total = 0
            for req in admitted:
                cost = _prefill_cost(req)
                if capped and total + cost > self.token_budget:
                    break
                capped.append(req)
                total += cost
            admitted = capped
        groups: Dict[int, PrefillGroup] = {}
        for req in admitted:
            slot = kv.alloc()
            if slot is None:     # defensive: admission overshot capacity
                break
            waiting.remove(req)
            cost = _prefill_cost(req)
            if cost == 0:
                bucket = 0
            elif exact_length:
                bucket = cost
            else:
                bucket = min(bucket_length(cost), max_context)
            group = groups.setdefault(bucket, PrefillGroup(bucket))
            group.slots.append(slot)
            group.requests.append(req)
        plan.prefills = [groups[b] for b in sorted(groups)]
        plan.decode_slots = kv.live_slots()
        return plan
