"""Iteration-level batch scheduling (Orca-style): admission policies +
``StepPlan`` construction.

Every engine iteration asks the ``BatchScheduler`` what to run:

    build_step(waiting, kv) -> StepPlan

The scheduler rejects oversized prompts, admits waiting requests under
the configured admission policy (bounded by free KV slots and an optional
per-step prefill token budget), allocates their slots from the
``KVCacheManager``, and groups admitted requests by padded prefill bucket
so several requests run as ONE batched ``model.prefill`` call. The engine
then executes each ``PrefillGroup`` (chunked by the resolved plan's
r1·m_a granularity) and decodes the full live batch.

Admission policies:
  fcfs          arrival order, fill every free slot
  spf           shortest-prompt-first (minimizes mean TTFT under load)
  token_budget  FCFS order, but stop admitting once the step's prefill
                tokens would exceed the budget (Sarathi-style chunked
                prefill at request granularity: long prompts no longer
                stall the decode batch for many consecutive steps; the
                first admitted request is always let through so a prompt
                larger than the budget cannot starve)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, runtime_checkable

from repro.runtime.kv import KVCacheManager
from repro.runtime.request import Request
from repro.sched.occupancy import bucket_length


@dataclass
class PrefillGroup:
    """Same-bucket requests prefilled in one padded batch. ``bucket`` is
    the padded prompt length (0 => nothing to prefill: empty or
    single-token prompts that go straight to decode)."""

    bucket: int
    slots: List[int] = field(default_factory=list)
    requests: List[Request] = field(default_factory=list)

    @property
    def prefill_tokens(self) -> int:
        return sum(max(len(r.prompt) - 1, 0) for r in self.requests)


@dataclass
class StepPlan:
    """What one engine iteration executes."""

    prefills: List[PrefillGroup] = field(default_factory=list)
    decode_slots: List[int] = field(default_factory=list)
    rejected: List[Request] = field(default_factory=list)

    @property
    def num_prefilled(self) -> int:
        return sum(len(g.requests) for g in self.prefills)

    @property
    def prefill_tokens(self) -> int:
        return sum(g.prefill_tokens for g in self.prefills)


@runtime_checkable
class AdmissionPolicy(Protocol):
    """Pick which waiting requests to admit this step (does not mutate
    ``waiting``; returns a subset, at most ``free_slots`` long).
    ``cost_fn`` overrides the per-request prefill cost — the paged-KV
    scheduler passes a prefix-discounted cost so cached prompts don't
    burn token budget they won't stream. Policies may ignore it; the
    scheduler falls back to the 3-arg call for older implementations."""

    name: str

    def admit(self, waiting: Sequence[Request], free_slots: int,
              token_budget: Optional[int] = None,
              cost_fn=None) -> List[Request]:
        ...


def _prefill_cost(req: Request) -> int:
    # resume_tokens == prompt for fresh requests; after a paged-KV
    # preemption it includes the generated tokens whose KV must be rebuilt
    return max(len(req.resume_tokens) - 1, 0)


class FCFSAdmission:
    name = "fcfs"

    def admit(self, waiting, free_slots, token_budget=None, cost_fn=None):
        return list(waiting[:max(free_slots, 0)])


class ShortestPromptFirst:
    name = "spf"

    def admit(self, waiting, free_slots, token_budget=None, cost_fn=None):
        cost = cost_fn or _prefill_cost
        ranked = sorted(waiting, key=lambda r: (cost(r),
                                                r.arrival_t, r.request_id))
        return ranked[:max(free_slots, 0)]


class TokenBudgetAdmission:
    """FCFS order under a per-step prefill token budget."""

    name = "token_budget"

    def __init__(self, token_budget: int = 512):
        self.token_budget = token_budget

    def admit(self, waiting, free_slots, token_budget=None, cost_fn=None):
        budget = self.token_budget if token_budget is None else token_budget
        cost_of = cost_fn or _prefill_cost
        out: List[Request] = []
        total = 0
        for req in waiting:
            if len(out) >= free_slots:
                break
            cost = cost_of(req)
            if out and total + cost > budget:
                break
            out.append(req)
            total += cost
        return out


ADMISSIONS = ("fcfs", "spf", "token_budget")


def make_admission(name: str, *,
                   token_budget: Optional[int] = None) -> AdmissionPolicy:
    if name == "fcfs":
        return FCFSAdmission()
    if name == "spf":
        return ShortestPromptFirst()
    if name == "token_budget":
        return TokenBudgetAdmission(token_budget or 512)
    raise ValueError(f"unknown admission policy {name!r}; "
                     f"choose from {ADMISSIONS}")


class BatchScheduler:
    """Builds one ``StepPlan`` per engine iteration.

    ``admission`` is a name from ``ADMISSIONS`` or any
    ``AdmissionPolicy``. ``token_budget`` (when set) bounds the prefill
    tokens any single step admits, independent of the policy.
    """

    def __init__(self, admission="fcfs",
                 token_budget: Optional[int] = None):
        if isinstance(admission, str):
            admission = make_admission(admission, token_budget=token_budget)
        self.admission = admission
        self.token_budget = token_budget

    def build_step(self, waiting: List[Request], kv: KVCacheManager, *,
                   max_context: Optional[int] = None,
                   exact_length: bool = False) -> StepPlan:
        """Admit from (and pop out of) ``waiting``, allocate slots, group
        by bucket. ``exact_length`` disables bucket padding (recurrent
        states would be corrupted by padded prefill tokens, so SSM/hybrid
        prompts group by exact length)."""
        max_context = max_context or kv.max_context
        plan = StepPlan()
        # block-granular KV (PagedKVCacheManager): admission also answers
        # to the page pool — watermark hysteresis, a per-request new-page
        # charge discounted by the prefix cache, and a pool-capacity cap
        paged = hasattr(kv, "admission_charge")

        keep = []
        for req in waiting:
            # the full (resume) sequence — the last token is fed through
            # decode — must fit the per-slot cache, else decode writes
            # clamp/overwrite
            n_total = len(req.resume_tokens)
            if n_total > max_context:
                req.error = (f"prompt of {n_total} tokens exceeds "
                             f"max_context={max_context}; refusing to "
                             "truncate")
                plan.rejected.append(req)
            elif paged and (kv.blocks_for_tokens(max(n_total - 1, 0))
                            > kv.pool.usable - 1):
                req.error = (f"prompt needs more KV pages than the pool "
                             f"holds ({kv.pool.usable} usable blocks of "
                             f"{kv.block_size})")
                plan.rejected.append(req)
            else:
                keep.append(req)
        waiting[:] = keep

        if paged and kv.admission_blocked():
            # above the high watermark: run decode-only steps until the
            # pool drains below the low watermark
            plan.decode_slots = kv.live_slots()
            return plan

        cost_fn = _prefill_cost
        if paged:
            def cost_fn(req):
                toks = req.resume_tokens
                Lp = max(len(toks) - 1, 0)
                return max(Lp - kv.cached_prefix_tokens(toks[:Lp]), 0)

        try:
            admitted = self.admission.admit(waiting, kv.free_count(),
                                            self.token_budget,
                                            cost_fn=cost_fn)
        except TypeError:   # older 3-arg AdmissionPolicy implementations
            admitted = self.admission.admit(waiting, kv.free_count(),
                                            self.token_budget)
        if self.token_budget is not None:
            # the budget bounds every step regardless of admission policy
            # (TokenBudgetAdmission additionally uses it to pick WHICH
            # requests to admit); the first request always passes so a
            # prompt larger than the budget cannot starve
            capped: List[Request] = []
            total = 0
            for req in admitted:
                cost = cost_fn(req)
                if capped and total + cost > self.token_budget:
                    break
                capped.append(req)
                total += cost
            admitted = capped
        if paged:
            # charge each admit its NEW pages (prefix hits are free) and
            # stop before the pool runs out, keeping one page of decode
            # headroom per already-live slot to delay preemption
            avail = kv.blocks_free() - kv.live_count()
            fitting: List[Request] = []
            for req in admitted:
                toks = req.resume_tokens
                new_pages, _ = kv.admission_charge(
                    toks[:max(len(toks) - 1, 0)])
                if new_pages > avail:
                    break
                fitting.append(req)
                avail -= new_pages
            admitted = fitting
        groups: Dict[int, PrefillGroup] = {}
        for req in admitted:
            slot = kv.alloc()
            if slot is None:     # defensive: admission overshot capacity
                break
            waiting.remove(req)
            cost = _prefill_cost(req)
            if cost == 0:
                bucket = 0
            elif exact_length:
                bucket = cost
            else:
                bucket = min(bucket_length(cost), max_context)
            group = groups.setdefault(bucket, PrefillGroup(bucket))
            group.slots.append(slot)
            group.requests.append(req)
        plan.prefills = [groups[b] for b in sorted(groups)]
        plan.decode_slots = kv.live_slots()
        return plan
