"""Paged KV cache with shared-prefix reuse: the block-granular memory
subsystem under the serving path.

The dense ``KVCacheManager`` preallocates ``num_slots`` rows of
``max_context`` KV positions — concurrency is capped by the WORST-CASE
context even though most conversations use a fraction of it. This module
replaces that layout with fixed-size blocks (vLLM's PagedAttention
layout, adapted to the repo's ledger/kernel contracts):

  ``BlockPool``      physical pages ``[num_blocks, block_size, Kv, D]``
                     per layer, a free-list + per-page refcounts, and
                     watermark accounting. ONE pool indexes every layer:
                     page p of layer t's arrays belongs to the same
                     logical block as page p of every other layer, so a
                     single block table serves the whole model.
  ``PrefixCache``    content-hash reuse: full prefill blocks are keyed
                     by a sha256 chain over their token chunks, so N
                     requests sharing a system prompt map their prefix
                     logical blocks to the SAME physical pages
                     (refcounted). Pages at refcount 0 stay cached
                     ("reclaimable") and are evicted LRU only under pool
                     pressure.
  ``PagedKVCacheManager``
                     drop-in ``KVCacheManager``: same slot/ledger API,
                     but each slot holds a block table (int row of
                     physical page ids) instead of a dense cache row.
                     ``table_array()`` feeds the paged decode kernel's
                     scalar-prefetched block table.

Copy-on-write is BY CONSTRUCTION rather than by fault: only FULL prefill
blocks (the first ``Lp // block_size``) are hashed and shared, and they
are immutable — decode appends at positions >= Lp, which always land in
a private tail page. A shared page is therefore never written after its
copy, and divergence after a common prefix lands in fresh pages without
any copy needing to happen.

Page 0 of the pool is reserved as a scratch sink: dead batch rows in the
vectorized decode scatter clamp their (unallocated, -1) table entries to
it, so they never corrupt a live page.
"""
from __future__ import annotations

import hashlib
import math
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.kv import KVCacheManager
from repro.sched.occupancy import OccupancySummary

#: reserved scratch page (see module docstring)
SCRATCH_PAGE = 0


@dataclass
class PagingStats:
    """Telemetry counters the engine/benchmarks surface."""

    prefix_hit_tokens: int = 0      # prefill tokens served from shared pages
    prefix_miss_tokens: int = 0     # prefill tokens that streamed fresh
    prefix_hit_blocks: int = 0
    prefix_inserted_blocks: int = 0
    prefix_reclaimed_blocks: int = 0
    preemptions: int = 0            # slots evicted-to-recompute by engine

    @property
    def prefix_hit_rate(self) -> float:
        total = self.prefix_hit_tokens + self.prefix_miss_tokens
        return self.prefix_hit_tokens / total if total else 0.0

    def reset(self) -> None:
        self.prefix_hit_tokens = 0
        self.prefix_miss_tokens = 0
        self.prefix_hit_blocks = 0
        self.prefix_inserted_blocks = 0
        self.prefix_reclaimed_blocks = 0
        self.preemptions = 0


class BlockPool:
    """Fixed-size physical KV pages: free-list allocation + per-page
    refcounts. The pool tracks PAGES, not contents — sharing policy
    (which pages are reclaimable instead of freed) lives in the caller.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("BlockPool needs >= 2 blocks "
                             "(page 0 is the reserved scratch sink)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # pop() serves the lowest page first — deterministic layouts
        self._free = list(range(num_blocks - 1, SCRATCH_PAGE, -1))
        self._ref = [0] * num_blocks
        self._ref[SCRATCH_PAGE] = 1          # never allocated, never freed
        self.allocs = 0
        self.frees = 0
        self.peak_used = 0

    # -- accounting --------------------------------------------------------
    @property
    def usable(self) -> int:
        """Pages that can hold KV (everything but the scratch page)."""
        return self.num_blocks - 1

    def free_count(self) -> int:
        return len(self._free)

    def used_count(self) -> int:
        """Pages off the free list (live + reclaimable-cached)."""
        return self.usable - len(self._free)

    def ref(self, page: int) -> int:
        return self._ref[page]

    # -- alloc / refcount lifecycle ---------------------------------------
    def alloc(self) -> Optional[int]:
        """Claim a fresh page at refcount 1 (None when the free list is
        empty — the caller may then reclaim a cached page and ``adopt``
        it)."""
        if not self._free:
            return None
        page = self._free.pop()
        assert self._ref[page] == 0
        self._ref[page] = 1
        self.allocs += 1
        self.peak_used = max(self.peak_used, self.used_count())
        return page

    def adopt(self, page: int) -> int:
        """Re-claim a reclaimable page (refcount 0, off the free list) —
        the prefix cache evicted it and hands the page over."""
        assert self._ref[page] == 0 and page not in self._free
        self._ref[page] = 1
        self.allocs += 1
        return page

    def retain(self, page: int) -> int:
        assert self._ref[page] > 0, f"retain of unreferenced page {page}"
        self._ref[page] += 1
        return page

    def release(self, page: int) -> int:
        """Drop one reference; returns the remaining count. The caller
        decides what a 0 means: ``free`` (private page) or keep-cached
        (prefix page, reclaimable)."""
        assert self._ref[page] > 0, f"release of unreferenced page {page}"
        self._ref[page] -= 1
        return self._ref[page]

    def free(self, page: int) -> None:
        """Return an unreferenced page to the free list."""
        assert page != SCRATCH_PAGE and self._ref[page] == 0
        self._free.append(page)
        self.frees += 1

    def check_invariants(self) -> None:
        """Structural soundness of the page ledger; raises
        ``AssertionError`` listing every broken invariant. Cheap (O(pages))
        — test teardowns call this after every scenario so a refcount
        leak surfaces at the scenario that caused it, not three tests
        later as an inexplicable pool exhaustion."""
        problems: List[str] = []
        if self._ref[SCRATCH_PAGE] < 1:
            problems.append(
                f"scratch page {SCRATCH_PAGE} refcount "
                f"{self._ref[SCRATCH_PAGE]} < 1 (must stay pinned)")
        if SCRATCH_PAGE in self._free:
            problems.append(f"scratch page {SCRATCH_PAGE} is on the "
                            f"free list")
        if len(set(self._free)) != len(self._free):
            dupes = sorted({p for p in self._free
                            if self._free.count(p) > 1})
            problems.append(f"free list has duplicate pages {dupes} "
                            f"(double free)")
        for p in self._free:
            if not 0 <= p < self.num_blocks:
                problems.append(f"free page {p} outside "
                                f"[0, {self.num_blocks})")
            elif self._ref[p] != 0:
                problems.append(f"free page {p} has refcount "
                                f"{self._ref[p]} != 0")
        for p, r in enumerate(self._ref):
            if r < 0:
                problems.append(f"page {p} refcount {r} < 0")
        if self.used_count() + self.free_count() != self.usable:
            problems.append(
                f"page accounting broken: used {self.used_count()} + "
                f"free {self.free_count()} != usable {self.usable}")
        if problems:
            raise AssertionError("BlockPool invariants violated:\n  "
                                 + "\n  ".join(problems))

    def __repr__(self) -> str:
        return (f"BlockPool(used={self.used_count()}/{self.usable}, "
                f"block_size={self.block_size})")


def chunk_keys(tokens: Sequence[int], block_size: int) -> List[bytes]:
    """sha256 chain over full token chunks: key_l commits to the WHOLE
    prefix up to block l, so equal keys imply equal logical contents
    (the KV of a position depends on every position before it). Partial
    tail chunks get no key — only full blocks are shareable. A content
    hash (not Python's randomized ``hash``) so keys are stable across
    processes and collision-safe at serving scale."""
    keys: List[bytes] = []
    h = b""
    arr = np.asarray(list(tokens), np.int64)
    for l in range(len(arr) // block_size):
        m = hashlib.sha256()
        m.update(h)
        m.update(arr[l * block_size:(l + 1) * block_size].tobytes())
        h = m.digest()
        keys.append(h)
    return keys


class PrefixCache:
    """key -> physical page map with refcount-aware retention.

    A page stays mapped while referenced; when its last reference drops
    it becomes RECLAIMABLE (kept mapped, parked in an LRU) instead of
    freed — the next request with the same prefix re-shares it for free.
    Pool pressure evicts reclaimable pages oldest-first via ``reclaim``.
    """

    def __init__(self):
        self._page_by_key: Dict[bytes, int] = {}
        self._key_by_page: Dict[int, bytes] = {}
        self._reclaimable: "OrderedDict[int, None]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._page_by_key)

    def reclaimable_count(self) -> int:
        return len(self._reclaimable)

    def lookup(self, key: bytes) -> Optional[int]:
        return self._page_by_key.get(key)

    def key_of(self, page: int) -> Optional[bytes]:
        return self._key_by_page.get(page)

    def insert(self, key: bytes, page: int) -> None:
        assert key not in self._page_by_key
        self._page_by_key[key] = page
        self._key_by_page[page] = key

    def on_retained(self, page: int) -> None:
        """Page gained a reference — no longer parked."""
        self._reclaimable.pop(page, None)

    def on_released(self, page: int) -> None:
        """Page hit refcount 0 but stays cached for future prefix hits."""
        assert page in self._key_by_page
        self._reclaimable[page] = None
        self._reclaimable.move_to_end(page)

    def reclaim(self) -> Optional[int]:
        """Evict the least-recently-parked refcount-0 page: drops its key
        so future lookups miss, and hands the page back for ``adopt``."""
        if not self._reclaimable:
            return None
        page, _ = self._reclaimable.popitem(last=False)
        key = self._key_by_page.pop(page)
        del self._page_by_key[key]
        return page

    def drop(self, page: int) -> None:
        """Unmap a page without reclaiming it — the rollback path for an
        ``insert`` whose contents never got written (a later allocation
        in the same assignment exhausted the pool). A dropped page must
        not be findable: a hit would share garbage KV."""
        key = self._key_by_page.pop(page, None)
        if key is not None:
            del self._page_by_key[key]
        self._reclaimable.pop(page, None)

    def __repr__(self) -> str:
        return (f"PrefixCache(entries={len(self)}, "
                f"reclaimable={self.reclaimable_count()})")


class PagedKVCacheManager(KVCacheManager):
    """``KVCacheManager`` with block-granular storage.

    Same slot/ledger surface (the engine's bookkeeping is unchanged);
    underneath, each slot maps its logical blocks to pool pages through a
    ``[num_slots, max_blocks]`` table, attention-layer caches are page
    pools ``[num_blocks, block_size, Kv, D]``, and ``merge_prefill``
    scatters prefill rows page-by-page — skipping pages served by the
    prefix cache. ``model=None`` still gives a ledger-only manager
    (tables/pool/prefix fully functional, no device arrays) for tests
    and capacity benchmarks.
    """

    def __init__(self, num_slots: int, max_context: int, model=None,
                 dtype=None, *, block_size: int = 32,
                 num_blocks: Optional[int] = None,
                 watermark_high: float = 0.90,
                 watermark_low: float = 0.75):
        super().__init__(num_slots, max_context, model=model, dtype=dtype)
        if not 0.0 < watermark_low <= watermark_high <= 1.0:
            raise ValueError("need 0 < watermark_low <= watermark_high <= 1")
        self.block_size = int(block_size)
        self.max_blocks = math.ceil(max_context / self.block_size)
        if num_blocks is None:
            # parity default: the same footprint as the dense layout
            num_blocks = num_slots * self.max_blocks + 1
        self.pool = BlockPool(num_blocks, self.block_size)
        self.prefix = PrefixCache()
        self.paging = PagingStats()
        self.watermark_high = float(watermark_high)
        self.watermark_low = float(watermark_low)
        self._throttled = False
        self._tables = np.full((num_slots, self.max_blocks), -1, np.int32)
        self._nblk = [0] * num_slots         # allocated logical blocks/slot
        self._table_dev = None               # jnp mirror, rebuilt on change

    # ------------------------------------------------------------------
    # pool pressure / watermarks
    # ------------------------------------------------------------------
    def blocks_free(self) -> int:
        """Pages an allocation can obtain: free-list + reclaimable."""
        return self.pool.free_count() + self.prefix.reclaimable_count()

    def utilization(self) -> float:
        """Fraction of usable pages pinned by live references (cached
        reclaimable pages don't count — they yield under pressure)."""
        return 1.0 - self.blocks_free() / max(self.pool.usable, 1)

    def admission_blocked(self) -> bool:
        """Watermark hysteresis: once utilization crosses HIGH, admission
        stays off until it falls back under LOW (prevents admit/preempt
        thrash at the boundary)."""
        u = self.utilization()
        if self._throttled:
            if u <= self.watermark_low:
                self._throttled = False
        elif u >= self.watermark_high:
            self._throttled = True
        return self._throttled

    # ------------------------------------------------------------------
    # page allocation
    # ------------------------------------------------------------------
    def _alloc_page(self) -> Optional[int]:
        page = self.pool.alloc()
        if page is None:
            reclaimed = self.prefix.reclaim()
            if reclaimed is None:
                return None
            self.paging.prefix_reclaimed_blocks += 1
            page = self.pool.adopt(reclaimed)
        return page

    def _release_page(self, page: int) -> None:
        if self.pool.release(page) == 0:
            if self.prefix.key_of(page) is not None:
                self.prefix.on_released(page)    # park, don't free
            else:
                self.pool.free(page)

    def _release_slot_pages(self, slot: int) -> None:
        for l in range(self._nblk[slot]):
            self._release_page(int(self._tables[slot, l]))
        self._tables[slot, :] = -1
        self._nblk[slot] = 0
        self._table_dev = None

    # ------------------------------------------------------------------
    # slot lifecycle overrides
    # ------------------------------------------------------------------
    def free(self, slot: int) -> None:
        self._release_slot_pages(slot)
        super().free(slot)

    # ------------------------------------------------------------------
    # admission probing (BatchScheduler)
    # ------------------------------------------------------------------
    def blocks_for_tokens(self, n_prefill_tokens: int) -> int:
        """Logical blocks a request with ``Lp`` prefill tokens needs at
        admission: positions 0..Lp inclusive (the fed-through last prompt
        token writes position Lp on its first decode step)."""
        return max(n_prefill_tokens, 0) // self.block_size + 1

    def cached_prefix_tokens(self, tokens: Sequence[int]) -> int:
        """Longest shared prefix (whole blocks, chain order) already
        resident — probe only, no refcounts taken."""
        hits = 0
        for key in chunk_keys(tokens, self.block_size):
            if self.prefix.lookup(key) is None:
                break
            hits += 1
        return hits * self.block_size

    def admission_charge(self, tokens: Sequence[int]) -> Tuple[int, int]:
        """(new_pages, cached_tokens) admitting ``tokens`` would cost —
        the scheduler charges block budget for new pages only and prefill
        token budget for non-cached tokens only."""
        cached = self.cached_prefix_tokens(tokens)
        total = self.blocks_for_tokens(len(tokens))
        return total - cached // self.block_size, cached

    # ------------------------------------------------------------------
    # block-table construction (prefill admission)
    # ------------------------------------------------------------------
    def assign_blocks(self, slot: int, tokens: Sequence[int]
                      ) -> List[Tuple[int, int, bool]]:
        """Map ``slot``'s logical blocks for a prefill of ``tokens`` to
        physical pages: shared pages for the cached prefix chain, fresh
        pages beyond it; full fresh blocks are registered for future
        sharing. Returns [(logical, page, cached)] — ``cached`` pages
        already hold their contents and must NOT be written.

        Raises ``RuntimeError`` on pool exhaustion after rolling the
        partial assignment back (admission charged capacity, so this is
        a bookkeeping bug or an over-admitting custom policy)."""
        assert self._nblk[slot] == 0, f"slot {slot} already has pages"
        tokens = list(tokens)
        n_blocks = self.blocks_for_tokens(len(tokens))
        keys = chunk_keys(tokens, self.block_size)
        out: List[Tuple[int, int, bool]] = []
        try:
            for l in range(n_blocks):
                # chain keys commit to the full prefix, so a hit after a
                # miss (middle page reclaimed, later page still cached)
                # is still content-correct and worth sharing
                page = self.prefix.lookup(keys[l]) if l < len(keys) else None
                if page is not None:
                    if self.pool.ref(page) == 0:
                        self.pool.adopt(page)    # revive a parked page
                    else:
                        self.pool.retain(page)
                    self.prefix.on_retained(page)
                    self.paging.prefix_hit_blocks += 1
                    cached = True
                else:
                    page = self._alloc_page()
                    if page is None:
                        raise RuntimeError(
                            "BlockPool exhausted during assign_blocks "
                            "(admission over-committed)")
                    cached = False
                    if l < len(keys):    # full fresh block: shareable
                        self.prefix.insert(keys[l], page)
                        self.paging.prefix_inserted_blocks += 1
                self._tables[slot, l] = page
                self._nblk[slot] = l + 1
                out.append((l, page, cached))
        except RuntimeError:
            # fresh full blocks were registered before their contents
            # were scattered; unmap them so no future request hits a
            # page that never got written
            for _, page, cached in out:
                if not cached:
                    self.prefix.drop(page)
            self._release_slot_pages(slot)
            raise
        hit_tokens = sum(self.block_size for _, _, c in out if c)
        self.paging.prefix_hit_tokens += hit_tokens
        self.paging.prefix_miss_tokens += max(len(tokens) - hit_tokens, 0)
        self._table_dev = None
        return out

    # ------------------------------------------------------------------
    # decode growth (engine, before each decode step)
    # ------------------------------------------------------------------
    def missing_decode_page(self, slot: int) -> bool:
        """Does the next decode write (position ledger-1) lack a page?"""
        write_pos = max(self._lengths[slot] - 1, 0)
        return write_pos // self.block_size >= self._nblk[slot]

    def ensure_decode_page(self, slot: int) -> bool:
        """Allocate the tail page the next decode write needs; False on
        pool exhaustion (the engine preempts a victim and retries)."""
        if not self.missing_decode_page(slot):
            return True
        page = self._alloc_page()
        if page is None:
            return False
        l = self._nblk[slot]
        self._tables[slot, l] = page
        self._nblk[slot] = l + 1
        self._table_dev = None
        return True

    # ------------------------------------------------------------------
    # whole-ledger invariants (test teardowns, debugging)
    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Cross-check the three ledgers against each other — pool
        refcounts vs block-table references vs prefix-cache parking.
        Raises ``AssertionError`` listing every broken invariant. Called
        by test teardowns after every paging scenario: a leak or double
        free surfaces at the scenario that caused it."""
        self.pool.check_invariants()
        problems: List[str] = []
        table_refs: Dict[int, int] = {}
        for slot in range(self.num_slots):
            n = self._nblk[slot]
            for l in range(self.max_blocks):
                page = int(self._tables[slot, l])
                if l < n:
                    if not 0 <= page < self.pool.num_blocks:
                        problems.append(
                            f"slot {slot} block {l}: page {page} outside "
                            f"[0, {self.pool.num_blocks})")
                        continue
                    if page == SCRATCH_PAGE:
                        problems.append(
                            f"slot {slot} block {l} maps the reserved "
                            f"scratch page {SCRATCH_PAGE}")
                        continue
                    table_refs[page] = table_refs.get(page, 0) + 1
                elif page != -1:
                    problems.append(
                        f"slot {slot} block {l} beyond nblk={n} holds "
                        f"{page}, expected -1 (stale mapping)")
            write_block = max(self._lengths[slot] - 1, 0) // self.block_size
            if self._live[slot] and write_block > n:
                problems.append(
                    f"slot {slot} length {self._lengths[slot]} writes "
                    f"block {write_block} but only {n} blocks are mapped "
                    f"(more than the one decode-growth page missing)")
        for page, refs in sorted(table_refs.items()):
            if self.pool.ref(page) != refs:
                problems.append(
                    f"page {page}: {refs} table reference(s) but pool "
                    f"refcount {self.pool.ref(page)} (leak or double "
                    f"free)")
            if page in self.pool._free:
                problems.append(f"page {page} is mapped by a table AND "
                                f"on the free list")
        for page in range(1, self.pool.num_blocks):
            if self.pool.ref(page) > 0 and page not in table_refs:
                problems.append(
                    f"page {page} refcount {self.pool.ref(page)} but no "
                    f"table maps it (leaked reference)")
        # prefix-cache bijection + parked-page discipline
        for key, page in self.prefix._page_by_key.items():
            if self.prefix._key_by_page.get(page) != key:
                problems.append(f"prefix cache maps key->page {page} but "
                                f"page->key disagrees")
        for page in self.prefix._reclaimable:
            if self.prefix.key_of(page) is None:
                problems.append(f"parked page {page} has no prefix key")
            if self.pool.ref(page) != 0:
                problems.append(
                    f"parked page {page} has refcount "
                    f"{self.pool.ref(page)} != 0 (parked means idle)")
            if page in self.pool._free:
                problems.append(f"parked page {page} is also on the "
                                f"free list")
        if problems:
            raise AssertionError(
                "PagedKVCacheManager invariants violated:\n  "
                + "\n  ".join(problems))

    # ------------------------------------------------------------------
    # cache surgery (paged layout)
    # ------------------------------------------------------------------
    def ensure_caches(self) -> None:
        if self.caches is not None:
            return
        if self.model is None:
            raise ValueError("ledger-only PagedKVCacheManager (model=None) "
                             "holds no caches")
        import jax.numpy as jnp
        # one page pool per layer, by initializing the model's cache with
        # batch=num_blocks, context=block_size: [P, bs, Kv, D] per array.
        # Page p means the same logical block in every layer, so a single
        # block table drives the whole model.
        caches = self.model.init_cache(self.pool.num_blocks,
                                       self.block_size, dtype=self.dtype)
        paged = []
        for c in caches:
            if isinstance(c, dict) and "index" in c:
                paged.append(dict(
                    c, index=jnp.zeros((self.num_slots,), jnp.int32)))
            else:
                raise ValueError(
                    "paged KV requires full-attention layer caches "
                    f"(got {type(c).__name__}); gate kv_layout='paged' "
                    "on a supported model")
        self.caches = paged

    def table_array(self):
        """The [num_slots, max_blocks] device block table the decode
        step's kernel prefetches (rebuilt only after a table change)."""
        import jax.numpy as jnp
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self._tables)
        return self._table_dev

    def merge_prefill(self, slots: Sequence[int], prefilled: List[Any],
                      lengths: Sequence[int],
                      tokens: Optional[Sequence[Sequence[int]]] = None
                      ) -> None:
        """Paged merge: assign each slot's block table (sharing cached
        prefix pages), then scatter the batched-prefill rows into the
        NON-cached pages of every layer. ``tokens[j]`` are row j's real
        prefill token ids — the prefix-cache key material; None disables
        sharing for that row."""
        if self.model is not None:
            self.ensure_caches()
        assignments = []
        for j, slot in enumerate(slots):
            n = int(lengths[j])
            if tokens is None or tokens[j] is None:
                assignments.append(self._assign_private(slot, n))
            else:
                toks = list(tokens[j])
                assert len(toks) == n, (len(toks), n)
                assignments.append(self.assign_blocks(slot, toks))

        if self.model is not None:
            self._scatter_prefill(slots, prefilled, lengths, assignments)
        for slot, n in zip(slots, lengths):
            self.set_length(slot, int(n) + 1)

    def _assign_private(self, slot: int, n_prefill_tokens: int
                        ) -> List[Tuple[int, int, bool]]:
        """Block table without prefix sharing (no token ids available)."""
        assert self._nblk[slot] == 0
        out = []
        for l in range(self.blocks_for_tokens(n_prefill_tokens)):
            page = self._alloc_page()
            if page is None:
                self._release_slot_pages(slot)
                raise RuntimeError("BlockPool exhausted during "
                                   "_assign_private")
            self._tables[slot, l] = page
            self._nblk[slot] = l + 1
            out.append((l, page, False))
        self.paging.prefix_miss_tokens += max(n_prefill_tokens, 0)
        self._table_dev = None
        return out

    def _scatter_prefill(self, slots, prefilled, lengths, assignments):
        import jax.numpy as jnp
        bs = self.block_size
        new_caches = []
        for c_all, c_new in zip(self.caches, prefilled):
            assert isinstance(c_all, dict) and "index" in c_all
            merged = dict(c_all)
            ix = np.asarray(slots, np.int32)
            merged["index"] = c_all["index"].at[ix].set(
                jnp.asarray(np.asarray(lengths, np.int32)))
            for name, pages in c_all.items():
                if name == "index":
                    continue
                page_ids: List[int] = []
                blocks = []
                for j, assignment in enumerate(assignments):
                    row = c_new[name][j]              # [bucket, Kv, D]
                    n_l = len(assignment)
                    pad = n_l * bs - row.shape[0]
                    if pad > 0:
                        row = jnp.pad(row, [(0, pad)] + [(0, 0)] *
                                      (row.ndim - 1))
                    row = row[:n_l * bs].reshape((n_l, bs) + row.shape[1:])
                    fresh = [l for l, _, cached in assignment if not cached]
                    if not fresh:
                        continue
                    page_ids.extend(int(assignment[l][1]) for l in fresh)
                    blocks.append(row[jnp.asarray(fresh, jnp.int32)])
                if page_ids:
                    src = jnp.concatenate(blocks, axis=0).astype(pages.dtype)
                    merged[name] = pages.at[
                        jnp.asarray(page_ids, jnp.int32)].set(src)
            new_caches.append(merged)
        self.caches = new_caches

    def reset_slot(self, slot: int) -> None:
        """Zero-prefill path: fresh single private page, index 0."""
        self._release_slot_pages(slot)
        assignment = self._assign_private(slot, 0)
        assert len(assignment) == 1
        if self.model is not None:
            self.ensure_caches()
            self.caches = [
                dict(c, index=c["index"].at[slot].set(0))
                if isinstance(c, dict) and "index" in c else c
                for c in self.caches]
        self.set_length(slot, 1)

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    def occupancy(self) -> OccupancySummary:
        return OccupancySummary.from_lengths(
            (self._lengths[s] for s in self.live_slots()),
            max_bucket=self.max_context,
            block_pressure=self.utilization())

    def paging_summary(self) -> Dict[str, float]:
        """One flat dict for engine stats / benchmark rows."""
        p = self.paging
        return {
            "block_size": self.block_size,
            "blocks_usable": self.pool.usable,
            "blocks_used": self.pool.used_count(),
            "blocks_free": self.blocks_free(),
            "blocks_reclaimable": self.prefix.reclaimable_count(),
            "utilization": self.utilization(),
            "peak_blocks_used": self.pool.peak_used,
            "prefix_entries": len(self.prefix),
            "prefix_hit_tokens": p.prefix_hit_tokens,
            "prefix_miss_tokens": p.prefix_miss_tokens,
            "prefix_hit_rate": p.prefix_hit_rate,
            "prefix_hit_blocks": p.prefix_hit_blocks,
            "prefix_reclaimed_blocks": p.prefix_reclaimed_blocks,
            "preemptions": p.preemptions,
        }

    def __repr__(self) -> str:
        return (f"PagedKVCacheManager(slots={self.live_count()}/"
                f"{self.num_slots}, {self.pool!r}, "
                f"hit_rate={self.paging.prefix_hit_rate:.2f})")
