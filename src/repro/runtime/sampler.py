"""Token sampling: greedy / temperature / top-k, per-slot parameters."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample(key, logits, temperature, top_k=0):
    """logits: [B, V]; temperature: [B] (0 => greedy per slot); top_k a
    Python int shared by the batch, or a per-slot [B] int vector
    (0 => no truncation for that slot)."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1)
    if isinstance(top_k, (int, np.integer)):
        if top_k > 0:
            kth = jax.lax.top_k(logits, int(top_k))[0][:, -1:]
            logits = jnp.where(logits < kth, -jnp.inf, logits)
    else:
        V = logits.shape[-1]
        k = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32),
                             logits.shape[:1])
        ranked = jnp.sort(logits, axis=-1)[:, ::-1]          # descending
        kth = jnp.take_along_axis(ranked,
                                  jnp.clip(k[:, None], 1, V) - 1, axis=-1)
        logits = jnp.where((k[:, None] > 0) & (logits < kth),
                           -jnp.inf, logits)
    temp = jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(key, logits / temp, axis=-1)
    return jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)
