"""Token sampling: greedy / temperature / top-k, per-slot parameters."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(key, logits, temperature, top_k: int = 0):
    """logits: [B, V]; temperature: [B] (0 => greedy per slot)."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1)
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    temp = jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(key, logits / temp, axis=-1)
    return jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)
