"""Serving request objects and queue bookkeeping."""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import List, Optional

_ids = itertools.count()


class RequestState(Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"
    REJECTED = "rejected"     # refused admission (e.g. prompt > max_context)
    LENGTH_CAPPED = "length_capped"   # context grew to max_context: ended
                                      # before the next write would clobber
                                      # the last KV cache row


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    top_k: int = 0                    # 0 => no truncation
    eos_token: Optional[int] = None
    request_id: int = field(default_factory=lambda: next(_ids))
    state: RequestState = RequestState.WAITING
    output: List[int] = field(default_factory=list)
    error: Optional[str] = None       # set when state == REJECTED
    arrival_t: float = field(default_factory=time.perf_counter)
    admit_t: Optional[float] = None   # left the waiting queue (slot granted)
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    preemptions: int = 0              # evicted-to-recompute count (paged KV)

    @property
    def resume_tokens(self) -> List[int]:
        """Everything a (re-)prefill must feed: the prompt plus any tokens
        generated before a preemption evicted this request's KV. Equals
        the prompt for a fresh request; generation resumes from the last
        emitted token with no duplication (the final resume token is fed
        through decode, exactly like a fresh prompt's last token)."""
        return list(self.prompt) + list(self.output)

    @property
    def done(self) -> bool:
        if self.eos_token is not None and self.output \
                and self.output[-1] == self.eos_token:
            return True
        return len(self.output) >= self.max_new_tokens

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.arrival_t

    @property
    def tpot(self) -> Optional[float]:
        """Mean time per output token after the first (None until
        finished or with fewer than two tokens)."""
        if self.first_token_t is None or self.finish_t is None:
            return None
        n = len(self.output) - 1
        if n <= 0:
            return None
        return (self.finish_t - self.first_token_t) / n
