"""KV/occupancy manager: slot allocation, the per-slot context-length
ledger, and the engine's layer-cache surgery (init / batched-prefill
merge / evict).

The ``ServingEngine`` used to do all of this inline in ``_prefill_one``;
pulling it out makes the cache a first-class object that

  * the ``BatchScheduler`` consults for free capacity when admitting,
  * the scheduling layer reads as an ``OccupancySummary`` (live slots +
    context-length histogram) so decode plans are solved on the real
    batch composition,
  * tests can exercise ledger accounting without building a model
    (``model=None`` gives a ledger-only manager).

Cache layout (one entry per layer, mirroring ``Model.init_cache``):
  * attention caches are dicts with a per-slot ``index`` vector (the
    continuous-batching position of each slot);
  * recurrent/SSM states are dicts of per-slot state rows (no index);
  * eviction is ledger-only — stale rows are unreachable (masked by the
    index / overwritten by the next prefill), so no scrubbing is needed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.sched.occupancy import OccupancySummary


@dataclass
class KVStats:
    allocs: int = 0
    frees: int = 0
    peak_live: int = 0


class KVCacheManager:
    def __init__(self, num_slots: int, max_context: int, model=None,
                 dtype=None):
        self.num_slots = num_slots
        self.max_context = max_context
        self.model = model
        self.dtype = dtype if dtype is not None else getattr(model, "dtype",
                                                             None)
        self.caches: Optional[List[Any]] = None
        self._live = [False] * num_slots
        # context length per live slot: prompt tokens + generated tokens,
        # i.e. the KV positions the NEXT decode step attends over
        self._lengths = [0] * num_slots
        self.stats = KVStats()

    # ------------------------------------------------------------------
    # slot allocation / ledger
    # ------------------------------------------------------------------
    def alloc(self) -> Optional[int]:
        """Claim the lowest free slot (None when full)."""
        for slot in range(self.num_slots):
            if not self._live[slot]:
                return self.take(slot)
        return None

    def take(self, slot: int) -> int:
        """Claim a specific slot (must be free)."""
        if self._live[slot]:
            raise ValueError(f"slot {slot} is already live")
        self._live[slot] = True
        self._lengths[slot] = 0
        self.stats.allocs += 1
        self.stats.peak_live = max(self.stats.peak_live, self.live_count())
        return slot

    def free(self, slot: int) -> None:
        """Evict a slot: ledger-only (stale cache rows are masked by the
        per-slot index and overwritten by the next prefill)."""
        if not self._live[slot]:
            raise ValueError(f"slot {slot} is not live")
        self._live[slot] = False
        self._lengths[slot] = 0
        self.stats.frees += 1

    def live_slots(self) -> List[int]:
        return [s for s in range(self.num_slots) if self._live[s]]

    def live_count(self) -> int:
        return sum(self._live)

    def free_count(self) -> int:
        return self.num_slots - self.live_count()

    def length(self, slot: int) -> int:
        return self._lengths[slot]

    def lengths(self) -> List[int]:
        """Per-slot context lengths (0 for dead slots) — the [num_slots]
        vector the decode step feeds to ragged attention."""
        return list(self._lengths)

    def set_length(self, slot: int, n: int) -> None:
        self._lengths[slot] = int(n)

    def note_decode(self, slots: Sequence[int]) -> None:
        """Each decoded token extends its slot's context by one."""
        for s in slots:
            self._lengths[s] += 1

    def occupancy(self) -> OccupancySummary:
        """The live decode composition for plan resolution."""
        return OccupancySummary.from_lengths(
            (self._lengths[s] for s in self.live_slots()),
            max_bucket=self.max_context)

    # ------------------------------------------------------------------
    # cache surgery (requires a model)
    # ------------------------------------------------------------------
    def ensure_caches(self) -> None:
        if self.caches is not None:
            return
        if self.model is None:
            raise ValueError("ledger-only KVCacheManager (model=None) "
                             "holds no caches")
        caches = self.model.init_cache(self.num_slots, self.max_context,
                                       dtype=self.dtype)
        # scalar prefill index -> per-slot index vector
        self.caches = [
            dict(c, index=jnp.zeros((self.num_slots,), jnp.int32))
            if isinstance(c, dict) and "index" in c else c
            for c in caches]

    def merge_prefill(self, slots: Sequence[int], prefilled: List[Any],
                      lengths: Sequence[int]) -> None:
        """Scatter a batched-prefill cache (row j of ``prefilled``) into
        per-slot row ``slots[j]``; ``lengths[j]`` is the number of real
        (unpadded) prompt tokens row j holds, which becomes the slot's
        cache index. The ledger records lengths[j] + 1: the last prompt
        token is fed through the next decode step."""
        self.ensure_caches()
        ix = np.asarray(slots, np.int32)
        lens = jnp.asarray(np.asarray(lengths, np.int32))
        new_caches = []
        for c_all, c_new in zip(self.caches, prefilled):
            if isinstance(c_all, dict) and "index" in c_all:
                merged = {}
                for name, arr in c_all.items():
                    if name == "index":
                        merged[name] = arr.at[ix].set(lens)
                    else:
                        merged[name] = arr.at[ix].set(
                            c_new[name].astype(arr.dtype))
                new_caches.append(merged)
            elif isinstance(c_all, dict):    # ssm/recurrent state
                merged = {name: arr.at[ix].set(c_new[name].astype(arr.dtype))
                          for name, arr in c_all.items()}
                new_caches.append(merged)
            else:
                new_caches.append(c_all)
        self.caches = new_caches
        for slot, n in zip(slots, lengths):
            self.set_length(slot, int(n) + 1)

    def reset_slot(self, slot: int) -> None:
        """Zero-prefill path (empty / single-token prompt): reset the
        slot's cache index so decode starts writing at position 0."""
        self.ensure_caches()
        self.caches = [
            dict(c, index=c["index"].at[slot].set(0))
            if isinstance(c, dict) and "index" in c else c
            for c in self.caches]
        self.set_length(slot, 1)

    def __repr__(self) -> str:
        return (f"KVCacheManager(slots={self.live_count()}/{self.num_slots}"
                f", max_context={self.max_context}, "
                f"occupancy={self.occupancy()!r})")
