"""Continuous-batching serving engine with FinDEP online planning.

Slot-based continuous batching: a fixed decode batch of ``num_slots``;
waiting requests are prefilled (right-padded to a bucket length) into free
slots, every engine step decodes one token for all live slots with
per-slot cache indices, finished requests are evicted and their slots
refilled. For MoE models the engine consults the FinDEPPlanner on every
(bucket, batch) shape — the paper's online phase (Fig. 6) — and executes
the MoE layers with the solved (r2, order) chunking when a mesh is
attached.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.planner import FinDEPPlanner
from repro.models import build_model
from repro.models.transformer import ExecutionContext, Model
from repro.runtime.request import Request, RequestState
from repro.runtime.sampler import sample


def _bucket(n: int, buckets=(64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    steps: int = 0
    start_t: float = field(default_factory=time.perf_counter)

    def throughput(self) -> float:
        dt = time.perf_counter() - self.start_t
        return (self.prefill_tokens + self.decode_tokens) / max(dt, 1e-9)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params=None, *, num_slots: int = 4,
                 max_context: int = 4096, mesh=None,
                 planner: Optional[FinDEPPlanner] = None,
                 dtype=jnp.float32, seed: int = 0):
        plan = None
        if planner is not None:
            plan = planner.plan(max_context)
        ctx = ExecutionContext(
            mesh=mesh, plan=plan,
            moe_impl="dep" if (mesh is not None and cfg.is_moe)
            else "capacity")
        self.cfg = cfg
        self.model = build_model(cfg, ctx=ctx, dtype=dtype)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.num_slots = num_slots
        self.max_context = max_context
        self.planner = planner
        self.key = jax.random.PRNGKey(seed + 1)
        self.slots: List[Optional[Request]] = [None] * num_slots
        self.caches = None
        self.last_tokens = jnp.zeros((num_slots, 1), jnp.int32)
        self.temps = jnp.zeros((num_slots,), jnp.float32)
        self.waiting: List[Request] = []
        self.stats = EngineStats()
        self._decode_jit = jax.jit(self._decode_step)
        self._memory = None

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.waiting.append(req)

    def _ensure_caches(self):
        if self.caches is None:
            self.caches = self.model.init_cache(
                self.num_slots, self.max_context,
                dtype=self.model.dtype)
            # per-slot cache index
            self.caches = [
                dict(c, index=jnp.zeros((self.num_slots,), jnp.int32))
                if isinstance(c, dict) and "index" in c else c
                for c in self.caches]

    def _prefill_one(self, slot: int, req: Request):
        """Prefill the first L-1 prompt tokens into ``slot``; the last
        prompt token is fed through the shared decode step (so its logits
        produce the first sampled token at the right position)."""
        self._ensure_caches()
        L = len(req.prompt)
        Lp = max(L - 1, 0)
        if Lp > 0:
            # recurrent states would be corrupted by padded prefill tokens,
            # so SSM/hybrid prefill at exact length (per-length retrace)
            bucket = (Lp if self.cfg.family in ("ssm", "hybrid")
                      else min(_bucket(Lp), self.max_context))
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :Lp] = req.prompt[:Lp][:bucket]
            _, cache1 = self.model.prefill(
                self.params, jnp.asarray(toks), seq_budget=self.max_context)
            new_caches = []
            for c_all, c_one in zip(self.caches, cache1):
                if isinstance(c_all, dict) and "index" in c_all:
                    merged = {}
                    for name, arr in c_all.items():
                        if name == "index":
                            merged[name] = arr.at[slot].set(Lp)
                        else:
                            merged[name] = arr.at[slot].set(
                                c_one[name][0].astype(arr.dtype))
                    new_caches.append(merged)
                elif isinstance(c_all, dict):    # ssm/recurrent state
                    merged = {name: arr.at[slot].set(
                        c_one[name][0].astype(arr.dtype))
                        for name, arr in c_all.items()}
                    new_caches.append(merged)
                else:
                    new_caches.append(c_all)
            self.caches = new_caches
        else:
            self.caches = [
                dict(c, index=c["index"].at[slot].set(0))
                if isinstance(c, dict) and "index" in c else c
                for c in self.caches]
        self.last_tokens = self.last_tokens.at[slot, 0].set(
            req.prompt[-1] if L else 0)
        self.stats.prefill_tokens += Lp
        req.state = RequestState.RUNNING
        self.slots[slot] = req
        self.temps = self.temps.at[slot].set(req.temperature)

    def _admit(self):
        for slot in range(self.num_slots):
            if self.slots[slot] is None and self.waiting:
                self._prefill_one(slot, self.waiting.pop(0))

    # ------------------------------------------------------------------
    def _decode_step(self, params, tokens, caches, temps, key):
        logits, caches = self.model.decode_step(params, tokens, caches)
        nxt = sample(key, logits[:, -1], temps)
        return nxt[:, None], caches

    def step(self) -> bool:
        """One engine iteration; returns False when idle."""
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return False
        self.key, sub = jax.random.split(self.key)
        nxt, self.caches = self._decode_jit(
            self.params, self.last_tokens, self.caches, self.temps, sub)
        self.last_tokens = nxt
        toks = np.asarray(nxt[:, 0])
        now = time.perf_counter()
        for i in live:
            req = self.slots[i]
            req.output.append(int(toks[i]))
            if req.first_token_t is None:
                req.first_token_t = now
            self.stats.decode_tokens += 1
            if req.done:
                req.state = RequestState.FINISHED
                req.finish_t = now
                self.slots[i] = None
        self.stats.steps += 1
        return True

    def run(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.step() and not self.waiting:
                break
        return finished
