"""Continuous-batching serving engine with per-shape online scheduling.

Slot-based continuous batching: a fixed decode batch of ``num_slots``;
waiting requests are prefilled (right-padded to a bucket length) into free
slots, every engine step decodes one token for all live slots with
per-slot cache indices, finished requests are evicted (collected in
``finished``) and their slots refilled.

Scheduling is delegated to a pluggable ``repro.sched.SchedulePolicy``
behind a per-shape ``PlanCache`` — the paper's online phase (Fig. 6):

  * every prefill resolves a plan for its (bucket, batch) shape before the
    prompt tokens run — a new bucket length triggers a solve, a recurring
    one hits the cache;
  * every decode step resolves a plan for the current decode-batch
    composition (number of live slots); the plan is only re-solved when the
    composition changes, so steady-state decode pays one dict lookup.

Resolved plans are passed per call into the model (and from there to the
DEP executor) as static arguments; the ``ExecutionContext`` stays an
immutable distribution template with no baked-in schedule.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.planner import FinDEPPlanner
from repro.core.solver import Plan
from repro.models import build_model
from repro.models.transformer import ExecutionContext, Model
from repro.runtime.request import Request, RequestState
from repro.runtime.sampler import sample
from repro.sched import FinDEPPolicy, PlanCache, SchedulePolicy


def _bucket(n: int, buckets=(64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    steps: int = 0
    start_t: float = field(default_factory=time.perf_counter)

    def throughput(self) -> float:
        dt = time.perf_counter() - self.start_t
        return (self.prefill_tokens + self.decode_tokens) / max(dt, 1e-9)


class ServingEngine:
    """``policy`` is any repro.sched.SchedulePolicy; passing the legacy
    ``planner=FinDEPPlanner(...)`` wraps it in a FinDEPPolicy. With neither,
    the engine runs unscheduled (dense/capacity MoE or non-MoE models)."""

    def __init__(self, cfg: ModelConfig, params=None, *, num_slots: int = 4,
                 max_context: int = 4096, mesh=None,
                 planner: Optional[FinDEPPlanner] = None,
                 policy: Optional[SchedulePolicy] = None,
                 dtype=jnp.float32, seed: int = 0):
        if policy is None and planner is not None:
            policy = FinDEPPolicy(planner)
        self.policy = policy
        self.plan_cache = (PlanCache(policy) if (policy is not None
                                                 and cfg.is_moe) else None)
        ctx = ExecutionContext(
            mesh=mesh,
            moe_impl="dep" if (mesh is not None and cfg.is_moe)
            else "capacity")
        # plans are always resolved (the schedule is observable via
        # resolved_plans()/plan_cache even on one device), but they are only
        # threaded into the compiled programs when the DEP executor can act
        # on them — otherwise every distinct schedule would retrace decode
        # for a program it cannot change
        self._dep_active = ctx.moe_impl == "dep"
        self.cfg = cfg
        self.model = build_model(cfg, ctx=ctx, dtype=dtype)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.num_slots = num_slots
        self.max_context = max_context
        self.planner = planner
        self.key = jax.random.PRNGKey(seed + 1)
        self.slots: List[Optional[Request]] = [None] * num_slots
        self.caches = None
        self.last_tokens = jnp.zeros((num_slots, 1), jnp.int32)
        self.temps = jnp.zeros((num_slots,), jnp.float32)
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        self.stats = EngineStats()
        # only the executor-visible (r2, order) slice is a static argument:
        # plans differing in modeled throughput share one compiled program,
        # so retraces are bounded by distinct executable schedules
        self._decode_jit = jax.jit(self._decode_step,
                                   static_argnames=("plan",))
        self._memory = None

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    def _resolve_plan(self, phase: str, seq_bucket: int,
                      batch_per_device: Optional[int]) -> Optional[Plan]:
        if self.plan_cache is None:
            return None
        return self.plan_cache.get(phase, seq_bucket, batch_per_device)

    def _exec_schedule(self, plan: Optional[Plan]):
        if plan is None or not self._dep_active:
            return None
        return plan.exec_schedule()

    def resolved_plans(self) -> Dict[Any, Plan]:
        """All (phase, bucket, batch) -> Plan resolutions so far."""
        return self.plan_cache.entries() if self.plan_cache else {}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.waiting.append(req)

    def _ensure_caches(self):
        if self.caches is None:
            self.caches = self.model.init_cache(
                self.num_slots, self.max_context,
                dtype=self.model.dtype)
            # per-slot cache index
            self.caches = [
                dict(c, index=jnp.zeros((self.num_slots,), jnp.int32))
                if isinstance(c, dict) and "index" in c else c
                for c in self.caches]

    def _prefill_one(self, slot: int, req: Request):
        """Prefill the first L-1 prompt tokens into ``slot``; the last
        prompt token is fed through the shared decode step (so its logits
        produce the first sampled token at the right position)."""
        self._ensure_caches()
        L = len(req.prompt)
        Lp = max(L - 1, 0)
        if Lp > 0:
            # recurrent states would be corrupted by padded prefill tokens,
            # so SSM/hybrid prefill at exact length (per-length retrace)
            bucket = (Lp if self.cfg.family in ("ssm", "hybrid")
                      else min(_bucket(Lp), self.max_context))
            plan = self._resolve_plan("prefill", bucket, 1)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :Lp] = req.prompt[:Lp][:bucket]
            _, cache1 = self.model.prefill(
                self.params, jnp.asarray(toks), seq_budget=self.max_context,
                plan=self._exec_schedule(plan))
            new_caches = []
            for c_all, c_one in zip(self.caches, cache1):
                if isinstance(c_all, dict) and "index" in c_all:
                    merged = {}
                    for name, arr in c_all.items():
                        if name == "index":
                            merged[name] = arr.at[slot].set(Lp)
                        else:
                            merged[name] = arr.at[slot].set(
                                c_one[name][0].astype(arr.dtype))
                    new_caches.append(merged)
                elif isinstance(c_all, dict):    # ssm/recurrent state
                    merged = {name: arr.at[slot].set(
                        c_one[name][0].astype(arr.dtype))
                        for name, arr in c_all.items()}
                    new_caches.append(merged)
                else:
                    new_caches.append(c_all)
            self.caches = new_caches
        else:
            self.caches = [
                dict(c, index=c["index"].at[slot].set(0))
                if isinstance(c, dict) and "index" in c else c
                for c in self.caches]
        self.last_tokens = self.last_tokens.at[slot, 0].set(
            req.prompt[-1] if L else 0)
        self.stats.prefill_tokens += Lp
        req.state = RequestState.RUNNING
        self.slots[slot] = req
        self.temps = self.temps.at[slot].set(req.temperature)

    def _admit(self):
        for slot in range(self.num_slots):
            if self.slots[slot] is None and self.waiting:
                self._prefill_one(slot, self.waiting.pop(0))

    # ------------------------------------------------------------------
    def _decode_step(self, params, tokens, caches, temps, key, plan=None):
        logits, caches = self.model.decode_step(params, tokens, caches,
                                                plan=plan)
        nxt = sample(key, logits[:, -1], temps)
        return nxt[:, None], caches

    def step(self) -> bool:
        """One engine iteration; returns False when idle."""
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return False
        # decode-batch composition = number of live slots; shape changes
        # (evictions/admissions) re-resolve, steady state hits the cache
        plan = self._resolve_plan("decode", self.max_context, len(live))
        self.key, sub = jax.random.split(self.key)
        nxt, self.caches = self._decode_jit(
            self.params, self.last_tokens, self.caches, self.temps, sub,
            plan=self._exec_schedule(plan))
        self.last_tokens = nxt
        toks = np.asarray(nxt[:, 0])
        now = time.perf_counter()
        for i in live:
            req = self.slots[i]
            req.output.append(int(toks[i]))
            if req.first_token_t is None:
                req.first_token_t = now
            self.stats.decode_tokens += 1
            if req.done:
                req.state = RequestState.FINISHED
                req.finish_t = now
                self.finished.append(req)
                self.slots[i] = None
        self.stats.steps += 1
        return True

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive the engine until idle (or ``max_steps``); returns the
        requests that finished during this call."""
        start = len(self.finished)
        for _ in range(max_steps):
            if not self.step() and not self.waiting:
                break
        return self.finished[start:]
