"""Continuous-batching serving engine: a thin loop over the batch/KV
runtime objects.

The engine owns almost nothing anymore — each iteration is

  1. ``BatchScheduler.build_step(waiting, kv)``: reject oversized
     prompts, admit under the configured admission policy (fcfs / spf /
     token_budget), allocate KV slots, group admitted requests by padded
     prefill bucket;
  2. one batched ``model.prefill`` per ``PrefillGroup`` (chunked by the
     resolved plan's r1·m_a granularity), scattered into per-slot caches
     by the ``KVCacheManager``;
  3. one ``model.decode_step`` over the full slot batch, with per-slot
     temperature/top-k sampling; finished slots are evicted and their
     requests collected in ``finished``.

``kv_layout="paged"`` swaps the dense per-slot KV rows for the
block-granular ``PagedKVCacheManager`` (``repro.runtime.paging``): slots
hold block tables over a shared page pool, identical prompt prefixes
share pages through a content-hash cache, admission charges only
non-cached pages (watermark hysteresis gates it under pool pressure),
and decode steps grow tail pages on demand — preempting the
cheapest-to-recompute victim back to the waiting queue when the pool
runs dry. ``paging_stats()`` surfaces occupancy / hit-rate / preemption
counters. Decode outputs are bit-identical to the dense layout at equal
kernel blocking (``decode_bc`` = page size).

Scheduling is delegated to a pluggable ``repro.sched.SchedulePolicy``
behind a per-shape ``PlanCache`` — the paper's online phase (Fig. 6):

  * every prefill group resolves a plan for its (bucket, batch) shape
    before the prompt tokens run — a new shape triggers a solve, a
    recurring one hits the cache;
  * every decode step resolves a plan for the KV ledger's
    ``OccupancySummary`` (live slots + context-length histogram), so the
    solver sees the real batch composition instead of the old
    (max_context, live-count) proxy; the plan is re-solved only when the
    composition changes, so steady-state decode pays one dict lookup.

Resolved plans are passed per call into the model (and from there to the
DEP executor) as static arguments; the ``ExecutionContext`` stays an
immutable distribution template with no baked-in schedule.
"""
from __future__ import annotations

import math
import time
import warnings
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.perf_model import HardwareProfile, get_profile
from repro.core.planner import FinDEPPlanner
from repro.core.solver import Plan
from repro.models import build_model
from repro.models.transformer import ExecutionContext, Model
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TraceRecorder, use_tracer
from repro.placement import (ExpertLoadTracker, Placement, SkewSummary,
                             capacity_scale, max_rank_load, rebalance)
from repro.profiling import (DriftMonitor, PeriodicRecalibrator, PlanRefresher,
                             ProfileKey, ProfileStore, StepTimer)
from repro.profiling import calibrate as run_calibration
from repro.runtime.batching import BatchScheduler, PrefillGroup, StepPlan
from repro.runtime.kv import KVCacheManager
from repro.runtime.paging import PagedKVCacheManager
from repro.runtime.request import Request, RequestState
from repro.runtime.sampler import sample
from repro.sched import (FinDEPPolicy, OccupancySummary, PlanCache,
                         SchedulePolicy)


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    steps: int = 0
    # token assignments lost to expert-capacity overflow (counted by
    # moe_dispatch when expert-load telemetry is on; stays 0 otherwise)
    dropped_tokens: int = 0
    # clock starts on first submit/step, NOT at engine construction —
    # construction-time weight init would count as idle serving time
    start_t: Optional[float] = None

    def ensure_started(self) -> None:
        if self.start_t is None:
            self.start_t = time.perf_counter()

    def reset(self) -> None:
        """Zero the counters and re-arm the clock (benchmark warmup)."""
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.steps = 0
        self.dropped_tokens = 0
        self.start_t = None

    def throughput(self) -> float:
        if self.start_t is None:
            return 0.0
        dt = time.perf_counter() - self.start_t
        return (self.prefill_tokens + self.decode_tokens) / max(dt, 1e-9)


class ServingEngine:
    """``plan_policy`` is any repro.sched.SchedulePolicy; ``scheduler`` a
    configured BatchScheduler (or use the ``admission``/``token_budget``
    shorthands). The legacy ``policy=``/``planner=`` kwargs still work
    (with a DeprecationWarning): ``planner=FinDEPPlanner(...)`` wraps
    itself in a FinDEPPolicy. With no policy at all, the engine runs
    unscheduled (dense/capacity MoE or non-MoE models)."""

    def __init__(self, cfg: ModelConfig, params=None, *, num_slots: int = 4,
                 max_context: int = 4096, mesh=None,
                 scheduler: Optional[BatchScheduler] = None,
                 admission: str = "fcfs",
                 token_budget: Optional[int] = None,
                 plan_policy: Optional[SchedulePolicy] = None,
                 planner: Optional[FinDEPPlanner] = None,
                 policy: Optional[SchedulePolicy] = None,
                 plan_cache_capacity: Optional[int] = None,
                 telemetry=None,
                 tracer=None,
                 metrics=None,
                 profile=None, calibrate: bool = False,
                 profile_store=None,
                 drift_threshold: Optional[float] = None,
                 drift_min_samples: int = 3,
                 drift_recalibrate: bool = True,
                 recalibrate_max_age_s: Optional[float] = None,
                 attn_impl: str = "decode_kernel",
                 kv_layout: str = "dense",
                 kv_block_size: int = 32,
                 kv_num_blocks: Optional[int] = None,
                 kv_watermark_high: float = 0.90,
                 kv_watermark_low: float = 0.75,
                 decode_bc: Optional[int] = None,
                 replicate_hot_k: int = 0,
                 rebalance_threshold: Optional[float] = None,
                 track_expert_load: Optional[bool] = None,
                 rebalance_min_observations: int = 3,
                 max_capacity_scale: float = 4.0,
                 interleave: str = "streams",
                 validate: bool = False,
                 dtype=jnp.float32, seed: int = 0):
        if policy is not None:
            warnings.warn(
                "ServingEngine(policy=...) is deprecated; pass "
                "plan_policy=...", DeprecationWarning, stacklevel=2)
            if plan_policy is None:
                plan_policy = policy
        if planner is not None:
            warnings.warn(
                "ServingEngine(planner=...) is deprecated; pass "
                "plan_policy=FinDEPPolicy(planner)",
                DeprecationWarning, stacklevel=2)
            if plan_policy is None:
                plan_policy = FinDEPPolicy(planner)
        self.policy = plan_policy          # back-compat alias
        self.plan_policy = plan_policy
        self.plan_cache = (PlanCache(plan_policy,
                                     capacity=plan_cache_capacity)
                           if (plan_policy is not None and cfg.is_moe)
                           else None)
        # measured cost models (repro.profiling): an explicit profile= /
        # calibrate= retunes the policy's planner before anything is solved
        self.calibration = None
        self._apply_profile(profile, calibrate, profile_store, mesh)
        # telemetry: StepTimer instance, or False to disable (default on)
        if telemetry is False:
            self.telemetry: Optional[StepTimer] = None
        else:
            self.telemetry = (telemetry if isinstance(telemetry, StepTimer)
                              else StepTimer())
        # tracer: a repro.obs.TraceRecorder (or True for a fresh one);
        # None/False = tracing off — the default, and the compiled
        # programs are bit-identical either way (test-locked)
        if tracer is True:
            tracer = TraceRecorder()
        self.tracer: Optional[TraceRecorder] = \
            tracer if isinstance(tracer, TraceRecorder) else None
        # metrics: a repro.obs.MetricsRegistry (shared across engines),
        # None for a fresh private one (default on — sources are only
        # polled at snapshot time), or False to disable
        if metrics is False:
            self.metrics: Optional[MetricsRegistry] = None
        else:
            self.metrics = (metrics if isinstance(metrics, MetricsRegistry)
                            else MetricsRegistry())
        self.drift: Optional[DriftMonitor] = None
        if drift_threshold is not None and self.plan_cache is not None:
            self.drift = DriftMonitor(
                self.plan_cache,
                timer=self.telemetry if self.telemetry is not None
                else StepTimer(),
                threshold=drift_threshold,
                min_samples=drift_min_samples,
                recalibrate=drift_recalibrate,
                metrics=self.metrics)
        # cron-style background re-calibration: when the stored profile
        # for this host goes stale, re-run the microbenchmarks off the
        # critical path (step() polls; the check is throttled)
        self.recalibrator: Optional[PeriodicRecalibrator] = None
        if (recalibrate_max_age_s is not None
                and self.plan_cache is not None
                and self.profile_store is not None):
            self.recalibrator = PeriodicRecalibrator(
                self.plan_cache, self.profile_store, mesh=mesh,
                max_age_s=recalibrate_max_age_s,
                refresher=self.drift.refresher if self.drift else None,
                timer=self.telemetry, metrics=self.metrics)
        # decode attention defaults to the ragged Pallas kernel: per-slot
        # ledger lengths let it skip KV blocks past each row's context
        # (attention_decode falls back to dense SDPA for MLA/ring caches);
        # attn_impl="xla" restores the dense path for A/B parity checks
        ctx = ExecutionContext(
            mesh=mesh,
            attn_impl=attn_impl,
            moe_impl="dep" if (mesh is not None and cfg.is_moe)
            else "capacity",
            decode_bc=decode_bc)
        # plans are always resolved (the schedule is observable via
        # resolved_plans()/plan_cache even on one device), but they are only
        # threaded into the compiled programs when the DEP executor can act
        # on them — otherwise every distinct schedule would retrace decode
        # for a program it cannot change
        self._dep_active = ctx.moe_impl == "dep"
        # cross-micro-batch interleaving for the DEP executor: "streams"
        # (default) emits the exec graph's ops in scheduled start order
        # so micro-batch i+1's GATE group is issued before micro-batch
        # i's E2A retires; "off" keeps the sequential per-stream walk.
        # Both execute bit-identical values (parity test-locked).
        if interleave not in ("off", "streams"):
            raise ValueError(f"interleave must be 'off' or 'streams', "
                             f"got {interleave!r}")
        self.interleave = interleave
        # opt-in static verification: every ExecProgram a resolved plan
        # compiles to is run through repro.analysis.graphcheck before it
        # reaches a trace (structure, capacity multiple, deadlock-freedom,
        # hint-vector validity); a tampered/dep-inconsistent hint vector
        # raises AnalysisError at plan time. Programs are hashable, so
        # each distinct program is checked once.
        self.validate = bool(validate)
        self._validated_programs: set = set()
        self.cfg = cfg
        self.model = build_model(cfg, ctx=ctx, dtype=dtype)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        # expert placement subsystem (observe -> place -> plan): gate loads
        # feed an EWMA tracker; a threshold breach re-solves the expert ->
        # rank map (+ hot replicas) on the refresh worker; the new layout
        # is applied between steps by permuting the stacked expert weights
        self.replicate_hot_k = max(int(replicate_hot_k), 0)
        self.rebalance_threshold = rebalance_threshold
        self.rebalance_min_observations = int(rebalance_min_observations)
        self._max_capacity_scale = float(max_capacity_scale)
        placement_wanted = (self.replicate_hot_k > 0
                            or rebalance_threshold is not None)
        if track_expert_load is None:
            track_expert_load = placement_wanted
        # stats collection needs the per-layer Python sink (absent under
        # scan_layers) and an MoE model; placement execution needs DEP
        self._track_load = bool(track_expert_load and cfg.is_moe
                                and not self.model.scan_layers)
        if placement_wanted and not self._track_load:
            warnings.warn(
                "replicate_hot_k/rebalance_threshold need expert-load "
                "telemetry (MoE model, scan_layers=False); placement is "
                "disabled", stacklevel=2)
            placement_wanted = False
        self.load_tracker = (ExpertLoadTracker(self.model.E_pad)
                             if self._track_load else None)
        self._ep_ranks = (mesh.shape[ctx.expert_axis]
                          if self._dep_active else 1)
        self.placement: Optional[Placement] = None
        self._pending_placement: Optional[Placement] = None
        self._placement_enabled = placement_wanted and self._dep_active
        if placement_wanted and not self._dep_active:
            warnings.warn(
                "replicate_hot_k/rebalance_threshold act on the DEP "
                "executor (mesh + MoE); load telemetry stays on but no "
                "re-placement will run", stacklevel=2)
        self._placement_refresher: Optional[PlanRefresher] = None
        self._owns_placement_refresher = False
        if self._placement_enabled:
            if self.drift is not None:
                self._placement_refresher = self.drift.refresher
            else:
                self._placement_refresher = PlanRefresher(self.plan_cache)
                self._owns_placement_refresher = True
        self.num_slots = num_slots
        self.max_context = max_context
        self.planner = planner
        self.key = jax.random.PRNGKey(seed + 1)
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout={kv_layout!r}; "
                             "choose 'dense' or 'paged'")
        self.kv_layout = kv_layout
        self._paged = kv_layout == "paged"
        if self._paged:
            # paged decode scatters/streams through a block table; ring
            # windows, MLA latent caches and recurrent states have no
            # block-granular layout (ROADMAP follow-up)
            if (cfg.attention != "full" or cfg.mla_kv_lora_rank
                    or cfg.family not in ("dense", "moe")):
                raise ValueError(
                    "kv_layout='paged' requires a full-attention GQA "
                    f"decoder (family={cfg.family!r}, "
                    f"attention={cfg.attention!r}, "
                    f"mla={cfg.mla_kv_lora_rank})")
            self.kv: KVCacheManager = PagedKVCacheManager(
                num_slots, max_context, model=self.model,
                dtype=self.model.dtype, block_size=kv_block_size,
                num_blocks=kv_num_blocks,
                watermark_high=kv_watermark_high,
                watermark_low=kv_watermark_low)
        else:
            self.kv = KVCacheManager(num_slots, max_context,
                                     model=self.model,
                                     dtype=self.model.dtype)
        self.scheduler = scheduler if scheduler is not None else \
            BatchScheduler(admission=admission, token_budget=token_budget)
        self.slots: List[Optional[Request]] = [None] * num_slots
        self.last_tokens = jnp.zeros((num_slots, 1), jnp.int32)
        self.temps = jnp.zeros((num_slots,), jnp.float32)
        self.top_ks = jnp.zeros((num_slots,), jnp.int32)
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        self.stats = EngineStats()
        # only the executor-visible task graph (keyed by r2/order/m_e) is
        # a static argument: plans differing in modeled throughput share
        # one compiled program, so retraces are bounded by distinct
        # executable schedules
        self._decode_jit = jax.jit(
            self._decode_step,
            static_argnames=("plan", "use_topk", "placement",
                             "cap_scale", "collect_stats"))
        self._memory = None
        self._h_ttft = self._h_tpot = None
        self._h_decode = self._h_prefill = None
        if self.metrics is not None:
            self._register_metrics(self.metrics)

    # ------------------------------------------------------------------
    # observability (repro.obs): metrics registration, phase spans
    # ------------------------------------------------------------------
    def _register_metrics(self, m: MetricsRegistry) -> None:
        """Wire every stat surface the engine owns into one registry:
        latency histograms observed at event sites, the existing counter
        surfaces as polled snapshot sources, and one registry-level
        ``reset()`` that clears ALL of them (including the StepTimer /
        expert-load EWMAs the old per-surface resets leaked)."""
        self._h_ttft = m.histogram(
            "repro_engine_ttft_seconds", "time to first token")
        self._h_tpot = m.histogram(
            "repro_engine_tpot_seconds", "mean time per output token")
        self._h_decode = m.histogram(
            "repro_engine_decode_step_seconds", "decode step wall time")
        self._h_prefill = m.histogram(
            "repro_engine_prefill_chunk_seconds",
            "prefill chunk wall time")
        m.register_source("repro_engine", self._engine_snapshot)
        m.register_reset(self.stats.reset)
        if self.plan_cache is not None:
            m.register_source("repro_plan_cache",
                              self.plan_cache.stats.as_dict)
        if self.telemetry is not None:
            m.register_source("repro_telemetry", self.telemetry.snapshot)
            m.register_reset(self.telemetry.reset)
        if self.load_tracker is not None:
            m.register_source("repro_expert_load",
                              self.load_tracker.snapshot)
            m.register_reset(self.load_tracker.reset)
        if self._paged:
            m.register_source("repro_paging", self.kv.paging_summary)
            m.register_reset(self.kv.paging.reset)
        if self.drift is not None:
            m.register_source("repro_drift", self._drift_snapshot)

    def _engine_snapshot(self) -> Dict[str, float]:
        return {"prefill_tokens_total": float(self.stats.prefill_tokens),
                "decode_tokens_total": float(self.stats.decode_tokens),
                "steps_total": float(self.stats.steps),
                "dropped_tokens_total": float(self.stats.dropped_tokens),
                "throughput_tokens_per_s": self.stats.throughput(),
                "waiting": float(len(self.waiting)),
                "live_slots": float(sum(r is not None
                                        for r in self.slots))}

    def _drift_snapshot(self) -> Dict[str, float]:
        st = self.drift.stats
        return {"observations_total": float(st.observations),
                "events_total": float(st.drift_events)}

    def reset_stats(self) -> None:
        """THE warmup boundary: one call clears every stat surface. With
        a metrics registry this routes through ``MetricsRegistry.reset()``
        (counters, histograms, and the registered reset hooks); without
        one it clears the same surfaces directly. Either way the
        StepTimer EWMAs and expert-load EWMAs restart — the old
        ``stats.reset()``-only idiom left them carrying warmup samples."""
        if self.metrics is not None:
            self.metrics.reset()
        else:
            self.stats.reset()
            if self.telemetry is not None:
                self.telemetry.reset()
            if self.load_tracker is not None:
                self.load_tracker.reset()
            if self._paged:
                self.kv.paging.reset()
        if self.tracer is not None:
            self.tracer.clear()

    @contextmanager
    def _phase(self, name: str, **args):
        """Phase span + active-tracer scope around a step phase. With no
        tracer this adds NOTHING to the path (no contextvar touch), so
        the executor walk and the compiled programs are unchanged."""
        if self.tracer is None:
            yield
            return
        with use_tracer(self.tracer), \
                self.tracer.span(name, track="engine", **args):
            yield

    # ------------------------------------------------------------------
    # measured cost models
    # ------------------------------------------------------------------
    def _apply_profile(self, profile, calibrate: bool, profile_store,
                       mesh) -> None:
        """Retune the policy's planner onto a measured HardwareProfile.

        ``calibrate=True`` runs the on-device microbenchmarks now (fast
        sweep) and, when a ``profile_store`` is given, persists the fit so
        the next process can pass ``profile=<name>`` instead of
        re-measuring. ``profile=`` accepts a HardwareProfile, a stored
        profile name, or a registry name (repro.core.perf_model.PROFILES).
        """
        store = None
        if profile_store is not None:
            store = (profile_store if isinstance(profile_store, ProfileStore)
                     else ProfileStore(profile_store))
        self.profile_store = store
        if not calibrate and profile is None:
            return
        if calibrate:
            key = ProfileKey.for_host(mesh)
            name = profile if isinstance(profile, str) else key.slug()
            result = run_calibration(name=name, fast=True, mesh=mesh)
            hw = result.profile
            self.calibration = result
            if store is not None:
                store.put_calibration(result, key, name=name)
        elif isinstance(profile, HardwareProfile):
            hw = profile
        else:
            try:
                hw = (store.load_profile(profile) if store is not None
                      else get_profile(profile))
            except KeyError:
                hw = get_profile(profile)
        reprofile = getattr(self.plan_policy, "reprofile", None)
        if callable(reprofile):
            reprofile(hw)
        elif self.plan_policy is not None:
            warnings.warn(
                f"policy {getattr(self.plan_policy, 'name', '?')!r} has no "
                "reprofile() hook; profile=/calibrate= had no effect on "
                "planning", stacklevel=3)

    def _observe(self, phase: str, key, measured_s: float,
                 plan: Optional[Plan], predicted_scale: float = 1.0) -> None:
        if phase == "decode" and self._h_decode is not None:
            self._h_decode.observe(measured_s)
        elif phase == "prefill" and self._h_prefill is not None:
            self._h_prefill.observe(measured_s)
        predicted = None
        breakdown = None
        if plan is not None and plan.makespan > 0.0:
            predicted = plan.makespan * predicted_scale
            # the lowered graph's per-primitive split of that prediction —
            # lets drift attribution retune gemm/attn/comm separately
            if plan.breakdown is not None:
                breakdown = plan.breakdown.scaled(predicted_scale).as_dict()
        if self.drift is not None:
            self.drift.observe(key, measured_s, predicted, phase=phase,
                               breakdown=breakdown)
        elif self.telemetry is not None:
            self.telemetry.observe(phase, measured_s, predicted_s=predicted,
                                   key=key, breakdown=breakdown)

    def close(self) -> None:
        """Stop the background refresh/recalibration workers (if any)."""
        if self.drift is not None:
            self.drift.close()
        if self.recalibrator is not None:
            self.recalibrator.close()
        if self._owns_placement_refresher \
                and self._placement_refresher is not None:
            self._placement_refresher.close()

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    def _resolve_plan(self, phase: str, seq_bucket: Optional[int] = None,
                      batch_per_device: Optional[int] = None,
                      occupancy: Optional[OccupancySummary] = None,
                      skew: Optional[SkewSummary] = None
                      ) -> Optional[Plan]:
        if self.plan_cache is None:
            return None
        return self.plan_cache.get(phase, seq_bucket, batch_per_device,
                                   occupancy=occupancy, skew=skew)

    def _exec_program(self, plan: Optional[Plan],
                      streams: Optional[int] = None):
        """The ``ExecProgram`` the DEP executor walks for ``plan`` —
        hashable, keyed by (r1, r2, order, m_e, interleave, hints) plus
        the active placement's replica count and epoch, so plans that
        compile to the same program share one trace and a re-balance
        keys a fresh one. ``streams`` overrides the stream split (the
        prefill path passes the lowered chunk's micro-batch count — the
        r1 streams one prefill call covers); decode uses the plan's
        r1."""
        if plan is None or (not self._dep_active and not self.validate):
            return None
        hot, epoch = 0, 0
        if self.placement is not None:
            hot, epoch = self.placement.hot_experts, self.placement.epoch
        program = plan.exec_program(streams=streams, hot_experts=hot,
                                    placement_epoch=epoch,
                                    interleave=self.interleave)
        if self.validate:
            self._check_program(program)
        # single-device engines still resolve plans (observable via
        # resolved_plans()), but the compiled programs must not see them
        return program if self._dep_active else None

    def _check_program(self, program) -> None:
        """Static-verify an ExecProgram (see ``validate``); memoized on
        the program's own hash so each distinct program pays once."""
        if program in self._validated_programs:
            return
        from repro.analysis import AnalysisError
        from repro.analysis.graphcheck import check_exec_program
        violations = check_exec_program(program)
        if violations:
            raise AnalysisError(violations)
        self._validated_programs.add(program)

    # ------------------------------------------------------------------
    # expert placement (observe -> place -> plan)
    # ------------------------------------------------------------------
    _PLACEMENT_KEY = ("__placement__",)

    def _current_skew(self) -> Optional[SkewSummary]:
        """The quantized skew fingerprint plans are resolved under; None
        when telemetry is off or routing is (still) uniform — the legacy
        key space and cost model."""
        if self.load_tracker is None:
            return None
        s = self.load_tracker.summary(placement=self.placement,
                                      num_ranks=self._ep_ranks)
        return None if s.is_uniform else s

    def _capacity_scale(self, skew: Optional[SkewSummary]) -> float:
        """Static capacity multiplier for the executed dispatch, rounded
        up to a power of two (bounds trace cardinality) and capped at
        ``max_capacity_scale`` (bounds buffer growth)."""
        if skew is None or not self._dep_active:
            return 1.0
        raw = capacity_scale(skew, self.cfg.moe.capacity_factor)
        if raw <= 1.0:
            return 1.0
        return float(min(2.0 ** math.ceil(math.log2(raw)),
                         self._max_capacity_scale))

    def rank_imbalance(self) -> float:
        """Worst EP rank's cold (non-replicated) load as a multiple of
        the uniform 1/eg share under the ACTIVE placement — the
        re-balance trigger metric (1.0 = perfectly flat)."""
        if self.load_tracker is None:
            return 1.0
        pl = self.placement if self.placement is not None else \
            Placement.uniform(self.model.E_pad, self._ep_ranks)
        return max_rank_load(pl, self.load_tracker.aggregate()) \
            * self._ep_ranks

    def _solve_placement(self) -> None:
        """Refresh-worker job: greedy re-placement against the tracked
        loads; the result is STAGED — ``step()`` applies it between
        decode steps (weight permutation must not race a running step)."""
        epoch = (self.placement.epoch if self.placement else 0) + 1
        self._pending_placement = rebalance(
            self.load_tracker.aggregate(), self._ep_ranks,
            replicate_hot_k=self.replicate_hot_k, epoch=epoch)

    def _maybe_rebalance(self) -> bool:
        """Schedule a background re-placement when the active layout's
        rank imbalance breaches ``rebalance_threshold``. Mirrors the
        drift machinery: one in-flight episode, never blocks a step."""
        if (not self._placement_enabled
                or self.rebalance_threshold is None
                or self._pending_placement is not None):
            return False
        if (self.load_tracker.observations
                < self.rebalance_min_observations):
            return False
        if self.rank_imbalance() <= self.rebalance_threshold:
            return False
        return self._placement_refresher.request_job(
            self._PLACEMENT_KEY, self._solve_placement)

    def rebalance_now(self) -> Optional[Placement]:
        """Synchronous re-placement (tests / maintenance windows): solve
        against the tracked loads and apply immediately."""
        if not self._placement_enabled or self.load_tracker is None:
            return None
        self._solve_placement()
        pending, self._pending_placement = self._pending_placement, None
        self._apply_placement(pending)
        return self.placement

    def _apply_placement(self, new: Placement) -> None:
        """Install a re-balanced layout: permute the stacked expert
        weights so physical slot ``new.perm[e]`` holds logical expert
        ``e``, bump the active placement (epoch keys fresh exec graphs
        and plan-cache entries), and invalidate stale-epoch entries."""
        old = self.placement if self.placement is not None else \
            Placement.uniform(new.num_experts, new.num_ranks)
        # physical gather realizing the old -> new layout change:
        # new_phys[p] = logical[inv_new[p]] = old_phys[old.perm[inv_new[p]]]
        inv_new = np.argsort(np.asarray(new.perm))
        gather = np.asarray(old.perm)[inv_new]
        if not np.array_equal(gather, np.arange(new.num_experts)):
            idx = jnp.asarray(gather)
            for layer in self.params["layers"]:
                if "moe" in layer and "experts" in layer["moe"]:
                    layer["moe"]["experts"] = jax.tree.map(
                        lambda a: a[idx], layer["moe"]["experts"])
        self.placement = new
        if self.metrics is not None:
            self.metrics.counter(
                "repro_rebalance_applied_total",
                "expert re-placements installed between steps").inc()
        if self.plan_cache is not None:
            # entries solved under an older placement epoch can never be
            # served again (lookups now carry the new epoch's summary)
            for key in list(self.plan_cache.entries()):
                tail = key[-1]
                if isinstance(tail, SkewSummary) and tail.epoch != new.epoch:
                    self.plan_cache.invalidate(key)

    def expert_load(self) -> Optional[Dict[str, float]]:
        """Expert-load telemetry snapshot (None when tracking is off)."""
        if self.load_tracker is None:
            return None
        return dict(observations=float(self.load_tracker.observations),
                    imbalance=self.load_tracker.imbalance(),
                    rank_imbalance=self.rank_imbalance(),
                    dropped_tokens=float(self.stats.dropped_tokens),
                    epoch=float(self.placement.epoch
                                if self.placement else 0),
                    hot_experts=float(self.placement.hot_experts
                                      if self.placement else 0))

    def resolved_plans(self) -> Dict[Any, Plan]:
        """Every resolution so far: prefill plans keyed
        (phase, bucket, batch), decode plans keyed
        (phase, OccupancySummary)."""
        return self.plan_cache.entries() if self.plan_cache else {}

    # ------------------------------------------------------------------
    @property
    def caches(self):
        return self.kv.caches

    def submit(self, req: Request):
        self.stats.ensure_started()
        self.waiting.append(req)

    def _finish(self, req: Request, state: RequestState,
                now: float) -> None:
        """THE single request-termination site (finished / length-capped
        / rejected): stamps the terminal state, records the lifecycle
        spans and the TTFT/TPOT observations."""
        req.state = state
        req.finish_t = now
        self.finished.append(req)
        if self.tracer is not None:
            self.tracer.request_lifecycle(req, finish_t=now)
        if self.metrics is not None:
            self.metrics.counter(
                "repro_engine_requests_total",
                "requests by terminal state",
                labels={"state": state.value}).inc()
            if req.ttft is not None:
                self._h_ttft.observe(req.ttft)
            if req.tpot is not None:
                self._h_tpot.observe(req.tpot)

    def _prefill_group(self, group: PrefillGroup):
        """Run one same-bucket group as batched prefill calls, chunked by
        the resolved plan's r1·m_a granularity (the AG-side samples one
        plan iteration admits), and scatter the rows into per-slot
        caches."""
        self.kv.ensure_caches()
        if group.bucket == 0:
            # empty/single-token prompts: nothing to prefill, the (only)
            # prompt token is fed through the shared decode step
            for slot, req in zip(group.slots, group.requests):
                self.kv.reset_slot(slot)
                self._activate(slot, req, prefilled=0)
            return
        skew = self._current_skew()
        plan = self._resolve_plan("prefill", group.bucket,
                                  len(group.requests), skew=skew)
        plan_key = ("prefill", group.bucket, len(group.requests))
        if skew is not None:
            plan_key = plan_key + (skew,)
        chunk = len(group.requests)
        n_mb = 1
        if plan is not None:
            # chunk granularity comes from the lowered task graph — the
            # number of AG micro-batches one plan iteration admits, times
            # the per-micro-batch sample count — rather than re-deriving
            # plan.r1 * plan.m_a by hand (one Plan->structure translation).
            # The SAME n_mb is the stream split of the interleaved prefill
            # program below: one prefill call covers the n_mb micro-batch
            # streams the solver scheduled, and the MoE walk interleaves
            # them instead of the host loop running them back-to-back.
            from repro.core.taskgraph import ATTN, LoweringSpec, lower
            graph = lower(plan, LoweringSpec(T=1))
            n_mb = len(graph.tasks_of(ATTN, layer=0))
            chunk = max(min(n_mb * max(int(plan.m_a), 1), chunk), 1)
        for ofs in range(0, len(group.requests), chunk):
            reqs = group.requests[ofs:ofs + chunk]
            slots = group.slots[ofs:ofs + chunk]
            toks = np.zeros((len(reqs), group.bucket), np.int32)
            lengths = []
            token_rows = []
            for j, req in enumerate(reqs):
                feed = req.resume_tokens     # prompt (+ preempted output)
                Lp = len(feed) - 1
                toks[j, :Lp] = feed[:Lp]
                lengths.append(Lp)
                token_rows.append(feed[:Lp])
            t0 = time.perf_counter()
            with self._phase("prefill_chunk", bucket=group.bucket,
                             reqs=len(reqs)):
                if self._track_load:
                    _, prefilled, mstats = self.model.prefill(
                        self.params, jnp.asarray(toks),
                        seq_budget=self.max_context,
                        plan=self._exec_program(plan, streams=n_mb),
                        placement=self.placement
                        if self._dep_active else None,
                        return_moe_stats=True,
                        capacity_scale=self._capacity_scale(skew))
                else:
                    _, prefilled = self.model.prefill(
                        self.params, jnp.asarray(toks),
                        seq_budget=self.max_context,
                        plan=self._exec_program(plan, streams=n_mb))
                    mstats = None
                jax.block_until_ready(prefilled)
            if mstats is not None:
                self.load_tracker.observe(np.asarray(mstats.load))
                self.stats.dropped_tokens += int(mstats.dropped)
            # plan.makespan models one full r1·m_a chunk; pro-rate the
            # prediction for a remainder chunk so it isn't biased short
            self._observe("prefill", plan_key, time.perf_counter() - t0,
                          plan, predicted_scale=len(reqs) / chunk)
            if self._paged:
                # token ids key the prefix cache: shared full blocks map
                # to already-resident pages and skip the copy
                self.kv.merge_prefill(slots, prefilled, lengths,
                                      tokens=token_rows)
            else:
                self.kv.merge_prefill(slots, prefilled, lengths)
            for slot, req, Lp in zip(slots, reqs, lengths):
                self._activate(slot, req, prefilled=Lp)

    def _activate(self, slot: int, req: Request, prefilled: int):
        self.stats.ensure_started()
        if req.admit_t is None:          # first admission, not a resume
            req.admit_t = time.perf_counter()
        feed = req.resume_tokens
        self.last_tokens = self.last_tokens.at[slot, 0].set(
            feed[-1] if feed else 0)
        self.temps = self.temps.at[slot].set(req.temperature)
        self.top_ks = self.top_ks.at[slot].set(req.top_k)
        self.stats.prefill_tokens += prefilled
        req.state = RequestState.RUNNING
        self.slots[slot] = req

    def _prefill_one(self, slot: int, req: Request):
        """Single-request shim over the batched path (kept for parity
        tests and direct callers): prefill the first L-1 prompt tokens
        into ``slot``; the last prompt token is fed through the shared
        decode step."""
        if len(req.resume_tokens) > self.max_context:
            raise ValueError(
                f"prompt of {len(req.resume_tokens)} tokens exceeds "
                f"max_context={self.max_context}; submit() rejects such "
                "requests instead of truncating")
        self.kv.take(slot)
        Lp = max(len(req.resume_tokens) - 1, 0)
        if Lp == 0:
            bucket = 0
        elif self.cfg.family in ("ssm", "hybrid"):
            bucket = Lp
        else:
            from repro.sched import bucket_length
            bucket = min(bucket_length(Lp), self.max_context)
        self._prefill_group(PrefillGroup(bucket, [slot], [req]))

    def _admit(self) -> StepPlan:
        step_plan = self.scheduler.build_step(
            self.waiting, self.kv, max_context=self.max_context,
            exact_length=self.cfg.family in ("ssm", "hybrid"))
        now = time.perf_counter()
        for req in step_plan.rejected:
            self._finish(req, RequestState.REJECTED, now)
        for group in step_plan.prefills:
            self._prefill_group(group)
        return step_plan

    # ------------------------------------------------------------------
    def _decode_step(self, params, tokens, caches, temps, top_ks, key,
                     lengths, block_tables=None, plan=None, use_topk=False,
                     placement=None, cap_scale=1.0, collect_stats=False):
        # placement / cap_scale / collect_stats are static: with the
        # defaults the model compiles the exact legacy program (no stats
        # reductions, uniform dispatch), so engines without expert-load
        # telemetry trace nothing new
        if collect_stats:
            logits, caches, mstats = self.model.decode_step(
                params, tokens, caches, plan=plan, lengths=lengths,
                block_tables=block_tables, placement=placement,
                return_moe_stats=True, capacity_scale=cap_scale)
        else:
            logits, caches = self.model.decode_step(
                params, tokens, caches, plan=plan, lengths=lengths,
                block_tables=block_tables, placement=placement,
                capacity_scale=cap_scale)
            mstats = None
        # use_topk is static: when no live request truncates, the compiled
        # program skips the per-slot [B, V] threshold sort entirely
        nxt = sample(key, logits[:, -1], temps, top_ks if use_topk else 0)
        return nxt[:, None], caches, mstats

    # ------------------------------------------------------------------
    # paged-KV capacity management
    # ------------------------------------------------------------------
    def _ensure_decode_capacity(self, live: List[int]) -> List[int]:
        """Grow each live slot's tail KV page before the decode write.
        On pool exhaustion, preempt the victim with the cheapest
        recompute (fewest accumulated tokens; youngest arrival breaks
        ties) — its pages are freed and the request re-queued at the HEAD
        of waiting for re-prefill from ``resume_tokens``. When no other
        slot is left to evict, the needy request ends LENGTH_CAPPED (the
        'keep' branch: recompute-later loses to keeping the rest of the
        batch running). Returns the slots that can decode this step."""
        ready: List[int] = []
        pending = list(live)
        while pending:
            i = pending.pop(0)
            ok = True
            while not self.kv.ensure_decode_page(i):
                candidates = [s for s in ready + pending if s != i]
                if not candidates:
                    req = self.slots[i]
                    self._finish(req, RequestState.LENGTH_CAPPED,
                                 time.perf_counter())
                    self.slots[i] = None
                    self.kv.free(i)
                    ok = False
                    break
                victim = min(candidates,
                             key=lambda s: (self.kv.length(s),
                                            -self.slots[s].arrival_t))
                self._preempt(victim)
                if victim in ready:
                    ready.remove(victim)
                if victim in pending:
                    pending.remove(victim)
            if ok:
                ready.append(i)
        return ready

    def _preempt(self, slot: int) -> None:
        """Evict a running request to recompute: free its pages (shared
        prefix pages stay cached) and re-queue it at the head of the
        waiting line so it re-prefills — prompt AND generated tokens —
        as soon as the pool allows."""
        req = self.slots[slot]
        req.state = RequestState.WAITING
        req.preemptions += 1
        self.kv.paging.preemptions += 1
        self.slots[slot] = None
        self.kv.free(slot)
        self.last_tokens = self.last_tokens.at[slot, 0].set(0)
        self.waiting.insert(0, req)

    def paging_stats(self) -> Optional[Dict[str, float]]:
        """Block occupancy / prefix hit-rate / preemption counters
        (None under the dense layout)."""
        return self.kv.paging_summary() if self._paged else None

    def step(self) -> bool:
        """One engine iteration; returns False when idle."""
        if self.recalibrator is not None:
            # throttled staleness check; calibration runs on the worker
            self.recalibrator.maybe_recalibrate()
        with self._phase("admit"):
            self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return False
        if self._paged:
            # every live slot needs a page for this step's KV write;
            # exhaustion preempts the cheapest-to-recompute victim
            live = self._ensure_decode_capacity(live)
            if not live:
                # capacity actions (preempt/cap) happened; not idle
                return True
        self.stats.ensure_started()
        # a re-balance staged by the refresh worker lands between steps:
        # the weight permutation + epoch bump must not race a running
        # decode, and the epoch keys fresh exec graphs from here on
        if self._pending_placement is not None:
            pending, self._pending_placement = self._pending_placement, None
            self._apply_placement(pending)
        # decode plan solved on the ledger's real composition (live slots
        # + context-length histogram) AND the observed routing-skew
        # fingerprint; re-resolves only when either changes
        occ = self.kv.occupancy()
        skew = self._current_skew()
        plan = self._resolve_plan("decode", occupancy=occ, skew=skew)
        plan_key = (("decode", occ) if skew is None
                    else ("decode", occ, skew))
        self.key, sub = jax.random.split(self.key)
        use_topk = any(r is not None and r.top_k > 0 for r in self.slots)
        # the ledger's per-slot context lengths drive the attention mask
        # AND the ragged kernel's block skip (dead slots decode as len 0)
        lengths = jnp.asarray(self.kv.lengths(), jnp.int32)
        tables = self.kv.table_array() if self._paged else None
        t0 = time.perf_counter()
        with self._phase("decode_step", step=self.stats.steps,
                         live=len(live)):
            nxt, new_caches, mstats = self._decode_jit(
                self.params, self.last_tokens, self.kv.caches, self.temps,
                self.top_ks, sub, lengths, tables,
                plan=self._exec_program(plan), use_topk=use_topk,
                placement=self.placement if self._dep_active else None,
                cap_scale=self._capacity_scale(skew),
                collect_stats=self._track_load)
            jax.block_until_ready(nxt)
        # measured decode wall-time vs the plan's modeled makespan: this is
        # the observe edge of the profiling loop — a sustained residual
        # breach re-solves THIS occupancy's plan on the refresh worker, so
        # the step itself never waits on Algorithm 1
        self._observe("decode", plan_key, time.perf_counter() - t0,
                      plan)
        if mstats is not None:
            # the observe edge of the PLACEMENT loop: gate loads feed the
            # EWMA tracker, capacity-overflow drops surface in the stats,
            # and a rank-imbalance breach stages a background re-placement
            self.load_tracker.observe(np.asarray(mstats.load))
            self.stats.dropped_tokens += int(mstats.dropped)
            self._maybe_rebalance()
        self.kv.caches = new_caches
        self.last_tokens = nxt
        self.kv.note_decode(live)
        toks = np.asarray(nxt[:, 0])
        now = time.perf_counter()
        for i in live:
            req = self.slots[i]
            req.output.append(int(toks[i]))
            if req.first_token_t is None:
                req.first_token_t = now
            self.stats.decode_tokens += 1
            # ledger length > max_context: the cache is full (this step
            # attended all C rows and wrote the last one); another decode
            # would clamp its write to C-1 and clobber that row, so the
            # request terminates at the cap instead of corrupting KV
            capped = self.kv.length(i) > self.max_context
            if req.done or capped:
                self._finish(req, RequestState.FINISHED if req.done
                             else RequestState.LENGTH_CAPPED, now)
                self.slots[i] = None
                self.kv.free(i)
        self.stats.steps += 1
        return True

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive the engine until idle (or ``max_steps``); returns the
        requests that finished during this call."""
        start = len(self.finished)
        for _ in range(max_steps):
            if not self.step() and not self.waiting:
                break
        return self.finished[start:]
