"""command-r-35b [dense] — [hf:CohereForAI/c4ai-command-r-v01].

40L, d_model=8192, 64 heads (GQA kv=8), d_ff=22528, vocab=256000.
GQA, no bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    ffn_dim=22528,
    vocab_size=256000,
    attention="full",
    qkv_bias=False,
    rope_theta=8000000.0,
    tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01",
)


def smoke():
    return CONFIG.reduced()
