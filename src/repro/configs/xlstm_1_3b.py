"""xlstm-1.3b [ssm] — xLSTM: Extended Long Short-Term Memory
[arXiv:2405.04517].

48L, d_model=2048, 4 heads (kv=4), d_ff=0 (FFN inside blocks), vocab=50304.
sLSTM + mLSTM blocks at 7:1 (mLSTM:sLSTM), per the paper's xLSTM[7:1].
"""
from repro.configs.base import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    ffn_dim=0,
    vocab_size=50304,
    attention="none",
    recurrent=RecurrentConfig(
        kind="mlstm",
        block_pattern=("mlstm",) * 7 + ("slstm",),
    ),
    source="arXiv:2405.04517",
)


def smoke():
    cfg = CONFIG.reduced(num_heads=2, num_kv_heads=2)
    import dataclasses
    return dataclasses.replace(
        cfg, recurrent=dataclasses.replace(
            cfg.recurrent, block_pattern=("mlstm", "slstm")))
