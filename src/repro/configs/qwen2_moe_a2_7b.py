"""qwen2-moe-a2.7b [moe] — [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model=2048, 16 heads (kv=16), expert d_ff=1408, vocab=151936,
MoE: 60 routed experts top-4 + 4 shared experts.
FinDEP-primary config: the shared experts exercise the ASAS/AASS orders.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    ffn_dim=0,
    vocab_size=151936,
    attention="full",
    qkv_bias=True,
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        expert_ffn_dim=1408,
        num_shared_experts=4,
        shared_ffn_dim=1408,
    ),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)


def smoke():
    return CONFIG.reduced()
