"""recurrentgemma-9b [hybrid] — Griffin/RecurrentGemma [arXiv:2402.19427].

38L (must be divisible by the (rec,rec,attn) pattern => 36 recurrent-pattern
layers + 2 trailing rec layers; we follow the model card's 38 layers with
pattern cycling), d_model=4096, 16 heads (GQA kv=1 => MQA) for the local
attention, d_ff=12288, vocab=256000. RG-LRU + local attention 1:2.
"""
from repro.configs.base import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    ffn_dim=12288,
    vocab_size=256000,
    attention="local",
    sliding_window=2048,
    recurrent=RecurrentConfig(
        kind="rg_lru",
        lru_width=4096,
        conv1d_width=4,
        block_pattern=("rec", "rec", "attn"),
    ),
    source="arXiv:2402.19427",
)


def smoke():
    import dataclasses
    cfg = CONFIG.reduced(num_layers=2)
    return dataclasses.replace(
        cfg, recurrent=dataclasses.replace(cfg.recurrent,
                                           block_pattern=("rec", "attn")))
