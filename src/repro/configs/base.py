"""Configuration schema for models, input shapes and DEP cluster layout.

Every assigned architecture gets one module in ``repro.configs`` exporting a
``CONFIG`` ModelConfig built from the exact hyper-parameters in its source
paper / model card (cited in the module docstring), plus a ``smoke()``
reduced variant (<=2 layers, d_model<=512, <=4 experts) used by CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

ARCH_FAMILIES = (
    "dense",    # decoder-only transformer, (GQA) softmax attention
    "moe",      # decoder-only transformer with routed experts
    "ssm",      # xLSTM-style recurrent blocks (sLSTM + mLSTM)
    "hybrid",   # RG-LRU recurrence + local attention (RecurrentGemma)
    "vlm",      # vision-language: stub ViT frontend + dense LM backbone
    "audio",    # encoder-decoder (Seamless-M4T style); stub audio frontend
)

ATTENTION_KINDS = ("full", "sliding", "mla", "local", "none")


@dataclass(frozen=True)
class MoEConfig:
    """Routed-expert configuration (paper notation: E, top_k, N_shared, H)."""

    num_experts: int                 # E — global routed experts
    top_k: int                       # experts activated per token
    expert_ffn_dim: int              # H — hidden dim of each routed expert
    num_shared_experts: int = 0      # N_shared — dense experts on every token
    shared_ffn_dim: int = 0          # hidden dim of each shared expert
    router_aux_loss_coef: float = 0.001
    capacity_factor: float = 1.25    # per-expert capacity = cf * tokens*topk/E
    moe_layer_start: int = 0         # first layer index that is MoE
    moe_layer_every: int = 1         # 1 => every layer from start is MoE


@dataclass(frozen=True)
class RecurrentConfig:
    """SSM / hybrid recurrence parameters."""

    kind: str = "rg_lru"             # "rg_lru" | "slstm" | "mlstm"
    lru_width: int = 0               # recurrence state width (0 -> d_model)
    conv1d_width: int = 4
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn") 1:2


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture. Field names follow the paper where possible
    (M = d_model, H = ffn hidden, E/top_k in MoEConfig, T = num_layers)."""

    name: str
    family: str                      # one of ARCH_FAMILIES
    num_layers: int                  # T
    d_model: int                     # M
    num_heads: int
    num_kv_heads: int                # GQA KV heads
    ffn_dim: int                     # dense FFN hidden (0 if pure-MoE/SSM)
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    attention: str = "full"          # ATTENTION_KINDS
    sliding_window: int = 4096       # used when attention == "sliding"/"local"
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    # --- enc-dec (audio) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # --- multimodal stub frontends (vlm/audio carve-out) ---
    frontend_tokens: int = 0         # patch/frame embeddings prepended
    # --- MLA (DeepSeek-V2 style latent attention) ---
    mla_kv_lora_rank: int = 0
    mla_q_lora_rank: int = 0
    # citation for the exact config
    source: str = ""

    def __post_init__(self):
        assert self.family in ARCH_FAMILIES, self.family
        assert self.attention in ATTENTION_KINDS, self.attention
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    # ---- derived quantities -------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def uses_attention(self) -> bool:
        return self.attention != "none"

    @property
    def subquadratic(self) -> bool:
        """True when the arch natively supports 500k-token decode."""
        return self.family in ("ssm", "hybrid") or self.attention in (
            "sliding", "local")

    def num_params(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        M, Hd = self.d_model, self.head_dim
        q = self.num_heads * Hd
        kv = self.num_kv_heads * Hd
        attn = M * q + 2 * M * kv + q * M
        if self.mla_kv_lora_rank:
            attn = M * self.mla_kv_lora_rank * 2 + self.mla_kv_lora_rank * (
                2 * self.num_heads * Hd) + q * M
        dense_ffn = 3 * M * self.ffn_dim if self.ffn_dim else 0
        per_layer = attn + dense_ffn
        n = self.num_layers * per_layer
        if self.moe is not None:
            moe_ffn = 3 * M * self.moe.expert_ffn_dim * self.moe.num_experts
            moe_ffn += 3 * M * self.moe.shared_ffn_dim * self.moe.num_shared_experts
            moe_ffn += M * self.moe.num_experts  # router
            n_moe_layers = len(self.moe_layer_indices())
            n += n_moe_layers * moe_ffn
            n -= n_moe_layers * dense_ffn  # MoE layers replace dense FFN
        if self.recurrent is not None:
            w = self.recurrent.lru_width or M
            n += self.num_layers * (2 * M * w + 2 * w)
        emb = self.vocab_size * M * (1 if self.tie_embeddings else 2)
        if self.is_encoder_decoder:
            n += self.num_encoder_layers * per_layer
        return n + emb

    def active_params(self) -> int:
        """Activated parameters per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.num_params()
        m = self.moe
        full = self.num_params()
        n_moe_layers = len(self.moe_layer_indices())
        routed_all = n_moe_layers * 3 * self.d_model * m.expert_ffn_dim * m.num_experts
        routed_act = n_moe_layers * 3 * self.d_model * m.expert_ffn_dim * m.top_k
        return full - routed_all + routed_act

    def moe_layer_indices(self):
        if self.moe is None:
            return []
        m = self.moe
        return [i for i in range(self.num_layers)
                if i >= m.moe_layer_start
                and (i - m.moe_layer_start) % m.moe_layer_every == 0]

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: same family/topology, tiny dims."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            ffn_dim=min(self.ffn_dim, 512) if self.ffn_dim else 0,
            vocab_size=min(self.vocab_size, 1024),
            head_dim=0,
            sliding_window=min(self.sliding_window, 64),
            num_encoder_layers=min(self.num_encoder_layers, 2),
            frontend_tokens=min(self.frontend_tokens, 16),
            mla_kv_lora_rank=min(self.mla_kv_lora_rank, 64),
            mla_q_lora_rank=min(self.mla_q_lora_rank, 64),
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                expert_ffn_dim=min(self.moe.expert_ffn_dim, 128),
                shared_ffn_dim=min(self.moe.shared_ffn_dim, 128),
            )
        if self.recurrent is not None:
            kw["recurrent"] = dataclasses.replace(
                self.recurrent,
                lru_width=min(self.recurrent.lru_width, 256)
                if self.recurrent.lru_width else 0,
            )
        kw.update(overrides)
        cfg = dataclasses.replace(self, **kw)
        return cfg


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# DEP cluster layout (paper Table 1: ag / eg)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DepClusterConfig:
    """Disaggregated-expert-parallel group sizes and link characteristics."""

    num_devices: int          # P
    ag: int                   # attention-group size
    eg: int                   # expert-group size
    dtype_bytes: int = 2      # bf16 activations

    def __post_init__(self):
        assert self.ag + self.eg <= self.num_devices
        assert self.ag >= 1 and self.eg >= 1
