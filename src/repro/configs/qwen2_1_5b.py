"""qwen2-1.5b [dense] — Qwen2 Technical Report [arXiv:2407.10671].

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936.
GQA with QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    ffn_dim=8960,
    vocab_size=151936,
    attention="full",
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    source="arXiv:2407.10671",
)


def smoke():
    return CONFIG.reduced()
