"""granite-moe-1b-a400m [moe] — [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L, d_model=1024, 16 heads (GQA kv=8), expert d_ff=512, vocab=49155,
MoE: 32 routed experts top-8, no shared experts (the paper's Qwen3-MoE-like
"no shared" scheduling case).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    ffn_dim=0,
    vocab_size=49155,
    attention="full",
    tie_embeddings=True,
    moe=MoEConfig(
        num_experts=32,
        top_k=8,
        expert_ffn_dim=512,
        num_shared_experts=0,
    ),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def smoke():
    return CONFIG.reduced()
