"""starcoder2-3b [dense] — StarCoder 2 and The Stack v2 [arXiv:2402.19173].

30L, d_model=3072, 24 heads (GQA kv=2), d_ff=12288, vocab=49152. GQA, RoPE.
(StarCoder2-3B uses sliding-window 4096 attention; we model it with the
sliding variant, which also makes long_500k decode natively feasible.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    ffn_dim=12288,
    vocab_size=49152,
    attention="sliding",
    sliding_window=4096,
    qkv_bias=True,
    rope_theta=100000.0,
    source="arXiv:2402.19173",
)


def smoke():
    return CONFIG.reduced()
