"""seamless-m4t-large-v2 [audio] — SeamlessM4T [arXiv:2308.11596].

Encoder-decoder transformer backbone: 24 decoder layers (+24 encoder
layers), d_model=1024, 16 heads (kv=16), d_ff=8192, vocab=256206.
The speech frontend (mel-spectrogram + conformer feature extractor) is
STUBBED per the assignment carve-out: input_specs() provides precomputed
frame embeddings. The decoder uses sliding-window attention for the
long_500k decode shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    ffn_dim=8192,
    vocab_size=256206,
    attention="full",
    is_encoder_decoder=True,
    num_encoder_layers=24,
    frontend_tokens=1024,
    source="arXiv:2308.11596",
)


def smoke():
    return CONFIG.reduced(frontend_tokens=8)
