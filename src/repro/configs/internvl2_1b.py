"""internvl2-1b [vlm] — InternVL2 [arXiv:2404.16821].

LM backbone (Qwen2-0.5B-style): 24L, d_model=896, 14 heads (GQA kv=2),
d_ff=4864, vocab=151655. InternViT vision encoder is STUBBED per the
assignment carve-out: input_specs() provides 256 precomputed patch
embeddings per image.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    ffn_dim=4864,
    vocab_size=151655,
    attention="full",
    qkv_bias=True,
    rope_theta=1000000.0,
    frontend_tokens=256,
    source="arXiv:2404.16821",
)


def smoke():
    return CONFIG.reduced(num_heads=2, num_kv_heads=2)
