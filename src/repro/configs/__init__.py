"""Architecture registry: the 10 assigned architectures + the paper's two
MoE backbones, and the 4 assigned input shapes."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (ARCH_FAMILIES, DepClusterConfig, ModelConfig,
                                MoEConfig, RecurrentConfig, SHAPES,
                                ShapeConfig)

# arch-id -> module name
_ARCH_MODULES = {
    "llama3-405b": "llama3_405b",
    "xlstm-1.3b": "xlstm_1_3b",
    "command-r-35b": "command_r_35b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "starcoder2-3b": "starcoder2_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-1b": "internvl2_1b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen2-1.5b": "qwen2_1_5b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    # paper backbones (benchmarks; not part of the assigned 10x4 grid)
    "deepseek-v2-lite": "deepseek_v2_lite",
    "qwen3-moe": "qwen3_moe",
}

ASSIGNED_ARCHS = tuple(list(_ARCH_MODULES)[:10])
PAPER_ARCHS = ("deepseek-v2-lite", "qwen3-moe")
ALL_ARCHS = tuple(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ALL_ARCHS}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = ["ARCH_FAMILIES", "ASSIGNED_ARCHS", "PAPER_ARCHS", "ALL_ARCHS",
           "SHAPES", "DepClusterConfig", "ModelConfig", "MoEConfig",
           "RecurrentConfig", "ShapeConfig", "get_config", "get_smoke_config",
           "get_shape"]
