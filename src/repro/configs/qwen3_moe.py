"""qwen3-moe — Qwen3 Technical Report [arXiv:2505.09388]; the paper's
no-shared-experts MoE backbone (benchmark tables). Qwen3-235B-A22B scaled
hyperparameters: 94L in full; the paper uses reduced-layer variants.

d_model=4096, 64 heads (GQA kv=4), 128 routed experts top-8, expert
d_ff=1536, vocab=151936, NO shared experts.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe",
    family="moe",
    num_layers=48,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    ffn_dim=0,
    vocab_size=151936,
    attention="full",
    moe=MoEConfig(
        num_experts=128,
        top_k=8,
        expert_ffn_dim=1536,
        num_shared_experts=0,
    ),
    source="arXiv:2505.09388",
)


def smoke():
    return CONFIG.reduced()
