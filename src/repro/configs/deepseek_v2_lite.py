"""deepseek-v2-lite — DeepSeek-V2 [arXiv:2405.04434]; the paper's primary
MoE backbone (shared experts + MLA). Used by the benchmark tables, not part
of the assigned-10 grid.

27L, d_model=2048, 16 heads, MLA kv_lora_rank=512, 64 routed experts top-6,
2 shared experts, expert d_ff=1408, vocab=102400. First layer dense
(d_ff=10944) in the real model; we make every layer MoE for scheduling
fidelity to the paper's DEP experiments (they use small layer-count
variants of DeepSeek-V2 236B).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    ffn_dim=0,
    vocab_size=102400,
    attention="mla",
    mla_kv_lora_rank=512,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        expert_ffn_dim=1408,
        num_shared_experts=2,
        shared_ffn_dim=1408,
    ),
    source="arXiv:2405.04434",
)


def smoke():
    return CONFIG.reduced()
