"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_device     / peak_FLOP/s
    memory term     = HLO_bytes_per_device     / HBM_bw
    collective term = wire_bytes_per_device    / link_bw

``compiled.cost_analysis()`` reports the SPMD per-device program, so the
terms above are per-device times; they equal the assignment's
"global / (chips * peak)" formulation because global = per_device * chips.

collective bytes are NOT in cost_analysis: we parse the post-partitioning
HLO (``compiled.as_text()``) and sum wire traffic for every collective:

    all-gather          result_bytes  * (N-1)/N
    reduce-scatter      operand_bytes * (N-1)/N
    all-reduce          2 * operand_bytes * (N-1)/N      (ring)
    all-to-all          operand_bytes * (N-1)/N
    collective-permute  operand_bytes

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt == "token" or dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _tuple_or_shape_bytes(text: str) -> int:
    """Sum bytes of all array shapes in a type string (handles tuples)."""
    return sum(_shape_bytes(m.group(0))
               for m in _SHAPE_RE.finditer(text))


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    wire_bytes: Dict[str, float] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Parse post-SPMD HLO and accumulate per-device wire bytes."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # async pairs appear as -start/-done; count -start only. Fused
        # sync ops appear bare.
        kind = None
        for k in COLLECTIVE_KINDS:
            if re.search(rf"(?<![\w-]){re.escape(k)}(-start)?\(", s):
                if f"{k}-done" in s:
                    kind = None
                else:
                    kind = k
                break
        if kind is None:
            continue
        # result type: between "= " and the op name
        m = re.search(r"=\s+(.*?)\s+" + re.escape(kind), s)
        result_bytes = _tuple_or_shape_bytes(m.group(1)) if m else 0
        # operand types: inside the call parens. Modern HLO prints operands
        # WITHOUT inline types ("all-reduce(%x)"), in which case we infer
        # from the result type: all-reduce / all-to-all / collective-permute
        # preserve shape; reduce-scatter's operand is result * N.
        m2 = re.search(re.escape(kind) + r"(?:-start)?\((.*?)\)", s)
        operand_bytes = _tuple_or_shape_bytes(m2.group(1)) if m2 else 0
        # group size N
        N = 1
        g = _GROUPS_RE.search(s)
        if g:
            N = len([x for x in g.group(1).split(",") if x.strip() != ""])
        else:
            gi = _IOTA_GROUPS_RE.search(s)
            if gi:
                N = int(gi.group(2))
        frac = (N - 1) / N if N > 1 else 0.0
        if operand_bytes == 0:               # untyped operands: infer
            operand_bytes = (result_bytes * N if kind == "reduce-scatter"
                             else result_bytes)
        if kind == "all-gather":
            wire = result_bytes * frac
        elif kind == "reduce-scatter":
            wire = operand_bytes * frac
        elif kind == "all-reduce":
            wire = 2.0 * operand_bytes * frac
        elif kind == "all-to-all":
            wire = operand_bytes * frac
        else:  # collective-permute
            wire = operand_bytes
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.wire_bytes[kind] = stats.wire_bytes.get(kind, 0.0) + wire
    return stats


@dataclass
class Roofline:
    name: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float           # 6*N*D (active params) global
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    collectives: CollectiveStats = None
    peak_memory_bytes: float = 0.0

    def as_row(self) -> dict:
        return dict(name=self.name,
                    compute_ms=self.compute_s * 1e3,
                    memory_ms=self.memory_s * 1e3,
                    collective_ms=self.collective_s * 1e3,
                    dominant=self.dominant,
                    useful_ratio=self.useful_ratio,
                    peak_mem_gb=self.peak_memory_bytes / 1e9)


def analyze(name: str, compiled, model_flops_global: float, chips: int,
            peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW,
            link_bw: float = LINK_BW) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):        # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    compute_s = flops / peak_flops
    memory_s = byts / hbm_bw
    coll_s = stats.total_bytes / link_bw
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", coll_s)), key=lambda kv: kv[1])[0]
    useful = model_flops_global / max(flops * chips, 1.0)
    try:
        ma = compiled.memory_analysis()
        peak = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                + ma.output_size_in_bytes)
    except Exception:
        peak = 0.0
    return Roofline(name=name, flops_per_device=flops,
                    bytes_per_device=byts,
                    collective_bytes=stats.total_bytes,
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=coll_s, dominant=dominant,
                    model_flops=model_flops_global, useful_ratio=useful,
                    collectives=stats, peak_memory_bytes=peak)


def scan_corrections(cfg, shape, dp_shards: int, mode: str,
                     q_chunk: int = 512, k_chunk: int = 1024) -> Dict[str, float]:
    """Analytic per-device counts hidden inside lax.scan bodies (XLA's
    cost_analysis counts a While body ONCE regardless of trip count).

    Two scan families need correction in the count-probes:
      * chunked (flash-style) attention: outer q-chunk scan x inner k-chunk
        scan -> counted 1/(nq*nk) of the pair grid;
      * xLSTM time recurrences (mLSTM/sLSTM): counted 1/S of the steps.
    RG-LRU uses associative_scan (fully unrolled in HLO, counted exactly).
    Training multiplies by 3 (fwd + ~2x bwd, the scan bodies are also
    differentiated into scans). Returns extra {"flops", "bytes"} per device.
    """
    from repro.models.transformer import layer_kinds
    kinds = layer_kinds(cfg)
    B_dev = max(shape.global_batch // max(dp_shards, 1), 1)
    S = shape.seq_len if mode in ("train", "prefill") else 1
    mult = 3.0 if mode == "train" else 1.0
    extra_flops = 0.0
    extra_bytes = 0.0
    if S <= 1:
        return {"flops": 0.0, "bytes": 0.0}

    n_attn = sum(1 for k in kinds if k in ("attn_mlp", "attn_moe", "attn"))
    if n_attn and cfg.uses_attention and S > 2048:
        H, D = cfg.num_heads, cfg.head_dim
        Kv = cfg.num_kv_heads
        nq = (S + q_chunk - 1) // q_chunk
        nk = (S + k_chunk - 1) // k_chunk
        fl = 4.0 * B_dev * H * S * S * D           # QK^T + PV (impl, no
        fl *= (1.0 - 1.0 / (nq * nk))              # causal skipping)
        by = (nq * 2.0 * S * Kv * D + 2.0 * S * H * D) * 2.0 * B_dev
        extra_flops += n_attn * fl * mult
        extra_bytes += n_attn * by * mult

    n_mlstm = sum(1 for k in kinds if k == "mlstm")
    if n_mlstm:
        hd = 2 * cfg.d_model // cfg.num_heads      # d_inner / H
        per_step_fl = 8.0 * B_dev * cfg.num_heads * hd * hd
        per_step_by = 2.0 * 4.0 * B_dev * cfg.num_heads * hd * hd
        extra_flops += n_mlstm * per_step_fl * (S - 1) * mult
        extra_bytes += n_mlstm * per_step_by * (S - 1) * mult

    n_slstm = sum(1 for k in kinds if k == "slstm")
    if n_slstm:
        M = cfg.d_model
        hd = M // cfg.num_heads
        per_step_fl = 8.0 * B_dev * M * hd + 30.0 * B_dev * M
        per_step_by = 4.0 * M * hd * 4.0           # r_gates re-read
        extra_flops += n_slstm * per_step_fl * (S - 1) * mult
        extra_bytes += n_slstm * per_step_by * (S - 1) * mult

    return {"flops": extra_flops, "bytes": extra_bytes}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 * N_active * D tokens (training: *3 for fwd+bwd...
    we follow the assignment: 6*N*D counts fwd+bwd; for inference steps we
    use 2*N*D forward-only)."""
    n_active = cfg.active_params()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
