from repro.roofline.analysis import (HBM_BW, LINK_BW, PEAK_FLOPS, Roofline,
                                     analyze, model_flops, parse_collectives,
                                     scan_corrections)

__all__ = ["HBM_BW", "LINK_BW", "PEAK_FLOPS", "Roofline", "analyze",
           "model_flops", "parse_collectives", "scan_corrections"]
