"""FinDEP online pipeline (paper Fig. 6).

Offline phase: pick model + (ag, eg); microbenchmark the hardware to fit the
alpha-beta models (or use an analytic HardwareProfile); cache StageModels
per sequence length is NOT possible (S enters the coefficients), so we cache
the HardwareProfile + DepModelSpec template and instantiate per request.

Online phase: on batch arrival (known batch size + sequence length), run
Algorithm 1 (< 1 s; typically < 10 ms here) to produce the Plan that the
executor (repro.core.dep) materializes as a chunked shard_map program.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.configs.base import DepClusterConfig, ModelConfig
from repro.core.perf_model import (DepModelSpec, HardwareProfile, StageModels,
                                   build_stage_models)
from repro.core.solver import Plan, SolverStats, solve


@dataclass
class PlannerConfig:
    mem_cap_samples: int = 64      # AG per-device sample capacity
    objective: str = "hybrid"
    r1_cap: int = 64
    r2_cap: int = 64


class FinDEPPlanner:
    """Offline-calibrated, online-solving planner."""

    def __init__(self, model_cfg: ModelConfig, cluster: DepClusterConfig,
                 hardware: HardwareProfile,
                 planner_cfg: Optional[PlannerConfig] = None):
        assert model_cfg.is_moe, "FinDEP plans MoE models"
        self.model_cfg = model_cfg
        self.cluster = cluster
        self.hardware = hardware
        self.cfg = planner_cfg or PlannerConfig()
        self._cache: Dict[Tuple[int, Optional[int]], Plan] = {}
        self.last_solve_time: float = 0.0
        self.last_stats: Optional[SolverStats] = None

    def stage_models(self, seq_len: int) -> StageModels:
        spec = DepModelSpec.from_model_config(self.model_cfg, seq_len)
        return build_stage_models(self.hardware, spec, self.cluster)

    def plan(self, seq_len: int,
             batch_per_device: Optional[int] = None) -> Plan:
        """Online solve for an arrived batch shape. ``batch_per_device``
        None => offline throughput mode (batch chosen by the solver)."""
        key = (seq_len, batch_per_device)
        if key in self._cache:
            return self._cache[key]
        models = self.stage_models(seq_len)
        T = len(self.model_cfg.moe_layer_indices())
        t0 = time.perf_counter()
        plan, stats = solve(models, T, self.cfg.mem_cap_samples,
                            objective=self.cfg.objective,
                            r1_cap=self.cfg.r1_cap, r2_cap=self.cfg.r2_cap,
                            fixed_batch=batch_per_device)
        self.last_solve_time = time.perf_counter() - t0
        self.last_stats = stats
        self._cache[key] = plan
        return plan
