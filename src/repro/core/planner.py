"""FinDEP online pipeline (paper Fig. 6).

Offline phase: pick model + (ag, eg); microbenchmark the hardware to fit the
alpha-beta models (or use an analytic HardwareProfile); cache StageModels
per sequence length is NOT possible (S enters the coefficients), so we cache
the HardwareProfile + DepModelSpec template and instantiate per request.

Online phase: on batch arrival (known batch size + sequence length), run
Algorithm 1 (< 1 s; typically < 10 ms here) to produce the Plan that the
executor (repro.core.dep) materializes as a chunked shard_map program.

Serving stacks should not call the planner directly per step: wrap it in a
``repro.sched.FinDEPPolicy`` behind a ``repro.sched.PlanCache`` so repeated
shapes hit the memo and only genuine shape changes pay a solve.
"""
from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.configs.base import DepClusterConfig, ModelConfig
from repro.core.analytic import StageTimes
from repro.core.perf_model import (DepModelSpec, HardwareProfile, StageModels,
                                   build_stage_models)
from repro.core.solver import Plan, SolverStats, solve
from repro.core.taskgraph import (LoweringSpec, ScheduleResult, TaskCosts,
                                  TaskGraph, lower, schedule)


@dataclass
class PlannerConfig:
    mem_cap_samples: int = 64      # AG per-device sample capacity
    objective: str = "hybrid"
    r1_cap: int = 64
    r2_cap: int = 64
    T_override: Optional[int] = None   # MoE depth override (paper tables
                                       # use reduced-depth variants)


class FinDEPPlanner:
    """Offline-calibrated, online-solving planner."""

    def __init__(self, model_cfg: ModelConfig, cluster: DepClusterConfig,
                 hardware: HardwareProfile,
                 planner_cfg: Optional[PlannerConfig] = None,
                 validate: bool = False):
        assert model_cfg.is_moe, "FinDEP plans MoE models"
        self.model_cfg = model_cfg
        self.cluster = cluster
        self.hardware = hardware
        self.cfg = planner_cfg or PlannerConfig()
        #: opt-in static verification: every fresh solve's full lowering
        #: is run through ``repro.analysis.graphcheck`` (structure,
        #: capacity, deadlock-freedom, race-free schedule under the
        #: measured stage costs) before the plan is memoized; violations
        #: raise ``repro.analysis.AnalysisError``.
        self.validate = validate
        # (seq_len, batch_per_device, r2_cap, decode_context) -> Plan
        self._cache: Dict[Tuple, Plan] = {}
        self.last_solve_time: float = 0.0
        self.last_stats: Optional[SolverStats] = None
        self.solve_count: int = 0
        self.total_solve_time: float = 0.0

    def num_moe_layers(self) -> int:
        """T in the paper's notation: MoE layers per forward pass."""
        return self.cfg.T_override or len(self.model_cfg.moe_layer_indices())

    def stage_models(self, seq_len: int,
                     decode_context: Optional[float] = None,
                     skew=None) -> StageModels:
        spec = DepModelSpec.from_model_config(self.model_cfg, seq_len)
        if self.cfg.T_override is not None:
            spec = dataclasses.replace(spec, T=self.cfg.T_override)
        if decode_context:
            spec = dataclasses.replace(spec,
                                       decode_context=float(decode_context))
        return build_stage_models(self.hardware, spec, self.cluster,
                                  skew=skew)

    def plan(self, seq_len: int, batch_per_device: Optional[int] = None,
             r2_cap: Optional[int] = None,
             decode_context: Optional[float] = None, skew=None) -> Plan:
        """Online solve for an arrived batch shape. ``batch_per_device``
        None => offline throughput mode (batch chosen by the solver).
        ``r2_cap`` overrides the configured chunking cap — r2_cap=1 yields
        the coarse sequential-DEP schedule under the same objective.
        ``decode_context`` switches the attention term to the decode model
        (one query per token over that many cached positions).
        ``skew`` (a quantized ``repro.placement.SkewSummary``) makes the
        per-stage cost models reflect observed routing skew; it joins the
        solve memo key, so recurring skew regimes hit the memo and only a
        regime shift (different quantized summary) pays a re-solve."""
        r2_cap = self.cfg.r2_cap if r2_cap is None else r2_cap
        if skew is not None and getattr(skew, "is_uniform", False):
            skew = None                 # uniform == legacy key, legacy cost
        key = (seq_len, batch_per_device, r2_cap, decode_context)
        if skew is not None:
            key = key + (skew,)
        if key in self._cache:
            return self._cache[key]
        models = self.stage_models(seq_len, decode_context=decode_context,
                                   skew=skew)
        T = self.num_moe_layers()
        t0 = time.perf_counter()
        plan, stats = solve(models, T, self.cfg.mem_cap_samples,
                            objective=self.cfg.objective,
                            r1_cap=self.cfg.r1_cap, r2_cap=r2_cap,
                            fixed_batch=batch_per_device)
        self.last_solve_time = time.perf_counter() - t0
        self.last_stats = stats
        self.solve_count += 1
        self.total_solve_time += self.last_solve_time
        if self.validate:
            self._validate_plan(plan, models)
        self._cache[key] = plan
        return plan

    def _validate_plan(self, plan: Plan, models: StageModels) -> None:
        """Static-verify a freshly solved plan's full lowering (see
        ``validate``): graphcheck under the measured stage costs, raising
        ``AnalysisError`` on any violation. Imported lazily — the
        analysis package imports this module for its sweep."""
        from repro.analysis import AnalysisError
        from repro.analysis.graphcheck import check_graph
        st = StageTimes.from_models(models, plan.m_a, plan.m_e)
        graph = self.lower(plan, hot_experts=1 if st.t_rep > 0.0 else 0)
        violations = check_graph(graph, TaskCosts.from_stage_times(st))
        if violations:
            raise AnalysisError(violations)

    def lower(self, plan: Plan, shared_blocks_a2e: bool = False,
              hot_experts: int = 0, placement_epoch: int = 0) -> TaskGraph:
        """Lower ``plan`` to its full T-layer ``TaskGraph`` under this
        planner's model (the same lowering the simulator schedules and
        the executor walks per layer). ``hot_experts``/``placement_epoch``
        carry the active expert placement into the graph (REP tasks +
        epoch identity); the defaults reproduce the unreplicated graph."""
        has_shared = (self.model_cfg.moe is not None
                      and self.model_cfg.moe.num_shared_experts > 0)
        return lower(plan, LoweringSpec(T=self.num_moe_layers(),
                                        has_shared=has_shared,
                                        shared_blocks_a2e=shared_blocks_a2e),
                     hot_experts=hot_experts,
                     placement_epoch=placement_epoch)

    def schedule_plan(self, plan: Plan, seq_len: int,
                      decode_context: Optional[float] = None,
                      shared_blocks_a2e: bool = False,
                      skew=None) -> ScheduleResult:
        """Lower ``plan`` and schedule it under this planner's measured
        stage models for ``seq_len`` — the modeled per-task timeline of
        one executed step (benchmarks/plan_trace renders this as a
        Gantt; Table 7 derives exposed-communication time from it).
        With ``skew`` the timeline includes the REP lane segment and the
        kappa/(1-rho)-scaled EXP/comm task times."""
        models = self.stage_models(seq_len, decode_context=decode_context,
                                   skew=skew)
        st = StageTimes.from_models(models, plan.m_a, plan.m_e)
        hot = 1 if st.t_rep > 0.0 else 0
        return schedule(self.lower(plan, shared_blocks_a2e=shared_blocks_a2e,
                                   hot_experts=hot),
                        TaskCosts.from_stage_times(st))

    def set_hardware(self, hardware: HardwareProfile) -> None:
        """Swap in a (re)calibrated profile. Every memoized plan was solved
        under the old alpha-beta models, so the memo is dropped — the next
        ``plan()`` per shape re-runs Algorithm 1 on the new fit."""
        self.hardware = hardware
        self.clear_cache()

    def plan_for_occupancy(self, occupancy, r2_cap: Optional[int] = None,
                           skew=None) -> Plan:
        """Decode solve on a KV-ledger ``OccupancySummary``: one token per
        live slot (S = 1 — a decode step routes exactly one token per
        sample into the MoE), attention LINEAR in the histogram's mean
        context rather than quadratic in a context *bucket*. The mean is
        widened by the standard error (sigma / sqrt(live)) so the modeled
        per-device context is a conservative estimate of the realized
        per-device mean when slots scatter across AG devices. The solved
        makespan is therefore the cost of ONE decode step over the real
        composition — directly comparable to the StepTimer's measured
        decode wall time, where the old (seq_bucket, live) prefill-style
        projection over-predicted by orders of magnitude."""
        ctx = occupancy.mean_context
        if occupancy.live:
            ctx += occupancy.std_context / math.sqrt(occupancy.live)
        # quantize to keep the solve-memo key cardinality bounded: the
        # sigma widening makes ctx near-continuous, and every distinct
        # float would otherwise pin a permanent entry in self._cache
        ctx = float(max(math.ceil(ctx / 16.0), 1) * 16)
        try:
            return self.plan(1, occupancy.live or None, r2_cap=r2_cap,
                             decode_context=ctx, skew=skew)
        except ValueError:
            # live count infeasible under the memory cap: solver's batch
            return self.plan(1, None, r2_cap=r2_cap, decode_context=ctx,
                             skew=skew)

    def clear_cache(self) -> None:
        self._cache.clear()
