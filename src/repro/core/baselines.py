"""Baselines the paper compares against: naive DEP, PPPipe
(MegaScale-Infer) — including the "best-configured PPPipe" search used in
Tables 5-6 (optimal m_a, r1 for PPPipe's own schedule) — and an EPS-MoE
style fixed-granularity expert pipeline. Each helper returns a ``Plan``,
so through ``repro.sched`` every baseline is *runnable* on the DEP
executor, not only analytic.

Since the task-graph IR (``repro.core.taskgraph``) every baseline is an
*alternate lowering* of the same IR rather than a separate simulator:
naive/PPPipe lower with ``shared_blocks_a2e=True`` (dispatch waits on
the shared expert) and the EPS pipeline is an AASS lowering with a fixed
r2 — ``simulate_naive``/``simulate_pppipe``/``simulate_dep`` are thin
wrappers over ``taskgraph.lower`` + ``taskgraph.schedule``. The returned
plans carry the graph-derived per-primitive ``breakdown`` tags like
solver plans do."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

from repro.core.analytic import StageTimes
from repro.core.perf_model import StageModels
from repro.core.simulator import (SimResult, simulate_dep, simulate_naive,
                                  simulate_pppipe)
from repro.core.solver import Plan, get_max_r1, max_r2


def _tagged(plan: Plan, res: SimResult) -> Plan:
    """Attach the lowered graph's per-primitive cost split to a baseline
    plan (normalized to the simulated makespan, same as solver plans)."""
    if res.scheduled is None:
        return plan
    return replace(plan, breakdown=res.scheduled.breakdown()
                   .normalized_to(plan.makespan))


def naive_plan(models: StageModels, T: int, mem_cap_samples: int,
               fixed_batch: Optional[int] = None) -> Plan:
    """Naive DEP: full mini-batch, strictly sequential."""
    m_a = fixed_batch if fixed_batch is not None else mem_cap_samples
    m_e = models.me_from_ma(m_a, 1)
    st = StageTimes.from_models(models, m_a, m_e)
    res = simulate_naive(st, T)
    tokens = m_a * models.cluster.ag * models.spec.S
    return _tagged(Plan(m_a=m_a, r1=1, m_e=m_e, r2=1, order="ASAS",
                        throughput=tokens / res.makespan,
                        makespan=res.makespan, objective="simulate"), res)


def pppipe_plan(models: StageModels, T: int, m_a: int, r1: int) -> Plan:
    """PPPipe with a given (m_a, r1): r2 = 1, shared blocks a2e."""
    m_e = models.me_from_ma(m_a, 1)
    st = StageTimes.from_models(models, m_a, m_e)
    res = simulate_pppipe(st, T, r1)
    tokens = r1 * m_a * models.cluster.ag * models.spec.S
    return _tagged(Plan(m_a=m_a, r1=r1, m_e=m_e, r2=1, order="ASAS",
                        throughput=tokens / res.makespan,
                        makespan=res.makespan, objective="simulate"), res)


def eps_pipeline_plan(models: StageModels, T: int, m_a: int,
                      r2: int = 4) -> Plan:
    """EPS-MoE-style expert pipeline: the whole mini-batch at once (r1 = 1)
    with the expert capacity split into a *fixed* number of chunks — the
    pipeline granularity is a hyper-parameter, not solved per shape. ``r2``
    is clipped to keep >= 1 token per expert per chunk."""
    r2 = max(1, min(r2, max_r2(models, m_a, cap=r2)))
    m_e = models.me_from_ma(m_a, r2)
    st = StageTimes.from_models(models, m_a, m_e)
    res = simulate_dep(st, T, 1, r2, order="AASS")
    tokens = m_a * models.cluster.ag * models.spec.S
    return _tagged(Plan(m_a=m_a, r1=1, m_e=m_e, r2=r2, order="AASS",
                        throughput=tokens / res.makespan,
                        makespan=res.makespan, objective="simulate"), res)


def best_pppipe(models: StageModels, T: int, mem_cap_samples: int,
                r1_cap: int = 64,
                fixed_batch: Optional[int] = None) -> Plan:
    """Best-configured PPPipe: exhaustive search over (m_a, r1) under the
    same memory constraint FinDEP gets. This is the paper's comparison
    point ("PPPipe with optimal ep, dp, m_a and r1 settings")."""
    best: Optional[Plan] = None
    for m_a in range(1, mem_cap_samples + 1):
        if fixed_batch is not None:
            if fixed_batch % m_a:
                continue
            r1_list = [fixed_batch // m_a]
        else:
            r1_list = range(1, get_max_r1(m_a, mem_cap_samples, r1_cap) + 1)
        for r1 in r1_list:
            if r1 == 0 or r1 > r1_cap:
                continue
            p = pppipe_plan(models, T, m_a, r1)
            if best is None or p.throughput > best.throughput:
                best = p
    assert best is not None
    return best
