"""DEP execution on a TPU mesh: the paper's A2E/E2A as r2-chunked
all_to_all collectives inside shard_map.

Adaptation (DESIGN.md §2): AG/EG are roles of mesh axes, not disjoint
device groups. Attention runs data-parallel over ("pod","data") and
tensor-parallel over "model"; routed experts are expert-parallel over
"model". The two DEP communication phases map to:

  A2E  = all_to_all(buffers, "model", split=expert_dim, concat=capacity)
  E2A  = all_to_all(outputs, "model", split=capacity,  concat=expert_dim)

FinDEP's fine-grained r2 chunking splits the capacity dimension into r2
chunks and emits chunk k+1's A2E before chunk k's expert FFN retires, so
XLA's async collective scheduler can overlap transport with expert compute
— the TPU analogue of the paper's multi-stream schedule. The solved task
order (ASAS/AASS) controls where the shared-expert GEMMs are emitted
relative to the chunk stream.

Two dispatch modes:
  * "sequence" (train / prefill): local tokens are split over the "model"
    axis (sequence dim), each peer routes its slice, buffers exchanged
    with all_to_all. This is the paper's dispatch/combine, collective-for-
    collective.
  * "replicated" (decode): tokens are replicated over "model" (batch/seq
    too small to split); each peer computes only its local experts'
    outputs and the combine is a single psum — no dispatch collective.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib
from repro.models.layers import mlp_apply


def _mesh_prod(mesh, axes) -> int:
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    return p


def _shared_schedule(order: str, shared_fn, shared_x, r2: int):
    """Where the shared-expert GEMMs are emitted relative to the r2 chunk
    stream (the solved task order). Returns ``emit(j)``: the shared part
    to emit at chunk boundary j (None = nothing at this boundary).

      AASS: the whole shared expert at chunk 0 (right after the first
            A2E / buffer slice is launched)
      ASAS: split into r2 segments, one per chunk boundary

    Both the sequence-mode all_to_all path and the replicated-token decode
    path consume this, so the executed order always matches the solved
    plan's (the decode path used to silently emit AASS placement for ASAS
    plans, mis-attributing the residual to hardware drift)."""
    if shared_fn is None:
        return lambda j: None
    if order == "ASAS":
        seg = shared_x.shape[0] // r2

        def emit(j):
            lo = j * seg
            hi = shared_x.shape[0] if j == r2 - 1 else (j + 1) * seg
            return shared_fn(shared_x[lo:hi])
    else:
        def emit(j):
            return shared_fn(shared_x) if j == 0 else None
    return emit


def _chunked_expert_alltoall(buffers, expert_params, axis: str, r2: int,
                             shared_fn=None, shared_x=None,
                             order: str = "AASS"):
    """buffers: [E_pad, C_loc, M] per peer -> (outputs [E_pad, C_loc, M]
    back in dispatch layout, shared_out or None).

    Emits r2 (A2E -> expert FFN -> E2A) chunk pipelines in program order;
    shared-expert GEMMs interleave according to ``order`` (see
    ``_shared_schedule``)."""
    E_pad, C_loc, M = buffers.shape
    chunk = C_loc // r2

    def a2e(buf):   # [E_pad, c, M] -> [E_loc, mo*c, M]
        return jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1,
                                  tiled=True)

    def e2a(out):   # [E_loc, mo*c, M] -> [E_pad, c, M]
        return jax.lax.all_to_all(out, axis, split_axis=1, concat_axis=0,
                                  tiled=True)

    emit = _shared_schedule(order, shared_fn, shared_x, r2)
    outs = []
    shared_parts = []
    for j in range(r2):
        buf = jax.lax.dynamic_slice_in_dim(buffers, j * chunk, chunk, 1)
        dispatched = a2e(buf)
        part = emit(j)
        if part is not None:
            shared_parts.append(part)
        outs.append(e2a(moe_lib.expert_ffn(expert_params, dispatched)))
    shared_out = (jnp.concatenate(shared_parts, axis=0)
                  if shared_parts else None)
    return jnp.concatenate(outs, axis=1), shared_out


def moe_apply_dep(params, x, mcfg: MoEConfig, ctx, num_experts_padded: int,
                  plan=None) -> Tuple[jax.Array, jax.Array]:
    """Schedule-driven MoE layer. x: [B, S, M] (global view). ``ctx`` is a
    repro.models.transformer.ExecutionContext carrying the mesh; ``plan``
    is the schedule resolved by a repro.sched.SchedulePolicy for the
    current shape (falls back to the deprecated ``ctx.plan``, then to the
    unchunked r2=1 schedule)."""
    mesh = ctx.mesh
    assert mesh is not None, "DEP impl needs a mesh"
    axis = ctx.expert_axis
    data_axes = tuple(a for a in mesh.axis_names if a != axis)
    B, S, M = x.shape
    mo = mesh.shape[axis]
    E_pad = num_experts_padded or mcfg.num_experts
    assert E_pad % mo == 0, (E_pad, mo)
    if plan is None:
        plan = getattr(ctx, "plan", None)
    r2 = max(int(plan.r2), 1) if plan is not None else 1
    order = plan.order if plan is not None else "AASS"
    # the solver's per-expert chunk granularity: align the capacity so each
    # of the r2 chunks is a multiple of the m_e the solver modeled (Eq. 3),
    # not merely r2-divisible. Capacity only ever rounds UP, so drops never
    # increase and schedule-free callers (m_e hint absent -> 1) are
    # unchanged.
    m_e_hint = getattr(plan, "m_e", None) if plan is not None else None
    m_e_q = max(int(m_e_hint), 1) if m_e_hint else 1

    seq_mode = S % mo == 0 and S >= mo
    dp = _mesh_prod(mesh, data_axes)
    b_shard = data_axes if (B % dp == 0 and B >= dp) else ()
    n_devices = _mesh_prod(mesh, mesh.axis_names)

    has_shared = "shared" in params
    in_spec = P(b_shard or None, axis if seq_mode else None, None)
    expert_spec = jax.tree.map(lambda _: P(axis, None, None),
                               params["experts"])
    router_spec = jax.tree.map(lambda _: P(), params["router"])
    specs = [in_spec, router_spec, expert_spec]
    args = [x, params["router"], params["experts"]]
    if has_shared:
        specs.append(jax.tree.map(lambda _: P(), params["shared"]))
        args.append(params["shared"])

    all_axes = tuple(mesh.axis_names)

    def local(x_loc, router_loc, experts_loc, *rest):
        shared_loc = rest[0] if rest else None
        Bl, Sl, _ = x_loc.shape
        xf = x_loc.reshape(-1, M)
        T_loc = xf.shape[0]
        cap = moe_lib.expert_capacity(T_loc, mcfg, E_pad,
                                      multiple_of=r2 * m_e_q)
        info = moe_lib.moe_dispatch({"router": router_loc}, xf, mcfg, cap,
                                    E_pad)
        shared_fn = (None if shared_loc is None
                     else (lambda xs: mlp_apply(shared_loc, xs)))
        if seq_mode:
            out, shared_out = _chunked_expert_alltoall(
                info.buffers, experts_loc, axis, r2,
                shared_fn=shared_fn, shared_x=xf, order=order)
        else:
            # replicated-token decode path; the shared expert interleaves
            # with the chunk stream per the SOLVED order (ASAS splits it
            # across the r2 chunk boundaries, same as the sequence path)
            mo_idx = jax.lax.axis_index(axis)
            E_loc = E_pad // mo
            chunk = cap // r2
            local_buf = jax.lax.dynamic_slice_in_dim(
                info.buffers, mo_idx * E_loc, E_loc, 0)
            emit = _shared_schedule(order, shared_fn, xf, r2)
            outs = []
            shared_parts = []
            for j in range(r2):
                buf = jax.lax.dynamic_slice_in_dim(local_buf, j * chunk,
                                                   chunk, 1)
                part = emit(j)
                if part is not None:
                    shared_parts.append(part)
                outs.append(moe_lib.expert_ffn(experts_loc, buf))
            local_out = jnp.concatenate(outs, axis=1)      # [E_loc, cap, M]
            shared_out = (jnp.concatenate(shared_parts, axis=0)
                          if shared_parts else None)
            # expert-local combine: each peer combines only ITS experts'
            # contributions into the dense [T, M] output and the E2A
            # collective is a psum of that — (E_pad*cap)/T ~ top_k*cf times
            # fewer bytes than psum-ing the padded dispatch buffers.
            pad = jnp.zeros((E_pad - E_loc,) + local_out.shape[1:],
                            local_out.dtype)
            out_local_layout = jnp.roll(
                jnp.concatenate([local_out, pad], axis=0),
                mo_idx * E_loc, axis=0)
            y_partial = moe_lib.moe_combine(info, out_local_layout, T_loc,
                                            x_loc.dtype)
            y = jax.lax.psum(y_partial, axis)
            if shared_out is not None:
                y = y + shared_out
            aux = jax.lax.psum(info.aux, all_axes) / n_devices
            return y.reshape(Bl, Sl, M), aux
        y = moe_lib.moe_combine(info, out, T_loc, x_loc.dtype)
        if shared_out is not None:
            y = y + shared_out
        # device-mean: exact over distinct shards, unbiased under replication
        aux = jax.lax.psum(info.aux, all_axes) / n_devices
        return y.reshape(Bl, Sl, M), aux

    y, aux = shard_map(
        local, mesh=mesh,
        in_specs=tuple(specs),
        out_specs=(in_spec, P()),
        check_rep=False,
    )(*args)
    return y, aux
