"""DEP execution on a TPU mesh: the paper's A2E/E2A as r2-chunked
all_to_all collectives inside shard_map.

Adaptation (DESIGN.md §2): AG/EG are roles of mesh axes, not disjoint
device groups. Attention runs data-parallel over ("pod","data") and
tensor-parallel over "model"; routed experts are expert-parallel over
"model". The two DEP communication phases map to:

  A2E  = all_to_all(buffers, "model", split=expert_dim, concat=capacity)
  E2A  = all_to_all(outputs, "model", split=capacity,  concat=expert_dim)

FinDEP's fine-grained r2 chunking splits the capacity dimension into r2
chunks and emits chunk k+1's A2E before chunk k's expert FFN retires, so
XLA's async collective scheduler can overlap transport with expert compute
— the TPU analogue of the paper's multi-stream schedule.

The executor is a WALKER over the task-graph IR: ``moe_apply_dep`` lowers
the resolved plan to a ``taskgraph.ExecProgram`` (or takes one directly)
and emits one jax op group per task of ``program.walk()`` — GATE →
router dispatch, A2E/E2A → chunk all_to_all (or buffer slice / psum
combine in replicated decode mode), EXP → routed-expert FFN, SHARED →
shared-expert GEMM segment. The solved task order (ASAS/AASS) is encoded
in the graph's SHARED boundary indices, so the executed order always
matches what the simulator scheduled — one lowering, not three
hand-rolled interpretations.

Cross-micro-batch interleaving: an ``ExecProgram`` lowered with r1 > 1
covers r1 micro-batch STREAMS of the same layer. Streams are a capacity
split of one router dispatch (token→expert assignment and drops are
stream-count invariant), so under ``interleave="streams"`` the walk
emits all streams' ops in the graph's SCHEDULED start order — stream
i+1's GATE-group work is issued before stream i's E2A retires, the
collective-matmul idiom — while ``interleave="off"`` runs the streams
back-to-back (the historical sequential walk). Both emit bit-identical
values; only the achieved comm/compute overlap differs.

Two dispatch modes:
  * "sequence" (train / prefill): local tokens are split over the "model"
    axis (sequence dim), each peer routes its slice, buffers exchanged
    with all_to_all. This is the paper's dispatch/combine, collective-for-
    collective.
  * "replicated" (decode): tokens are replicated over "model" (batch/seq
    too small to split); each peer computes only its local experts'
    outputs and the combine is a single psum — no dispatch collective.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import MoEConfig
from repro.core import taskgraph as tg
from repro.models import moe as moe_lib
from repro.models.layers import mlp_apply
# module-level so the no-tracer walk pays no per-trace import lookup
from repro.obs.trace import active_tracer


def _mesh_prod(mesh, axes) -> int:
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    return p


def as_exec_program(plan) -> tg.ExecProgram:
    """The executor's program for ``plan``: an ``ExecProgram`` passes
    through; a bare ``taskgraph.TaskGraph`` is wrapped with
    ``interleave="off"`` (the historical emission); a full ``Plan`` is
    lowered from its (r2, order, m_e) slice; None means the unchunked
    single-stream r2=1 schedule."""
    if plan is None:
        return tg.ExecProgram(tg.lower_exec(1, "AASS", 1))
    if isinstance(plan, tg.ExecProgram):
        return plan
    if isinstance(plan, tg.TaskGraph):
        return tg.ExecProgram(plan)
    r2 = max(int(getattr(plan, "r2", 1) or 1), 1)
    m_e = getattr(plan, "m_e", 1) or 1
    return tg.ExecProgram(tg.lower_exec(r2, getattr(plan, "order", "AASS"),
                                        max(int(m_e), 1)))


def as_exec_graph(plan) -> tg.TaskGraph:
    """The executor's task graph for ``plan`` (see ``as_exec_program``;
    this is its ``.graph`` view for callers that only need structure)."""
    return as_exec_program(plan).graph


def _shared_part(shared_fn, shared_x, k: int, n_seg: int):
    """The shared-expert GEMM for segment ``k`` of ``n_seg`` (the graph's
    SHARED task at stream-major segment index ``k``): each (mb, boundary)
    SHARED task owns one equal row range of the local batch; ASAS lowers
    r2 segments per stream, AASS one per stream. Rows of the shared GEMM
    are independent, so any segmentation concatenates back to the
    whole-batch product."""
    if n_seg == 1:
        return shared_fn(shared_x)
    seg = shared_x.shape[0] // n_seg
    lo = k * seg
    hi = shared_x.shape[0] if k == n_seg - 1 else (k + 1) * seg
    return shared_fn(shared_x[lo:hi])


def _walk_chunk_stream(program, handlers) -> None:
    """Emit ops for the program's executed order. ``program`` is a
    ``taskgraph.ExecProgram`` (a bare ``TaskGraph`` is accepted and means
    its single-stream ``interleave="off"`` walk); ``handlers`` maps task
    kind -> callable(task) returning the op group's value(s); missing
    kinds are skipped (e.g. SHARED for models without a shared expert).

    When a ``repro.obs`` tracer is scoped (``use_tracer``) around the
    caller, each handler call is wrapped in a task span (``emit=True``).
    Under jit the walk runs at jax trace time, so these spans record
    op-emission order and trace cost once per compiled program — NOT
    per-step execution time. When the walk executes EAGERLY (shard_map
    outside jit dispatches each op immediately) and the tracer was built
    with ``fence=True``, the walker fences each handler's returned value
    (``maybe_fence``) before closing its span: the spans then bound real
    on-device work per task — the fenced-emission trace the overlap
    attributor consumes (``obs.device``). With no active tracer (the
    default) this is the bare loop and the emitted program is
    identical."""
    if isinstance(program, tg.TaskGraph):
        program = tg.ExecProgram(program)
    tracer = active_tracer()
    if tracer is None:
        for task in program.walk():
            h = handlers.get(task.kind)
            if h is not None:
                h(task)
        return
    clock = tracer.clock
    fence = tracer.fence
    for task in program.walk():
        h = handlers.get(task.kind)
        if h is not None:
            t0 = clock()
            out = h(task)
            if fence:
                tracer.maybe_fence(out)
            tracer.task_span(task, t0, clock(), emit=True)


def _graph_expert_alltoall(program: tg.ExecProgram, buffers, expert_params,
                           axis: str, shared_fn=None, shared_x=None,
                           hot_weights=None, hot_rows=None):
    """Sequence-mode walk: buffers [E_pad, C_loc, M] per peer ->
    (outputs [E_pad, C_loc, M] back in dispatch layout, shared_out or
    None). Each A2E/EXP/E2A task becomes one chunk of the paper's
    dispatch -> expert FFN -> combine pipeline, in program order, so
    XLA's async collective scheduler can overlap transport with compute;
    SHARED tasks interleave at their lowered chunk boundaries.

    Task (stream i, chunk j) covers capacity columns
    [(i·r2+j)·c, (i·r2+j+1)·c) of the dispatch buffers — streams are a
    capacity split of ONE router dispatch, so the emitted values are
    independent of both the stream count and the emission order; the
    results reassemble in fixed (i, j) order. Under
    ``interleave="streams"`` the walk follows the scheduled start order
    (stream i+1's work issued before stream i retires); per-stream
    dispatch state lives in dicts keyed (mb, chunk), so each stream is
    naturally double-buffered — a stream's chunk buffer is dropped
    (donated) as soon as its consumer pops it, whatever the interleave.

    ``hot_weights``/``hot_rows`` realize the placement's REP task: the
    replicated hot experts' FFN runs on THIS peer's dispatch rows (the
    tokens are locally resident — no wire crossing) and the results
    overwrite the corresponding rows of the combined output; with r1
    streams each REP task runs its stream's capacity slice. Each
    (expert, capacity-slot) row of ``expert_ffn`` depends only on its
    own input row and the expert's weights, so the spliced rows are
    bit-identical to what the A2E -> EXP -> E2A round trip returns for
    them — replicas=0 therefore executes the exact unreplicated
    program."""
    graph = program.graph
    E_pad, C_loc, M = buffers.shape
    r1, r2 = graph.r1, graph.r2
    chunk = C_loc // (r1 * r2)
    n_seg = graph.shared_segments          # per stream
    rep_chunk = C_loc // r1                # REP slice width per stream
    dispatched = {}
    ffn_out = {}
    outs = {}
    shared_parts = {}
    hot_out = {}

    def on_a2e(t):     # [E_pad, c, M] -> [E_loc, mo*c, M]
        buf = jax.lax.dynamic_slice_in_dim(
            buffers, (t.mb * r2 + t.chunk) * chunk, chunk, 1)
        dispatched[(t.mb, t.chunk)] = jax.lax.all_to_all(
            buf, axis, split_axis=0, concat_axis=1, tiled=True)
        return dispatched[(t.mb, t.chunk)]

    def on_shared(t):
        if shared_fn is None:
            return None
        part = _shared_part(shared_fn, shared_x,
                            t.mb * n_seg + t.chunk, r1 * n_seg)
        shared_parts[(t.mb, t.chunk)] = part
        return part

    def on_exp(t):
        out = moe_lib.expert_ffn(expert_params,
                                 dispatched.pop((t.mb, t.chunk)))
        ffn_out[(t.mb, t.chunk)] = out
        return out

    def on_e2a(t):     # [E_loc, mo*c, M] -> [E_pad, c, M]
        out = jax.lax.all_to_all(ffn_out.pop((t.mb, t.chunk)), axis,
                                 split_axis=1, concat_axis=0,
                                 tiled=True)
        outs[(t.mb, t.chunk)] = out
        return out

    def on_rep(t):     # hot-expert FFN on the locally resident rows
        rows = jax.lax.dynamic_slice_in_dim(
            buffers[hot_rows], t.mb * rep_chunk, rep_chunk, 1)
        hot_out[t.mb] = moe_lib.expert_ffn(hot_weights, rows)
        return hot_out[t.mb]

    handlers = {tg.A2E: on_a2e, tg.SHARED: on_shared,
                tg.EXP: on_exp, tg.E2A: on_e2a}
    if hot_weights is not None:
        handlers[tg.REP] = on_rep
    _walk_chunk_stream(program, handlers)
    if hot_weights is not None and not hot_out:
        # plan graph lowered without a REP task (e.g. a stale epoch-0
        # graph): still execute the hot FFN, after the chunk stream
        hot_out[0] = moe_lib.expert_ffn(hot_weights, buffers[hot_rows])
    shared_out = (jnp.concatenate([shared_parts[k]
                                   for k in sorted(shared_parts)], axis=0)
                  if shared_parts else None)
    out = jnp.concatenate([outs[k] for k in sorted(outs)], axis=1)
    if hot_out:
        hot = jnp.concatenate([hot_out[k] for k in sorted(hot_out)], axis=1)
        out = out.at[hot_rows].set(hot)
    return out, shared_out


def _graph_replicated_experts(program: tg.ExecProgram, local_buf,
                              expert_params, shared_fn=None, shared_x=None):
    """Replicated-token decode walk: each peer runs only its local
    experts' chunks; A2E tasks become buffer slices (the transport is the
    single psum combine after the walk, realized by the caller at the
    E2A position) and SHARED tasks interleave per the solved order. The
    same (stream, chunk) capacity split and fixed-order reassembly as
    the sequence walk."""
    graph = program.graph
    cap = local_buf.shape[1]
    r1, r2 = graph.r1, graph.r2
    chunk = cap // (r1 * r2)
    n_seg = graph.shared_segments
    sliced = {}
    outs = {}
    shared_parts = {}

    def on_a2e(t):
        sliced[(t.mb, t.chunk)] = jax.lax.dynamic_slice_in_dim(
            local_buf, (t.mb * r2 + t.chunk) * chunk, chunk, 1)
        return sliced[(t.mb, t.chunk)]

    def on_shared(t):
        if shared_fn is None:
            return None
        part = _shared_part(shared_fn, shared_x,
                            t.mb * n_seg + t.chunk, r1 * n_seg)
        shared_parts[(t.mb, t.chunk)] = part
        return part

    def on_exp(t):
        out = moe_lib.expert_ffn(expert_params,
                                 sliced.pop((t.mb, t.chunk)))
        outs[(t.mb, t.chunk)] = out
        return out

    _walk_chunk_stream(program, {tg.A2E: on_a2e, tg.SHARED: on_shared,
                                 tg.EXP: on_exp})
    shared_out = (jnp.concatenate([shared_parts[k]
                                   for k in sorted(shared_parts)], axis=0)
                  if shared_parts else None)
    out = jnp.concatenate([outs[k] for k in sorted(outs)], axis=1)
    return out, shared_out


def moe_apply_dep(params, x, mcfg: MoEConfig, ctx, num_experts_padded: int,
                  plan=None, placement=None, return_stats: bool = False,
                  capacity_scale: float = 1.0):
    """Schedule-driven MoE layer. x: [B, S, M] (global view). ``ctx`` is a
    repro.models.transformer.ExecutionContext carrying the mesh; ``plan``
    is the schedule resolved by a repro.sched.SchedulePolicy for the
    current shape — a ``taskgraph.ExecProgram`` (preferred; see
    ``Plan.exec_program``), a bare ``TaskGraph`` (historical single-
    stream emission), a ``Plan`` (lowered here), or None (the unchunked
    r2=1 schedule).

    ``placement`` is an optional ``repro.placement.Placement`` over the
    PADDED expert dimension: its ``perm`` re-homes each logical expert's
    dispatch to the physical buffer row where the (engine-permuted)
    weights live, and its replicated hot experts execute the REP task —
    their FFN runs on the locally resident dispatch rows in sequence
    mode, bit-identically splicing over the wire round trip. ``None`` or
    the uniform no-replica placement takes exactly the legacy path.
    ``return_stats`` appends a ``moe.MoEStats`` (global [E] logical load
    histogram + dropped-assignment count) to the return.
    ``capacity_scale`` (static float >= 1) widens the dispatch capacity
    to the observed hottest-expert load (see
    ``placement.capacity_scale``); 1.0 is the legacy uniform sizing."""
    mesh = ctx.mesh
    assert mesh is not None, "DEP impl needs a mesh"
    axis = ctx.expert_axis
    data_axes = tuple(a for a in mesh.axis_names if a != axis)
    B, S, M = x.shape
    mo = mesh.shape[axis]
    E_pad = num_experts_padded or mcfg.num_experts
    assert E_pad % mo == 0, (E_pad, mo)
    program = as_exec_program(plan)
    graph = program.graph
    if placement is not None and placement.is_uniform:
        placement = None        # the legacy path IS this placement
    if placement is not None:
        assert placement.num_experts == E_pad, \
            (placement.num_experts, E_pad)
        assert placement.num_ranks == mo, (placement.num_ranks, mo)
    # the solver's per-expert chunk granularity: align the capacity so
    # each of the r1·r2 (stream, chunk) slices is a multiple of the m_e
    # the solver modeled (Eq. 3), not merely slice-count-divisible.
    # Capacity only ever rounds UP, so drops never increase and
    # schedule-free callers (m_e hint absent -> 1) are unchanged.
    cap_multiple = graph.r1 * graph.r2 * graph.m_e

    seq_mode = S % mo == 0 and S >= mo
    dp = _mesh_prod(mesh, data_axes)
    b_shard = data_axes if (B % dp == 0 and B >= dp) else ()
    n_devices = _mesh_prod(mesh, mesh.axis_names)

    has_shared = "shared" in params
    in_spec = P(b_shard or None, axis if seq_mode else None, None)
    expert_spec = jax.tree.map(lambda _: P(axis, None, None),
                               params["experts"])
    router_spec = jax.tree.map(lambda _: P(), params["router"])
    specs = [in_spec, router_spec, expert_spec]
    args = [x, params["router"], params["experts"]]
    if has_shared:
        specs.append(jax.tree.map(lambda _: P(), params["shared"]))
        args.append(params["shared"])

    # placement: the logical->physical dispatch map, plus the replicated
    # hot experts' rows and weights (gathered from the GLOBAL stacked
    # arrays here, replicated to every peer — that IS the replication)
    expert_map = None
    hot_rows = None
    hot_weights = None
    if placement is not None:
        expert_map = jnp.asarray(placement.perm, jnp.int32)
        specs.append(P())
        args.append(expert_map)
        if placement.hot_experts and seq_mode:
            perm = placement.perm
            hot_rows = jnp.asarray([perm[e] for e in placement.replicated],
                                   jnp.int32)
            hot_weights = jax.tree.map(lambda a: a[hot_rows],
                                       params["experts"])
            specs.extend([P(), jax.tree.map(lambda _: P(), hot_weights)])
            args.extend([hot_rows, hot_weights])

    all_axes = tuple(mesh.axis_names)
    # axes that actually shard tokens: psum over them recovers the GLOBAL
    # load/drop counts on every device (the rest only replicate tokens)
    tok_axes = (b_shard or ()) + ((axis,) if seq_mode else ())

    def local(x_loc, router_loc, experts_loc, *rest):
        rest = list(rest)
        shared_loc = rest.pop(0) if has_shared else None
        emap_loc = rest.pop(0) if expert_map is not None else None
        hrows_loc = rest.pop(0) if hot_rows is not None else None
        hw_loc = rest.pop(0) if hot_weights is not None else None
        Bl, Sl, _ = x_loc.shape
        xf = x_loc.reshape(-1, M)
        T_loc = xf.shape[0]
        # the walk's GATE task: router dispatch into capacity buffers.
        # capacity_scale widens the buffers to the observed hottest-expert
        # load (skew-aware planning) — 1.0 is the legacy uniform sizing.
        cap = moe_lib.expert_capacity(T_loc, mcfg, E_pad,
                                      multiple_of=cap_multiple,
                                      scale=capacity_scale)
        info = moe_lib.moe_dispatch({"router": router_loc}, xf, mcfg, cap,
                                    E_pad, expert_map=emap_loc)
        stats = None
        if return_stats:
            if tok_axes:
                load = jax.lax.psum(info.load, tok_axes)
                dropped = jax.lax.psum(info.dropped, tok_axes)
            else:
                load, dropped = info.load, info.dropped
            stats = moe_lib.MoEStats(load=load, dropped=dropped)
        shared_fn = (None if shared_loc is None
                     else (lambda xs: mlp_apply(shared_loc, xs)))
        if seq_mode:
            out, shared_out = _graph_expert_alltoall(
                program, info.buffers, experts_loc, axis,
                shared_fn=shared_fn, shared_x=xf,
                hot_weights=hw_loc, hot_rows=hrows_loc)
        else:
            # replicated-token decode path; the shared expert interleaves
            # with the chunk stream per the SOLVED order (the graph's
            # SHARED boundary indices, same lowering as the sequence path)
            mo_idx = jax.lax.axis_index(axis)
            E_loc = E_pad // mo
            local_buf = jax.lax.dynamic_slice_in_dim(
                info.buffers, mo_idx * E_loc, E_loc, 0)
            local_out, shared_out = _graph_replicated_experts(
                program, local_buf, experts_loc,
                shared_fn=shared_fn, shared_x=xf)   # [E_loc, cap, M]
            # expert-local combine (the walk's E2A tasks): each peer
            # combines only ITS experts' contributions into the dense
            # [T, M] output and the transport is a psum of that —
            # (E_pad*cap)/T ~ top_k*cf times fewer bytes than psum-ing
            # the padded dispatch buffers.
            pad = jnp.zeros((E_pad - E_loc,) + local_out.shape[1:],
                            local_out.dtype)
            out_local_layout = jnp.roll(
                jnp.concatenate([local_out, pad], axis=0),
                mo_idx * E_loc, axis=0)
            y_partial = moe_lib.moe_combine(info, out_local_layout, T_loc,
                                            x_loc.dtype)
            y = jax.lax.psum(y_partial, axis)
            if shared_out is not None:
                y = y + shared_out
            aux = jax.lax.psum(info.aux, all_axes) / n_devices
            y = y.reshape(Bl, Sl, M)
            return (y, aux, stats) if return_stats else (y, aux)
        y = moe_lib.moe_combine(info, out, T_loc, x_loc.dtype)
        if shared_out is not None:
            y = y + shared_out
        # device-mean: exact over distinct shards, unbiased under replication
        aux = jax.lax.psum(info.aux, all_axes) / n_devices
        y = y.reshape(Bl, Sl, M)
        return (y, aux, stats) if return_stats else (y, aux)

    out_specs = (in_spec, P())
    if return_stats:
        out_specs += (moe_lib.MoEStats(load=P(), dropped=P()),)
    return shard_map(
        local, mesh=mesh,
        in_specs=tuple(specs),
        out_specs=out_specs,
        check_rep=False,
    )(*args)
