"""FinDEP core: performance models, analytic makespan, the task-graph
execution IR with its exact scheduler/simulator, Algorithm-1 solver,
baselines, and the online planner."""
from repro.core.analytic import (ORDER_AASS, ORDER_ASAS, ORDERS, StageTimes,
                                 makespan_closed_form, makespan_naive,
                                 makespan_pppipe, throughput, xyfg)
from repro.core.baselines import (best_pppipe, eps_pipeline_plan, naive_plan,
                                  pppipe_plan)
from repro.core.taskgraph import (CostBreakdown, ExecProgram, LoweringSpec,
                                  ScheduleResult, Task, TaskCosts, TaskGraph,
                                  ascii_gantt, lower, lower_exec, schedule,
                                  stream_major_order, stream_serial_deps)
from repro.core.perf_model import (PROFILES, TPU_V5E, PAPER_A6000, AlphaBeta,
                                   DepModelSpec, HardwareProfile, StageModels,
                                   build_stage_models, calibrated_stage_models,
                                   fit_alpha_beta, fit_profile, get_profile,
                                   register_profile)
from repro.core.planner import FinDEPPlanner, PlannerConfig
from repro.core.simulator import (SimResult, non_overlapped_comm_time,
                                  simulate_dep, simulate_naive,
                                  simulate_pppipe)
from repro.core.solver import (Plan, SolverStats, solve,
                               solve_brute_force, solve_r2)

__all__ = [
    "ORDER_AASS", "ORDER_ASAS", "ORDERS", "StageTimes",
    "makespan_closed_form", "makespan_naive", "makespan_pppipe",
    "throughput", "xyfg", "best_pppipe", "eps_pipeline_plan", "naive_plan",
    "pppipe_plan",
    "PROFILES", "TPU_V5E", "PAPER_A6000", "AlphaBeta", "DepModelSpec",
    "HardwareProfile", "StageModels", "build_stage_models",
    "calibrated_stage_models", "fit_alpha_beta", "fit_profile",
    "get_profile", "register_profile",
    "FinDEPPlanner", "PlannerConfig", "SimResult",
    "non_overlapped_comm_time", "simulate_dep", "simulate_naive",
    "simulate_pppipe", "Plan", "SolverStats", "solve",
    "solve_brute_force", "solve_r2",
    "Task", "TaskGraph", "TaskCosts", "CostBreakdown", "ExecProgram",
    "LoweringSpec", "ScheduleResult", "lower", "lower_exec", "schedule",
    "ascii_gantt", "stream_major_order", "stream_serial_deps",
]
