"""FinDEP configuration search (paper Algorithm 1).

Searches (m_a, r1, m_e, r2, order) maximizing throughput subject to the AG
memory constraint r1 * m_a <= M_cap, exploiting:

  * Theorems 1-2: throughput is monotonically increasing in m_a  -> iterate
    m_a descending and only visit the Pareto frontier of (m_a, r1);
  * Theorem 3:   monotonically non-decreasing in r1              -> use the
    maximal memory-feasible r1 for each m_a;
  * Theorem 4:   the makespan is convex in 1/r2                  -> find r2
    by integer ternary search instead of enumeration.

Three objective modes:
  "analytic"  -- paper-faithful closed forms (Eq. 13 / AASS analogue);
  "simulate"  -- exact event-order simulator (slower, exact);
  "hybrid"    -- analytic search, then re-rank the top-K candidates with the
                 simulator (beyond-paper refinement; default).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, replace
from typing import Callable, List, Optional, Tuple

from repro.core.analytic import (ORDER_AASS, ORDER_ASAS, ORDERS, StageTimes,
                                 makespan_closed_form)
from repro.core.perf_model import StageModels
from repro.core.simulator import simulate_makespan
from repro.core.taskgraph import (CostBreakdown, ExecProgram, LoweringSpec,
                                  TaskCosts, TaskGraph, lower, lower_exec,
                                  schedule)

OBJECTIVES = ("analytic", "simulate", "hybrid")


@dataclass(frozen=True)
class Plan:
    """A fully-specified FinDEP schedule configuration.

    ``breakdown`` carries the modeled per-primitive cost split
    (gemm/attn/comm seconds, normalized to ``makespan``) derived from the
    lowered task graph -- telemetry uses it to attribute measured
    residuals to individual hardware primitives."""

    m_a: int
    r1: int
    m_e: float
    r2: int
    order: str
    throughput: float          # tokens / second
    makespan: float            # seconds for the full T-layer mini-batch
    objective: str = "analytic"
    breakdown: Optional[CostBreakdown] = None

    def exec_graph(self, hot_experts: int = 0,
                   placement_epoch: int = 0) -> TaskGraph:
        """The task graph the DEP executor walks: one layer, one
        micro-batch of the chunk stream (m_a/r1 are realized by the
        caller's batching and T by the transformer loop, so the graph is
        keyed only by what changes the compiled program: r2, order,
        floored m_e — plus the active placement's replica count and
        epoch, so a re-balance keys a fresh trace)."""
        return lower_exec(max(int(self.r2), 1), self.order,
                          max(int(math.floor(self.m_e)), 1),
                          hot_experts=max(int(hot_experts), 0),
                          placement_epoch=int(placement_epoch))

    def exec_program(self, streams: Optional[int] = None,
                     hot_experts: int = 0, placement_epoch: int = 0,
                     interleave: str = "streams",
                     hints: Optional[Tuple[int, ...]] = None
                     ) -> ExecProgram:
        """The executor-visible ``taskgraph.ExecProgram``: the exec
        graph lowered with ``streams`` micro-batch streams (default: the
        plan's r1 — the stream split the solver's makespan assumed) plus
        the emission policy. Under ``interleave="streams"`` the walk
        follows the scheduled start order; priority hints default to the
        schedule of the exec graph under per-task costs derived from the
        plan's modeled ``breakdown`` (``ScheduleResult.priority_hints``),
        falling back to the structural default when the plan carries no
        breakdown."""
        r1 = max(int(streams if streams is not None else self.r1), 1)
        graph = lower_exec(max(int(self.r2), 1), self.order,
                           max(int(math.floor(self.m_e)), 1),
                           hot_experts=max(int(hot_experts), 0),
                           placement_epoch=int(placement_epoch),
                           r1=r1)
        if interleave == "streams" and hints is None:
            hints = self._exec_hints(graph)
        return ExecProgram(graph, interleave, hints)

    def _exec_hints(self, graph: TaskGraph) -> Optional[Tuple[int, ...]]:
        """Priority hints for ``graph`` from the plan's modeled cost
        split: the breakdown's class totals are spread uniformly over
        that class's tasks (attn over ATTN, comm over A2E+E2A, gemm over
        EXP chunks and SHARED segments). Only the relative magnitudes
        matter — they order the interleaved emission. None (no breakdown)
        defers to the structural default."""
        bd = self.breakdown
        if bd is None or bd.total <= 0.0:
            return None
        r1f = max(int(self.r1), 1)
        r2f = max(int(self.r2), 1)
        n_seg = graph.shared_segments
        attn_t = bd.attn / r1f
        comm_t = bd.comm / (2.0 * r1f * r2f)
        gemm_t = bd.gemm / (r1f * (r2f + n_seg))
        costs = TaskCosts(attn=attn_t, shared=gemm_t * n_seg, exp=gemm_t,
                          comm=comm_t, rep=gemm_t)
        return schedule(graph, costs).priority_hints()

    def as_dict(self):
        return dict(m_a=self.m_a, r1=self.r1, m_e=self.m_e, r2=self.r2,
                    order=self.order, throughput=self.throughput,
                    makespan=self.makespan, objective=self.objective)


def plan_breakdown(models: StageModels, T: int, plan: Plan) -> CostBreakdown:
    """Modeled per-primitive (gemm/attn/comm) seconds for one execution
    of ``plan``, from the lowered graph's per-task busy sums, normalized
    so the classes sum to ``plan.makespan`` (the makespan includes idle
    gaps the busy sums don't)."""
    st = StageTimes.from_models(models, plan.m_a, plan.m_e)
    graph = lower(plan, LoweringSpec(T=T,
                                     has_shared=models.spec.n_shared > 0),
                  hot_experts=1 if st.t_rep > 0.0 else 0)
    res = schedule(graph, TaskCosts.from_stage_times(st))
    return res.breakdown().normalized_to(plan.makespan)


@dataclass
class SolverStats:
    evaluations: int = 0
    candidates_visited: int = 0
    wall_time_s: float = 0.0


def _makespan(models: StageModels, T: int, m_a: int, r1: int, r2: int,
              order: str, objective: str) -> float:
    m_e = models.me_from_ma(m_a, r2)
    st = StageTimes.from_models(models, m_a, m_e)
    if objective == "simulate":
        # makespan-only vectorized recurrence: the solver evaluates
        # hundreds of candidates and never reads the per-task schedule
        return simulate_makespan(st, T, r1, r2, order=order)
    return makespan_closed_form(st, T, r1, r2, order)


def _throughput(models: StageModels, T: int, m_a: int, r1: int, r2: int,
                order: str, objective: str) -> Tuple[float, float]:
    ms = _makespan(models, T, m_a, r1, r2, order, objective)
    tokens = r1 * m_a * models.cluster.ag * models.spec.S
    return tokens / ms, ms


def max_r2(models: StageModels, m_a: int, cap: int = 64) -> int:
    """Largest r2 keeping m_e >= 1 token per expert per chunk."""
    s, c = models.spec, models.cluster
    ub = (m_a * c.ag * s.top_k * s.S) // s.E
    return max(1, min(cap, int(ub)))


def solve_r2(models: StageModels, T: int, m_a: int, r1: int, order: str,
             objective: str = "analytic", r2_cap: int = 64,
             stats: Optional[SolverStats] = None) -> Tuple[int, float, float]:
    """1-D search for r2. Ternary search (valid by Theorem 4 convexity) for
    the analytic objective; exhaustive scan when simulating (no convexity
    guarantee). Returns (r2*, throughput, makespan)."""
    hi = max_r2(models, m_a, cap=r2_cap)

    def eval_r2(r2: int) -> Tuple[float, float]:
        if stats is not None:
            stats.evaluations += 1
        return _throughput(models, T, m_a, r1, r2, order, objective)

    if objective == "simulate" or hi <= 6:
        best = max(((r2,) + eval_r2(r2) for r2 in range(1, hi + 1)),
                   key=lambda t: t[1])
        return best

    lo = 1
    while hi - lo > 2:
        m1 = lo + (hi - lo) // 3
        m2 = hi - (hi - lo) // 3
        if eval_r2(m1)[0] >= eval_r2(m2)[0]:
            hi = m2 - 1 if m2 > m1 else m2
        else:
            lo = m1 + 1
    best = max(((r2,) + eval_r2(r2) for r2 in range(lo, hi + 1)),
               key=lambda t: t[1])
    return best


def get_max_r1(m_a: int, mem_cap_samples: int, r1_cap: int = 64) -> int:
    """Paper's getMaxR1: largest r1 with r1 * m_a <= memory capacity."""
    if m_a <= 0 or m_a > mem_cap_samples:
        return 0
    return min(mem_cap_samples // m_a, r1_cap)


def solve(models: StageModels, T: int, mem_cap_samples: int,
          objective: str = "hybrid", r2_cap: int = 64, r1_cap: int = 64,
          orders: Tuple[str, ...] = ORDERS, top_k_refine: int = 8,
          fixed_batch: Optional[int] = None) -> Tuple[Plan, SolverStats]:
    """Algorithm 1. ``fixed_batch`` (samples per AG device) switches to the
    online mode where r1 * m_a must exactly cover the arrived batch."""
    assert objective in OBJECTIVES
    stats = SolverStats()
    t0 = time.perf_counter()
    search_obj = "analytic" if objective == "hybrid" else objective

    candidates: List[Plan] = []
    prev_r1 = -1
    for m_a in range(mem_cap_samples, 0, -1):
        if fixed_batch is not None:
            if fixed_batch % m_a != 0:
                continue
            r1 = fixed_batch // m_a
            if r1 > r1_cap or m_a * r1 > mem_cap_samples:
                continue
        else:
            r1 = get_max_r1(m_a, mem_cap_samples, r1_cap)
            if r1 == 0 or r1 == prev_r1:   # skip non-Pareto-optimal (m_a,r1)
                prev_r1 = r1
                continue
            prev_r1 = r1
        stats.candidates_visited += 1
        for order in orders:
            r2, tps, ms = solve_r2(models, T, m_a, r1, order,
                                   objective=search_obj, r2_cap=r2_cap,
                                   stats=stats)
            m_e = models.me_from_ma(m_a, r2)
            candidates.append(Plan(m_a=m_a, r1=r1, m_e=m_e, r2=r2,
                                   order=order, throughput=tps, makespan=ms,
                                   objective=search_obj))

    if not candidates:
        raise ValueError("no feasible (m_a, r1) under the memory constraint")

    candidates.sort(key=lambda p: p.throughput, reverse=True)

    if objective == "hybrid":
        # Re-rank the analytic top-K with the exact simulator.
        refined = []
        for p in candidates[:top_k_refine]:
            tps, ms = _throughput(models, T, p.m_a, p.r1, p.r2, p.order,
                                  "simulate")
            stats.evaluations += 1
            refined.append(Plan(m_a=p.m_a, r1=p.r1, m_e=p.m_e, r2=p.r2,
                                order=p.order, throughput=tps, makespan=ms,
                                objective="hybrid"))
        refined.sort(key=lambda p: p.throughput, reverse=True)
        best = refined[0]
    else:
        best = candidates[0]

    # tag the winning plan with its modeled per-primitive cost split (one
    # extra graph schedule; candidates stay untagged to keep the search
    # cheap)
    best = replace(best, breakdown=plan_breakdown(models, T, best))
    stats.wall_time_s = time.perf_counter() - t0
    return best, stats


def solve_brute_force(models: StageModels, T: int, mem_cap_samples: int,
                      objective: str = "analytic", r2_cap: int = 16,
                      r1_cap: int = 16,
                      fixed_batch: Optional[int] = None) -> Plan:
    """Exhaustive reference over (m_a, r1, r2, order); for tests."""
    best: Optional[Plan] = None
    for m_a in range(1, mem_cap_samples + 1):
        if fixed_batch is not None:
            if fixed_batch % m_a:
                continue
            r1_list = [fixed_batch // m_a]
        else:
            r1_list = range(1, get_max_r1(m_a, mem_cap_samples, r1_cap) + 1)
        for r1 in r1_list:
            if r1 == 0 or r1 > r1_cap or r1 * m_a > mem_cap_samples:
                continue
            for order in ORDERS:
                for r2 in range(1, max_r2(models, m_a, r2_cap) + 1):
                    tps, ms = _throughput(models, T, m_a, r1, r2, order,
                                          objective)
                    if best is None or tps > best.throughput:
                        m_e = models.me_from_ma(m_a, r2)
                        best = Plan(m_a=m_a, r1=r1, m_e=m_e, r2=r2,
                                    order=order, throughput=tps, makespan=ms,
                                    objective=objective)
    assert best is not None
    return best
