"""alpha-beta performance models (paper Eqs. 7-9) and their composition into
per-stage layer models (Eqs. 1-4, 10-11).

All times are SECONDS. Workload units follow the paper:
  * GEMM      x = m*k*n          (product of the three GEMM dims)
  * attention y = N_h B S^2 (d_k + d_v)
  * comm      z = bytes on the wire per device

The paper fits these with least squares on microbenchmarks (Fig. 7,
R^2 > 0.994); ``fit_alpha_beta`` reproduces that procedure and
``benchmarks/perf_model_fit.py`` validates linearity on this host's
measured GEMMs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import DepClusterConfig, ModelConfig

# ---------------------------------------------------------------------------
# alpha-beta primitive
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AlphaBeta:
    """t(x) = alpha + beta * x  (alpha: fixed overhead [s], beta: [s/unit])."""

    alpha: float
    beta: float

    def __call__(self, x: float) -> float:
        return self.alpha + self.beta * x

    def scaled(self, count: float) -> "AlphaBeta":
        """count back-to-back invocations: count*alpha + count*beta*x'."""
        return AlphaBeta(self.alpha * count, self.beta * count)

    def as_dict(self) -> dict:
        return {"alpha": self.alpha, "beta": self.beta}

    @staticmethod
    def from_dict(d: dict) -> "AlphaBeta":
        return AlphaBeta(float(d["alpha"]), float(d["beta"]))


def fit_alpha_beta(xs: Sequence[float], ts: Sequence[float]) -> Tuple[AlphaBeta, float]:
    """Least-squares fit of t = alpha + beta*x; returns (model, R^2)."""
    x = np.asarray(xs, dtype=np.float64)
    t = np.asarray(ts, dtype=np.float64)
    A = np.stack([np.ones_like(x), x], axis=1)
    coef, *_ = np.linalg.lstsq(A, t, rcond=None)
    alpha, beta = float(coef[0]), float(coef[1])
    pred = alpha + beta * x
    ss_res = float(np.sum((t - pred) ** 2))
    ss_tot = float(np.sum((t - t.mean()) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    return AlphaBeta(alpha, beta), r2


# ---------------------------------------------------------------------------
# Hardware profiles
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareProfile:
    """Per-device alpha-beta models for the primitive operations.

    ``decode`` is an optional FOURTH primitive: single-query ragged
    decode attention, fitted in BYTES-STREAMED units (z = sum(lengths) *
    kv_heads * (d_k + d_v) * dtype_bytes). Decode attention is
    bandwidth-bound — one query streams the whole KV cache — so reusing
    the prefill attention fit (FLOP-shaped, compute-bound regime)
    systematically mis-slopes it. Profiles without a decode fit fall
    back to the prefill attention model (pre-PR-6 behaviour)."""

    name: str
    gemm: AlphaBeta     # x = m*k*n
    attn: AlphaBeta     # y = N_h B S^2 (d_k + d_v)
    comm: AlphaBeta     # z = bytes per device on the a2e/e2a path
    decode: Optional[AlphaBeta] = None   # z = KV bytes streamed

    @staticmethod
    def from_peaks(name: str, *, peak_flops: float, link_bw: float,
                   gemm_eff: float = 0.6, attn_eff: float = 0.35,
                   launch_overhead: float = 5e-6,
                   comm_overhead: float = 15e-6) -> "HardwareProfile":
        """Analytic profile from peak numbers. ``peak_flops`` counts 2 FLOPs
        per MAC, so beta_gm = 2 / (eff * peak) per m*k*n unit."""
        return HardwareProfile(
            name=name,
            gemm=AlphaBeta(launch_overhead, 2.0 / (gemm_eff * peak_flops)),
            attn=AlphaBeta(launch_overhead, 2.0 / (attn_eff * peak_flops)),
            comm=AlphaBeta(comm_overhead, 1.0 / link_bw),
        )

    def as_dict(self) -> dict:
        """JSON-safe representation. ``json`` serializes floats with
        ``repr``, which round-trips IEEE doubles exactly, so
        ``from_dict(as_dict())`` is bit-for-bit."""
        out = {"name": self.name, "gemm": self.gemm.as_dict(),
               "attn": self.attn.as_dict(), "comm": self.comm.as_dict()}
        if self.decode is not None:
            out["decode"] = self.decode.as_dict()
        return out

    @staticmethod
    def from_dict(d: dict) -> "HardwareProfile":
        return HardwareProfile(
            name=str(d["name"]),
            gemm=AlphaBeta.from_dict(d["gemm"]),
            attn=AlphaBeta.from_dict(d["attn"]),
            comm=AlphaBeta.from_dict(d["comm"]),
            decode=(AlphaBeta.from_dict(d["decode"])
                    if d.get("decode") is not None else None),
        )

    def scaled(self, ratio: float, *, name: Optional[str] = None
               ) -> "HardwareProfile":
        """Uniformly rescale every primitive by ``ratio`` (> 1 = slower).
        Used by drift recalibration: a uniform rescale leaves the solver's
        argmax unchanged but brings modeled makespans back onto the
        measured wall-times."""
        return self.scaled_by({"gemm": ratio, "attn": ratio,
                               "comm": ratio}, name=name)

    def scaled_by(self, ratios: Dict[str, float], *,
                  name: Optional[str] = None) -> "HardwareProfile":
        """Rescale each primitive by its own ratio (missing keys keep a
        primitive unchanged). Per-primitive drift attribution uses this
        to retune alpha_c/beta_c (comm) separately from the GEMM and
        attention terms — unlike the uniform ``scaled``, this CAN move
        the solver's argmax, which is the point."""
        def sc(m: AlphaBeta, kind: str) -> AlphaBeta:
            r = float(ratios.get(kind, 1.0))
            return AlphaBeta(m.alpha * r, m.beta * r)
        # drift attribution tags decode tasks with the attn class, so the
        # decode fit follows the attn ratio unless given one of its own
        decode = None
        if self.decode is not None:
            r = float(ratios.get("decode", ratios.get("attn", 1.0)))
            decode = AlphaBeta(self.decode.alpha * r, self.decode.beta * r)
        return HardwareProfile(name=name or self.name,
                               gemm=sc(self.gemm, "gemm"),
                               attn=sc(self.attn, "attn"),
                               comm=sc(self.comm, "comm"),
                               decode=decode)


# TPU v5e analytic target (roofline constants from the assignment):
# 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI. The a2e all_to_all
# moves z bytes per device over ICI; with 2 bidirectional links usable on a
# torus axis we take ~45 GB/s effective per device.
TPU_V5E = HardwareProfile.from_peaks(
    "tpu_v5e", peak_flops=197e12, link_bw=45e9)

# The paper's Testbed A fit (Fig. 7 caption, times converted ms -> s):
# alpha_gm=0.17ms, beta_gm=8.59e-11 ms/unit -> 8.59e-14 s per m*k*n
# (~23 TFLOP/s effective, consistent with A6000 fp16); attention likewise.
# comm (eg=4,ag=4): alpha=0.37ms, beta=2.55e-6 ms/B -> 2.55e-9 s/B
# (~0.4 GB/s effective per-pair NCCL over shared PCIe — this is what makes
# communication a first-order term in the paper's testbeds).
PAPER_A6000 = HardwareProfile(
    "paper_a6000",
    gemm=AlphaBeta(0.17e-3, 8.59e-14),
    attn=AlphaBeta(0.15e-3, 1.54e-14),
    comm=AlphaBeta(0.37e-3, 2.55e-9),
)

PROFILES = {p.name: p for p in (TPU_V5E, PAPER_A6000)}


def register_profile(profile: HardwareProfile,
                     overwrite: bool = True) -> HardwareProfile:
    """Add a (typically calibrated) profile to the in-process registry so
    planners and CLIs can refer to it by name."""
    if not overwrite and profile.name in PROFILES:
        raise ValueError(f"profile {profile.name!r} already registered")
    PROFILES[profile.name] = profile
    return profile


def get_profile(name: str) -> HardwareProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown hardware profile {name!r}; registered: "
                       f"{sorted(PROFILES)}") from None


# ---------------------------------------------------------------------------
# DEP stage models (Eqs. 1-4 composed with Eqs. 7-9)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DepModelSpec:
    """The scheduler's view of one transformer layer of an MoE model
    (paper Table 1 notation)."""

    S: int              # sequence length per sample
    M: int              # embedding size
    H: int              # expert FFN hidden size
    E: int              # global routed experts
    top_k: int
    n_shared: int       # N_shared
    shared_H: int       # hidden size of each shared expert
    T: int              # number of (MoE) layers
    n_heads: int
    d_k: int
    d_v: int
    n_kv_heads: int = 0  # 0 -> MHA (= n_heads)
    # > 0: decode-phase attention — each of the S tokens per sample is a
    # single query streaming `decode_context` cached KV positions (the
    # occupancy histogram's mean context), so the attention workload is
    # LINEAR in context instead of the prefill S^2 term. 0 = prefill.
    decode_context: float = 0.0

    @staticmethod
    def from_model_config(cfg: ModelConfig, S: int) -> "DepModelSpec":
        assert cfg.moe is not None, "DEP schedules MoE models"
        m = cfg.moe
        return DepModelSpec(
            S=S, M=cfg.d_model, H=m.expert_ffn_dim, E=m.num_experts,
            top_k=m.top_k, n_shared=m.num_shared_experts,
            shared_H=m.shared_ffn_dim or m.expert_ffn_dim,
            T=len(cfg.moe_layer_indices()),
            n_heads=cfg.num_heads, d_k=cfg.head_dim, d_v=cfg.head_dim,
            n_kv_heads=cfg.num_kv_heads,
        )


@dataclass(frozen=True)
class StageModels:
    """Linear per-stage models t_a, t_s, t_e, t_c as functions of m_a / m_e.

    t_a(m_a): attention segment on one AG device, m_a samples (Eq. 1/10/11)
    t_s(m_a): shared-expert segment on one AG device (Eq. 2)
    t_e(m_e): routed-expert chunk on one EG device (Eq. 3; note: we keep the
              factor 3 from Eq. 3 that the prose's alpha_e/beta_e drops)
    t_c(m_e): one direction of a2e/e2a for one m_e chunk (Eq. 4/9)
    t_rep(m_a): replicated hot-expert segment on one AG device — only set
              when the models were built under a ``SkewSummary`` with
              replication (rho > 0); None = no REP stage modeled
    skew:     the quantized skew fingerprint these models were scaled by
              (None = uniform routing assumed)
    """

    t_a: AlphaBeta
    t_s: AlphaBeta
    t_e: AlphaBeta
    t_c: AlphaBeta
    spec: DepModelSpec
    cluster: DepClusterConfig
    t_rep: Optional[AlphaBeta] = None
    skew: Optional[object] = None      # repro.placement.SkewSummary

    # -- token-conservation constraint (paper SS4.2):
    #    m_a * ag * top_k * S = m_e * r2 * E
    def me_from_ma(self, m_a: float, r2: int) -> float:
        s = self.spec
        return m_a * self.cluster.ag * s.top_k * s.S / (r2 * s.E)

    def ma_from_me(self, m_e: float, r2: int) -> float:
        s = self.spec
        return m_e * r2 * s.E / (self.cluster.ag * s.top_k * s.S)


def build_stage_models(hw: HardwareProfile, spec: DepModelSpec,
                       cluster: DepClusterConfig,
                       skew=None) -> StageModels:
    """Compose the primitive alpha-beta models into per-stage linear models.

    ``skew`` (a ``repro.placement.SkewSummary``, optional) makes the
    stage models reflect OBSERVED routing skew instead of the uniform
    assumption the paper's Eqs. 3-4 make:

      * t_e scales by ``kappa`` — the EXP lane finishes when its
        most-loaded rank does, and under skewed routing the worst rank
        holds ``kappa`` x the mean cold load;
      * t_c scales by ``(1 - rho)`` — tokens routed to replicated hot
        experts are computed on their attention rank and never cross
        the A2E/E2A wire;
      * ``t_rep`` appears when ``rho > 0``: the hot-expert FFN segment
        each AG rank runs locally (3 GEMMs over the rho fraction of
        this rank's routed assignments).

    ``skew=None`` (or a uniform summary) reproduces the pre-skew models
    exactly."""
    s, c = spec, cluster
    if skew is not None and getattr(skew, "is_uniform", False):
        skew = None
    kv_heads = s.n_kv_heads or s.n_heads

    # --- attention (Eq. 1): 4 projections + self-attention -----------------
    # q/o projections: m_a*S x M x (n_heads*d)  |  k/v: m_a*S x M x (kv*d)
    # prefill: S queries x S keys per sample (the paper's S^2 unit).
    # decode (decode_context > 0): each token is ONE query over the cached
    # context — the term the ragged kernel makes proportional to actual
    # occupancy — so the workload is S * mean_context, linear in context.
    if s.decode_context > 0 and hw.decode is not None:
        # dedicated decode fit: bytes of KV streamed per sample (the
        # ragged kernel reads kv_heads, not n_heads, rows — GQA shares
        # them across the query heads)
        attn_model = hw.decode
        attn_units = (s.S * s.decode_context * kv_heads
                      * (s.d_k + s.d_v) * c.dtype_bytes)
    elif s.decode_context > 0:
        attn_model = hw.attn
        attn_units = s.S * s.decode_context * s.n_heads * (s.d_k + s.d_v)
    else:
        attn_model = hw.attn
        attn_units = (s.S ** 2) * s.n_heads * (s.d_k + s.d_v)
    beta_a = hw.gemm.beta * (
        s.S * s.M * s.n_heads * s.d_k          # Q proj
        + s.S * s.M * kv_heads * s.d_k         # K proj
        + s.S * s.M * kv_heads * s.d_v         # V proj
        + s.S * s.M * s.n_heads * s.d_v        # O proj
    ) + attn_model.beta * attn_units
    alpha_a = 4 * hw.gemm.alpha + attn_model.alpha
    t_a = AlphaBeta(alpha_a, beta_a)

    # --- shared expert (Eq. 2): 3 N_shared GEMMs of m_a*S x M x H ----------
    t_s = AlphaBeta(3 * s.n_shared * hw.gemm.alpha,
                    3 * s.n_shared * hw.gemm.beta * s.S * s.M * s.shared_H)

    # --- routed experts (Eq. 3): 3 (E/eg) GEMMs of m_e x M x H -------------
    # Under skew the lane is bound by its most-loaded rank: kappa x the
    # mean per-rank cold load (kappa = 1 when balanced).
    kappa = float(getattr(skew, "kappa", 1.0)) if skew is not None else 1.0
    rho = float(getattr(skew, "rho", 0.0)) if skew is not None else 0.0
    e_per_dev = s.E / c.eg
    t_e = AlphaBeta(3 * e_per_dev * hw.gemm.alpha,
                    3 * e_per_dev * hw.gemm.beta * s.M * s.H * kappa)

    # --- a2e / e2a (Eq. 4): z = (E/eg) * m_e * M elements per device -------
    # Hot-replica tokens (rho of the routed volume) stay on their AG rank.
    t_c = AlphaBeta(hw.comm.alpha,
                    hw.comm.beta * e_per_dev * s.M * c.dtype_bytes
                    * (1.0 - rho))

    # --- replicated hot experts: 3 GEMMs over rho of this AG rank's
    # routed assignments (m_a * S tokens x top_k), each M x H -------------
    t_rep = None
    if rho > 0.0:
        t_rep = AlphaBeta(3 * hw.gemm.alpha,
                          3 * hw.gemm.beta * s.S * s.top_k * rho
                          * s.M * s.H)

    return StageModels(t_a=t_a, t_s=t_s, t_e=t_e, t_c=t_c,
                       spec=spec, cluster=cluster, t_rep=t_rep, skew=skew)


def fit_profile(measured: dict, name: str = "calibrated"
                ) -> Tuple[HardwareProfile, Dict[str, float]]:
    """Least-squares fit a HardwareProfile from measured (x, t) samples.

    ``measured`` maps {"gemm": (xs, ts), "attn": (xs, ts), "comm": (zs, ts)}
    in the primitive units of this module's header. Returns the profile and
    the per-primitive R^2 of each fit (the paper's Fig. 7 quality gate).
    """
    models, r2s = {}, {}
    for kind in ("gemm", "attn", "comm"):
        models[kind], r2s[kind] = fit_alpha_beta(*measured[kind])
    decode = None
    if "decode" in measured:    # optional fourth primitive
        decode, r2s["decode"] = fit_alpha_beta(*measured["decode"])
    hw = HardwareProfile(name, gemm=models["gemm"], attn=models["attn"],
                         comm=models["comm"], decode=decode)
    return hw, r2s


def calibrated_stage_models(measured: dict, spec: DepModelSpec,
                            cluster: DepClusterConfig) -> StageModels:
    """Build StageModels from measured (x, t) samples.

    ``measured`` maps {"gemm": (xs, ts), "attn": (xs, ts), "comm": (zs, ts)}.
    """
    hw, _ = fit_profile(measured)
    return build_stage_models(hw, spec, cluster)
