"""Exact discrete-event simulation of the DEP 4-resource pipeline.

Resources (paper Section 3.2): AG compute, A2E link, EG compute, E2A link.
Tasks per layer t:  A(t,i) and S(t,i) for micro-batch i < r1 on AG;
a2e(t,i,j) / E(t,i,j) / e2a(t,i,j) for chunk j < r2 on link/EG/link.

Precedence constraints implement Eq. 5 rules 6-10:
  * S(t,i)        >= end A(t,i)
  * a2e(t,i,j)    >= end A(t,i)           (FinDEP: shared does NOT block a2e)
                  >= end S(t,i)           (PPPipe/naive: it does)
  * E(t,i,j)      >= end a2e(t,i,j)
  * e2a(t,i,j)    >= end E(t,i,j)
  * A(t+1,i)      >= max(end e2a(t,i,r2-1), end S(t,i))
Rules 1-5 (mutual exclusion per resource) hold because each resource
processes its tasks sequentially in a fixed order: AG in the policy order
(ASAS / AASS), links and EG FIFO by (t, i, j).

Because every resource order is fixed, completion times follow a forward
recurrence -- no event heap needed; the result is exact and O(#tasks).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.analytic import ORDER_AASS, ORDER_ASAS, StageTimes

Interval = Tuple[float, float]


@dataclass
class SimResult:
    makespan: float
    busy: Dict[str, float]                    # summed busy time per resource
    intervals: Optional[Dict[str, List[Interval]]] = None
    # completion views used by tests:
    last_e2a_end: float = 0.0
    last_shared_end: float = 0.0

    def utilization(self, resource: str) -> float:
        return self.busy[resource] / self.makespan if self.makespan else 0.0


def _ag_order(order: str, r1: int, has_shared: bool):
    """Within-layer AG task sequence: list of ("A"|"S", i)."""
    seq = []
    if not has_shared:
        return [("A", i) for i in range(r1)]
    if order == ORDER_ASAS:
        for i in range(r1):
            seq.append(("A", i))
            seq.append(("S", i))
    elif order == ORDER_AASS:
        seq.extend(("A", i) for i in range(r1))
        seq.extend(("S", i) for i in range(r1))
    else:
        raise ValueError(f"unknown order {order!r}")
    return seq


def simulate_dep(st: StageTimes, T: int, r1: int, r2: int,
                 order: str = ORDER_ASAS,
                 shared_blocks_a2e: bool = False,
                 record_intervals: bool = False) -> SimResult:
    """Simulate the full T-layer pipeline; returns exact makespan."""
    has_shared = st.t_s > 0.0
    ag_seq = _ag_order(order, r1, has_shared)

    ag_free = a2e_free = eg_free = e2a_free = 0.0
    # per micro-batch completion of previous layer's combine + shared
    prev_ready = [0.0] * r1
    intervals: Dict[str, List[Interval]] = {k: [] for k in
                                            ("AG", "A2E", "EG", "E2A")}
    busy = {k: 0.0 for k in intervals}

    def run(resource: str, free: float, ready: float, dur: float) -> float:
        start = max(free, ready)
        end = start + dur
        busy[resource] += dur
        if record_intervals:
            intervals[resource].append((start, end))
        return end

    a_end = [0.0] * r1
    s_end = [0.0] * r1
    last_shared_end = 0.0
    last_e2a_end = 0.0

    for _t in range(T):
        # ---- AG tasks in policy order ---------------------------------
        for kind, i in ag_seq:
            if kind == "A":
                end = run("AG", ag_free, prev_ready[i], st.t_a)
                a_end[i] = end
            else:
                end = run("AG", ag_free, a_end[i], st.t_s)
                s_end[i] = end
            ag_free = end
        if not has_shared:
            for i in range(r1):
                s_end[i] = a_end[i]

        # ---- dispatch / expert / combine chunks FIFO -------------------
        e2a_last = [0.0] * r1
        for i in range(r1):
            gate = s_end[i] if (shared_blocks_a2e and has_shared) else a_end[i]
            for _j in range(r2):
                a2e_free = run("A2E", a2e_free, gate, st.t_c)
                eg_free = run("EG", eg_free, a2e_free, st.t_e)
                e2a_free = run("E2A", e2a_free, eg_free, st.t_c)
            e2a_last[i] = e2a_free

        for i in range(r1):
            prev_ready[i] = max(e2a_last[i], s_end[i])
        last_shared_end = max(s_end)
        last_e2a_end = max(e2a_last)

    makespan = max(last_e2a_end, last_shared_end)
    return SimResult(makespan=makespan, busy=busy,
                     intervals=intervals if record_intervals else None,
                     last_e2a_end=last_e2a_end,
                     last_shared_end=last_shared_end)


# ---------------------------------------------------------------------------
# Baselines, exact versions
# ---------------------------------------------------------------------------


def simulate_naive(st: StageTimes, T: int,
                   record_intervals: bool = False) -> SimResult:
    """Naive DEP: one mini-batch, fully sequential (r1 = r2 = 1, shared
    blocks a2e)."""
    return simulate_dep(st, T, r1=1, r2=1, order=ORDER_ASAS,
                        shared_blocks_a2e=True,
                        record_intervals=record_intervals)


def simulate_pppipe(st: StageTimes, T: int, r1: int,
                    record_intervals: bool = False) -> SimResult:
    """PPPipe (MegaScale-Infer): r1 micro-batches, no token chunking,
    shared expert treated as part of attention (blocks a2e)."""
    return simulate_dep(st, T, r1=r1, r2=1, order=ORDER_ASAS,
                        shared_blocks_a2e=True,
                        record_intervals=record_intervals)


# ---------------------------------------------------------------------------
# Interval analytics (Table 7: non-overlapped communication time)
# ---------------------------------------------------------------------------


def _union(iv: List[Interval]) -> List[Interval]:
    if not iv:
        return []
    iv = sorted(iv)
    out = [iv[0]]
    for s, e in iv[1:]:
        if s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def _subtract(a: List[Interval], b: List[Interval]) -> List[Interval]:
    """a \\ b for sorted disjoint interval lists."""
    out = []
    bi = 0
    for s, e in a:
        cur = s
        while bi < len(b) and b[bi][1] <= cur:
            bi += 1
        k = bi
        while k < len(b) and b[k][0] < e:
            bs, be = b[k]
            if bs > cur:
                out.append((cur, bs))
            cur = max(cur, be)
            if cur >= e:
                break
            k += 1
        if cur < e:
            out.append((cur, e))
    return out


def total_len(iv: List[Interval]) -> float:
    return sum(e - s for s, e in iv)


def non_overlapped_comm_time(res: SimResult) -> float:
    """Time when a link (A2E or E2A) is busy but neither AG nor EG computes.

    This is the exposed-communication metric of paper Table 7: communication
    that could not be hidden behind any computation.
    """
    assert res.intervals is not None, "simulate with record_intervals=True"
    comm = _union(res.intervals["A2E"] + res.intervals["E2A"])
    compute = _union(res.intervals["AG"] + res.intervals["EG"])
    return total_len(_subtract(comm, compute))
