"""Exact discrete-event simulation of the DEP 4-resource pipeline, as a
thin wrapper over the task-graph IR (``repro.core.taskgraph``).

Resources (paper Section 3.2): AG compute, A2E link, EG compute, E2A link.
Tasks per layer t:  A(t,i) and S(t,i) for micro-batch i < r1 on AG;
a2e(t,i,j) / E(t,i,j) / e2a(t,i,j) for chunk j < r2 on link/EG/link.

Precedence constraints implement Eq. 5 rules 6-10:
  * S(t,i)        >= end A(t,i)
  * a2e(t,i,j)    >= end A(t,i)           (FinDEP: shared does NOT block a2e)
                  >= end S(t,i)           (PPPipe/naive: it does)
  * E(t,i,j)      >= end a2e(t,i,j)
  * e2a(t,i,j)    >= end E(t,i,j)
  * A(t+1,i)      >= max(end e2a(t,i,r2-1), end S(t,i))
Rules 1-5 (mutual exclusion per resource) hold because each resource
processes its tasks sequentially in a fixed order: AG in the policy order
(ASAS / AASS), links and EG FIFO by (t, i, j).

These rules ARE the lowering rules of ``taskgraph.lower``; this module
only (a) maps the legacy ``(st, T, r1, r2, order)`` signature onto a
lowering + ``taskgraph.schedule`` call and (b) keeps the baseline entry
points -- naive DEP and PPPipe are alternate lowerings
(``shared_blocks_a2e=True``) of the same IR, not separate simulators.
The generic list scheduler is exact and O(#tasks) because every
resource's service order is fixed by the graph's emission order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.analytic import ORDER_ASAS, StageTimes
from repro.core.taskgraph import (ATTN, E2A, SHARED, ScheduleResult,
                                  TaskCosts, _lower_structure, schedule,
                                  schedule_makespan)

Interval = Tuple[float, float]


@dataclass
class SimResult:
    makespan: float
    busy: Dict[str, float]                    # summed busy time per resource
    intervals: Optional[Dict[str, List[Interval]]] = None
    # completion views used by tests:
    last_e2a_end: float = 0.0
    last_shared_end: float = 0.0
    #: the underlying per-task schedule (graph, starts, ends) -- the same
    #: structure the executor walks and telemetry tags against
    scheduled: Optional[ScheduleResult] = None

    def utilization(self, resource: str) -> float:
        return self.busy[resource] / self.makespan if self.makespan else 0.0


def simulate_graph(graph, costs: TaskCosts,
                   record_intervals: bool = False) -> SimResult:
    """Schedule ANY lowered ``TaskGraph`` and wrap it as a ``SimResult``.
    The one scheduling code path behind every ``simulate_*`` entry."""
    res = schedule(graph, costs)
    # lanes serve FIFO, so a kind's last-scheduled end IS its max end,
    # and the last ATTN/SHARED/E2A tasks sit in the last layer
    last_shared = res.last_end(SHARED if graph.has_shared else ATTN)
    return SimResult(makespan=res.makespan, busy=res.busy,
                     intervals=res.intervals if record_intervals else None,
                     last_e2a_end=res.last_end(E2A),
                     last_shared_end=last_shared,
                     scheduled=res)


def _hot_experts_for(st: StageTimes) -> int:
    """Structural REP flag from the stage times: a positive t_rep means
    the models were built under a replicating placement, so the lowering
    emits the REP task (mirrors how ``has_shared`` follows t_s)."""
    return 1 if getattr(st, "t_rep", 0.0) > 0.0 else 0


def simulate_dep(st: StageTimes, T: int, r1: int, r2: int,
                 order: str = ORDER_ASAS,
                 shared_blocks_a2e: bool = False,
                 record_intervals: bool = False) -> SimResult:
    """Simulate the full T-layer pipeline; returns exact makespan."""
    graph = _lower_structure(T=T, r1=r1, r2=r2, order=order,
                             has_shared=st.t_s > 0.0,
                             shared_blocks_a2e=shared_blocks_a2e,
                             hot_experts=_hot_experts_for(st))
    return simulate_graph(graph, TaskCosts.from_stage_times(st),
                          record_intervals=record_intervals)


def simulate_makespan(st: StageTimes, T: int, r1: int, r2: int,
                      order: str = ORDER_ASAS,
                      shared_blocks_a2e: bool = False) -> float:
    """Makespan of ``simulate_dep`` without the per-task schedule — the
    solver's simulate objective evaluates hundreds of candidate plans and
    only reads the makespan, so it takes the vectorized lane recurrence
    (``taskgraph.schedule_makespan``) instead of the generic list
    scheduler. Identical to ``simulate_dep(...).makespan`` up to float
    rounding (parity-locked by test)."""
    graph = _lower_structure(T=T, r1=r1, r2=r2, order=order,
                             has_shared=st.t_s > 0.0,
                             shared_blocks_a2e=shared_blocks_a2e,
                             hot_experts=_hot_experts_for(st))
    return schedule_makespan(graph, TaskCosts.from_stage_times(st))


# ---------------------------------------------------------------------------
# Baselines: alternate lowerings of the same IR
# ---------------------------------------------------------------------------


def simulate_naive(st: StageTimes, T: int,
                   record_intervals: bool = False) -> SimResult:
    """Naive DEP: one mini-batch, fully sequential (r1 = r2 = 1, shared
    blocks a2e)."""
    return simulate_dep(st, T, r1=1, r2=1, order=ORDER_ASAS,
                        shared_blocks_a2e=True,
                        record_intervals=record_intervals)


def simulate_pppipe(st: StageTimes, T: int, r1: int,
                    record_intervals: bool = False) -> SimResult:
    """PPPipe (MegaScale-Infer): r1 micro-batches, no token chunking,
    shared expert treated as part of attention (blocks a2e)."""
    return simulate_dep(st, T, r1=r1, r2=1, order=ORDER_ASAS,
                        shared_blocks_a2e=True,
                        record_intervals=record_intervals)


# ---------------------------------------------------------------------------
# Interval analytics (Table 7: non-overlapped communication time)
# ---------------------------------------------------------------------------


def _union(iv: List[Interval]) -> List[Interval]:
    if not iv:
        return []
    iv = sorted(iv)
    out = [iv[0]]
    for s, e in iv[1:]:
        if s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def _subtract(a: List[Interval], b: List[Interval]) -> List[Interval]:
    """a \\ b for sorted disjoint interval lists."""
    out = []
    bi = 0
    for s, e in a:
        cur = s
        while bi < len(b) and b[bi][1] <= cur:
            bi += 1
        k = bi
        while k < len(b) and b[k][0] < e:
            bs, be = b[k]
            if bs > cur:
                out.append((cur, bs))
            cur = max(cur, be)
            if cur >= e:
                break
            k += 1
        if cur < e:
            out.append((cur, e))
    return out


def total_len(iv: List[Interval]) -> float:
    return sum(e - s for s, e in iv)


def non_overlapped_comm_time(res) -> float:
    """Time when a link (A2E or E2A) is busy but neither AG nor EG
    computes, for a ``SimResult`` (simulate with record_intervals=True)
    or directly for a ``taskgraph.ScheduleResult`` -- the Table 7
    exposed-communication metric computed from the lowered graph's
    scheduled intervals.
    """
    intervals = res.intervals
    assert intervals is not None, "simulate with record_intervals=True"
    comm = _union(intervals["A2E"] + intervals["E2A"])
    compute = _union(intervals["AG"] + intervals["EG"])
    return total_len(_subtract(comm, compute))
