"""Closed-form makespan / throughput model of the FinDEP pipeline.

Implements the paper's timestamp recurrences for the ASAS order
(Section 4.2, Fig. 5) and the objective of Eq. 13 / Eq. 17:

    X(m_a) = t_a(m_a) + t_s(m_a)
    Y(m_e) = max(t_e(m_e), t_a2e(m_e))
    F      = max(X, r2 * Y)
    G      = t_a + t_a2e + t_e + t_e2a + (r2 - 1) * Y            (Eq. 12)

    D = (T-1)*max(G, r1*F) + max(X, G) + (r2-1)*Y + (r1-1)*F     (Eq. 13 denom)

and an analogous closed form for the AASS order derived with the same
deterministic tandem-queue decomposition. ``repro.core.simulator`` is the
exact event-order ground truth; tests quantify how tight these closed forms
are (the paper itself treats Eq. 13 as the objective of its solver).

All times in seconds.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.perf_model import StageModels

ORDER_ASAS = "ASAS"
ORDER_AASS = "AASS"
ORDERS = (ORDER_ASAS, ORDER_AASS)


@dataclass(frozen=True)
class StageTimes:
    """Concrete per-segment durations for a chosen (m_a, m_e)."""

    t_a: float   # one attention segment (m_a samples) on AG
    t_s: float   # one shared-expert segment (m_a samples) on AG
    t_e: float   # one routed-expert chunk (m_e tokens/expert) on EG
    t_c: float   # one direction of a2e/e2a for one chunk
    t_rep: float = 0.0   # replicated hot-expert segment (m_a samples) on AG

    @staticmethod
    def from_models(models: StageModels, m_a: float, m_e: float) -> "StageTimes":
        t_rep_model = getattr(models, "t_rep", None)
        return StageTimes(
            t_a=models.t_a(m_a),
            t_s=models.t_s(m_a) if models.spec.n_shared > 0 else 0.0,
            t_e=models.t_e(m_e),
            t_c=models.t_c(m_e),
            t_rep=t_rep_model(m_a) if t_rep_model is not None else 0.0,
        )


@dataclass(frozen=True)
class XYFG:
    X: float
    Y: float
    F: float
    G: float


def xyfg(st: StageTimes, r1: int, r2: int) -> XYFG:
    # t_rep (replicated hot-expert segment) runs on AG between the gate
    # and the shared expert, so it joins X: the per-micro-batch AG work.
    X = st.t_a + st.t_rep + st.t_s
    Y = max(st.t_e, st.t_c)
    F = max(X, r2 * Y)
    G = st.t_a + 2.0 * st.t_c + st.t_e + (r2 - 1) * Y
    return XYFG(X=X, Y=Y, F=F, G=G)


def makespan_asas(st: StageTimes, T: int, r1: int, r2: int) -> float:
    """Eq. 13 denominator (the paper's closed-form ASAS makespan)."""
    v = xyfg(st, r1, r2)
    return ((T - 1) * max(v.G, r1 * v.F)
            + max(v.X, v.G)
            + (r2 - 1) * v.Y
            + (r1 - 1) * v.F)


def makespan_aass(st: StageTimes, T: int, r1: int, r2: int) -> float:
    """Closed-form AASS makespan via the same decomposition.

    NOTE: unlike the ASAS form (Eq. 13, a guaranteed upper bound), this is
    a two-sided approximation (within [0.85, 1.0] x exact over randomized
    workloads) — cross-micro-batch queueing on the links has no clean
    closed form under AASS. The solver's default "hybrid" objective
    re-ranks the analytic top-K with the exact event simulator, so this
    only needs to rank candidates sensibly.

    Within a layer AG runs A_0..A_{r1-1} then S_0..S_{r1-1}; chunk (i, j)
    enters the a2e->expert->e2a deterministic tandem at (i+1)*t_a after the
    layer's AG start. Departure of the last chunk from the tandem is
        2*t_c + t_e + max(r1*t_a + (r2-1)*Y, t_a + (r1*r2 - 1)*Y).
    The per-layer steady-state offset is max(AG work, tandem rate, chain):
        P = max(r1*(t_a + t_s), r1*r2*Y, G)
    """
    v = xyfg(st, r1, r2)
    P = max(r1 * v.X, r1 * r2 * v.Y, v.G)
    tandem_last = (2.0 * st.t_c + st.t_e
                   + max(r1 * st.t_a + (r2 - 1) * v.Y,
                         st.t_a + (r1 * r2 - 1) * v.Y))
    shared_last = r1 * st.t_a + r1 * (st.t_rep + st.t_s)
    return (T - 1) * P + max(tandem_last, shared_last)


def makespan_closed_form(st: StageTimes, T: int, r1: int, r2: int,
                         order: str) -> float:
    if order == ORDER_ASAS:
        return makespan_asas(st, T, r1, r2)
    if order == ORDER_AASS:
        return makespan_aass(st, T, r1, r2)
    raise ValueError(f"unknown order {order!r}")


def throughput(models: StageModels, T: int, m_a: float, r1: int, r2: int,
               order: str = ORDER_ASAS, makespan: float | None = None) -> float:
    """Tokens/second (Eq. 6 numerator r1*m_a*ag, scaled by S to tokens)."""
    m_e = models.me_from_ma(m_a, r2)
    if makespan is None:
        st = StageTimes.from_models(models, m_a, m_e)
        makespan = makespan_closed_form(st, T, r1, r2, order)
    tokens = r1 * m_a * models.cluster.ag * models.spec.S
    return tokens / makespan


# ---------------------------------------------------------------------------
# Baseline closed forms (naive DEP / PPPipe) -- see also core.simulator for
# the exact event-order versions.
# ---------------------------------------------------------------------------


def makespan_naive(st: StageTimes, T: int) -> float:
    """Strictly sequential DEP: per layer A -> S -> a2e -> E -> e2a."""
    return T * (st.t_a + st.t_rep + st.t_s + st.t_c + st.t_e + st.t_c)


def makespan_pppipe(st: StageTimes, T: int, r1: int) -> float:
    """PPPipe (MegaScale-Infer): r1 micro-batches, shared expert folded into
    the attention stage (a2e waits for shared), no r2 chunking.

    Stage chain per micro-batch: [A+S] -> a2e -> E -> e2a with deterministic
    tandem recursion; per-layer offset max(chain, r1 * bottleneck stage).
    """
    stage_ag = st.t_a + st.t_rep + st.t_s
    chain = stage_ag + st.t_c + st.t_e + st.t_c
    bottleneck = max(stage_ag, st.t_c, st.t_e)
    P = max(chain, r1 * bottleneck)
    fill = chain + (r1 - 1) * bottleneck
    return (T - 1) * P + fill
