"""Task-graph execution IR: ONE lowering from a solved ``Plan`` to typed
tasks, shared by the simulator, the DEP executor, and telemetry.

FinDEP's core contribution is partitioning the DEP step into fine-grained
tasks and scheduling them (paper Section 3). Before this module, the repo
interpreted a ``Plan`` (r1, r2, m_a, m_e, shared-expert order) three
independent times: the event simulator rebuilt the task timeline
analytically, ``core.dep`` re-derived the execution order imperatively,
and telemetry could only attribute residuals at whole-step granularity.
Now all three consume the same structure:

    lower(plan, spec)  ->  TaskGraph          (the single lowering)
      |-- schedule(graph, TaskCosts)          exact event-order makespan
      |         (repro.core.simulator wraps this as simulate_dep/naive/
      |          pppipe -- baselines are alternate LOWERINGS, not
      |          separate simulators)
      |-- graph.exec_walk()                   program-order task stream
      |         the DEP executor (repro.core.dep) maps each task kind to
      |         jax ops: A2E/E2A -> chunked all_to_all, EXP -> expert
      |         FFN, SHARED -> shared-expert GEMM segment, GATE -> router
      |         dispatch
      `-- ScheduleResult.kind_busy()          per-primitive cost tags
                telemetry (repro.profiling) attributes measured residuals
                to GEMM vs attention vs comm instead of uniformly
                rescaling the whole profile

Task kinds and resources
------------------------

    kind      resource  class      meaning (paper Section 3.2)
    ATTN      AG        attn       attention segment, m_a samples
    SHARED    AG        gemm       shared-expert GEMM segment
    GATE      AG        gemm       router dispatch (zero-cost in the
                                   analytic model; folded into t_a)
    A2E       A2E       comm       dispatch all_to_all for one chunk
    EXP       EG        gemm       routed-expert FFN for one chunk
    E2A       E2A       comm       combine all_to_all for one chunk
    REP       AG        gemm       replicated hot-expert FFN on the
                                   locally resident tokens (placement
                                   subsystem; absent when hot_experts=0)

A ``Task`` is pure STRUCTURE (no durations): two plans that compile to
the same program lower to equal graphs, so a ``TaskGraph`` is a valid
jit static argument. Durations come from ``TaskCosts`` at schedule time.

Lowering rules (ASAS order, FinDEP semantics):

    A(t,i)        on AG, after max(e2a(t-1,i,last), shared(t-1,i,last))
    GATE(t,i)     on AG, after A(t,i)                    (zero cost)
    S(t,i,k)      on AG, after A(t,i); ASAS splits the shared expert
                  into r2 segments (one per chunk boundary -- what the
                  executor emits); AASS keeps one whole-batch task at
                  boundary 0
    a2e(t,i,j)    on A2E link, after A(t,i) + GATE(t,i); under
                  ``shared_blocks_a2e`` (naive / PPPipe lowerings) also
                  after the last shared segment
    E(t,i,j)      on EG, after a2e(t,i,j)
    e2a(t,i,j)    on E2A link, after E(t,i,j)

Mutual exclusion per resource (Eq. 5 rules 1-5) holds because every
resource serves its tasks in the graph's emission order (AG in the
policy order, links and EG FIFO by (t, i, j)); with that order fixed,
completion times follow a forward recurrence and ``schedule`` is exact
and O(#tasks) -- no event heap.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property, lru_cache
from typing import Dict, List, Optional, Tuple

from repro.core.analytic import ORDER_AASS, ORDER_ASAS, StageTimes

# -- task kinds -------------------------------------------------------------
ATTN = "ATTN"
SHARED = "SHARED"
GATE = "GATE"
A2E = "A2E"
EXP = "EXP"
E2A = "E2A"
REP = "REP"
# REP is appended so the positional kind indices of the original six
# kinds (and every per_kind tuple built against them) stay stable.
KINDS = (ATTN, SHARED, GATE, A2E, EXP, E2A, REP)

# -- resources (scheduling lanes) and their classes -------------------------
RESOURCES = ("AG", "A2E", "EG", "E2A")
#: coarse resource classes used for telemetry attribution
RESOURCE_CLASS = {"AG": "compute_a", "EG": "compute_e",
                  "A2E": "comm", "E2A": "comm"}
#: hardware-primitive class per task kind (which alpha-beta model a task's
#: duration comes from -- the tag drift attribution retunes against)
KIND_CLASS = {ATTN: "attn", SHARED: "gemm", GATE: "gemm", EXP: "gemm",
              A2E: "comm", E2A: "comm", REP: "gemm"}
KIND_RESOURCE = {ATTN: "AG", SHARED: "AG", GATE: "AG",
                 A2E: "A2E", EXP: "EG", E2A: "E2A", REP: "AG"}

Interval = Tuple[float, float]


@dataclass(frozen=True)
class Task:
    """One typed node of the execution IR.

    ``chunk`` is the r2 chunk index for A2E/EXP/E2A; for SHARED it is the
    chunk *boundary* at which the executor emits the segment (ASAS: one
    segment per boundary; AASS: the whole shared expert at boundary 0).
    ``deps`` are indices into ``TaskGraph.tasks`` and always point to
    earlier positions (the tuple is topologically ordered by
    construction)."""

    kind: str
    layer: int                     # t  < T
    mb: int                        # micro-batch i < r1
    chunk: int = 0                 # j  < r2 (see above for SHARED)
    deps: Tuple[int, ...] = ()

    @property
    def resource(self) -> str:
        return KIND_RESOURCE[self.kind]

    def describe(self) -> str:
        """Human-readable identity for error messages and reports."""
        return (f"{self.kind}(layer={self.layer}, mb={self.mb}, "
                f"chunk={self.chunk})")


@dataclass(frozen=True)
class LoweringSpec:
    """Everything a lowering needs beyond the plan itself.

    ``T`` is the number of MoE layers the graph spans; ``has_shared``
    drops SHARED tasks for models without a shared expert;
    ``shared_blocks_a2e`` is the naive/PPPipe semantics where dispatch
    waits for the shared expert (FinDEP's independence is the default).
    ``r1``/``r2`` override the plan's values -- ``EXEC_SPEC`` uses
    ``T=1, r1=1`` because the executor's unit of work is one micro-batch
    of one layer (the caller's batching realizes r1; the transformer
    loop realizes T)."""

    T: int
    has_shared: bool = True
    shared_blocks_a2e: bool = False
    r1: Optional[int] = None
    r2: Optional[int] = None


#: the executor's view: one micro-batch of one layer
EXEC_SPEC = LoweringSpec(T=1, r1=1)


@dataclass(frozen=True)
class TaskGraph:
    """Immutable, hashable task graph. The graph's STRUCTURE is a pure
    function of its lowering parameters, so those scalars ARE the
    identity (O(1) hash/eq — cheap jit static argument); the emitted
    task list and the scheduler's compact program are derived lazily and
    cached per (lru-cached) instance.

    ``tasks`` is emission-ordered: per layer, the AG sequence in the
    policy order, then the chunk stream FIFO by (i, j) — the order every
    resource serves its tasks in.

    ``m_e`` is the solver's per-expert chunk granularity (tokens per
    expert per chunk, floored); the executor aligns its capacity to
    ``r2 * m_e`` so the chunks it runs are the ones the solver modeled.

    ``hot_experts`` is the number of replicated (hot) experts under the
    active ``placement.Placement``: when > 0 the lowering emits one REP
    task per (layer, mb) on the AG lane — the locally-resident hot FFN
    work that skips the A2E/E2A wire. ``placement_epoch`` carries the
    placement generation into the graph identity (hash/eq) so jit static
    args and ``PlanCache`` entries keyed on the graph can never serve a
    stale replica layout; the epoch does NOT change the emitted
    structure. Both default to 0, which lowers bit-identically to the
    pre-placement graphs.
    """

    T: int
    r1: int
    r2: int
    order: str
    m_e: int = 1
    has_shared: bool = True
    shared_blocks_a2e: bool = False
    hot_experts: int = 0
    placement_epoch: int = 0

    @property
    def shared_segments(self) -> int:
        """Segments the shared expert is split into per (layer, mb)."""
        return self.r2 if self.order == ORDER_ASAS else 1

    @cached_property
    def _emitted(self) -> Tuple[Tuple[int, int, int, int, Tuple[int, ...]],
                                ...]:
        """Compact emission records (kind_idx, layer, mb, chunk, deps) —
        the single source both ``tasks`` and ``_program`` derive from."""
        return tuple(_emit_structure(self.T, self.r1, self.r2, self.order,
                                     self.has_shared,
                                     self.shared_blocks_a2e,
                                     self.hot_experts))

    @cached_property
    def tasks(self) -> Tuple[Task, ...]:
        return tuple(Task(KINDS[k], t, i, c, deps)
                     for k, t, i, c, deps in self._emitted)

    @cached_property
    def _program(self) -> Tuple[Tuple[int, int, Tuple[int, ...]], ...]:
        """(resource_idx, kind_idx, deps) triples for the scheduler's
        inner loop."""
        return tuple((_KIND_RESOURCE_IDX[k], k, deps)
                     for k, _, _, _, deps in self._emitted)

    def tasks_of(self, kind: str, layer: Optional[int] = None,
                 mb: Optional[int] = None) -> List[Tuple[int, Task]]:
        return [(i, t) for i, t in enumerate(self.tasks)
                if t.kind == kind
                and (layer is None or t.layer == layer)
                and (mb is None or t.mb == mb)]

    def exec_walk(self, mb: int = 0) -> Tuple[Task, ...]:
        """The (layer 0, micro-batch ``mb``) slice in executed PROGRAM
        order: GATE, the REP task when the placement replicates hot
        experts, then per chunk j: A2E(j), SHARED segments at boundary j,
        EXP(j), E2A(j) (under ``shared_blocks_a2e`` the boundary-j shared
        segments precede A2E(j) — dispatch waits for them). This is the
        op-emission order ``repro.core.dep`` walks, and it matches the
        hand-rolled loops it replaced op for op."""
        slice_ = [t for t in self.tasks if t.layer == 0 and t.mb == mb]
        by_kind: Dict[str, Dict[int, Task]] = {}
        for t in slice_:
            by_kind.setdefault(t.kind, {})[t.chunk] = t
        walk: List[Task] = []
        if GATE in by_kind:
            walk.append(by_kind[GATE][0])
        if REP in by_kind:
            walk.append(by_kind[REP][0])
        for j in range(self.r2):
            shared_j = ([by_kind[SHARED][j]]
                        if j in by_kind.get(SHARED, {}) else [])
            if self.shared_blocks_a2e:
                walk.extend(shared_j)
            walk.append(by_kind[A2E][j])
            if not self.shared_blocks_a2e:
                walk.extend(shared_j)
            walk.append(by_kind[EXP][j])
            walk.append(by_kind[E2A][j])
        return tuple(walk)

    def exec_streams(self) -> Tuple[Tuple[Task, ...], ...]:
        """The layer-0 walk grouped by ``Task.mb``: one program-order
        stream per micro-batch (r1 entries, each an ``exec_walk(mb)``).
        Streams carry no cross-stream DATA deps — each stream's tasks
        only depend on its own (the router dispatch runs once over the
        whole chunk; streams are a capacity split, see ``ExecProgram``) —
        so any dep-respecting interleave of the streams computes the same
        values. The *resource* constraints across streams (AG/link/EG
        lanes are shared) are explicit in the emitted graph:
        ``stream_serial_deps`` derives the cross-stream serialization
        edges that model the sequential executor, while the scheduled
        interleave honors only the true per-stream edges."""
        return tuple(self.exec_walk(mb=i) for i in range(self.r1))

    def exec_interleaved(self,
                         hints: Optional[Tuple[int, ...]] = None
                         ) -> Tuple[Task, ...]:
        """All streams' walk tasks in SCHEDULED start order — the
        collective-matmul-style emission where micro-batch i+1's GATE
        group is issued before micro-batch i's E2A retires.

        ``hints`` are per-task priority ranks indexed by emission order
        (``ScheduleResult.priority_hints()``); when ``None`` the graph is
        scheduled under ``_HINT_COSTS`` (fixed shape-typical cost ratios
        — only the relative order matters). Because a schedule never
        starts a task before its deps end, sorting by (hint, emission
        index) is always a valid topological interleave; ATTN tasks are
        excluded (attention runs outside the MoE layer, as in
        ``exec_walk``)."""
        if hints is None:
            hints = schedule(self, _HINT_COSTS).priority_hints()
        n = len(self.tasks)
        if len(hints) != n:
            raise ValueError(
                f"hints length {len(hints)} != task count {n}")
        order = sorted(range(n), key=lambda i: (hints[i], i))
        pos = {idx: p for p, idx in enumerate(order)}
        for idx in order:
            for d in self.tasks[idx].deps:
                if pos[d] > pos[idx]:
                    task, dep = self.tasks[idx], self.tasks[d]
                    raise ValueError(
                        f"hints are not dep-consistent: "
                        f"{task.describe()} [emission {idx}, hint "
                        f"{hints[idx]}, interleaved position {pos[idx]}] "
                        f"would run before its dependency "
                        f"{dep.describe()} [emission {d}, hint "
                        f"{hints[d]}, interleaved position {pos[d]}]")
        return tuple(self.tasks[i] for i in order
                     if self.tasks[i].layer == 0
                     and self.tasks[i].kind != ATTN)

    def validate(self) -> None:
        """Deps must point backwards (topological emission order)."""
        for i, t in enumerate(self.tasks):
            for d in t.deps:
                if not 0 <= d < i:
                    raise ValueError(
                        f"task {i} ({t.kind}) dep {d} is not earlier")


_KIND_IDX = {k: i for i, k in enumerate(KINDS)}
_KIND_RESOURCE_IDX = tuple(RESOURCES.index(KIND_RESOURCE[k]) for k in KINDS)
_ATTN_I, _SHARED_I, _GATE_I = (_KIND_IDX[ATTN], _KIND_IDX[SHARED],
                               _KIND_IDX[GATE])
_A2E_I, _EXP_I, _E2A_I = _KIND_IDX[A2E], _KIND_IDX[EXP], _KIND_IDX[E2A]
_REP_I = _KIND_IDX[REP]


# ---------------------------------------------------------------------------
# The single lowering
# ---------------------------------------------------------------------------


def lower(plan, spec: LoweringSpec, hot_experts: int = 0,
          placement_epoch: int = 0) -> TaskGraph:
    """Lower a solved ``Plan`` (anything with r1/r2/order and optionally
    m_e) to a ``TaskGraph`` under ``spec``. THE single Plan->structure
    translation: the simulator schedules this graph, the executor walks
    it, telemetry tags against it. ``hot_experts``/``placement_epoch``
    carry the active expert placement (replica-aware lowering)."""
    r1 = spec.r1 if spec.r1 is not None else max(int(plan.r1), 1)
    r2 = spec.r2 if spec.r2 is not None else max(int(plan.r2), 1)
    m_e = getattr(plan, "m_e", 1) or 1
    return _lower_structure(T=spec.T, r1=r1, r2=r2, order=plan.order,
                            has_shared=spec.has_shared,
                            shared_blocks_a2e=spec.shared_blocks_a2e,
                            m_e=max(int(m_e), 1),
                            hot_experts=max(int(hot_experts), 0),
                            placement_epoch=int(placement_epoch))


def lower_exec(r2: int, order: str, m_e: int = 1, hot_experts: int = 0,
               placement_epoch: int = 0, r1: int = 1) -> TaskGraph:
    """The executor's graph for a schedule (r2, order, m_e): one layer,
    shared tasks present — the walker skips them when the model has no
    shared expert. ``r1`` > 1 lowers the layer as r1 micro-batch streams
    for the interleaved executor (``ExecProgram``); the default single
    stream is ``EXEC_SPEC``'s historical unit of work."""
    return _lower_structure(T=1, r1=max(int(r1), 1), r2=max(int(r2), 1),
                            order=order,
                            has_shared=True, shared_blocks_a2e=False,
                            m_e=max(int(m_e), 1),
                            hot_experts=max(int(hot_experts), 0),
                            placement_epoch=int(placement_epoch))


@lru_cache(maxsize=4096)
def _lower_structure(T: int, r1: int, r2: int, order: str, has_shared: bool,
                     shared_blocks_a2e: bool, m_e: int = 1,
                     hot_experts: int = 0,
                     placement_epoch: int = 0) -> TaskGraph:
    if order not in (ORDER_ASAS, ORDER_AASS):
        raise ValueError(f"unknown order {order!r}")
    assert T >= 1 and r1 >= 1 and r2 >= 1
    return TaskGraph(T=T, r1=r1, r2=r2, order=order, m_e=m_e,
                     has_shared=has_shared,
                     shared_blocks_a2e=shared_blocks_a2e,
                     hot_experts=hot_experts,
                     placement_epoch=placement_epoch)


def _emit_structure(T: int, r1: int, r2: int, order: str, has_shared: bool,
                    shared_blocks_a2e: bool, hot_experts: int = 0):
    """Yield (kind_idx, layer, mb, chunk, deps) in emission order — the
    lowering rules of the module docstring, in compact form.

    With ``hot_experts > 0`` one REP task per (layer, mb) follows GATE on
    the AG lane: the replicated hot-expert FFN runs on locally resident
    tokens, so A2E does NOT wait for it (same independence as the shared
    expert) but the next layer's attention does (it needs the combined
    output)."""
    n_seg = r2 if order == ORDER_ASAS else 1
    rep = hot_experts > 0
    idx = 0
    prev_e2a = [-1] * r1      # last e2a of (t-1, i)
    prev_sha = [-1] * r1      # last AG task (shared/REP/A) of (t-1, i)
    for t in range(T):
        a_id = [-1] * r1
        gate_id = [-1] * r1
        rep_id = [-1] * r1
        sha_last = [-1] * r1
        records = []

        def emit(kind_i, i, chunk, deps):
            nonlocal idx
            records.append((kind_i, t, i, chunk, deps))
            idx += 1
            return idx - 1

        def emit_ag(i):
            deps = tuple(d for d in (prev_e2a[i], prev_sha[i]) if d >= 0)
            a_id[i] = emit(_ATTN_I, i, 0, deps)
            gate_id[i] = emit(_GATE_I, i, 0, (a_id[i],))
            if rep:
                rep_id[i] = emit(_REP_I, i, 0, (gate_id[i],))

        def emit_shared(i):
            for k in range(n_seg):
                sha_last[i] = emit(_SHARED_I, i, k, (a_id[i],))

        if order == ORDER_ASAS:
            for i in range(r1):
                emit_ag(i)
                if has_shared:
                    emit_shared(i)
        else:                                  # AASS: all A's, then all S's
            for i in range(r1):
                emit_ag(i)
            if has_shared:
                for i in range(r1):
                    emit_shared(i)

        # chunk stream, FIFO by (i, j)
        for i in range(r1):
            gate_deps = [a_id[i], gate_id[i]]
            if shared_blocks_a2e and has_shared:
                gate_deps.append(sha_last[i])
            gd = tuple(gate_deps)
            for j in range(r2):
                a2e = emit(_A2E_I, i, j, gd)
                exp = emit(_EXP_I, i, j, (a2e,))
                prev_e2a[i] = emit(_E2A_I, i, j, (exp,))
            if has_shared:
                prev_sha[i] = sha_last[i]
            else:
                prev_sha[i] = rep_id[i] if rep_id[i] >= 0 else a_id[i]
        yield from records


# ---------------------------------------------------------------------------
# Costs + the generic resource-constrained list scheduler
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TaskCosts:
    """Per-kind durations (seconds). SHARED tasks are segments: each
    costs ``shared / graph.shared_segments`` so the whole shared expert
    still sums to ``t_s``."""

    attn: float
    shared: float
    exp: float
    comm: float
    gate: float = 0.0
    rep: float = 0.0

    @staticmethod
    def from_stage_times(st: StageTimes) -> "TaskCosts":
        return TaskCosts(attn=st.t_a, shared=st.t_s, exp=st.t_e,
                         comm=st.t_c, rep=getattr(st, "t_rep", 0.0))

    def per_kind(self, graph: TaskGraph) -> Tuple[float, ...]:
        """Durations indexed by KINDS order for ``graph``."""
        seg = self.shared / graph.shared_segments
        return (self.attn, seg, self.gate, self.comm, self.exp, self.comm,
                self.rep)


@dataclass(frozen=True)
class CostBreakdown:
    """Predicted seconds per hardware-primitive class for one plan
    execution -- the tags telemetry attributes measured residuals to."""

    gemm: float
    attn: float
    comm: float

    @property
    def total(self) -> float:
        return self.gemm + self.attn + self.comm

    def scaled(self, f: float) -> "CostBreakdown":
        return CostBreakdown(self.gemm * f, self.attn * f, self.comm * f)

    def normalized_to(self, total: float) -> "CostBreakdown":
        """Rescale so the classes sum to ``total`` (a plan's modeled
        makespan includes idle gaps the per-task busy sums don't)."""
        return self.scaled(total / self.total) if self.total > 0 else self

    def as_dict(self) -> Dict[str, float]:
        return {"gemm": self.gemm, "attn": self.attn, "comm": self.comm}


@dataclass
class ScheduleResult:
    """Exact per-task schedule of a graph under given costs.

    Per-kind busy sums and last completion times are accumulated inside
    the scheduling pass (every lane serves FIFO, so the last-emitted
    task of a kind carries that kind's max end) -- readers are O(1), no
    re-scan of the task list."""

    graph: TaskGraph
    starts: List[float]
    ends: List[float]
    busy: Dict[str, float]                 # per resource lane
    makespan: float
    busy_by_kind: Tuple[float, ...] = ()   # indexed by KINDS order
    last_by_kind: Tuple[float, ...] = ()   # indexed by KINDS order

    @property
    def intervals(self) -> Dict[str, List[Interval]]:
        """Per-resource (start, end) lists in service order -- the view
        ``non_overlapped_comm_time`` and the Gantt renderer consume."""
        out: Dict[str, List[Interval]] = {r: [] for r in RESOURCES}
        for t, s, e in zip(self.graph.tasks, self.starts, self.ends):
            out[t.resource].append((s, e))
        return out

    def spans(self) -> Tuple[Tuple[Task, float, float], ...]:
        """(task, start, end) triples in emission order -- the view the
        Chrome-trace exporter and the replay harness consume."""
        return tuple(zip(self.graph.tasks, self.starts, self.ends))

    def lane_idle(self) -> Dict[str, float]:
        """Idle seconds per resource lane within the makespan (lanes a
        graph never uses, e.g. links at r2=1 with zero comm cost, still
        report the full makespan as idle)."""
        return {r: self.makespan - self.busy.get(r, 0.0)
                for r in RESOURCES}

    def kind_busy(self) -> Dict[str, float]:
        """Summed busy seconds per task kind."""
        return dict(zip(KINDS, self.busy_by_kind))

    def breakdown(self) -> CostBreakdown:
        """Busy seconds per hardware-primitive class (gemm/attn/comm)."""
        cls: Dict[str, float] = {"gemm": 0.0, "attn": 0.0, "comm": 0.0}
        for k, v in self.kind_busy().items():
            cls[KIND_CLASS[k]] += v
        return CostBreakdown(**cls)

    def last_end(self, kind: str) -> float:
        """End of the last-scheduled task of ``kind`` (== that kind's
        max end: lanes are FIFO so ends increase in emission order)."""
        return self.last_by_kind[_KIND_IDX[kind]]

    def priority_hints(self) -> Tuple[int, ...]:
        """Per-task priority ranks derived from the scheduled
        ``starts``/``ends``: hint[i] = position of task i when all tasks
        are sorted by (start, end, emission index). This is the export
        the interleaved executor consumes (``ExecProgram.hints`` →
        ``TaskGraph.exec_interleaved``): emitting ops in hint order makes
        the executed program order *be* the schedule's start order —
        collective-matmul-style scheduling hints — instead of relying on
        XLA's async scheduler to rediscover the overlap. A schedule never
        starts a task before its deps end, so hint order is always a
        valid topological emission order."""
        n = len(self.starts)
        order = sorted(range(n),
                       key=lambda i: (self.starts[i], self.ends[i], i))
        hints = [0] * n
        for rank, idx in enumerate(order):
            hints[idx] = rank
        return tuple(hints)


def schedule(graph: TaskGraph, costs: TaskCosts) -> ScheduleResult:
    """Resource-constrained list scheduling over ANY TaskGraph: each
    resource serves its tasks in emission order; a task starts at
    max(resource free, deps done). Because the emission order fixes
    every resource's service order, a single forward pass is exact --
    this is the generic replacement for the hand-written simulator
    recurrences (and reproduces them to float precision)."""
    durs = costs.per_kind(graph)
    program = graph._program
    n = len(program)
    starts = [0.0] * n
    ends = [0.0] * n
    free = [0.0] * len(RESOURCES)
    busy = [0.0] * len(RESOURCES)
    kbusy = [0.0] * len(KINDS)
    klast = [0.0] * len(KINDS)
    idx = 0
    for r, k, deps in program:
        ready = 0.0
        for d in deps:
            e = ends[d]
            if e > ready:
                ready = e
        f = free[r]
        start = f if f > ready else ready
        dur = durs[k]
        end = start + dur
        starts[idx] = start
        ends[idx] = end
        free[r] = end
        busy[r] += dur
        kbusy[k] += dur
        klast[k] = end
        idx += 1
    makespan = max(ends) if ends else 0.0
    return ScheduleResult(graph=graph, starts=starts, ends=ends,
                          busy=dict(zip(RESOURCES, busy)),
                          makespan=makespan, busy_by_kind=tuple(kbusy),
                          last_by_kind=tuple(klast))


#: fixed shape-typical cost ratios used to order the default interleave
#: (only the relative magnitudes matter: comm chunks are comparable to
#: expert chunks, attention dominates a single shared segment). Plans
#: carrying a measured ``CostBreakdown`` derive sharper hints via
#: ``Plan.exec_program``.
_HINT_COSTS = TaskCosts(attn=4.0, shared=1.0, exp=2.0, comm=3.0,
                        gate=0.0, rep=0.5)


@dataclass(frozen=True)
class ExecProgram:
    """The executor-visible program: an exec ``TaskGraph`` plus the
    realized emission policy. This is what flows into
    ``dep.moe_apply_dep`` as a jit static argument (hashable; the graph
    hashes on its lowering scalars, the hints are a plain tuple).

    ``interleave``:
      * ``"off"``     — the historical single-stream walk: each
        micro-batch stream runs start-to-finish in program order
        (``exec_walk`` per stream, streams concatenated).
      * ``"streams"`` — ``exec_interleaved``: all r1 streams' ops
        emitted in scheduled start order, so micro-batch i+1's GATE
        group is issued before micro-batch i's E2A retires.

    Streams are realized as a capacity split, NOT a routing split: the
    router dispatch runs ONCE over the whole chunk (so token→expert
    assignment, capacity overflow, and drops are identical whatever the
    stream count), and each (stream i, chunk j) task covers capacity
    columns [(i·r2+j)·c, (i·r2+j+1)·c) of the dispatch buffers. The
    emitted values are therefore bit-identical across ``interleave``
    modes and stream counts — only the op order (and hence the achieved
    comm/compute overlap) changes.

    ``hints`` orders the ``"streams"`` emission
    (``ScheduleResult.priority_hints()``); ``None`` falls back to the
    structural default (``_HINT_COSTS``)."""

    graph: TaskGraph
    interleave: str = "off"
    hints: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.interleave not in ("off", "streams"):
            raise ValueError(
                f"interleave must be 'off' or 'streams', "
                f"got {self.interleave!r}")

    @property
    def streams(self) -> int:
        """Number of micro-batch streams the walk covers."""
        return self.graph.r1

    @property
    def capacity_multiple(self) -> int:
        """Alignment the executor's expert capacity must satisfy so
        every (stream, chunk) slice has equal width: streams·r2·m_e
        regardless of ``interleave`` — both modes slice the same
        (stream, chunk) grid, which is what makes them bit-identical."""
        return self.graph.r1 * self.graph.r2 * self.graph.m_e

    def walk(self) -> Tuple[Task, ...]:
        """The op-emission order the DEP executor realizes."""
        if self.interleave == "streams":
            return self.graph.exec_interleaved(self.hints)
        return tuple(t for s in self.graph.exec_streams() for t in s)


def stream_serial_deps(graph: TaskGraph) -> Dict[int, Tuple[int, ...]]:
    """The explicit cross-stream dependency edges that model the
    SEQUENTIAL executor: micro-batch stream i+1 starts only after stream
    i fully retires (the engine's chunked-prefill loop blocks on each
    chunk's output before issuing the next). Returns extra dep edges
    {first task of stream i: (last task of stream i-1 per lane, ...)}
    for i ≥ 1 — the edges ``obs.replay`` adds when replaying the
    sequential realization, and the complement of what the interleaved
    program removes."""
    extra: Dict[int, Tuple[int, ...]] = {}
    first_of: Dict[int, int] = {}
    last_per_lane: Dict[int, Dict[str, int]] = {}
    for idx, t in enumerate(graph.tasks):
        if t.mb not in first_of:
            first_of[t.mb] = idx
        last_per_lane.setdefault(t.mb, {})[t.resource] = idx
    for i in range(1, graph.r1):
        if i in first_of and (i - 1) in last_per_lane:
            extra[first_of[i]] = tuple(sorted(
                last_per_lane[i - 1].values()))
    return extra


def stream_major_order(graph: TaskGraph) -> Tuple[int, ...]:
    """Task indices reordered stream-major (all of micro-batch 0 in
    emission order, then micro-batch 1, ...) — the per-lane service
    order of the sequential realization. Paired with
    ``stream_serial_deps`` this is deadlock-free: every stream's tasks
    precede the next stream's in every lane's queue."""
    idx = sorted(range(len(graph.tasks)),
                 key=lambda i: (graph.tasks[i].mb, i))
    return tuple(idx)


def _fifo_ends(free0: float, ready, d: float):
    """End times of a FIFO lane serving equal-duration tasks: the
    recurrence e_k = max(e_{k-1}, r_k) + d unrolls to a running max of
    r_k - k*d (subtracting the k services already queued turns the
    serial dependency into a prefix maximum), which numpy scans in one
    ``maximum.accumulate`` instead of a Python loop."""
    import numpy as np
    r = np.asarray(ready, np.float64)
    k = np.arange(r.shape[0], dtype=np.float64)
    g = r - k * d
    if g.shape[0]:
        g[0] = max(g[0], free0)
    g = np.maximum.accumulate(g)
    return g + (k + 1.0) * d


def schedule_makespan(graph: TaskGraph, costs: TaskCosts) -> float:
    """Makespan of ``schedule(graph, costs)`` without materializing the
    per-task schedule.

    The generic list scheduler is exact but pays a ~3x Python-loop
    constant over the legacy hand-written recurrences (PR 5 perf note),
    and the solver's simulate objective only consumes the makespan.
    Because every lane serves equal-duration tasks FIFO, each lane's
    completion times follow e_k = max(e_{k-1}, r_k) + d — a recurrence
    ``_fifo_ends`` evaluates as a vectorized prefix max. The only Python
    loop left is over layers. Agrees with ``schedule().makespan`` to
    float rounding (locked by test at 1e-9 relative).
    """
    import numpy as np
    durs = costs.per_kind(graph)
    attn_d, seg_d, gate_d = durs[_ATTN_I], durs[_SHARED_I], durs[_GATE_I]
    a2e_d, exp_d, e2a_d = durs[_A2E_I], durs[_EXP_I], durs[_E2A_I]
    rep_d = durs[_REP_I] if graph.hot_experts > 0 else 0.0
    has_rep = graph.hot_experts > 0
    r1, r2 = graph.r1, graph.r2
    n_seg = graph.shared_segments if graph.has_shared else 0
    asas = graph.order == ORDER_ASAS

    free_ag = free_a2e = free_eg = free_e2a = 0.0
    prev_e2a = np.zeros(r1)
    prev_sha = np.zeros(r1)
    ii = np.arange(r1, dtype=np.float64)
    for _ in range(graph.T):
        ready = np.maximum(prev_e2a, prev_sha)
        if asas:
            # per-mb AG block: ATTN, GATE, [REP], then n_seg shared segs
            block_d = attn_d + gate_d + rep_d + n_seg * seg_d
            block_end = _fifo_ends(free_ag, ready, block_d)
            attn_end = block_end - block_d + attn_d
            gate_end = attn_end + gate_d
            rep_end = gate_end + rep_d
            sha_end = rep_end + n_seg * seg_d
            free_ag = float(block_end[-1])
        else:
            # AASS: all (ATTN, GATE, [REP]) blocks, then all shared tasks
            block_d = attn_d + gate_d + rep_d
            block_end = _fifo_ends(free_ag, ready, block_d)
            attn_end = block_end - block_d + attn_d
            gate_end = attn_end + gate_d
            rep_end = block_end
            free_ag = float(block_end[-1])
            if n_seg:
                # shared(i) deps only attn(i), which ends before the last
                # gate — the lane never waits, so the ends are a cumsum
                sha_end = free_ag + (ii + 1.0) * seg_d
                free_ag = float(sha_end[-1])
            else:
                sha_end = attn_end
        gd = gate_end
        if graph.shared_blocks_a2e and graph.has_shared:
            gd = np.maximum(gd, sha_end)
        a2e_end = _fifo_ends(free_a2e, np.repeat(gd, r2), a2e_d)
        exp_end = _fifo_ends(free_eg, a2e_end, exp_d)
        e2a_end = _fifo_ends(free_e2a, exp_end, e2a_d)
        free_a2e = float(a2e_end[-1])
        free_eg = float(exp_end[-1])
        free_e2a = float(e2a_end[-1])
        prev_e2a = e2a_end.reshape(r1, r2)[:, -1]
        if graph.has_shared:
            prev_sha = sha_end
        else:
            prev_sha = rep_end if has_rep else attn_end
    return max(free_ag, free_a2e, free_eg, free_e2a)


# ---------------------------------------------------------------------------
# ASCII Gantt rendering (benchmarks/plan_trace.py)
# ---------------------------------------------------------------------------

_GANTT_GLYPH = {ATTN: "A", SHARED: "S", GATE: "g", A2E: ">", EXP: "E",
                E2A: "<", REP: "R"}


def ascii_gantt(res: ScheduleResult, width: int = 80) -> str:
    """Render a scheduled graph as one text row per resource lane; each
    column is makespan/width seconds, marked with the glyph of the task
    occupying it ('.' = idle, '*' = multiple kinds in one column)."""
    if res.makespan <= 0.0:
        return "\n".join(f"{r:>4} |" for r in RESOURCES)
    scale = width / res.makespan
    rows = []
    for r in RESOURCES:
        cells = ["."] * width
        for t, s, e in zip(res.graph.tasks, res.starts, res.ends):
            if t.resource != r or e <= s:
                continue
            lo = min(int(s * scale), width - 1)
            hi = min(max(int(e * scale), lo + 1), width)
            g = _GANTT_GLYPH[t.kind]
            for c in range(lo, hi):
                cells[c] = g if cells[c] in (".", g) else "*"
        rows.append(f"{r:>4} |{''.join(cells)}|")
    rows.append(f"     0{'-' * (width - 10)}{res.makespan * 1e3:8.3f}ms")
    return "\n".join(rows)
