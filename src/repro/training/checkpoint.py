"""Checkpointing: pytree -> npz + structure JSON (no external deps)."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _to_numpy(x):
    """bfloat16 has no numpy dtype npz accepts: store as uint16 view."""
    x = np.asarray(x)
    if x.dtype.name == "bfloat16":
        return x.view(np.uint16), "bfloat16"
    return x, x.dtype.name


def save_checkpoint(path: str, tree: Any, step: int = 0):
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        arr, dt = _to_numpy(x)
        arrays[f"leaf_{i}"] = arr
        dtypes.append(dt)
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves),
                   "dtypes": dtypes, "treedef": str(treedef)}, f)


def load_checkpoint(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    assert meta["n_leaves"] == len(leaves), "checkpoint/model mismatch"
    import ml_dtypes  # ships with jax
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if meta.get("dtypes") and meta["dtypes"][i] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        assert arr.shape == tuple(ref.shape), (i, arr.shape, ref.shape)
        new_leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return treedef.unflatten(new_leaves), meta["step"]
