from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import (AdamWConfig, OptState, apply_updates,
                                      init_opt_state)
from repro.training.train_loop import TrainResult, train

__all__ = ["load_checkpoint", "save_checkpoint", "AdamWConfig", "OptState",
           "apply_updates", "init_opt_state", "TrainResult", "train"]
